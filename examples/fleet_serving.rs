//! Sharded fleet serving demo (DESIGN.md §Fleet): horizontal scale-out
//! over the serving engine.
//!
//! Two scenarios from the zoo:
//!
//! * `fleet-balanced` — eight near-equal GCN lanes on a 12F+8G pool.
//!   A four-shard fleet carves the pool into even 3F+2G slices, the
//!   router spreads two lanes per shard, every shard serves on its own
//!   OS thread with its own schedule cache (registry-prewarmed from the
//!   lanes' expected regimes, so first admissions hit), and no
//!   migration triggers.
//! * `fleet-skewed` — an overloaded 250 ms-deadline lane co-locating
//!   with bulk on one slice of a two-shard paper-testbed fleet. The hot
//!   shard's shed rate clears the hysteresis bound while the other
//!   shard coasts, so the fleet drains the worst-shedding stream and
//!   re-admits it on the cold shard, prewarming the destination cache
//!   with the stream's carried-over plans.
//!
//! `--trace <path>` writes the balanced run's shard-namespaced Perfetto
//! `trace_events` JSON (shard N's streams/leases/budget tracks become
//! `shardN:`-prefixed processes; load it at `ui.perfetto.dev`).
//!
//! Run: `cargo run --release --example fleet_serving -- [--trace trace.json]`

use dype::devices::GroundTruth;
use dype::engine::EngineConfig;
use dype::fleet::{FleetConfig, ServingFleet};
use dype::perfmodel::OracleModels;
use dype::scenario::catalog;
use dype::telemetry::export;

fn main() -> anyhow::Result<()> {
    let mut trace_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--trace" => trace_path = Some(args.next().expect("--trace needs a path")),
            other => panic!("unknown argument '{other}'"),
        }
    }

    // --- Balanced: eight near-equal lanes over a four-shard fleet.
    let built = catalog::fleet_balanced().build()?;
    let sys = built.system.clone();
    println!(
        "fleet-balanced: {} lanes on {}F + {}G, 4 shards, registry prewarm on\n",
        built.streams.len(),
        sys.n_fpga,
        sys.n_gpu
    );
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let cfg = FleetConfig {
        shards: 4,
        engine: built.apply(EngineConfig::default()),
        telemetry: trace_path.is_some(),
        registry_prewarm: true,
        ..FleetConfig::default()
    };
    let mut fleet = ServingFleet::new(sys, &est, cfg);
    let report = fleet.serve(&built.streams);
    print!("{}", report.render());
    assert!(report.conserved(), "every request completes or sheds exactly once");
    assert!(report.migrations.is_empty(), "a balanced fleet never migrates");
    for s in &report.shards {
        assert_eq!(s.streams.len(), 2, "the router spreads eight equal lanes two per shard");
    }

    if let Some(p) = &trace_path {
        let doc = export::perfetto_fleet(&report.timelines());
        export::validate(&doc).expect("the exporter emits strictly valid traces");
        std::fs::write(p, format!("{doc}\n"))?;
        println!("trace: shard-namespaced Perfetto export -> {p}");
    }

    // --- Skewed: an overloaded deadline lane forces a migration.
    let built = catalog::fleet_skewed().build()?;
    let sys = built.system.clone();
    println!(
        "\nfleet-skewed: {} lanes on {}F + {}G, 2 shards\n",
        built.streams.len(),
        sys.n_fpga,
        sys.n_gpu
    );
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let cfg = FleetConfig {
        shards: 2,
        engine: built.apply(EngineConfig::default()),
        ..FleetConfig::default()
    };
    let mut fleet = ServingFleet::new(sys, &est, cfg);
    let report = fleet.serve(&built.streams);
    print!("{}", report.render());
    assert!(report.conserved(), "conservation holds across migrations");
    assert!(!report.migrations.is_empty(), "the hot shard sheds past hysteresis and migrates");

    println!("\nOK — balanced fleet spread evenly; skewed fleet migrated off the hot shard.");
    Ok(())
}
