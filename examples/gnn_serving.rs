//! End-to-end driver (DESIGN.md "End-to-end validation"): serve streamed
//! GCN inferences over a real synthetic graph through a DYPE-scheduled
//! multi-stage pipeline whose stages execute the AOT-compiled HLO
//! artifacts (Pallas SpMM / GEMM kernels lowered through JAX) via PJRT.
//!
//! Proves all three layers compose:
//!   L1 Pallas kernels  →  L2 JAX GCN layer  →  HLO text artifacts
//!   →  L3 Rust coordinator schedules + streams real batched requests.
//!
//! Numerics are verified two ways:
//!   * pipeline-of-kernels output == monolithic `gcn_layer` artifact
//!     applied twice (same weights), and
//!   * a pure-Rust dense reference computation of Â·relu(Â·X·Θ₁)·Θ₂.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example gnn_serving -- [n_inferences]

use std::time::Instant;

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::coordinator::Coordinator;
use dype::devices::GroundTruth;
use dype::perfmodel::OracleModels;
use dype::pipeline::{run_pipeline, ArgSource, KernelBinding, StageSpec};
use dype::runtime::{default_artifact_dir, HostTensor, Runtime};
use dype::scheduler::StagePlan;
use dype::util::Rng;
use dype::workload::{gnn, BlockEllGraph};

fn main() -> anyhow::Result<()> {
    let n_inf: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(12);
    let dir = default_artifact_dir();

    // ---- L3: schedule the workload from its data characteristics -------
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let mut coord = Coordinator::new(sys.clone(), &est, Objective::Performance);
    let wl = gnn::e2e_gcn_workload();
    let auto = coord.process_batch(&wl).clone();
    println!("DYPE schedule for {}: {}", wl.name, auto.mnemonic());

    // For the demo we force a 2-stage pipeline (layer 1 | layer 2) when the
    // auto-schedule collapses to one stage, so the streamed execution
    // exercises true pipeline parallelism across stage threads.
    let plan: Vec<StagePlan> = if auto.stages.len() >= 2 {
        auto.plan()
    } else {
        println!(
            "(auto schedule is single-stage on this tiny graph; forcing 2 stages for the demo)"
        );
        let mut p = auto.plan();
        let s = p[0];
        p.clear();
        p.push(StagePlan { first: 0, last: 1, dev: s.dev, n: 1 });
        p.push(StagePlan { first: 2, last: 3, dev: s.dev, n: 1 });
        p
    };

    // ---- Static data (§II-B pre-loading) --------------------------------
    let g = BlockEllGraph::generate(8, 4, 128, 128, 42);
    let mut rng = Rng::seed_from_u64(7);
    let theta1: Vec<f32> = (0..128 * 128).map(|_| rng.gen_range_f32(-0.05, 0.05)).collect();
    let theta2: Vec<f32> = (0..128 * 128).map(|_| rng.gen_range_f32(-0.05, 0.05)).collect();
    let blocks_t = HostTensor::f32(g.blocks.clone(), &[8, 4, 128, 128]);
    let indices_t = HostTensor::i32(g.indices.clone(), &[8, 4]);

    let bind = |layer: usize| -> Vec<KernelBinding> {
        let theta = if layer == 0 { theta1.clone() } else { theta2.clone() };
        vec![
            KernelBinding {
                artifact: "spmm".into(),
                args: vec![
                    ArgSource::Static(blocks_t.clone()),
                    ArgSource::Static(indices_t.clone()),
                    ArgSource::Dynamic,
                ],
            },
            KernelBinding {
                artifact: "gemm".into(),
                args: vec![
                    ArgSource::Dynamic,
                    ArgSource::Static(HostTensor::f32(theta, &[128, 128])),
                ],
            },
        ]
    };
    // Kernel bindings indexed by workload kernel id (SpMM1,GeMM1,SpMM2,GeMM2).
    let per_kernel: Vec<KernelBinding> = bind(0).into_iter().chain(bind(1)).collect();

    let stages: Vec<StageSpec> = plan
        .iter()
        .enumerate()
        .map(|(i, s)| StageSpec {
            name: format!("stage{i}-{}{}", s.n, s.dev.letter()),
            kernels: per_kernel[s.first..=s.last].to_vec(),
        })
        .collect();

    // ---- Batched requests ------------------------------------------------
    let inputs: Vec<HostTensor> = (0..n_inf)
        .map(|i| {
            let mut r = Rng::seed_from_u64(100 + i as u64);
            let x: Vec<f32> = (0..1024 * 128).map(|_| r.gen_range_f32(-1.0, 1.0)).collect();
            HostTensor::f32(x, &[1024, 128])
        })
        .collect();

    println!("streaming {n_inf} inferences through {} pipeline stages...", stages.len());
    let t0 = Instant::now();
    let report = run_pipeline(dir.clone(), stages, inputs.clone())?;
    println!(
        "real execution: {:.2}s wall, {:.2} inf/s on this host (compile+warmup {:.2}s excluded)",
        report.wall_time,
        report.throughput,
        t0.elapsed().as_secs_f64() - report.wall_time
    );
    for (i, b) in report.stage_busy.iter().enumerate() {
        println!("  stage {i}: busy {b:.2}s ({:.0}% of wall)", 100.0 * b / report.wall_time);
    }

    // ---- Verification 1: monolithic gcn_layer artifact ------------------
    // relu is inside gcn_layer; our per-kernel pipeline applies relu only
    // via the gemm artifact... the gcn_layer artifact = relu(spmm·gemm).
    // The kernel chain (spmm → gemm) omits relu, so compare against
    // spmm+gemm composition executed monolithically per layer instead.
    let mut rt = Runtime::new(&dir)?;
    let mut worst = 0f32;
    for (i, x) in inputs.iter().enumerate().take(3) {
        let y1 = rt.execute("spmm", &[blocks_t.clone(), indices_t.clone(), x.clone()])?;
        let h1 = rt.execute("gemm", &[y1, HostTensor::f32(theta1.clone(), &[128, 128])])?;
        let y2 = rt.execute("spmm", &[blocks_t.clone(), indices_t.clone(), h1])?;
        let expect = rt.execute("gemm", &[y2, HostTensor::f32(theta2.clone(), &[128, 128])])?;
        let got = report.outputs[i].as_f32()?;
        let want = expect.as_f32()?;
        for (a, b) in got.iter().zip(want) {
            worst = worst.max((a - b).abs());
        }
    }
    println!("pipeline vs monolithic re-execution: max |Δ| = {worst:.2e}");
    assert!(worst < 1e-3, "numerics mismatch");

    // ---- Verification 2: pure-Rust dense reference ----------------------
    let dense = g.to_dense(); // 1024×1024
    let x0 = inputs[0].as_f32()?;
    let mut ref_out = gcn_two_layer_ref(&dense, x0, &theta1, &theta2, 1024, 128);
    let got = report.outputs[0].as_f32()?;
    let mut max_rel = 0f32;
    for (a, b) in got.iter().zip(ref_out.iter_mut()) {
        let denom = b.abs().max(1e-3);
        max_rel = max_rel.max((a - *b).abs() / denom);
    }
    println!("pipeline vs pure-Rust dense reference: max rel err = {max_rel:.2e}");
    assert!(max_rel < 1e-2, "reference mismatch");

    println!("OK — all three layers compose and agree.");
    Ok(())
}

/// Dense reference: Â·(Â·X·Θ₁)·Θ₂ (no activations — matches the kernel
/// chain, which composes raw spmm/gemm artifacts).
fn gcn_two_layer_ref(
    adj: &[f32],
    x: &[f32],
    theta1: &[f32],
    theta2: &[f32],
    v: usize,
    f: usize,
) -> Vec<f32> {
    let spmm = |a: &[f32], b: &[f32]| -> Vec<f32> {
        let mut out = vec![0f32; v * f];
        for i in 0..v {
            for k in 0..v {
                let av = a[i * v + k];
                if av != 0.0 {
                    for j in 0..f {
                        out[i * f + j] += av * b[k * f + j];
                    }
                }
            }
        }
        out
    };
    let gemm = |a: &[f32], b: &[f32]| -> Vec<f32> {
        let mut out = vec![0f32; v * f];
        for i in 0..v {
            for k in 0..f {
                let av = a[i * f + k];
                for j in 0..f {
                    out[i * f + j] += av * b[k * f + j];
                }
            }
        }
        out
    };
    let h = gemm(&spmm(adj, x), theta1);
    gemm(&spmm(adj, &h), theta2)
}
