//! Fig-2 / §II scenario: a GNN traffic-forecasting service whose input
//! graph sparsity drifts over the day. The DYPE coordinator observes each
//! batch's characteristics, reschedules when the current mapping has
//! become sufficiently suboptimal, and the demo quantifies the gain over
//! remaining on the initial static schedule.
//!
//! Run: `cargo run --release --example traffic_forecast`

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::coordinator::Coordinator;
use dype::devices::GroundTruth;
use dype::perfmodel::{calibrate, OracleModels};
use dype::scheduler::{evaluate_plan, PowerTable};
use dype::workload::{gnn, Dataset};

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let models = calibrate::calibrated_registry(&sys);
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let comm = sys.comm_model();

    // A day of traffic: edge density swells at rush hour (more vehicle
    // interactions → denser interaction graph) and thins overnight.
    // Feature length fixed; vertices fixed (the road network: 1M
    // intersections, 200-dim sensor embeddings).
    let phases: Vec<(&str, u64)> = vec![
        ("03:00 night", 2_000_000),
        ("07:00 ramp-up", 20_000_000),
        ("09:00 rush hour", 150_000_000),
        ("12:00 midday", 50_000_000),
        ("18:00 rush hour", 150_000_000),
        ("23:00 evening", 8_000_000),
    ];

    let mut coord = Coordinator::new(sys.clone(), &models, Objective::Performance);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };

    let mut first_plan = None;
    let mut dynamic_total = 0.0; // seconds to serve a fixed batch per phase
    let mut static_total = 0.0;
    const BATCH: f64 = 1000.0; // inferences per phase

    println!(
        "{:<16} {:>12} {:>10} {:>12} {:>12}",
        "time", "edges", "schedule", "DYPE inf/s", "static inf/s"
    );
    for (label, edges) in &phases {
        let ds = Dataset::new("TF", "traffic", 1_000_000, *edges, 200, 0.2);
        let wl = gnn::gcn_workload(&ds, 2, 128);
        let sched = coord.process_batch(&wl).clone();
        if first_plan.is_none() {
            first_plan = Some(sched.plan());
        }
        // Ground-truth measurement of both policies on this phase's data.
        let dyn_meas = evaluate_plan(&wl, &sched.plan(), &oracle, &comm, &power);
        let stat_meas = evaluate_plan(&wl, first_plan.as_ref().unwrap(), &oracle, &comm, &power);
        dynamic_total += BATCH / dyn_meas.throughput();
        static_total += BATCH / stat_meas.throughput();
        println!(
            "{:<16} {:>12} {:>10} {:>12.1} {:>12.1}",
            label,
            edges,
            sched.mnemonic(),
            dyn_meas.throughput(),
            stat_meas.throughput()
        );
    }

    println!("\nreschedule events:");
    for e in coord.reschedule_events() {
        println!(
            "  batch {}: {} -> {} (estimated gain {:.0}%)",
            e.batch,
            e.old_mnemonic,
            e.new_mnemonic,
            e.estimated_gain * 100.0
        );
    }
    println!(
        "\nserving {} inferences/phase: dynamic {:.1}s vs static {:.1}s  ({:.2}x speedup)",
        BATCH as u64,
        dynamic_total,
        static_total,
        static_total / dynamic_total
    );
    assert!(static_total >= dynamic_total * 0.999, "dynamic must not lose");
}
