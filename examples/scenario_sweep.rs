//! Scenario-zoo sweep demo (DESIGN.md §Scenarios): the repo's answer to
//! the paper's 86-case study, in serving form.
//!
//! Every manifest in the checked-in zoo (`scenarios/*.json`, built here
//! from `scenario::catalog` — the same trees, tree-compared in CI) is
//! crossed with every serving policy: frozen static leases, the
//! adaptive-drain default, adaptive with mid-slot preemption, and the
//! deadline-tuned preemptive config. Each cell is one full engine run;
//! the report ranks cells by SLO-discounted useful throughput, stars the
//! Pareto-non-dominated cells per scenario, marks the winner, and closes
//! with the adaptive-vs-static scoreboard — the "77 of 86" headline,
//! re-derived on live code.
//!
//! Run: `cargo run --release --example scenario_sweep -- [--quick]`
//! (`--quick` sweeps the three smallest scenarios only).

use dype::analysis::lint_manifest;
use dype::scenario::catalog;
use dype::scenario::sweep::{run_grid_parallel, run_zoo_parallel, Policy};
use dype::util::pool::default_threads;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");

    // Static pre-pass (`dype lint` does the same before every sweep):
    // prove the zoo feasible without running a single simulated event.
    let lints: Vec<_> = catalog::all().iter().map(lint_manifest).collect();
    let warnings: usize = lints.iter().map(|r| r.warnings()).sum();
    anyhow::ensure!(lints.iter().all(|r| r.is_clean()), "the zoo must lint error-clean");
    println!("lint: {} manifests feasible ({warnings} advisory warning(s))\n", lints.len());

    // The parallel grid fans cells out across a worker pool and is
    // byte-identical to the serial sweep (pinned by a tier-1 test).
    let report = if quick {
        let subset = vec![catalog::skewed_pair(2, 11), catalog::mmpp_burst(), catalog::diurnal()];
        run_grid_parallel(&subset, &Policy::ALL, default_threads())?
    } else {
        run_zoo_parallel()?
    };

    let n_scenarios = report.scenarios().len();
    println!(
        "scenario zoo sweep: {} scenarios x {} policies = {} cells\n",
        n_scenarios,
        Policy::ALL.len(),
        report.cells.len()
    );
    print!("{}", report.render());

    println!("\nper-scenario winners:");
    for sc in report.scenarios() {
        if let Some(w) = report.winner(sc) {
            println!(
                "  {:<20} {:<16} score {:.2} (shed {:.1}%)",
                sc,
                w.policy.name(),
                w.score(),
                w.shed_rate() * 100.0
            );
        }
    }
    Ok(())
}
