//! Quickstart: schedule a GNN workload on the paper's testbed and compare
//! DYPE's three objective modes.
//!
//! Run: `cargo run --release --example quickstart`

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::devices::GroundTruth;
use dype::perfmodel::calibrate;
use dype::pipeline::PipelineSim;
use dype::scheduler::{DpScheduler, PowerTable};
use dype::workload::{gnn, Dataset};

fn main() {
    // 1. Describe the system: 3 Alveo U280 FPGAs + 2 Instinct MI210 GPUs
    //    over PCIe 4.0 (the paper's §III-A prototype).
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);

    // 2. Calibrate the §V kernel performance models (two-step process:
    //    synthetic profiles -> benchmark -> linear regression).
    let models = calibrate::calibrated_registry(&sys);
    println!("calibrated {} kernel performance models:", models.len());
    for (tag, dev, rmse, r2) in models.fit_report() {
        println!("  {tag:8} on {dev:4}: rmse={rmse:.2e}s R²={r2:.4}");
    }

    // 3. Describe the workload from its *data characteristics* — a 2-layer
    //    GCN over ogbn-arxiv (Table I).
    let ds = Dataset::ogbn_arxiv();
    let wl = gnn::gcn_workload(&ds, 2, 128);
    println!(
        "\nworkload {}: {} kernels, {:.2} GFLOP/inference",
        wl.name,
        wl.len(),
        wl.total_flops() * 1e-9
    );

    // 4. Run Algorithm 1 under each design objective.
    let sched_builder = DpScheduler::new(&sys, &models);
    println!("\n{:<12} {:>10} {:>12} {:>10}", "mode", "schedule", "thp(inf/s)", "J/inf");
    for obj in Objective::paper_modes() {
        let s = sched_builder.schedule(&wl, obj);
        println!(
            "{:<12} {:>10} {:>12.1} {:>10.3}",
            obj.name(),
            s.mnemonic(),
            s.throughput(),
            s.energy_per_inf
        );
    }

    // 5. Measure the perf-opt schedule on the simulated testbed by
    //    streaming 500 inferences through the pipeline.
    let sched = sched_builder.schedule(&wl, Objective::Performance);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model())
        .with_degree_skew(ds.degree_skew);
    let oracle = dype::perfmodel::OracleModels { gt: &gt };
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let comm = sys.comm_model();
    let retimed = dype::scheduler::evaluate_plan(&wl, &sched.plan(), &oracle, &comm, &power);
    let report = PipelineSim::new(&power, &comm).run(&wl, &retimed, 500);
    println!(
        "\nmeasured on the simulated testbed: {:.1} inf/s, {:.3} J/inf ({} inferences, makespan {:.2}s)",
        report.throughput, report.energy_per_inf, report.inferences, report.makespan
    );
    for (i, u) in report.stage_utilization.iter().enumerate() {
        println!("  stage {i} utilization {:.0}%", u * 100.0);
    }
}
