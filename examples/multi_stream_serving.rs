//! Multi-stream serving demo (DESIGN.md §Serving): two concurrent request
//! streams — a traffic-forecast GCN with a day-cycle sparsity drift and a
//! sliding-window transformer cycling through sequence-length regimes —
//! share the paper's 3F+2G testbed.
//!
//! The device pool is split demand-proportionally across the streams,
//! each stream's coordinator reschedules on drift behind its hysteresis
//! threshold, and all coordinators memoize into one schedule cache, so a
//! reschedule on previously-seen drift is a cache hit (re-timed plan)
//! instead of a full Algorithm-1 run.
//!
//! Run: `cargo run --release --example multi_stream_serving -- [cycles]`

use dype::config::{Interconnect, SystemSpec};
use dype::experiments::{multi_stream_scenario, run_multi_stream};
use dype::metrics::{fmt_percent, Table};

fn main() {
    let cycles: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(3);
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    println!(
        "system: {}F + {}G over {} — serving 2 concurrent streams, {cycles} drift cycles each\n",
        sys.n_fpga, sys.n_gpu, sys.interconnect
    );

    let streams = multi_stream_scenario(cycles, 6, 42);
    for s in &streams {
        println!(
            "stream {:<18} {:>4} requests, offered {:>6.1} req/s, demand {:>8.1} GFLOP/s",
            s.name,
            s.trace.len(),
            s.offered_rate(),
            s.demand() * 1e-9
        );
    }

    let report = run_multi_stream(&sys, &streams);

    println!();
    let mut t = Table::new(&[
        "stream",
        "devices",
        "done",
        "thp(req/s)",
        "p50(ms)",
        "p90(ms)",
        "p99(ms)",
        "resched",
        "cache",
    ]);
    for sr in &report.streams {
        let r = &sr.report;
        t.row(vec![
            sr.name.clone(),
            sr.partition.clone(),
            format!("{}", r.completed),
            format!("{:.1}", r.throughput),
            format!("{:.2}", r.p50_latency * 1e3),
            format!("{:.2}", r.p90_latency * 1e3),
            format!("{:.2}", r.p99_latency * 1e3),
            format!("{}", r.reschedules),
            fmt_percent(r.cache.hit_rate()),
        ]);
    }
    print!("{}", t.render());

    println!(
        "\ncombined: {} inferences in {:.2}s ({:.1} inf/s aggregate), fairness {:.3}",
        report.total_completed, report.makespan, report.aggregate_throughput, report.fairness
    );
    println!("schedule cache: {}", report.cache);

    // The acceptance bar: recurring drift across ≥2 concurrent streams
    // must be absorbed by the cache, not re-solved by the DP.
    assert!(
        report.cache.hit_rate() > 0.5,
        "expected >50% schedule-cache hits, got {}",
        fmt_percent(report.cache.hit_rate())
    );
    assert_eq!(
        report.total_completed,
        streams.iter().map(|s| s.trace.len()).sum::<usize>(),
        "no request may starve"
    );
    println!("OK — recurring drift served from the schedule cache.");
}
