//! Multi-stream serving demo (DESIGN.md §Serving): two concurrent request
//! streams — a traffic-forecast GCN with a day-cycle sparsity drift and a
//! sliding-window transformer cycling through sequence-length regimes —
//! share the paper's 3F+2G testbed through the event-heap serving engine.
//!
//! Device leases are sized demand-proportionally, each stream's
//! coordinator reschedules on drift behind its hysteresis threshold, and
//! all coordinators memoize into one schedule cache, so a reschedule on
//! previously-seen drift is a cache hit (re-timed plan) instead of a full
//! Algorithm-1 run. Serving is **adaptive by default**: leases migrate
//! when observed demand drifts from the offered estimate, and every
//! migration prewarms the cache for the prospective partition so known
//! regimes stay hits; `--static` freezes the initial leases (the
//! historical default, the A/B baseline). With `--cache <path>` the
//! cache is loaded before the run and saved after it, so a *restarted*
//! server skips the cold-start DP storm entirely; `--energy-slo`
//! swaps in the three-class energy/SLO scenario (DESIGN.md §Energy &
//! SLOs) under a joule budget at 30% of the unbudgeted run's average
//! draw, showing budget exhaustion defer below-priority streams while
//! the p99 feedback controller re-weights the leases; `--deadlines`
//! swaps in the mixed deadline/best-effort scenario under the
//! preemptive policy, showing infeasible requests shed at admission,
//! per-stream deadline attainment, and criticality-tied migration
//! modes (the critical lane preempts while the bulk lane drains).
//! `--trace <path>` attaches a timeline recorder to the run and writes
//! the Perfetto `trace_events` JSON (load it at `ui.perfetto.dev`, or
//! check it with `dype trace-validate <path>`).
//!
//! Run: `cargo run --release --example multi_stream_serving -- \
//!       [cycles] [--cache schedules.json] [--static] [--energy-slo] \
//!       [--deadlines] [--trace trace.json]`

use std::sync::{Arc, Mutex};

use dype::config::{Interconnect, SystemSpec};
use dype::coordinator::MultiStreamServer;
use dype::devices::GroundTruth;
use dype::engine::EngineConfig;
use dype::experiments::{
    deadline_config, deadline_scenario, energy_slo_config, energy_slo_scenario,
    multi_stream_scenario, run_multi_stream,
};
use dype::metrics::{fmt_percent, Table};
use dype::perfmodel::OracleModels;
use dype::scheduler::ScheduleCache;
use dype::telemetry::{export, Recorder};

fn main() {
    let mut cycles = 3usize;
    let mut cache_path: Option<String> = None;
    let mut statik = false;
    let mut energy_slo = false;
    let mut deadlines = false;
    let mut trace_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--cache" => cache_path = Some(args.next().expect("--cache needs a path")),
            "--trace" => trace_path = Some(args.next().expect("--trace needs a path")),
            "--static" => statik = true,
            "--energy-slo" => energy_slo = true,
            "--deadlines" => deadlines = true,
            other => cycles = other.parse().expect("cycles must be a number"),
        }
    }

    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    if energy_slo {
        println!(
            "system: {}F + {}G over {} — three QoS classes under an energy budget\n",
            sys.n_fpga, sys.n_gpu, sys.interconnect
        );
    } else if deadlines {
        println!(
            "system: {}F + {}G over {} — mixed deadline/best-effort classes, \
             preemptive re-partitioning\n",
            sys.n_fpga, sys.n_gpu, sys.interconnect
        );
    } else {
        println!(
            "system: {}F + {}G over {} — serving 2 concurrent streams, {cycles} drift cycles each\n",
            sys.n_fpga, sys.n_gpu, sys.interconnect
        );
    }

    // Warm start: a persisted cache turns the whole cold-start DP storm
    // into hits (one file read; every known regime re-times its plan).
    let cache = match &cache_path {
        Some(p) if std::path::Path::new(p).exists() => {
            let loaded = ScheduleCache::load_from(p, 64).expect("readable cache file");
            println!("warm start: loaded {} cached schedules from {p}", loaded.len());
            Arc::new(Mutex::new(loaded))
        }
        Some(p) => {
            println!("cold start: no cache file at {p} yet (will be written after the run)");
            ScheduleCache::shared(64)
        }
        None => ScheduleCache::shared(64),
    };

    let streams = if energy_slo {
        energy_slo_scenario(6, 42)
    } else if deadlines {
        deadline_scenario(8, 42)
    } else {
        multi_stream_scenario(cycles, 6, 42)
    };
    for s in &streams {
        println!(
            "stream {:<22} {:>4} requests, offered {:>6.1} req/s, demand {:>8.1} GFLOP/s, \
             priority {:.0}{}{}",
            s.name,
            s.trace.len(),
            s.offered_rate(),
            s.demand() * 1e-9,
            s.slo.priority,
            match s.slo.p99_target {
                Some(t) => format!(", p99 target {:.0}ms", t * 1e3),
                None => String::new(),
            },
            match s.slo.deadline {
                Some(d) => format!(", deadline {:.0}ms", d * 1e3),
                None => String::new(),
            }
        );
    }

    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let cfg = if energy_slo {
        // Self-calibrating cap: 30% of the average draw an unbudgeted run
        // of the same scenario sustains, so exhaustion is guaranteed.
        let probe = run_multi_stream(&sys, &streams);
        let avg_watts = probe.total_energy / probe.makespan;
        println!(
            "\nunbudgeted probe: {:.1} J over {:.2}s ({:.0} W avg) — capping at {:.0} W",
            probe.total_energy,
            probe.makespan,
            avg_watts,
            0.3 * avg_watts
        );
        energy_slo_config(0.3 * avg_watts)
    } else if deadlines {
        deadline_config() // preemptive policy, per-stream overrides apply
    } else if statik {
        EngineConfig::builder().static_leases().build()
    } else {
        EngineConfig::default() // adaptive with prewarming
    };
    let recorder = trace_path.as_ref().map(|_| Recorder::timeline());
    let mut cfg = cfg;
    if let Some(rec) = &recorder {
        cfg.recorder = Some(rec.clone());
    }
    let mut server =
        MultiStreamServer::with_cache(sys, &est, cache.clone()).with_engine_config(cfg);
    let report = server.serve(&streams);

    println!();
    let mut t = Table::new(&[
        "stream",
        "lease",
        "done",
        "shed",
        "thp(req/s)",
        "p50(ms)",
        "p99(ms)",
        "energy(J)",
        "slo",
        "ddl",
        "defer",
        "resched",
        "cache",
        "util",
    ]);
    for (i, sr) in report.streams.iter().enumerate() {
        let r = &sr.report;
        t.row(vec![
            sr.name.clone(),
            sr.partition.clone(),
            format!("{}", r.completed),
            format!("{}", r.shed),
            format!("{:.1}", r.throughput),
            format!("{:.2}", r.p50_latency * 1e3),
            format!("{:.2}", r.p99_latency * 1e3),
            format!("{:.1}", r.energy),
            fmt_percent(r.slo_attainment),
            fmt_percent(r.deadline_attainment),
            format!("{}", r.deferrals),
            format!("{}", r.reschedules),
            fmt_percent(r.cache.hit_rate()),
            fmt_percent(report.engine.utilization[i]),
        ]);
    }
    print!("{}", t.render());

    println!(
        "\ncombined: {} inferences in {:.2}s ({:.1} inf/s aggregate), fairness {:.3}",
        report.total_completed, report.makespan, report.aggregate_throughput, report.fairness
    );
    println!(
        "energy: {:.1} J modeled ({:.3} inf/J); budget: {} windows, {:.1} J charged",
        report.total_energy,
        report.throughput_per_joule,
        report.engine.budget_windows,
        report.engine.joules_charged()
    );
    println!("schedule cache: {}", report.cache);
    println!("engine: {}", report.engine);

    if let Some(p) = &cache_path {
        cache.lock().unwrap().save_to(p).expect("writable cache path");
        println!("saved {} cached schedules to {p}", cache.lock().unwrap().len());
    }

    if let (Some(p), Some(rec)) = (&trace_path, &recorder) {
        let names: Vec<String> = streams.iter().map(|s| s.name.clone()).collect();
        let records = rec.drain();
        let doc = export::perfetto(&records, &names);
        export::validate(&doc).expect("the exporter emits strictly valid traces");
        std::fs::write(p, format!("{doc}\n")).expect("writable trace path");
        println!("trace: {} records -> {p} (Perfetto trace_events JSON)", records.len());
    }

    // The acceptance bars. Default scenario: recurring drift across ≥2
    // concurrent streams must be absorbed by the cache, not re-solved by
    // the DP — and since the adaptive-by-default flip that bar holds for
    // migrating runs too, because every migration prewarms the
    // prospective partition's keys. Energy/SLO scenario: the 30% power
    // cap must defer below-priority work — and never the
    // highest-priority stream. Deadline scenario: the overloaded
    // deadline class must shed its infeasible requests at admission, and
    // the Drain-pinned bulk lane must never cancel a slot even under the
    // preemptive policy.
    if energy_slo {
        assert!(
            report.engine.deferrals >= 1,
            "a 30% power cap must exhaust some window and defer work"
        );
        assert_eq!(
            report.streams[0].report.deferrals,
            0,
            "the highest-priority stream is never deferred"
        );
    } else if deadlines {
        assert!(
            report.streams[0].report.shed >= 1,
            "the overloaded deadline class must shed infeasible requests"
        );
        assert_eq!(
            report.streams[3].report.slot_preemptions,
            0,
            "the Drain override must hold for the bulk lane"
        );
        for sr in &report.streams[1..] {
            assert_eq!(sr.report.shed, 0, "{}: best-effort lanes never shed", sr.name);
        }
    } else {
        assert!(
            report.cache.hit_rate() > 0.5,
            "expected >50% schedule-cache hits, got {}",
            fmt_percent(report.cache.hit_rate())
        );
    }
    assert_eq!(
        report.total_completed + report.engine.sheds,
        streams.iter().map(|s| s.trace.len()).sum::<usize>(),
        "every request completes or is shed — no request may starve"
    );
    if energy_slo {
        println!("OK — budget exhaustion deferred only below-priority streams.");
    } else if deadlines {
        println!(
            "OK — {} infeasible requests shed at admission; the bulk lane drained while \
             critical lanes preempted.",
            report.engine.sheds
        );
    } else {
        println!("OK — recurring drift served from the schedule cache.");
    }
}
