//! §IV-B case study: sliding-window transformer serving across the
//! (seq_len, window) grid. For each input configuration DYPE re-derives
//! the hybrid FPGA/GPU pipeline; the sweep prints the chosen schedules and
//! the gain over the GPU-only deployment (the Fig-8 experiment's axis).
//!
//! Run: `cargo run --release --example transformer_sweep`

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::devices::GroundTruth;
use dype::metrics::{fmt_ratio, Table};
use dype::perfmodel::{calibrate, OracleModels};
use dype::scheduler::{baselines, evaluate_plan, DpScheduler, PowerTable};
use dype::workload::transformer;

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let models = calibrate::calibrated_registry(&sys);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let comm = sys.comm_model();

    let mut t = Table::new(&[
        "seq_len", "window", "DYPE schedule", "DYPE inf/s", "GPU-only", "thp gain", "eng gain",
    ]);
    for (seq, win) in transformer::paper_sweep() {
        let wl = transformer::paper_transformer(seq, win);
        let dype = DpScheduler::new(&sys, &models).schedule(&wl, Objective::Performance);
        let gpu = baselines::gpu_only(&sys, &models, &wl, Objective::Performance);
        // Measure both under ground truth.
        let d = evaluate_plan(&wl, &dype.plan(), &oracle, &comm, &power);
        let g = evaluate_plan(&wl, &gpu.plan(), &oracle, &comm, &power);
        t.row(vec![
            seq.to_string(),
            win.to_string(),
            compress(&d.mnemonic()),
            format!("{:.2}", d.throughput()),
            format!("{:.2}", g.throughput()),
            fmt_ratio(d.throughput() / g.throughput()),
            fmt_ratio(g.energy_per_inf / d.energy_per_inf),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nReading: FPGA participation pays off increasingly at long sequences on this\n\
         substrate (quadratic dense attention on the GPU vs SWAT's linear band).\n\
         NOTE: the paper's Fig 8 reports the opposite trend (gains taper with seq as\n\
         communication overhead grows); see EXPERIMENTS.md for the divergence analysis."
    );
}

/// Long mnemonics (32-layer pipelines) print as e.g. `1F1G…(6 stages)`.
fn compress(m: &str) -> String {
    if m.len() <= 16 {
        m.to_string()
    } else {
        let stages = m.chars().filter(|c| c.is_ascii_alphabetic()).count();
        format!("{}…({} stages)", &m[..10], stages)
    }
}
