//! Table IV — "Throughput (thp) and energy efficiency (eng) improvement
//! of DYPE on GNN and transformers workloads".
//!
//! For every case in the GNN grid (2 models × 6 datasets × 3 interconnects)
//! and the transformer grid (17 (seq,w) points × 3 interconnects), measure
//! DYPE's three modes and all baselines on ground truth, then report the
//! averaged improvement ratios exactly as the paper's rows.
//!
//! Paper anchors (average row): DYPE-perf vs FleetRec* 1.53x thp / 1.09x
//! eng; vs GPU-only 1.44x thp / 1.66x eng; energy-opt trades throughput
//! (0.99x / 0.87x) for efficiency (1.29x / 1.86x).

use dype::experiments::{gnn_cases, reference_workload, run_case, transformer_cases, Registries};
use dype::metrics::{mean, Table};

struct Acc {
    thp: [Vec<f64>; 3],
    eng: [Vec<f64>; 3],
}

impl Acc {
    fn new() -> Acc {
        Acc { thp: Default::default(), eng: Default::default() }
    }

    fn push(&mut self, mode: usize, dype: (f64, f64), base: (f64, f64)) {
        self.thp[mode].push(dype.0 / base.0);
        self.eng[mode].push(base.1 / dype.1); // efficiency ratio = inverse energy ratio
    }

    fn row(&self, name: &str, t: &mut Table) {
        let mut cells = vec![name.to_string()];
        for m in 0..3 {
            cells.push(format!("{:.2}x", mean(&self.thp[m])));
            cells.push(format!("{:.2}x", mean(&self.eng[m])));
        }
        t.row(cells);
    }
}

fn main() {
    println!("=== Table IV: DYPE improvement over baselines ===");
    println!("(columns: perf-opt thp/eng, balanced thp/eng, energy-opt thp/eng)\n");
    let regs = Registries::train();

    let header = [
        "vs", "perf thp", "perf eng", "bal thp", "bal eng", "eopt thp", "eopt eng",
    ];

    let mut grand: std::collections::BTreeMap<&str, Acc> = Default::default();

    for (title, cases) in [
        ("GNN workloads", gnn_cases()),
        ("Transformer workloads", transformer_cases()),
    ] {
        let mut accs: std::collections::BTreeMap<&str, Acc> = Default::default();
        for case in &cases {
            let est = regs.get(case.sys.interconnect);
            let r = run_case(case, est, &reference_workload(&case.wl));
            let dype = [r.dype_perf, r.dype_balanced, r.dype_energy];
            // FleetRec* falls back to static where pinning is infeasible
            // (paper merges the rows for transformers).
            let fleet = r.fleetrec.unwrap_or(r.statik);
            for m in 0..3 {
                for (name, base) in [
                    ("FleetRec*", fleet),
                    ("static", r.statik),
                    ("theoretical-additive", r.theoretical_additive),
                    ("FPGA-only", r.fpga_only),
                    ("GPU-only", r.gpu_only),
                ] {
                    accs.entry(name).or_insert_with(Acc::new).push(m, dype[m], base);
                    grand.entry(name).or_insert_with(Acc::new).push(m, dype[m], base);
                }
            }
        }
        println!("--- {title} ({} cases) ---", cases.len());
        let mut t = Table::new(&header);
        for name in ["FleetRec*", "static", "theoretical-additive", "FPGA-only", "GPU-only"] {
            accs[name].row(name, &mut t);
        }
        print!("{}\n", t.render());
    }

    println!("--- Average (GNN + transformer) ---");
    let mut t = Table::new(&header);
    for name in ["FleetRec*", "theoretical-additive", "GPU-only"] {
        grand[name].row(name, &mut t);
    }
    print!("{}", t.render());

    // Shape checks against the paper's headline claims.
    let perf_vs_fleet = mean(&grand["FleetRec*"].thp[0]);
    let perf_vs_gpu = mean(&grand["GPU-only"].thp[0]);
    let bal_eng_vs_gpu = mean(&grand["GPU-only"].eng[1]);
    let eopt_eng_vs_gpu = mean(&grand["GPU-only"].eng[2]);
    let eopt_thp_vs_fleet = mean(&grand["FleetRec*"].thp[2]);
    let eopt_eng_vs_fleet = mean(&grand["FleetRec*"].eng[2]);
    assert!(perf_vs_fleet >= 1.0, "DYPE-perf must beat FleetRec* on average: {perf_vs_fleet:.2}");
    assert!(perf_vs_gpu >= 1.0, "DYPE-perf must beat GPU-only on average: {perf_vs_gpu:.2}");
    assert!(
        bal_eng_vs_gpu >= 1.0,
        "heterogeneity must help energy in balanced mode: {bal_eng_vs_gpu:.2}"
    );
    assert!(
        eopt_eng_vs_gpu > bal_eng_vs_gpu,
        "energy-opt must push efficiency further: {eopt_eng_vs_gpu:.2} vs {bal_eng_vs_gpu:.2}"
    );
    assert!(eopt_eng_vs_fleet >= eopt_thp_vs_fleet, "energy-opt trades throughput for efficiency");
    println!(
        "\nshape check OK: perf-opt {:.2}x thp vs FleetRec* (paper 1.53x), {:.2}x thp vs GPU-only (paper 1.44x), balanced {:.2}x / energy-opt {:.2}x eng vs GPU-only (paper 1.77x / 1.86x)",
        perf_vs_fleet, perf_vs_gpu, bal_eng_vs_gpu, eopt_eng_vs_gpu
    );
}
