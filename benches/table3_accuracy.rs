//! Table III — "Accuracy of DYPE scheduler on GNN workloads".
//!
//! Methodology (§VI-B): run the scheduler with the *estimated* kernel
//! performance (§V linear models) and with the *actual measured*
//! performance (ground-truth oracle); measure both resulting schedules on
//! the hardware (pipeline simulator over ground truth); count the cases
//! where the estimate-driven schedule is sub-optimal and the average
//! relative loss over those cases.
//!
//! Paper: throughput-optimized 3/42 sub-optimal, 5.94% avg loss;
//!        energy-optimized 4/42 sub-optimal, 2.46% avg loss.

use dype::config::Objective;
use dype::experiments::{table3_cases, Registries, MEASURE_N};
use dype::metrics::{mean, Table};
use dype::perfmodel::OracleModels;
use dype::scheduler::DpScheduler;

fn main() {
    println!("=== Table III: scheduler accuracy under estimation error ===\n");
    let regs = Registries::train();
    let cases = table3_cases();
    assert_eq!(cases.len(), 42);

    let mut table = Table::new(&["objective", "# sub-optimal", "avg loss (%)", "paper"]);
    for (obj, metric_name, paper) in [
        (Objective::Performance, "throughput", "3/42, 5.94%"),
        (Objective::Energy, "energy eff.", "4/42, 2.46%"),
    ] {
        let mut suboptimal = 0usize;
        let mut losses = Vec::new();
        let mut detail = Vec::new();
        for case in &cases {
            let est = regs.get(case.sys.interconnect);
            let oracle = OracleModels { gt: &case.gt };
            let from_est = DpScheduler::new(&case.sys, est).schedule(&case.wl, obj);
            let from_gt = DpScheduler::new(&case.sys, &oracle).schedule(&case.wl, obj);
            let (thp_e, eng_e) = case.measure(&from_est.plan(), MEASURE_N);
            let (thp_g, eng_g) = case.measure(&from_gt.plan(), MEASURE_N);
            // Metric per objective: throughput or energy efficiency.
            let (est_m, gt_m) = match obj {
                Objective::Performance => (thp_e, thp_g),
                _ => (1.0 / eng_e, 1.0 / eng_g),
            };
            if est_m < gt_m * (1.0 - 1e-6) && from_est.mnemonic() != from_gt.mnemonic() {
                suboptimal += 1;
                let loss = (1.0 - est_m / gt_m) * 100.0;
                losses.push(loss);
                detail.push(format!(
                    "  {} [{}]: est {} vs opt {} -> {:.2}% loss",
                    case.label,
                    metric_name,
                    from_est.mnemonic(),
                    from_gt.mnemonic(),
                    loss
                ));
            }
        }
        let avg = if losses.is_empty() { 0.0 } else { mean(&losses) };
        table.row(vec![
            obj.name().to_string(),
            format!("{suboptimal}/42"),
            format!("{avg:.2}%"),
            paper.to_string(),
        ]);
        if !detail.is_empty() {
            println!("{} sub-optimal cases ({}):", obj.name(), detail.len());
            for d in &detail {
                println!("{d}");
            }
            println!();
        }
        // Shape check: the scheduler tolerates estimation error — most
        // cases optimal, losses bounded.
        assert!(
            suboptimal <= 12,
            "{}: too many sub-optimal cases ({suboptimal}/42) — estimator too weak",
            obj.name()
        );
        assert!(avg < 25.0, "{}: losses too large ({avg:.1}%)", obj.name());
    }
    print!("{}", table.render());
}
