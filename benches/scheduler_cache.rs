//! Schedule-cache bench (DESIGN.md §Perf + Experiment index): quantifies
//! what the cache buys on the serving path — a reschedule on
//! previously-seen drift becomes a plan re-timing instead of a full
//! Algorithm-1 run.
//!
//! Measures, per workload depth (4-kernel GCN, 40-kernel service
//! transformer, 160-kernel paper transformer):
//!   * `dp_cold`   — full DP (tables + selection + rebuild), the miss path;
//!   * `cache_hit` — key build + lookup + `evaluate_plan` re-timing;
//! then replays the canonical two-stream drift scenario and reports the
//! end-to-end hit rate the serving layer sees.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::devices::GroundTruth;
use dype::perfmodel::OracleModels;
use dype::scheduler::{
    cache::CacheKey, evaluate_plan, system_fingerprint, DpScheduler, PowerTable, ScheduleCache,
};
use dype::util::bench::{bench, fmt_time, header, record_json};
use dype::workload::{gnn, transformer, Dataset, Workload};

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };

    println!("{}", header());

    let cases: Vec<(&str, Workload, usize)> = vec![
        ("gcn_4_kernels", gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128), 50),
        ("transformer_40_kernels", transformer::transformer_workload(4096, 1024, 8), 20),
        ("transformer_160_kernels", transformer::paper_transformer(4096, 512), 10),
    ];

    for (name, wl, iters) in &cases {
        let sched = DpScheduler::new(&sys, &oracle);
        let cold = bench(&format!("dp_cold/{name}"), 2, *iters, || {
            std::hint::black_box(sched.schedule(wl, Objective::Performance));
        });
        println!("{}", cold.report());

        // Warm a cache with this workload's bucket, then time the hit path.
        let fp = system_fingerprint(&sys);
        let mut cache = ScheduleCache::new(8);
        let plan = sched.schedule(wl, Objective::Performance).plan();
        cache.insert(CacheKey::new(fp, wl, Objective::Performance), plan);
        let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
        let comm = sys.comm_model();
        let hit = bench(&format!("cache_hit/{name}"), 2, *iters, || {
            let key = CacheKey::new(fp, wl, Objective::Performance);
            let plan = cache.lookup(&key).expect("warmed entry");
            std::hint::black_box(evaluate_plan(wl, &plan, &oracle, &comm, &power));
        });
        println!("{}", hit.report());
        println!(
            "  -> hit path is {:.0}x cheaper ({} vs {})\n",
            cold.median / hit.median.max(1e-12),
            fmt_time(cold.median),
            fmt_time(hit.median)
        );
        record_json(&[
            (format!("scheduler_cache/dp_cold/{name}"), cold.median),
            (format!("scheduler_cache/cache_hit/{name}"), hit.median),
        ]);
    }

    // End-to-end: the canonical two-stream recurring-drift scenario.
    let streams = dype::experiments::multi_stream_scenario(3, 6, 42);
    let report = dype::experiments::run_multi_stream(&sys, &streams);
    println!(
        "multi-stream drift replay: {} requests, {} DP runs avoided of {} reschedule \
         decisions (cache: {})",
        report.total_completed,
        report.cache.hits,
        report.cache.lookups(),
        report.cache
    );
    assert!(report.cache.hit_rate() > 0.5, "cache must absorb recurring drift");
}
