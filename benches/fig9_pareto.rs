//! Fig 9 — "Design space exploration": Pareto-optimal schedules in
//! (throughput, energy, device count) for the paper's four showcased
//! cases, PCIe 4.0:
//!   (a) GCN, synthetic-1        — energy improves cheaply (eopt-friendly)
//!   (b) Transformer, 2048/512   — energy-opt costs much throughput
//!   (c) Transformer, 12288/2048 — ditto, longer context
//!   (d) GCN, ogbn-arxiv         — a third Pareto point sits in between

use dype::config::{Interconnect, SystemSpec};
use dype::experiments::Registries;
use dype::metrics::Table;
use dype::scheduler::{pareto_front, DpScheduler};
use dype::workload::{gnn, transformer, Dataset, Workload};

fn main() {
    println!("=== Fig 9: Pareto-optimal schedules (PCIe 4.0) ===\n");
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let regs = Registries::train();
    let est = regs.get(Interconnect::Pcie4);

    let cases: Vec<(&str, Workload)> = vec![
        ("(a) GCN, synthetic-1", gnn::gcn_workload(&Dataset::synthetic1(), 2, 128)),
        ("(b) Transformer, len 2048, w 512", transformer::paper_transformer(2048, 512)),
        ("(c) Transformer, len 12288, w 2048", transformer::paper_transformer(12288, 2048)),
        ("(d) GCN, ogbn-arxiv", gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128)),
    ];

    for (label, wl) in cases {
        let tables = DpScheduler::new(&sys, est).tables(&wl);
        let front = pareto_front(&tables);
        println!("--- {label} ---");
        let mut t = Table::new(&["schedule", "thp(inf/s)", "J/inf", "devices"]);
        for p in &front {
            t.row(vec![
                compress(&p.mnemonic),
                format!("{:.2}", p.throughput),
                format!("{:.4}", p.energy_per_inf),
                format!("{}F{}G", p.n_fpga, p.n_gpu),
            ]);
        }
        print!("{}", t.render());

        // Shape checks: a real front exists (trade-offs to explore), and
        // it is a proper front (already asserted by construction).
        assert!(!front.is_empty());
        if front.len() >= 2 {
            let thp_span = front[0].throughput / front.last().unwrap().throughput;
            let eng_span = front[0].energy_per_inf / front.last().unwrap().energy_per_inf;
            println!(
                "front: {} points, throughput span {:.2}x, energy span {:.2}x\n",
                front.len(),
                thp_span,
                eng_span
            );
        } else {
            println!("front collapsed to a single dominant schedule\n");
        }
    }
}

fn compress(m: &str) -> String {
    if m.len() <= 14 {
        m.to_string()
    } else {
        let stages = m.chars().filter(|c| c.is_ascii_alphabetic()).count();
        format!("{}…({}st)", &m[..8], stages)
    }
}
