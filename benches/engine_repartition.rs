//! Serving-engine bench (DESIGN.md §Serving): per-event overhead of the
//! event-heap loop, and what the adaptive default buys over frozen
//! leases on a demand-skewed two-stream scenario — in both migration
//! modes (drain vs mid-slot preemption).
//!
//! The scenario (`experiments::skewed_pair_scenario`) offers two streams
//! with near-equal *total* demand but phase-reversed load, so the
//! initial demand-proportional leases are wrong in both halves: static
//! leases leave the currently-heavy stream under-provisioned, while the
//! adaptive engine notices the observed-FLOP skew, migrates devices, and
//! prewarms the schedule cache for every prospective partition (so the
//! migrations do not re-pay the DP for known regimes).
//!
//! Reported per mode: simulated makespan, aggregate throughput, Jain
//! fairness, lease migrations (and mid-slot preemptions), prewarm hits,
//! events processed, and host-side wall time per event (which includes
//! coordinator DP/cache work on the dispatch path — the full per-event
//! serving cost, not just heap bookkeeping).

use std::time::Instant;

use dype::config::{Interconnect, SystemSpec};
use dype::coordinator::MultiStreamReport;
use dype::engine::{EngineConfig, RepartitionPolicy};
use dype::experiments::{run_multi_stream_static, run_multi_stream_with, skewed_pair_scenario};
use dype::metrics::Table;
use dype::util::bench::{fmt_time, record_json};

fn row(t: &mut Table, mode: &str, r: &MultiStreamReport, wall: f64) {
    let events = r.engine.events_processed.max(1);
    t.row(vec![
        mode.to_string(),
        format!("{:.2}s", r.makespan),
        format!("{:.1}", r.aggregate_throughput),
        format!("{:.3}", r.fairness),
        format!("{}", r.engine.lease_migrations),
        format!("{}", r.engine.slot_preemptions),
        format!("{}", r.engine.prewarm_hits),
        format!("{}", r.engine.events_processed),
        fmt_time(wall / events as f64),
    ]);
}

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let streams = skewed_pair_scenario(16, 77);
    let offered: usize = streams.iter().map(|s| s.trace.len()).sum();
    println!(
        "skewed two-stream scenario: {} requests over {}F+{}G, phase-reversed demand\n",
        offered, sys.n_fpga, sys.n_gpu
    );

    let t0 = Instant::now();
    let statik = run_multi_stream_static(&sys, &streams);
    let static_wall = t0.elapsed().as_secs_f64();

    let drain_cfg = EngineConfig::builder().repartition(RepartitionPolicy::reactive(1.0)).build();
    let t1 = Instant::now();
    let adaptive = run_multi_stream_with(&sys, &streams, drain_cfg);
    let adaptive_wall = t1.elapsed().as_secs_f64();

    let preempt_cfg = EngineConfig::builder().preemptive(1.0).build();
    let t2 = Instant::now();
    let preempt = run_multi_stream_with(&sys, &streams, preempt_cfg);
    let preempt_wall = t2.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "mode",
        "makespan",
        "thp(inf/s)",
        "fairness",
        "migrations",
        "mid-slot",
        "prewarm",
        "events",
        "wall/event",
    ]);
    row(&mut t, "static-leases", &statik, static_wall);
    row(&mut t, "adaptive-drain", &adaptive, adaptive_wall);
    row(&mut t, "adaptive-preempt", &preempt, preempt_wall);
    print!("{}", t.render());

    println!(
        "\nre-partitioning: makespan {:.2}s -> {:.2}s drain ({:+.1}%) / {:.2}s preempt \
         ({:+.1}%); preempt refunded {:.1} ms of lease time and {:.2} J, engine: {}",
        statik.makespan,
        adaptive.makespan,
        (adaptive.makespan / statik.makespan - 1.0) * 100.0,
        preempt.makespan,
        (preempt.makespan / statik.makespan - 1.0) * 100.0,
        preempt.engine.slot_time_refunded * 1e3,
        preempt.engine.joules_refunded,
        preempt.engine,
    );

    assert_eq!(statik.total_completed, offered, "static run lost requests");
    assert_eq!(adaptive.total_completed, offered, "adaptive run lost requests");
    assert_eq!(preempt.total_completed, offered, "preemptive run lost requests");
    assert_eq!(statik.engine.lease_migrations, 0, "frozen leases must not move");
    assert!(
        adaptive.engine.lease_migrations >= 1,
        "the skew must trigger at least one lease migration"
    );
    assert!(
        preempt.engine.lease_migrations >= 1,
        "the skew must trigger at least one preemptive migration"
    );

    // CI perf trajectory (see util::bench::record_json): host wall time
    // per processed event per mode. Diffed against the tracked
    // BENCH_serving.json baseline by the bench-smoke job.
    record_json(&[
        (
            "engine_repartition/static_per_event".to_string(),
            static_wall / statik.engine.events_processed.max(1) as f64,
        ),
        (
            "engine_repartition/adaptive_per_event".to_string(),
            adaptive_wall / adaptive.engine.events_processed.max(1) as f64,
        ),
        (
            "engine_repartition/preempt_per_event".to_string(),
            preempt_wall / preempt.engine.events_processed.max(1) as f64,
        ),
    ]);
}
