//! Serving-engine bench (DESIGN.md §Serving): per-event overhead of the
//! event-heap loop, and what online lease re-partitioning buys over
//! static leases on a demand-skewed two-stream scenario.
//!
//! The scenario (`experiments::skewed_pair_scenario`) offers two streams
//! with near-equal *total* demand but phase-reversed load, so the
//! initial demand-proportional leases are wrong in both halves: static
//! leases leave the currently-heavy stream under-provisioned, while the
//! adaptive engine notices the observed-FLOP skew and migrates devices.
//!
//! Reported per mode: simulated makespan, aggregate throughput, Jain
//! fairness, lease migrations, events processed, and host-side wall time
//! per event (which includes coordinator DP/cache work on the dispatch
//! path — the full per-event serving cost, not just heap bookkeeping).

use std::time::Instant;

use dype::config::{Interconnect, SystemSpec};
use dype::coordinator::MultiStreamReport;
use dype::engine::{EngineConfig, RepartitionPolicy};
use dype::experiments::{run_multi_stream, run_multi_stream_with, skewed_pair_scenario};
use dype::metrics::Table;
use dype::util::bench::{fmt_time, record_json};

fn row(t: &mut Table, mode: &str, r: &MultiStreamReport, wall: f64) {
    let events = r.engine.events_processed.max(1);
    t.row(vec![
        mode.to_string(),
        format!("{:.2}s", r.makespan),
        format!("{:.1}", r.aggregate_throughput),
        format!("{:.3}", r.fairness),
        format!("{}", r.engine.lease_migrations),
        format!("{}", r.engine.events_processed),
        fmt_time(wall / events as f64),
    ]);
}

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let streams = skewed_pair_scenario(16, 77);
    let offered: usize = streams.iter().map(|s| s.trace.len()).sum();
    println!(
        "skewed two-stream scenario: {} requests over {}F+{}G, phase-reversed demand\n",
        offered, sys.n_fpga, sys.n_gpu
    );

    let t0 = Instant::now();
    let statik = run_multi_stream(&sys, &streams);
    let static_wall = t0.elapsed().as_secs_f64();

    let cfg = EngineConfig {
        repartition: Some(RepartitionPolicy::reactive(1.0)),
        ..EngineConfig::default()
    };
    let t1 = Instant::now();
    let adaptive = run_multi_stream_with(&sys, &streams, cfg);
    let adaptive_wall = t1.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "mode",
        "makespan",
        "thp(inf/s)",
        "fairness",
        "migrations",
        "events",
        "wall/event",
    ]);
    row(&mut t, "static-leases", &statik, static_wall);
    row(&mut t, "online-repartition", &adaptive, adaptive_wall);
    print!("{}", t.render());

    println!(
        "\nre-partitioning: makespan {:.2}s -> {:.2}s ({:+.1}%), \
         aggregate throughput {:.1} -> {:.1} inf/s, engine: {}",
        statik.makespan,
        adaptive.makespan,
        (adaptive.makespan / statik.makespan - 1.0) * 100.0,
        statik.aggregate_throughput,
        adaptive.aggregate_throughput,
        adaptive.engine,
    );

    assert_eq!(statik.total_completed, offered, "static run lost requests");
    assert_eq!(adaptive.total_completed, offered, "adaptive run lost requests");
    assert!(
        adaptive.engine.lease_migrations >= 1,
        "the skew must trigger at least one lease migration"
    );

    // CI perf trajectory (see util::bench::record_json): host wall time
    // per processed event, static vs adaptive.
    record_json(&[
        (
            "engine_repartition/static_per_event".to_string(),
            static_wall / statik.engine.events_processed.max(1) as f64,
        ),
        (
            "engine_repartition/adaptive_per_event".to_string(),
            adaptive_wall / adaptive.engine.events_processed.max(1) as f64,
        ),
    ]);
}
