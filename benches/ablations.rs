//! Ablation studies for DYPE's design choices (DESIGN.md):
//!
//! 1. **P2P transfers** (§III-B): re-run the GNN grid with host-staged
//!    transfers only — how much schedule quality does the P2P build buy?
//! 2. **Estimation noise** (§VI-B): Table III's sub-optimal count as a
//!    function of the measurement-noise amplitude the estimators face.
//! 3. **Balanced-mode floor** (§II-A): the energy/throughput frontier the
//!    30%-reduction knob trades along.
//! 4. **QoS mode** (§II extension): absolute-floor scheduling behaves as
//!    specified across floors.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::devices::GroundTruth;
use dype::experiments::{measure_plan, Case};
use dype::metrics::{mean, Table};
use dype::perfmodel::{calibrate, OracleModels};
use dype::scheduler::DpScheduler;
use dype::workload::{gnn, Dataset};

fn main() {
    ablate_p2p();
    ablate_noise();
    ablate_balanced_floor();
    ablate_qos();
}

/// 1: schedule + measure the GNN grid with and without P2P.
fn ablate_p2p() {
    println!("=== Ablation 1: FPGA-GPU P2P transfers (on vs off) ===\n");
    let mut t = Table::new(&["workload", "thp w/ P2P", "thp staged", "P2P gain"]);
    let mut gains = Vec::new();
    for ds in Dataset::table1() {
        let wl = gnn::gcn_workload(&ds, 2, 128);
        let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
        // With P2P.
        let gt_on = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model())
            .with_degree_skew(ds.degree_skew);
        let est_on = OracleModels { gt: &gt_on };
        let on = DpScheduler::new(&sys, &est_on).schedule(&wl, Objective::Performance);
        let (thp_on, _) = measure_plan(&sys, &gt_on, &wl, &on.plan(), 100);
        // Without P2P: every cross-device hop stages through the host.
        let mut comm_off = sys.comm_model();
        comm_off.p2p_enabled = false;
        let mut gt_off = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), comm_off.clone())
            .with_degree_skew(ds.degree_skew);
        gt_off.comm = comm_off.clone();
        let est_off = OracleModels { gt: &gt_off };
        let mut sched_off = DpScheduler::new(&sys, &est_off);
        sched_off.comm = comm_off;
        let off = sched_off.schedule(&wl, Objective::Performance);
        let (thp_off, _) = measure_plan_with(&gt_off, &sys, &wl, &off);
        let gain = thp_on / thp_off;
        gains.push(gain);
        t.row(vec![
            wl.name.clone(),
            format!("{thp_on:.2}"),
            format!("{thp_off:.2}"),
            format!("{gain:.2}x"),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nmean P2P gain: {:.2}x — P2P matters exactly where pipelines span device types\n",
        mean(&gains)
    );
    assert!(mean(&gains) >= 1.0, "P2P can never hurt");
}

fn measure_plan_with(
    gt: &GroundTruth,
    sys: &SystemSpec,
    wl: &dype::workload::Workload,
    sched: &dype::scheduler::Schedule,
) -> (f64, f64) {
    use dype::pipeline::PipelineSim;
    use dype::scheduler::{evaluate_plan, PowerTable};
    let oracle = OracleModels { gt };
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let timed = evaluate_plan(wl, &sched.plan(), &oracle, &gt.comm, &power);
    let r = PipelineSim::new(&power, &gt.comm).run(wl, &timed, 100);
    (r.throughput, r.energy_per_inf)
}

/// 2: Table III sub-optimality vs noise amplitude.
fn ablate_noise() {
    println!("=== Ablation 2: scheduler accuracy vs measurement noise ===\n");
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let mut t = Table::new(&["noise σ", "# sub-optimal (of 12)", "avg loss (%)"]);
    for sigma in [0.0, 0.03, 0.10, 0.25] {
        let mut sub = 0usize;
        let mut losses = Vec::new();
        for ds in Dataset::table1() {
            for wl in gnn::paper_gnn_workloads(&ds) {
                let mut gt =
                    GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model())
                        .with_degree_skew(ds.degree_skew);
                gt.noise_sigma = sigma;
                let reg = calibrate::calibrated_registry_against(&sys, &gt);
                let oracle = OracleModels { gt: &gt };
                let est_s = DpScheduler::new(&sys, &reg).schedule(&wl, Objective::Performance);
                let opt_s = DpScheduler::new(&sys, &oracle).schedule(&wl, Objective::Performance);
                let (te, _) = measure_plan(&sys, &gt, &wl, &est_s.plan(), 50);
                let (tg, _) = measure_plan(&sys, &gt, &wl, &opt_s.plan(), 50);
                if te < tg * (1.0 - 1e-6) {
                    sub += 1;
                    losses.push((1.0 - te / tg) * 100.0);
                }
            }
        }
        let avg = if losses.is_empty() { 0.0 } else { mean(&losses) };
        t.row(vec![format!("{sigma:.2}"), format!("{sub}/12"), format!("{avg:.2}")]);
    }
    print!("{}", t.render());
    println!("\nthe scheduler degrades gracefully: loss grows sublinearly with noise\n");
}

/// 3: sweep the balanced-mode throughput floor.
fn ablate_balanced_floor() {
    println!("=== Ablation 3: balanced-mode floor sweep (GIN-OP @ PCIe4) ===\n");
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let ds = Dataset::ogbn_products();
    let wl = gnn::gin_workload(&ds, 2, 128, 2);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model())
        .with_degree_skew(ds.degree_skew);
    let oracle = OracleModels { gt: &gt };
    let sched = DpScheduler::new(&sys, &oracle);
    let tables = sched.tables(&wl);
    let max_thp = tables.max_throughput();
    let mut t = Table::new(&["floor", "schedule", "thp (frac of max)", "J/inf"]);
    let mut last_energy = f64::INFINITY;
    for frac in [1.0, 0.9, 0.7, 0.5, 0.3, 0.0] {
        let fs = tables.select(Objective::Balanced { min_throughput_frac: frac }).unwrap();
        let s = tables.reconstruct(&fs);
        t.row(vec![
            format!("{:.0}%", frac * 100.0),
            s.mnemonic(),
            format!("{:.2}", s.throughput() / max_thp),
            format!("{:.4}", s.energy_per_inf),
        ]);
        // Loosening the floor must never increase minimum energy.
        assert!(s.energy_per_inf <= last_energy * (1.0 + 1e-9));
        last_energy = s.energy_per_inf;
    }
    print!("{}", t.render());
    println!("\nmonotone: energy-per-inference falls as the floor loosens\n");
}

/// 4: QoS (absolute floor) mode.
fn ablate_qos() {
    println!("=== Ablation 4: QoS mode (absolute throughput floor) ===\n");
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let ds = Dataset::ogbn_arxiv();
    let wl = gnn::gcn_workload(&ds, 2, 128);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model())
        .with_degree_skew(ds.degree_skew);
    let oracle = OracleModels { gt: &gt };
    let sched = DpScheduler::new(&sys, &oracle);
    let perf = sched.schedule(&wl, Objective::Performance);
    let mut t = Table::new(&["QoS floor (inf/s)", "schedule", "thp", "J/inf"]);
    for floor in [10.0, 0.5 * perf.throughput(), 0.9 * perf.throughput(), 10.0 * perf.throughput()]
    {
        let s = sched.schedule(&wl, Objective::QoS { min_throughput: floor });
        // Reachable floors are honored; unreachable ones degrade to max.
        if floor <= perf.throughput() {
            assert!(s.throughput() >= floor * (1.0 - 1e-6), "QoS floor violated");
        }
        t.row(vec![
            format!("{floor:.1}"),
            s.mnemonic(),
            format!("{:.1}", s.throughput()),
            format!("{:.3}", s.energy_per_inf),
        ]);
    }
    print!("{}", t.render());
    println!("\nQoS floors honored when feasible; best-effort at the max otherwise");
}
