//! Static-analyzer bench (DESIGN.md §Static Analysis): full-zoo lint
//! cost, one median for the whole catalog and one for the heaviest
//! single manifest, recorded to the CI perf trajectory via
//! `DYPE_BENCH_JSON` (see `util::bench::record_json`).
//!
//! Lint runs at the head of every `dype scenario-sweep` and `dype
//! fleet` invocation, so its cost is part of those commands' startup
//! latency — the trajectory exists to catch the analyzer's model pass
//! (one DP + re-time per distinct (lease, workload, objective) triple)
//! regressing from memoized to quadratic.

use dype::analysis::lint_manifest;
use dype::scenario::catalog;
use dype::util::bench::{bench, header, record_json};

fn main() {
    let zoo = catalog::all();
    println!("{}", header());
    let mut entries = Vec::new();

    let name = "lint/zoo".to_string();
    let stats = bench(&name, 1, 5, || {
        for m in &zoo {
            std::hint::black_box(lint_manifest(m));
        }
    });
    println!("{}", stats.report());
    entries.push((name, stats.median));

    let fleet = catalog::fleet_balanced();
    let name = "lint/fleet_balanced".to_string();
    let stats = bench(&name, 1, 5, || {
        std::hint::black_box(lint_manifest(&fleet));
    });
    println!("{}", stats.report());
    entries.push((name, stats.median));

    record_json(&entries);
}
