//! Fleet scale-out bench (DESIGN.md §Fleet): completed-request
//! throughput of a 4-shard fleet vs a single shard on the balanced
//! fleet scenario, plus the router's per-admission cost.
//!
//! The scenario is `catalog::fleet_balanced` with its request counts
//! scaled up (eight near-equal lanes, 480 requests on a 12F+8G pool).
//! A 1-shard fleet is the bare engine (pinned bit-identical in
//! `rust/tests/fleet.rs`), so the 1-vs-4 delta is exactly what sharding
//! buys: four engines on four OS threads, each serving a quarter of the
//! lanes on a quarter of the pool. On a host with >= 4 workers the
//! 4-shard fleet must clear 3x the single shard's throughput — that bar
//! is asserted here and the medians feed the CI perf trajectory
//! (recorded as seconds per completed request, so a *rise* is a
//! regression, matching the bench gate's direction).

use std::time::Instant;

use dype::devices::GroundTruth;
use dype::engine::EngineConfig;
use dype::fleet::{FleetConfig, ServingFleet};
use dype::perfmodel::OracleModels;
use dype::scenario::catalog;
use dype::util::bench::{bench, fmt_time, record_json};
use dype::util::pool::default_threads;

fn main() {
    let mut m = catalog::fleet_balanced();
    for s in &mut m.streams {
        for p in &mut s.phases {
            p.count = 60;
        }
    }
    let built = m.build().expect("manifest builds");
    let sys = built.system.clone();
    let offered: usize = built.streams.iter().map(|s| s.trace.len()).sum();
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };

    println!(
        "fleet scale-out: {} requests over {} lanes on {}F+{}G ({} host workers)\n",
        offered,
        built.streams.len(),
        sys.n_fpga,
        sys.n_gpu,
        default_threads()
    );

    // Best-of-3 wall clock per shard count; every run must complete the
    // whole offered load (balanced lanes have no deadlines, so nothing
    // sheds and the throughput numbers compare like for like).
    let serve_wall = |shards: usize| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let cfg = FleetConfig {
                shards,
                threads: shards,
                engine: built.apply(EngineConfig::default()),
                ..FleetConfig::default()
            };
            let mut fleet = ServingFleet::new(sys.clone(), &est, cfg);
            let t0 = Instant::now();
            let report = fleet.serve(&built.streams);
            let wall = t0.elapsed().as_secs_f64();
            assert_eq!(report.total_completed, offered, "balanced fleet completes everything");
            assert!(report.conserved());
            best = best.min(wall);
        }
        best
    };

    let wall1 = serve_wall(1);
    let wall4 = serve_wall(4);
    let per1 = wall1 / offered as f64;
    let per4 = wall4 / offered as f64;
    println!(
        "1 shard : {} wall, {}/request ({:.0} req/s host)",
        fmt_time(wall1),
        fmt_time(per1),
        offered as f64 / wall1
    );
    println!(
        "4 shards: {} wall, {}/request ({:.0} req/s host)",
        fmt_time(wall4),
        fmt_time(per4),
        offered as f64 / wall4
    );
    println!("speedup : {:.2}x", wall1 / wall4);

    // Router cost: place all eight lanes across four shards, timed per
    // admission (demand estimate + regime extraction + affinity probes).
    let router = ServingFleet::new(sys.clone(), &est, FleetConfig::new(4));
    let stats = bench("fleet/route", 2, 20, || {
        std::hint::black_box(router.route(&built.streams));
    });
    let route_per = stats.median / built.streams.len() as f64;
    println!("\nrouter: {} per admission over {} lanes x 4 shards", fmt_time(route_per), 8);

    // The scale-out bar needs real parallel workers: on a starved host
    // (CI containers can pin us to one core) the 4 shards time-share a
    // single core and wall clock cannot scale, so the bar is only
    // meaningful — and only asserted — with >= 4 workers available.
    if default_threads() >= 4 {
        assert!(
            wall1 >= 3.0 * wall4,
            "4-shard fleet must clear 3x single-shard throughput: {} vs {} wall",
            fmt_time(wall1),
            fmt_time(wall4)
        );
        println!("OK — 4-shard fleet cleared the 3x scale-out bar.");
    } else {
        println!("note: {} worker(s) available, 3x scale-out bar not asserted", default_threads());
    }

    record_json(&[
        ("fleet/1shard_throughput".to_string(), per1),
        ("fleet/4shard_throughput".to_string(), per4),
        ("fleet/route_per_admission".to_string(), route_per),
    ]);
}
