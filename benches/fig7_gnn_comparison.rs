//! Fig 7 — "Throughput and energy efficiency comparison between DYPE and
//! the baselines, normalized to FPGA-only".
//!
//! The paper's five selected workloads (GCN-OP, GIN-OP, GIN-S1, GIN-S3,
//! GIN-S4) across the three interconnects; static / FleetRec* / DYPE
//! (balanced mode, as in the figure) normalized to the FPGA-only setup.

use dype::config::{Interconnect, SystemSpec};
use dype::experiments::{reference_workload, run_case, Case, Registries};
use dype::metrics::Table;
use dype::workload::{gnn, Dataset};

fn main() {
    println!("=== Fig 7: normalized throughput / energy efficiency (FPGA-only = 1.0) ===\n");
    let regs = Registries::train();

    let selected: Vec<(Dataset, bool)> = vec![
        (Dataset::ogbn_products(), false), // GCN-OP
        (Dataset::ogbn_products(), true),  // GIN-OP
        (Dataset::synthetic1(), true),     // GIN-S1
        (Dataset::synthetic3(), true),     // GIN-S3
        (Dataset::synthetic4(), true),     // GIN-S4
    ];

    let mut thp_table = Table::new(&[
        "workload", "interconnect", "static", "FleetRec*", "DYPE", "GPU-only",
    ]);
    let mut eng_table = Table::new(&[
        "workload", "interconnect", "static", "FleetRec*", "DYPE", "GPU-only",
    ]);

    // Track the paper's qualitative observations.
    let mut dype_gain_s3 = Vec::new(); // DYPE gain vs static per interconnect (GIN-S3)
    let mut fleet_vs_static_wins = 0usize;
    let mut fleet_vs_static_total = 0usize;

    for (ds, is_gin) in &selected {
        let wl = if *is_gin {
            gnn::gin_workload(ds, 2, 128, 2)
        } else {
            gnn::gcn_workload(ds, 2, 128)
        };
        for ic in Interconnect::ALL {
            let sys = SystemSpec::paper_testbed(ic);
            let case = Case::new(sys, wl.clone(), ds.degree_skew);
            let est = regs.get(ic);
            let r = run_case(&case, est, &reference_workload(&wl));
            let fleet = r.fleetrec.unwrap_or(r.statik);
            let base_thp = r.fpga_only.0;
            let base_eng = r.fpga_only.1;
            thp_table.row(vec![
                wl.name.clone(),
                ic.to_string(),
                format!("{:.2}", r.statik.0 / base_thp),
                format!("{:.2}", fleet.0 / base_thp),
                format!("{:.2}", r.dype_balanced.0 / base_thp),
                format!("{:.2}", r.gpu_only.0 / base_thp),
            ]);
            eng_table.row(vec![
                wl.name.clone(),
                ic.to_string(),
                format!("{:.2}", base_eng / r.statik.1),
                format!("{:.2}", base_eng / fleet.1),
                format!("{:.2}", base_eng / r.dype_balanced.1),
                format!("{:.2}", base_eng / r.gpu_only.1),
            ]);
            if wl.name == "GIN-S3" {
                dype_gain_s3.push(r.dype_balanced.0 / r.statik.0);
            }
            if fleet.0 >= r.statik.0 * 0.999 {
                fleet_vs_static_wins += 1;
            }
            fleet_vs_static_total += 1;
            // DYPE (unconstrained) must beat or match both fixed policies.
            assert!(
                r.dype_perf.0 >= fleet.0 * 0.9 && r.dype_perf.0 >= r.statik.0 * 0.9,
                "{}: DYPE-perf unexpectedly below a fixed baseline",
                case.label
            );
        }
    }

    println!("Throughput (normalized to FPGA-only):");
    print!("{}\n", thp_table.render());
    println!("Energy efficiency (normalized to FPGA-only):");
    print!("{}", eng_table.render());

    // §VI-C2: FleetRec consistently outperforms or matches static.
    println!(
        "\nFleetRec* >= static in {fleet_vs_static_wins}/{fleet_vs_static_total} cells (paper: consistently)"
    );
    // §VI-C2: GIN-S3's balanced stage times make interconnect matter most:
    // DYPE's edge should not shrink as bandwidth grows.
    println!(
        "GIN-S3 DYPE/static gain per interconnect (PCIe4, PCIe5, CXL3): {:.2}x {:.2}x {:.2}x",
        dype_gain_s3[0], dype_gain_s3[1], dype_gain_s3[2]
    );
    assert!(
        fleet_vs_static_wins * 3 >= fleet_vs_static_total * 2,
        "FleetRec should mostly match/beat static"
    );
}
