//! Scheduler performance bench (§Perf, DESIGN.md): Algorithm 1 must stay
//! "lightweight" — rescheduling happens on the serving path when input
//! characteristics drift, so DP latency is user-visible.
//!
//! Times: DP over the 4-kernel GCN, the 6-kernel GIN, and the 160-kernel
//! 32-layer transformer; plus calibration and the streaming simulator.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::devices::GroundTruth;
use dype::perfmodel::{calibrate, OracleModels};
use dype::pipeline::PipelineSim;
use dype::scheduler::{DpScheduler, PowerTable};
use dype::util::bench::{bench, header};
use dype::workload::{gnn, transformer, Dataset};

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };
    let reg = calibrate::calibrated_registry(&sys);

    println!("{}", header());

    let gcn = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
    let s = bench("dp_schedule/gcn_4_kernels", 3, 50, || {
        std::hint::black_box(
            DpScheduler::new(&sys, &oracle).schedule(&gcn, Objective::Performance),
        );
    });
    println!("{}", s.report());

    let gin = gnn::gin_workload(&Dataset::ogbn_products(), 2, 128, 2);
    let s = bench("dp_schedule/gin_6_kernels", 3, 50, || {
        std::hint::black_box(
            DpScheduler::new(&sys, &oracle).schedule(&gin, Objective::Performance),
        );
    });
    println!("{}", s.report());

    let tf = transformer::paper_transformer(4096, 512);
    let s = bench("dp_schedule/transformer_160_kernels", 1, 10, || {
        std::hint::black_box(DpScheduler::new(&sys, &oracle).schedule(&tf, Objective::Performance));
    });
    println!("{}", s.report());

    let s = bench("dp_schedule/transformer_160_kernels_est", 1, 10, || {
        std::hint::black_box(DpScheduler::new(&sys, &reg).schedule(&tf, Objective::Performance));
    });
    println!("{}", s.report());

    let s = bench("calibrate/full_registry_6_models", 1, 5, || {
        std::hint::black_box(calibrate::calibrated_registry(&sys));
    });
    println!("{}", s.report());

    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let comm = sys.comm_model();
    let sched = DpScheduler::new(&sys, &oracle).schedule(&gcn, Objective::Performance);
    let s = bench("pipeline_sim/gcn_1000_inferences", 3, 30, || {
        std::hint::black_box(PipelineSim::new(&power, &comm).run(&gcn, &sched, 1000));
    });
    println!("{}", s.report());
}
