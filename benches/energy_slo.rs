//! Energy/SLO serving bench (DESIGN.md §Energy & SLOs): what a joule
//! budget costs and buys on the three-class serving scenario.
//!
//! Two points on the serving throughput-vs-joules frontier:
//!
//!   * `unbudgeted` — the latency-only engine (adaptive default, no
//!     metering): fastest, hungriest;
//!   * `budgeted`   — the same streams under a power cap at 30% of the
//!     unbudgeted run's average draw, with SLO-weighted adaptive leases:
//!     below-priority admissions defer at window exhaustion, the
//!     latency-critical stream keeps its service level.
//!
//! Also times the budgeted serve end to end (the full dispatch +
//! ledger + controller path) and records it to the CI perf trajectory
//! via `DYPE_BENCH_JSON` (see `util::bench::record_json`).

use std::time::Instant;

use dype::config::{Interconnect, SystemSpec};
use dype::coordinator::MultiStreamReport;
use dype::experiments::{
    energy_slo_config, energy_slo_scenario, run_multi_stream, run_multi_stream_with,
};
use dype::metrics::{fmt_percent, Table};
use dype::util::bench::{bench, record_json};

fn row(t: &mut Table, mode: &str, r: &MultiStreamReport, wall: f64) {
    t.row(vec![
        mode.to_string(),
        format!("{:.2}s", r.makespan),
        format!("{:.1}", r.aggregate_throughput),
        format!("{:.1}", r.total_energy),
        format!("{:.3}", r.throughput_per_joule),
        format!("{}", r.engine.deferrals),
        fmt_percent(r.streams[0].report.slo_attainment),
        format!("{:.1}ms", wall * 1e3),
    ]);
}

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let streams = energy_slo_scenario(6, 55);
    let offered: usize = streams.iter().map(|s| s.trace.len()).sum();
    println!(
        "three-class energy/SLO scenario: {} requests over {}F+{}G\n",
        offered, sys.n_fpga, sys.n_gpu
    );

    let t0 = Instant::now();
    let unbudgeted = run_multi_stream(&sys, &streams);
    let unbudgeted_wall = t0.elapsed().as_secs_f64();
    let avg_watts = unbudgeted.total_energy / unbudgeted.makespan;
    let cfg = energy_slo_config(0.3 * avg_watts);

    let t1 = Instant::now();
    let budgeted = run_multi_stream_with(&sys, &streams, cfg.clone());
    let budgeted_wall = t1.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "mode",
        "makespan",
        "thp(inf/s)",
        "joules",
        "inf/J",
        "deferrals",
        "crit-slo",
        "wall",
    ]);
    row(&mut t, "unbudgeted", &unbudgeted, unbudgeted_wall);
    row(&mut t, "budgeted-30%", &budgeted, budgeted_wall);
    print!("{}", t.render());

    println!(
        "\nbudget: cap {:.0} W, {} windows, {:.1} J charged; \
         critical stream deferrals {} (must stay 0), engine: {}",
        0.3 * avg_watts,
        budgeted.engine.budget_windows,
        budgeted.engine.joules_charged(),
        budgeted.streams[0].report.deferrals,
        budgeted.engine,
    );

    // Host-side cost of the full budgeted dispatch path, for the CI
    // perf trajectory (short-iteration smoke, not a stable benchmark).
    let serve = bench("energy_slo/budgeted_serve", 1, 5, || {
        std::hint::black_box(run_multi_stream_with(&sys, &streams, cfg.clone()));
    });
    println!("\n{}", serve.report());
    let events = budgeted.engine.events_processed.max(1) as f64;
    record_json(&[
        ("energy_slo/budgeted_serve".to_string(), serve.median),
        ("energy_slo/budgeted_per_event".to_string(), serve.median / events),
    ]);

    assert_eq!(unbudgeted.total_completed, offered, "unbudgeted run lost requests");
    assert_eq!(budgeted.total_completed, offered, "budgeted run lost requests");
    assert!(budgeted.engine.deferrals >= 1, "a 30% power cap must defer something");
    assert_eq!(
        budgeted.streams[0].report.deferrals, 0,
        "the highest-priority stream is never deferred"
    );
}
