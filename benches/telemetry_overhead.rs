//! Telemetry overhead bench (ISSUE 7): what a trace recorder costs the
//! serving hot path, per processed event.
//!
//! Two modes over the same seeded skewed-pair scenario under the
//! adaptive-drain policy: recorder **off** (the default `NullRecorder`
//! path — one `Option` branch per emission site, no record ever built)
//! and recorder **on** (a `TimelineRecorder` accumulating every typed
//! record). Both modes must produce bitwise-identical serving outcomes;
//! only host wall time may differ.
//!
//! The recorder-off median is the number the bench-smoke 2% gate guards
//! (`engine_repartition` medians are re-checked against the tracked
//! baseline): zero-cost-when-off is an acceptance criterion, not an
//! aspiration. The recorder-on median documents the opt-in price.

use std::time::Instant;

use dype::config::{Interconnect, SystemSpec};
use dype::coordinator::MultiStreamReport;
use dype::engine::{EngineConfig, RepartitionPolicy};
use dype::experiments::{run_multi_stream_with, skewed_pair_scenario};
use dype::metrics::Table;
use dype::telemetry::Recorder;
use dype::util::bench::{fmt_time, record_json};

const REPS: usize = 5;

fn drain_cfg() -> EngineConfig {
    EngineConfig { repartition: Some(RepartitionPolicy::reactive(1.0)), ..EngineConfig::default() }
}

fn median(walls: &mut [f64]) -> f64 {
    walls.sort_by(f64::total_cmp);
    walls[walls.len() / 2]
}

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let streams = skewed_pair_scenario(16, 77);
    let offered: usize = streams.iter().map(|s| s.trace.len()).sum();
    println!(
        "skewed two-stream scenario: {} requests over {}F+{}G, adaptive-drain, {REPS} reps\n",
        offered, sys.n_fpga, sys.n_gpu
    );

    // Warm the allocator and caches before timing anything.
    run_multi_stream_with(&sys, &streams, drain_cfg());

    let mut off_walls = Vec::with_capacity(REPS);
    let mut off: Option<MultiStreamReport> = None;
    for _ in 0..REPS {
        let t = Instant::now();
        let r = run_multi_stream_with(&sys, &streams, drain_cfg());
        off_walls.push(t.elapsed().as_secs_f64());
        off = Some(r);
    }
    let off = off.unwrap();

    let mut on_walls = Vec::with_capacity(REPS);
    let mut on: Option<MultiStreamReport> = None;
    let mut records = 0usize;
    for _ in 0..REPS {
        let rec = Recorder::timeline();
        let mut cfg = drain_cfg();
        cfg.recorder = Some(rec.clone());
        let t = Instant::now();
        let r = run_multi_stream_with(&sys, &streams, cfg);
        on_walls.push(t.elapsed().as_secs_f64());
        records = rec.drain().len();
        on = Some(r);
    }
    let on = on.unwrap();

    // The recorder is a pure observer: identical serving outcomes.
    assert_eq!(on.total_completed, off.total_completed, "recorder changed what was served");
    assert_eq!(on.makespan, off.makespan, "recorder changed the simulated clock");
    assert_eq!(on.engine.events_processed, off.engine.events_processed);
    assert!(records > 0, "the timeline recorder captured nothing");

    let events = off.engine.events_processed.max(1) as f64;
    let off_med = median(&mut off_walls);
    let on_med = median(&mut on_walls);

    let mut t = Table::new(&["mode", "makespan", "events", "records", "wall/event"]);
    for (mode, med, n) in [("recorder-off", off_med, 0usize), ("recorder-on", on_med, records)] {
        t.row(vec![
            mode.to_string(),
            format!("{:.2}s", off.makespan),
            format!("{}", off.engine.events_processed),
            format!("{n}"),
            fmt_time(med / events),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nrecorder on/off wall ratio: {:.3} ({} records, {:.1} records/event)",
        on_med / off_med,
        records,
        records as f64 / events
    );

    // CI perf trajectory (see util::bench::record_json): the off median
    // is the zero-cost-when-off guard, the on median the opt-in price.
    record_json(&[
        ("telemetry_overhead/recorder_off_per_event".to_string(), off_med / events),
        ("telemetry_overhead/recorder_on_per_event".to_string(), on_med / events),
    ]);
}
