//! Table V — "Scheduling Result of DYPE on GNN workloads".
//!
//! The optimal schedule mnemonic for every (GNN workload × interconnect ×
//! objective) cell, plus the paper's closing count: in how many of the
//! 108 cells could a static or FleetRec schedule have matched DYPE's
//! choice (paper: 8/108).

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::experiments::{reference_workload, Registries};
use dype::metrics::Table;
use dype::scheduler::{baselines, DpScheduler};
use dype::workload::{gnn, Dataset};

fn main() {
    println!("=== Table V: DYPE schedules per dataset x interconnect x mode ===\n");
    let regs = Registries::train();

    let mut t = Table::new(&[
        "workload", "PCIe4 perf", "PCIe4 bal", "PCIe4 eopt", "PCIe5 perf", "PCIe5 bal",
        "PCIe5 eopt", "CXL3 perf", "CXL3 bal", "CXL3 eopt",
    ]);

    let mut total_cells = 0usize;
    let mut static_matchable = 0usize;
    let mut distinct = std::collections::BTreeSet::new();

    for ds in Dataset::table1() {
        for wl in gnn::paper_gnn_workloads(&ds) {
            let mut cells = vec![wl.name.clone()];
            for ic in Interconnect::ALL {
                let sys = SystemSpec::paper_testbed(ic);
                let est = regs.get(ic);
                let sched = DpScheduler::new(&sys, est);
                // Static/FleetRec reference choices for the match count.
                let static_plan = baselines::tune_static_plan(
                    &sys,
                    est,
                    &reference_workload(&wl),
                    Objective::Performance,
                );
                let static_mn: String =
                    static_plan.iter().map(|p| format!("{}{}", p.n, p.dev.letter())).collect();
                let fleet_mn = baselines::fleetrec(&sys, est, &wl, Objective::Performance)
                    .map(|s| s.mnemonic());
                for obj in Objective::paper_modes() {
                    let mn = sched.schedule(&wl, obj).mnemonic();
                    total_cells += 1;
                    if mn == static_mn || Some(&mn) == fleet_mn.as_ref() {
                        static_matchable += 1;
                    }
                    distinct.insert(mn.clone());
                    cells.push(mn);
                }
            }
            t.row(cells);
        }
    }
    print!("{}", t.render());
    println!(
        "\nstatic/FleetRec matches DYPE's choice in {static_matchable}/{total_cells} cells (paper: 8/108)"
    );
    println!("distinct optimal schedules across the grid: {}", distinct.len());

    // Shape checks: dynamic scheduling must matter — many distinct optima,
    // and fixed policies can cover only a minority of cells.
    assert_eq!(total_cells, 108);
    assert!(
        distinct.len() >= 4,
        "expected schedule diversity across datasets/interconnects, got {distinct:?}"
    );
    assert!(
        static_matchable * 2 < total_cells,
        "a static policy should not cover most cells ({static_matchable}/{total_cells})"
    );
}
