//! Fig 8 — "Throughput and energy efficiency gain of DYPE over GPU-only
//! on sliding-window-based transformer workloads of window size fixed to
//! 512".
//!
//! Sweep seq_len at w = 512 on PCIe 4.0 (plus the other interconnects for
//! context). Paper shape: gains exist but *shrink* as the sequence grows —
//! rising communication overhead outpaces the benefit of FPGA attention.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::experiments::{measure_plan, Case, Registries, MEASURE_N};
use dype::metrics::Table;
use dype::scheduler::{baselines, DpScheduler};
use dype::workload::transformer;

fn main() {
    println!("=== Fig 8: DYPE gain over GPU-only, transformers w=512 ===\n");
    let regs = Registries::train();
    let seqs = [1024u64, 2048, 4096, 8192, 16384];

    for ic in Interconnect::ALL {
        let sys = SystemSpec::paper_testbed(ic);
        let est = regs.get(ic);
        let mut t = Table::new(&["seq_len", "DYPE thp", "GPU-only thp", "thp gain", "eng gain"]);
        let mut gains = Vec::new();
        for &seq in &seqs {
            let wl = transformer::paper_transformer(seq, 512);
            let case = Case::new(sys.clone(), wl.clone(), 0.0);
            let dype = DpScheduler::new(&sys, est).schedule(&wl, Objective::Performance);
            let gpu = baselines::gpu_only(&sys, est, &wl, Objective::Performance);
            let d = case.measure(&dype.plan(), MEASURE_N);
            let gpu_sys = SystemSpec { n_fpga: 0, ..sys.clone() };
            let g = measure_plan(&gpu_sys, &case.gt, &wl, &gpu.plan(), MEASURE_N);
            let thp_gain = d.0 / g.0;
            let eng_gain = g.1 / d.1;
            gains.push(thp_gain);
            t.row(vec![
                seq.to_string(),
                format!("{:.2}", d.0),
                format!("{:.2}", g.0),
                format!("{:.2}x", thp_gain),
                format!("{:.2}x", eng_gain),
            ]);
        }
        println!("--- {ic} ---");
        print!("{}\n", t.render());

        if ic == Interconnect::Pcie4 {
            let peak = gains.iter().cloned().fold(0.0f64, f64::max);
            let last = *gains.last().unwrap();
            assert!(peak >= 1.0, "DYPE should beat GPU-only somewhere in the sweep");
            // DIVERGENCE NOTE (EXPERIMENTS.md): the paper's Fig 8 shows
            // gains *tapering* with sequence length (their measured comm
            // overhead outgrew the heterogeneity benefit). On this
            // substrate the GPU's dense quadratic attention grows faster
            // than the (linear) transfer volume, so the gain *rises* with
            // seq instead. Both curves agree that gains exist and that
            // the absolute advantage is modest at short sequences.
            println!(
                "shape (PCIe4): gains {:?} — rising with seq on this substrate; paper's Fig 8 tapers (see EXPERIMENTS.md)\n",
                gains.iter().map(|g| format!("{g:.2}x")).collect::<Vec<_>>()
            );
            let _ = (peak, last);
        }
    }
}
