//! Scenario-sweep bench (DESIGN.md §Scenarios): per-cell engine-run cost
//! on a seeded subset of the zoo, one median per scenario×policy cell,
//! recorded to the CI perf trajectory via `DYPE_BENCH_JSON` (see
//! `util::bench::record_json`).
//!
//! The subset is the three canonical scenarios at reduced request
//! counts — big enough to exercise repartitioning, shedding, and
//! preemption, small enough that the whole grid stays a smoke test. The
//! point is the *trajectory*: a regression in admission, lease pricing,
//! or the event heap shows up as a step in every cell at once, while a
//! policy-specific regression (say, preemption bookkeeping) moves only
//! its own column.

use dype::scenario::catalog;
use dype::scenario::sweep::{run_cell, Policy};
use dype::scenario::ScenarioManifest;
use dype::util::bench::{bench, header, record_json};

fn main() {
    let subset: Vec<ScenarioManifest> =
        vec![catalog::multi_stream(1, 2, 9), catalog::skewed_pair(3, 11), catalog::deadline(4, 23)];

    println!("{}", header());
    let mut entries = Vec::new();
    for m in &subset {
        for policy in Policy::ALL {
            let name = format!("scenario_sweep/{}/{}", m.name, policy.name());
            let stats = bench(&name, 1, 5, || {
                std::hint::black_box(run_cell(m, policy).expect("cell runs"));
            });
            println!("{}", stats.report());
            entries.push((name, stats.median));
        }
    }
    record_json(&entries);
}
