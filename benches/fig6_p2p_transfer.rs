//! Fig 6 — "Data transfer speedup with P2P direct data transfer".
//!
//! Regenerates the GPU↔FPGA transfer-size sweep: host-staged vs P2P
//! latency and the speedup curve. Paper shape: large speedups for small
//! transfers (CPU involvement overhead), converging to ~2× around 1 MB.

use dype::devices::{CommModel, DeviceType, Endpoint, Interconnect};
use dype::metrics::Table;

fn main() {
    println!("=== Fig 6: P2P vs CPU-staged GPU->FPGA transfer ===\n");
    let mut c = CommModel::new(Interconnect::Pcie4);

    let sizes: Vec<f64> = [
        1e3, 4e3, 16e3, 64e3, 256e3, 1e6, 4e6, 16e6, 64e6,
    ]
    .to_vec();

    let mut t = Table::new(&["size", "staged(µs)", "p2p(µs)", "speedup"]);
    let mut speedups = Vec::new();
    for &bytes in &sizes {
        c.p2p_enabled = false;
        let staged = c.transfer_time(
            bytes,
            Endpoint::Devices(DeviceType::Gpu, 1),
            Endpoint::Devices(DeviceType::Fpga, 1),
        );
        c.p2p_enabled = true;
        let p2p = c.transfer_time(
            bytes,
            Endpoint::Devices(DeviceType::Gpu, 1),
            Endpoint::Devices(DeviceType::Fpga, 1),
        );
        let speedup = staged / p2p;
        speedups.push((bytes, speedup));
        t.row(vec![
            fmt_size(bytes),
            format!("{:.1}", staged * 1e6),
            format!("{:.1}", p2p * 1e6),
            format!("{:.2}x", speedup),
        ]);
    }
    print!("{}", t.render());

    // Paper-shape assertions.
    let at_1mb = speedups.iter().find(|(b, _)| *b == 1e6).unwrap().1;
    let at_1kb = speedups[0].1;
    assert!(at_1kb > at_1mb, "small transfers must benefit most");
    assert!((1.6..2.6).contains(&at_1mb), "Fig 6: speedup at 1MB should be ~2x, got {at_1mb:.2}");
    println!(
        "\nshape check OK: {:.1}x at 1KB declining to {:.2}x at 1MB (paper: ~2x at 1MB)",
        at_1kb, at_1mb
    );
}

fn fmt_size(b: f64) -> String {
    if b >= 1e6 {
        format!("{:.0}MB", b / 1e6)
    } else {
        format!("{:.0}KB", b / 1e3)
    }
}
