//! Deadline-SLO serving bench (DESIGN.md §Energy & SLOs): what
//! admission-time feasibility shedding and criticality-tied preemption
//! cost and buy on the mixed deadline/best-effort scenario.
//!
//! Two policies over the same four streams:
//!
//!   * `drain-policy`    — the adaptive default (drain-mode migrations);
//!     the interactive lane still sheds infeasible requests and still
//!     preempts via its own per-stream override;
//!   * `preempt-policy`  — [`dype::experiments::deadline_config`]: the
//!     policy-level mode is `Preempt`, so unmarked lanes preempt too
//!     while the `bulk-drain` lane's override keeps it draining.
//!
//! Also times the preemptive serve end to end (dispatch + feasibility
//! check + per-stream mode resolution) and records it to the CI perf
//! trajectory via `DYPE_BENCH_JSON` (see `util::bench::record_json`).

use std::time::Instant;

use dype::config::{Interconnect, SystemSpec};
use dype::coordinator::MultiStreamReport;
use dype::engine::EngineConfig;
use dype::experiments::{deadline_config, deadline_scenario, run_multi_stream_with};
use dype::metrics::{fmt_percent, Table};
use dype::util::bench::{bench, record_json};

fn row(t: &mut Table, mode: &str, r: &MultiStreamReport, wall: f64) {
    let interactive = &r.streams[0].report;
    t.row(vec![
        mode.to_string(),
        format!("{:.2}s", r.makespan),
        format!("{}", r.total_completed),
        format!("{}", r.engine.sheds),
        fmt_percent(interactive.deadline_attainment),
        format!("{}", r.engine.slot_preemptions),
        format!("{}", r.streams[3].report.slot_preemptions),
        format!("{:.1}ms", wall * 1e3),
    ]);
}

fn main() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let streams = deadline_scenario(8, 77);
    let offered: usize = streams.iter().map(|s| s.trace.len()).sum();
    println!(
        "mixed deadline/best-effort scenario: {} requests over {}F+{}G\n",
        offered, sys.n_fpga, sys.n_gpu
    );

    let t0 = Instant::now();
    let drain = run_multi_stream_with(&sys, &streams, EngineConfig::default());
    let drain_wall = t0.elapsed().as_secs_f64();

    let cfg = deadline_config();
    let t1 = Instant::now();
    let preempt = run_multi_stream_with(&sys, &streams, cfg.clone());
    let preempt_wall = t1.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "mode",
        "makespan",
        "done",
        "shed",
        "ddl-attain",
        "preempts",
        "bulk-preempts",
        "wall",
    ]);
    row(&mut t, "drain-policy", &drain, drain_wall);
    row(&mut t, "preempt-policy", &preempt, preempt_wall);
    print!("{}", t.render());

    println!(
        "\npreemptive run: {} sheds, interactive deadline attainment {}, engine: {}",
        preempt.engine.sheds,
        fmt_percent(preempt.streams[0].report.deadline_attainment),
        preempt.engine,
    );

    // Host-side cost of the full deadline-aware dispatch path, for the
    // CI perf trajectory (short-iteration smoke, not a stable benchmark).
    let serve = bench("deadline_slo/deadline_serve", 1, 5, || {
        std::hint::black_box(run_multi_stream_with(&sys, &streams, cfg.clone()));
    });
    println!("\n{}", serve.report());
    let events = preempt.engine.events_processed.max(1) as f64;
    record_json(&[
        ("deadline_slo/deadline_serve".to_string(), serve.median),
        ("deadline_slo/deadline_per_event".to_string(), serve.median / events),
    ]);

    for r in [&drain, &preempt] {
        assert_eq!(
            r.total_completed + r.engine.sheds,
            offered,
            "every request completes or is shed"
        );
        assert!(r.streams[0].report.shed >= 1, "the overloaded deadline class must shed");
        assert_eq!(r.streams[3].report.slot_preemptions, 0, "bulk-drain never cancels a slot");
        for sr in &r.streams[1..] {
            assert_eq!(sr.report.shed, 0, "{}: best-effort lanes never shed", sr.name);
        }
    }
}
