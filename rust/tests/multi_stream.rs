//! Integration tests for multi-stream serving and the schedule cache:
//! coordinator reschedule hysteresis, cache hit/miss behaviour across
//! quantized-feature boundaries, invalidation on `SystemSpec` changes,
//! and starvation-freedom with ≥2 concurrent streams under recurring
//! drift (the ISSUE-1 acceptance scenario).

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::coordinator::{partition_system, Coordinator, MultiStreamServer, StreamSpec};
use dype::coordinator::server::generate_trace;
use dype::devices::GroundTruth;
use dype::engine::EngineConfig;
use dype::experiments::{multi_stream_scenario, run_multi_stream};
use dype::perfmodel::OracleModels;
use dype::scenario::catalog;
use dype::scheduler::{cache::CacheKey, system_fingerprint, ScheduleCache};
use dype::workload::{gnn, Dataset, Workload};

fn sys() -> SystemSpec {
    SystemSpec::paper_testbed(Interconnect::Pcie4)
}

fn traffic(edges: u64) -> Workload {
    gnn::gcn_workload(&Dataset::new("TF", "traffic", 1_000_000, edges, 200, 0.2), 2, 128)
}

// ---- acceptance scenario ----------------------------------------------

#[test]
fn two_streams_with_recurring_drift_hit_cache_and_never_starve() {
    let streams = multi_stream_scenario(2, 5, 7);
    assert!(streams.len() >= 2, "acceptance requires ≥ 2 concurrent streams");
    let report = run_multi_stream(&sys(), &streams);

    // No starvation: every request of every stream completes.
    let offered: usize = streams.iter().map(|s| s.trace.len()).sum();
    assert_eq!(report.total_completed, offered);
    for (sr, spec) in report.streams.iter().zip(&streams) {
        assert_eq!(sr.report.completed, spec.trace.len(), "{} starved", sr.name);
        // Per-stream latency percentiles are present and ordered.
        assert!(sr.report.p50_latency > 0.0);
        assert!(sr.report.p50_latency <= sr.report.p90_latency);
        assert!(sr.report.p90_latency <= sr.report.p99_latency);
        assert!(sr.report.p99_latency.is_finite());
    }

    // Recurring drift is served from the cache: hit rate > 50%.
    assert!(
        report.cache.hit_rate() > 0.5,
        "hit rate {:.2} on repeated workload characteristics",
        report.cache.hit_rate()
    );
    assert!(report.fairness > 0.4, "fairness index {:.3}", report.fairness);
}

#[test]
fn every_stream_gets_devices_and_the_pool_is_conserved() {
    let s = sys();
    let streams = multi_stream_scenario(1, 3, 21);
    let demands: Vec<f64> = streams.iter().map(StreamSpec::demand).collect();
    let parts = partition_system(&s, &demands).expect("2 streams on 5 devices");
    assert_eq!(parts.iter().map(|p| p.n_fpga).sum::<usize>(), s.n_fpga);
    assert_eq!(parts.iter().map(|p| p.n_gpu).sum::<usize>(), s.n_gpu);
    for p in &parts {
        assert!(p.n_fpga + p.n_gpu >= 1);
    }
}

// ---- schedule-cache persistence (warm restart) -------------------------

#[test]
fn persisted_cache_warm_starts_a_restarted_server() {
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let oracle = OracleModels { gt: &gt };
    let streams = multi_stream_scenario(2, 4, 33);
    let path = std::env::temp_dir().join(format!("dype_warm_{}.json", std::process::id()));

    // First server lifetime: cold start pays the DP storm, then persists.
    let cold_cache = ScheduleCache::shared(64);
    let mut server = MultiStreamServer::with_cache(s.clone(), &oracle, cold_cache.clone());
    let cold = server.serve(&streams);
    assert!(cold.cache.misses >= 1, "cold start must run the DP at least once");
    cold_cache.lock().unwrap().save_to(&path).unwrap();

    // "Restart": a fresh server, fresh coordinators, loaded cache.
    let loaded = ScheduleCache::load_from(&path, 64).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.len(), cold_cache.lock().unwrap().len());
    let warm_cache = std::sync::Arc::new(std::sync::Mutex::new(loaded));
    let mut restarted = MultiStreamServer::with_cache(s, &oracle, warm_cache);
    let warm = restarted.serve(&streams);

    assert_eq!(warm.total_completed, cold.total_completed);
    assert_eq!(warm.cache.misses, 0, "restart skips the cold-start DP storm");
    assert!(warm.cache.hits > 0);
}

// ---- reschedule hysteresis --------------------------------------------

#[test]
fn hysteresis_bounds_reschedules_under_oscillating_drift() {
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let oracle = OracleModels { gt: &gt };
    let night = traffic(2_000_000);
    let rush = traffic(150_000_000);

    // An infinite threshold never swaps after the first schedule…
    let mut frozen = Coordinator::new(s.clone(), &oracle, Objective::Performance);
    frozen.reschedule_threshold = f64::INFINITY;
    // …a zero threshold chases every profitable drift.
    let mut eager = Coordinator::new(s.clone(), &oracle, Objective::Performance);
    eager.reschedule_threshold = 0.0;
    // The default threshold sits between the two.
    let mut default = Coordinator::new(s, &oracle, Objective::Performance);

    for _ in 0..5 {
        for wl in [&night, &rush] {
            frozen.process_batch(wl);
            eager.process_batch(wl);
            default.process_batch(wl);
        }
    }
    assert_eq!(frozen.reschedule_events().len(), 0);
    assert!(
        eager.reschedule_events().len() >= default.reschedule_events().len(),
        "eager {} < default {}",
        eager.reschedule_events().len(),
        default.reschedule_events().len()
    );
    for e in default.reschedule_events() {
        assert!(e.estimated_gain > 0.05, "swap below hysteresis: {}", e.estimated_gain);
    }
}

#[test]
fn cached_coordinator_applies_the_same_hysteresis() {
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let oracle = OracleModels { gt: &gt };
    let cache = ScheduleCache::shared(16);
    let mut plain = Coordinator::new(s.clone(), &oracle, Objective::Performance);
    let mut cached = Coordinator::new(s, &oracle, Objective::Performance).with_cache(cache);
    for _ in 0..4 {
        for edges in [2_000_000u64, 150_000_000] {
            let wl = traffic(edges);
            plain.process_batch(&wl);
            cached.process_batch(&wl);
        }
    }
    assert_eq!(
        plain.reschedule_events().len(),
        cached.reschedule_events().len(),
        "memoization must not change the reschedule policy"
    );
}

// ---- schedule cache ----------------------------------------------------

#[test]
fn cache_hits_inside_bucket_misses_across_boundary() {
    let s = sys();
    let fp = system_fingerprint(&s);
    let mut cache = ScheduleCache::new(8);
    let base = traffic(2_000_000);
    let drift = traffic(2_080_000); // +4%: same octave/density buckets
    let surge = traffic(150_000_000); // 75×: crosses bucket boundaries

    let k = CacheKey::new(fp, &base, Objective::Performance);
    assert!(cache.lookup(&k).is_none());
    cache.insert(
        k,
        vec![dype::scheduler::StagePlan {
            first: 0,
            last: base.len() - 1,
            dev: dype::devices::DeviceType::Gpu,
            n: 1,
        }],
    );
    assert!(cache.lookup(&CacheKey::new(fp, &drift, Objective::Performance)).is_some());
    assert!(cache.lookup(&CacheKey::new(fp, &surge, Objective::Performance)).is_none());
}

#[test]
fn cache_invalidated_when_system_spec_changes() {
    let a = sys();
    let mut shrunk = sys();
    shrunk.n_fpga = 1;
    let mut retuned = sys();
    retuned.fpga.spmm_freq *= 1.5;

    let gt = GroundTruth::new(a.gpu.clone(), a.fpga.clone(), a.comm_model());
    let oracle = OracleModels { gt: &gt };
    let cache = ScheduleCache::shared(16);
    let wl = traffic(2_000_000);

    let mut c1 = Coordinator::new(a, &oracle, Objective::Performance).with_cache(cache.clone());
    c1.process_batch(&wl); // miss + insert
    c1.process_batch(&wl); // hit
    assert_eq!(c1.cache_stats().unwrap().hits, 1);

    // A coordinator over a *different* system sharing the same cache must
    // not reuse the stale plan: its fingerprint scopes the key space.
    for other in [shrunk, retuned] {
        let g = GroundTruth::new(other.gpu.clone(), other.fpga.clone(), other.comm_model());
        let o = OracleModels { gt: &g };
        let before = cache.lock().unwrap().stats().misses;
        let mut c2 = Coordinator::new(other, &o, Objective::Performance).with_cache(cache.clone());
        c2.process_batch(&wl);
        assert_eq!(cache.lock().unwrap().stats().misses, before + 1);
    }
}

#[test]
fn single_and_multi_stream_servers_agree_on_cache_semantics() {
    // A lone stream served through the multi-stream front-end behaves like
    // the single-stream Server: same completions, same miss count.
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let oracle = OracleModels { gt: &gt };
    let phases = vec![(traffic(2_000_000), 8), (traffic(150_000_000), 8), (traffic(2_000_000), 8)];
    let trace = generate_trace(&phases, 20.0, 3);

    let mut single = dype::coordinator::Server::new(s.clone(), &oracle, Objective::Performance)
        .with_cache(ScheduleCache::shared(8));
    let sr = single.serve(&trace);

    let streams = vec![StreamSpec::new("solo", Objective::Performance, trace)];
    let mut multi = MultiStreamServer::new(s, &oracle);
    let mr = multi.serve(&streams);

    assert_eq!(sr.completed, mr.total_completed);
    assert_eq!(sr.cache.misses, mr.cache.misses);
    assert!(sr.cache.hit_rate() > 0.5 && mr.cache.hit_rate() > 0.5);
}

// ---- registry prewarm (single-engine path) -----------------------------

/// The single-engine twin of the fleet guarantee in `tests/fleet.rs`:
/// a registry-prewarmed [`MultiStreamServer`] never cold-misses under
/// static leases — seeding plans for every (lease, workload) pair in
/// the streams' registry before the clock starts bounds the first-window
/// miss count at zero.
#[test]
fn registry_prewarm_eliminates_cold_misses_under_static_leases() {
    let built = catalog::fleet_balanced().build().expect("manifest builds");
    let s = built.system.clone();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let oracle = OracleModels { gt: &gt };
    let mut server = MultiStreamServer::new(s, &oracle)
        .with_engine_config(EngineConfig::builder().static_leases().build())
        .with_registry_prewarm();
    let seeded = server.registry_prewarm(&built.streams);
    assert!(seeded >= 1, "the registry prewarm seeded nothing");
    let report = server.serve(&built.streams);
    let offered: usize = built.streams.iter().map(|st| st.trace.len()).sum();
    assert_eq!(report.total_completed + report.engine.sheds, offered);
    assert_eq!(report.cache.misses, 0, "cold miss despite the registry prewarm");
    assert!(report.cache.hits > 0, "the seeded plans were never hit");
}
