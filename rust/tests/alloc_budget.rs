//! Allocation budget for the engine's hot path, measured with the
//! `telemetry-alloc` counting allocator (this test only builds when the
//! feature is on — see `required-features` in Cargo.toml).
//!
//! The zero-allocation rewrite's contract is *differential*: growing
//! the offered load must not grow the allocation count with it, because
//! steady-state pops, admissions, and completions all run on slab and
//! scratch storage. Per-run constants (lane setup, first-touch Vec
//! growth, cold schedule-cache misses) are allowed — they are identical
//! across run sizes and cancel in the subtraction.
//!
//! Run single-threaded (`--test-threads=1`, as CI does): the counter is
//! process-global, so a concurrent test's allocations would leak into
//! the sampled window.

use dype::prelude::*;

/// Serve `n` requests per stream under the adaptive default on the
/// given queue; return the engine-loop allocation count and the events
/// processed (both sampled by the engine itself, so report assembly
/// outside the loop does not pollute the window).
fn engine_allocs(n: usize, queue: QueueKind) -> (u64, u64) {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let wl = gnn::gcn_workload(&Dataset::synthetic2(), 2, 128);
    let streams = vec![
        StreamSpec::new("a", Objective::Performance, generate_trace(&[(wl.clone(), n)], 25.0, 3)),
        StreamSpec::new("b", Objective::Performance, generate_trace(&[(wl, n)], 25.0, 4)),
    ];
    let cfg = EngineConfig::builder().event_queue(queue).build();
    let report = ServingEngine::new(sys, &est).with_config(cfg).serve(&streams);
    assert_eq!(report.total_completed, 2 * n, "no deadline lanes, so every request completes");
    (report.engine.telemetry.allocations, report.engine.events_processed)
}

#[test]
fn counting_allocator_is_live() {
    let before = dype::telemetry::alloc::allocations();
    // black_box keeps the optimizer from eliding the heap allocation.
    let v = std::hint::black_box(vec![0u64; 1024]);
    assert!(dype::telemetry::alloc::allocations() > before, "telemetry-alloc hook not installed");
    drop(v);
}

/// Tripling the offered load must cost (almost) no extra allocations
/// per extra event, on both queue implementations. The 0.5 ceiling is
/// deliberately loose against amortized growth (completion logs double,
/// calendar buckets resize) while still an order of magnitude below the
/// several-allocations-per-event behavior of the pre-slab engine.
#[test]
fn steady_state_allocations_per_event_stay_near_zero() {
    for queue in [QueueKind::Calendar, QueueKind::Heap] {
        let (small_allocs, small_events) = engine_allocs(150, queue);
        let (big_allocs, big_events) = engine_allocs(450, queue);
        assert!(big_events > small_events, "{queue:?}: larger run must pop more events");
        let extra_allocs = big_allocs.saturating_sub(small_allocs);
        let extra_events = big_events - small_events;
        let per_event = extra_allocs as f64 / extra_events as f64;
        assert!(
            per_event < 0.5,
            "{queue:?}: {extra_allocs} extra allocations over {extra_events} extra events \
             ({per_event:.3}/event) — the hot path is allocating again"
        );
    }
}
