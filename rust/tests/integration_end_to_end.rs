//! Cross-module integration: calibration → scheduling → measurement, the
//! coordinator's dynamic loop, and the paper's qualitative claims
//! end-to-end on the simulated testbed.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::coordinator::Coordinator;
use dype::devices::{DeviceType, GroundTruth};
use dype::experiments::{measure_plan, reference_workload, run_case, Case, Registries};
use dype::perfmodel::{calibrate, OracleModels};
use dype::scheduler::{baselines, DpScheduler};
use dype::workload::{gnn, transformer, Dataset};

#[test]
fn calibrated_scheduler_close_to_oracle_scheduler() {
    // The whole point of §V: schedules from estimates should rarely lose
    // much against schedules from measurements.
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let reg = calibrate::calibrated_registry(&sys);
    for ds in Dataset::table1() {
        let wl = gnn::gcn_workload(&ds, 2, 128);
        let case = Case::new(sys.clone(), wl.clone(), ds.degree_skew);
        let oracle = OracleModels { gt: &case.gt };
        let from_est = DpScheduler::new(&sys, &reg).schedule(&wl, Objective::Performance);
        let from_gt = DpScheduler::new(&sys, &oracle).schedule(&wl, Objective::Performance);
        let (thp_e, _) = case.measure(&from_est.plan(), 100);
        let (thp_g, _) = case.measure(&from_gt.plan(), 100);
        assert!(
            thp_e >= thp_g * 0.75,
            "{}: estimate-driven schedule loses {:.0}%",
            ds.code,
            (1.0 - thp_e / thp_g) * 100.0
        );
    }
}

#[test]
fn heterogeneity_beats_homogeneous_on_mixed_workloads() {
    // §VI-C1 "one plus one equals more than two" — at least: DYPE ≥
    // max(GPU-only, FPGA-only) on ground truth for the OGB datasets.
    let regs = Registries::train();
    for ic in Interconnect::ALL {
        let sys = SystemSpec::paper_testbed(ic);
        let est = regs.get(ic);
        for ds in [Dataset::ogbn_arxiv(), Dataset::ogbn_products()] {
            let wl = gnn::gin_workload(&ds, 2, 128, 2);
            let case = Case::new(sys.clone(), wl.clone(), ds.degree_skew);
            let r = run_case(&case, est, &reference_workload(&wl));
            let best_homog = r.gpu_only.0.max(r.fpga_only.0);
            assert!(
                r.dype_perf.0 >= best_homog * 0.9,
                "{}: DYPE {:.2} vs best homogeneous {:.2}",
                case.label,
                r.dype_perf.0,
                best_homog
            );
        }
    }
}

#[test]
fn sparsity_shifts_move_schedules_toward_fpgas() {
    // §VI-C2: as dataset sparsity increases, optimal schedules include
    // FPGAs more (GIN-S1 → GIN-S4 trend).
    let sys = SystemSpec::paper_testbed(Interconnect::Cxl3);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };
    let fpga_share = |ds: &Dataset| {
        let wl = gnn::gin_workload(ds, 2, 128, 2);
        let s = DpScheduler::new(&sys, &oracle).schedule(&wl, Objective::Energy);
        s.fpgas_used()
    };
    let dense = fpga_share(&Dataset::synthetic1());
    let sparse = fpga_share(&Dataset::ogbn_arxiv());
    assert!(
        sparse >= dense,
        "sparser dataset should use at least as many FPGAs ({sparse} vs {dense})"
    );
}

#[test]
fn transformer_long_sequences_favor_fpga_attention() {
    // Fig 8's driver: at seq=16384 the FPGA (linear) must beat the GPU's
    // dense quadratic attention per §V models — so DYPE's perf schedule
    // should involve FPGAs at long sequences on a fast interconnect.
    let sys = SystemSpec::paper_testbed(Interconnect::Cxl3);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let t_fpga = gt.ideal_kernel_time(
        &dype::workload::KernelKind::WindowAttn { seq: 16384, window: 512, heads: 8, dim: 64 },
        DeviceType::Fpga,
    );
    let t_gpu = gt.ideal_kernel_time(
        &dype::workload::KernelKind::WindowAttn { seq: 16384, window: 512, heads: 8, dim: 64 },
        DeviceType::Gpu,
    );
    assert!(t_fpga < t_gpu, "SWAT must win at long seq: {t_fpga} vs {t_gpu}");
}

#[test]
fn coordinator_tracks_daily_drift_and_never_loses_to_static() {
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let reg = calibrate::calibrated_registry(&sys);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };
    let mut coord = Coordinator::new(sys.clone(), &reg, Objective::Performance);
    let mut first_plan = None;
    let mut dyn_time = 0.0;
    let mut stat_time = 0.0;
    for edges in [4_000_000u64, 120_000_000, 15_000_000, 60_000_000] {
        let ds = Dataset::new("TF", "traffic", 230_000, edges, 600, 0.2);
        let wl = gnn::gcn_workload(&ds, 2, 128);
        let sched = coord.process_batch(&wl).clone();
        if first_plan.is_none() {
            first_plan = Some(sched.plan());
        }
        let (thp_dyn, _) = measure_plan(&sys, &gt, &wl, &sched.plan(), 50);
        let (thp_stat, _) = measure_plan(&sys, &gt, &wl, first_plan.as_ref().unwrap(), 50);
        dyn_time += 1.0 / thp_dyn;
        stat_time += 1.0 / thp_stat;
    }
    assert!(dyn_time <= stat_time * 1.001, "dynamic {dyn_time} vs static {stat_time}");
}

#[test]
fn fleetrec_between_static_and_dype() {
    // The §VI hierarchy: static ≤ FleetRec* ≤ DYPE (throughput, estimated
    // on the same estimator that tuned all three).
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };
    for ds in Dataset::table1() {
        let wl = gnn::gin_workload(&ds, 2, 128, 2);
        let reference = gnn::gin_workload(&Dataset::ogbn_arxiv(), 2, 128, 2);
        let static_plan =
            baselines::tune_static_plan(&sys, &oracle, &reference, Objective::Performance);
        let statik = baselines::apply_static_plan(&sys, &oracle, &wl, &static_plan);
        let fleet = baselines::fleetrec(&sys, &oracle, &wl, Objective::Performance).unwrap();
        let dype = DpScheduler::new(&sys, &oracle).schedule(&wl, Objective::Performance);
        assert!(fleet.throughput() >= statik.throughput() * (1.0 - 1e-9), "{}", ds.code);
        assert!(dype.throughput() >= fleet.throughput() * (1.0 - 1e-9), "{}", ds.code);
    }
}

#[test]
fn transformer_scheduling_scales_to_paper_depth() {
    // The 32-layer model (160 kernels) must schedule quickly and validly.
    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };
    let wl = transformer::paper_transformer(4096, 512);
    let t0 = std::time::Instant::now();
    let s = DpScheduler::new(&sys, &oracle).schedule(&wl, Objective::Performance);
    let dt = t0.elapsed();
    s.validate(wl.len(), sys.n_fpga, sys.n_gpu).unwrap();
    assert!(dt.as_secs_f64() < 30.0, "DP too slow for serving-path rescheduling: {dt:?}");
}
