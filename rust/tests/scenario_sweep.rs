//! Integration tests for the scenario zoo and the declarative sweep
//! runner (DESIGN.md §Scenarios) — the repo's regression net for the
//! paper's "optimal in 77 of 86 cases" headline:
//!
//! * the checked-in `scenarios/*.json` files mirror the catalog builders
//!   tree-for-tree (a manifest edit without a catalog edit, or vice
//!   versa, fails here);
//! * the four canonical scenarios round-trip through the JSON manifest
//!   format **bit-identically** — same request ids, arrival bit
//!   patterns, workloads, SLOs as the historical
//!   `experiments::*_scenario` entry points;
//! * a seeded-subset sweep grid keeps the adaptive default at or above
//!   the static-lease baseline (the regression the zoo exists to catch);
//! * the flash-crowd stressor stays queue-bounded via early shedding,
//!   and the shed-aware demand bid keeps an overloaded deadline lane's
//!   pool share alive.

use std::path::PathBuf;

use dype::engine::MigrationMode;
use dype::experiments::{
    self, deadline_scenario, energy_slo_scenario, multi_stream_scenario, skewed_pair_scenario,
};
use dype::scenario::sweep::{run_grid, Policy};
use dype::scenario::{catalog, ScenarioManifest};
use dype::util::json;

fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("scenarios")
}

// ---- the checked-in zoo ------------------------------------------------

#[test]
fn checked_in_manifests_mirror_the_catalog_tree_for_tree() {
    for m in catalog::all() {
        let path = scenarios_dir().join(m.file_name());
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{} is missing its checked-in twin: {e}", m.name));
        let file_tree = json::parse(&text).unwrap_or_else(|e| panic!("{}: {e}", m.name));
        assert_eq!(
            file_tree,
            m.to_json(),
            "{} drifted from catalog::{}; regenerate it from to_pretty_string():\n{}",
            path.display(),
            m.name.replace('-', "_"),
            m.to_pretty_string()
        );
        let parsed = ScenarioManifest::parse_str(&text).unwrap_or_else(|e| panic!("{e:#}"));
        assert_eq!(parsed, m, "{} parses to a different manifest", path.display());
    }
}

#[test]
fn no_orphan_files_in_the_scenarios_directory() {
    let expected: Vec<String> = catalog::all().iter().map(|m| m.file_name()).collect();
    for entry in std::fs::read_dir(scenarios_dir()).expect("scenarios/ exists") {
        let entry = entry.unwrap();
        if entry.file_type().unwrap().is_dir() {
            // `scenarios/lint/` holds deliberately-infeasible negative
            // fixtures for `dype lint`; they have no catalog builders by
            // design and are excluded from the tree-compare above.
            continue;
        }
        let name = entry.file_name().to_string_lossy().into_owned();
        assert!(
            expected.contains(&name),
            "scenarios/{name} has no catalog builder — add it to catalog::all()"
        );
    }
}

#[test]
fn every_checked_in_manifest_loads_and_builds() {
    for m in catalog::all() {
        let loaded = ScenarioManifest::load(scenarios_dir().join(m.file_name()))
            .unwrap_or_else(|e| panic!("{e:#}"));
        let built = loaded.build().unwrap_or_else(|e| panic!("{}: {e:#}", m.name));
        assert!(!built.streams.is_empty(), "{} built no streams", m.name);
    }
}

// ---- bit-identical manifest round-trip of the canonical scenarios ------

fn assert_streams_identical(
    label: &str,
    via_manifest: &[dype::coordinator::StreamSpec],
    legacy: &[dype::coordinator::StreamSpec],
) {
    assert_eq!(via_manifest.len(), legacy.len(), "{label}: stream count");
    for (a, b) in via_manifest.iter().zip(legacy) {
        assert_eq!(a.name, b.name, "{label}");
        assert_eq!(a.objective, b.objective, "{label}/{}", a.name);
        assert_eq!(a.slo, b.slo, "{label}/{}", a.name);
        assert_eq!(a.trace.len(), b.trace.len(), "{label}/{}", a.name);
        for (x, y) in a.trace.iter().zip(&b.trace) {
            assert_eq!(x.id, y.id, "{label}/{}", a.name);
            assert_eq!(
                x.arrival.to_bits(),
                y.arrival.to_bits(),
                "{label}/{} diverges at id {} ({} vs {})",
                a.name,
                x.id,
                x.arrival,
                y.arrival
            );
            assert_eq!(x.workload.name, y.workload.name, "{label}/{} id {}", a.name, x.id);
            assert_eq!(x.workload.kernels, y.workload.kernels, "{label}/{} id {}", a.name, x.id);
        }
    }
}

/// Serialize → parse → build must reproduce the historical builders bit
/// for bit: ids, arrival bit patterns, workload kernel chains, SLOs.
#[test]
fn canonical_scenarios_round_trip_bit_identically() {
    let cases: Vec<(&str, ScenarioManifest, Vec<dype::coordinator::StreamSpec>)> = vec![
        ("multi-stream", catalog::multi_stream(2, 4, 9), multi_stream_scenario(2, 4, 9)),
        ("skewed-pair", catalog::skewed_pair(5, 11), skewed_pair_scenario(5, 11)),
        ("energy-slo", catalog::energy_slo(4, 17), energy_slo_scenario(4, 17)),
        ("deadline", catalog::deadline(8, 23), deadline_scenario(8, 23)),
    ];
    for (label, manifest, legacy) in cases {
        let reparsed = ScenarioManifest::parse_str(&manifest.to_pretty_string())
            .unwrap_or_else(|e| panic!("{label}: {e:#}"));
        assert_eq!(reparsed, manifest, "{label} drifts through serialization");
        let built = reparsed.build().unwrap_or_else(|e| panic!("{label}: {e:#}"));
        assert_streams_identical(label, &built.streams, &legacy);
    }
}

#[test]
fn deadline_manifest_carries_the_migration_overrides() {
    let built = catalog::deadline(8, 23).build().unwrap();
    assert_eq!(
        built.streams[0].slo.migration,
        Some(MigrationMode::Preempt { min_remaining: 0.005 })
    );
    assert_eq!(built.streams[3].slo.migration, Some(MigrationMode::Drain));
}

// ---- the seeded-subset sweep grid --------------------------------------

/// The regression net proper: on a small seeded subset of the zoo, the
/// best adaptive policy must stay at (or within a whisker of) the
/// static-lease baseline in every scenario, and ahead in most — the
/// CI-sized analogue of the paper's 77-of-86 scoreboard.
#[test]
fn adaptive_wins_or_ties_static_on_the_seeded_subset() {
    let subset =
        vec![catalog::multi_stream(1, 2, 9), catalog::skewed_pair(3, 11), catalog::deadline(4, 23)];
    let report = run_grid(&subset, &Policy::ALL).expect("grid runs");
    assert_eq!(report.cells.len(), subset.len() * Policy::ALL.len());

    for c in &report.cells {
        let label = format!("{}/{}", c.scenario, c.policy.name());
        assert!(c.conserved(), "{label}: {} + {} != {}", c.completed, c.sheds, c.offered);
        assert!(c.score().is_finite(), "{label}: non-finite score");
    }

    for sc in report.scenarios() {
        let adaptive = report.best_adaptive_score(sc);
        let baseline = report.best_static_score(sc);
        assert!(
            adaptive >= 0.85 * baseline,
            "{sc}: best adaptive score {adaptive:.3} collapsed below static {baseline:.3}"
        );
    }
    let (wins, n) = report.adaptive_scoreboard();
    assert_eq!(n, 3);
    assert!(wins >= 2, "adaptive wins or ties only {wins} of {n} seeded scenarios");

    let rendered = report.render();
    assert!(rendered.contains("win"), "report must mark winners:\n{rendered}");
    assert!(rendered.contains(&format!("{wins} of {n} scenarios")), "{rendered}");
}

// ---- stressor regressions (satellites 1 + 2) ---------------------------

/// Early shedding at admission must keep the flash-crowd queue bounded:
/// arrivals that cannot make their deadline from deep queue positions
/// are refused on arrival instead of rotting in the queue.
#[test]
fn flash_crowd_stays_queue_bounded_via_early_shedding() {
    let built = catalog::flash_crowd().build().unwrap();
    let cfg = built.apply(Policy::Deadline.engine_config());
    let report = experiments::run_multi_stream_with(&built.system, &built.streams, cfg);
    let lane = &report.streams[0];
    assert_eq!(lane.name, "deadline-interactive");
    assert!(lane.report.shed >= 1, "a 200/s burst into a 250 ms deadline lane must shed");
    assert!(
        lane.report.max_queue_depth <= 30,
        "queue depth {} — early shedding failed to bound the burst",
        lane.report.max_queue_depth
    );
}

/// Shed-aware demand bidding: the overloaded deadline lane sheds, but
/// its shed FLOPs still count toward its demand EWMA, so its pool share
/// must not decay to nothing.
#[test]
fn shed_aware_bidding_keeps_the_overloaded_lane_funded() {
    let built = catalog::deadline(8, 23).build().unwrap();
    let cfg = built.apply(Policy::Deadline.engine_config());
    let report = experiments::run_multi_stream_with(&built.system, &built.streams, cfg);
    let total_shed: usize = report.streams.iter().map(|s| s.report.shed).sum();
    assert!(total_shed >= 1, "the overloaded deadline scenario must shed");
    let share = report.engine.final_pool_share[0];
    assert!(
        share > 0.05,
        "deadline-interactive ends with pool share {share:.3}; shed demand fell out of its bid"
    );
}
