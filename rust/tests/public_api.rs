//! Golden check on the crate's curated public surface.
//!
//! [`dype::prelude`] is the stable API: examples, benches, and
//! downstream users import it wholesale, so its contents are a contract
//! — growing or shrinking it is an API decision, not a side effect of a
//! refactor. Two halves enforce that:
//!
//! * the explicit import below is the *compile-time* half — a removed
//!   or renamed re-export fails to resolve;
//! * [`prelude_matches_the_golden_surface`] is the *textual* half — it
//!   parses the prelude block out of `lib.rs` and diffs the re-exported
//!   names against [`GOLDEN_PRELUDE`], so silent additions fail too.
//!
//! To change the surface deliberately: edit the prelude, update the
//! golden list here, and note the change in DESIGN.md.

// The compile-time half: every golden name must resolve through the
// prelude. Kept exhaustive on purpose — the smoke test below only
// exercises a handful of them.
#[allow(unused_imports)]
use dype::prelude::{
    baselines, calibrate, generate_trace, gnn, lint_engine_config, lint_fleet, lint_manifest,
    transformer, Arrival, CacheStats, Coordinator, Dataset, DeviceType, Diagnostic, DpScheduler,
    EnergyBudget, EngineConfig, EngineConfigBuilder, FleetConfig, FleetMigration, FleetReport,
    GroundTruth, Interconnect, KernelDesc, KernelKind, LintReport, MigrationMode, ModelRegistry,
    MultiStreamReport, MultiStreamServer, Objective, OracleModels, PipelineSim, Policy, QueueKind,
    Recorder, RepartitionPolicy, ScenarioManifest, Schedule, ScheduleCache, ServeReport, Server,
    ServingEngine, ServingFleet, Severity, ShardReport, SloController, Snapshot, Stage, StreamSlo,
    StreamSpec, SweepReport, SystemSpec, TraceRecorder, Workload,
};

/// Every name `dype::prelude` re-exports. Order here is cosmetic (the
/// test sorts both sides); completeness is what is golden.
const GOLDEN_PRELUDE: &[&str] = &[
    "Arrival",
    "CacheStats",
    "Coordinator",
    "Dataset",
    "DeviceType",
    "Diagnostic",
    "DpScheduler",
    "EnergyBudget",
    "EngineConfig",
    "EngineConfigBuilder",
    "FleetConfig",
    "FleetMigration",
    "FleetReport",
    "GroundTruth",
    "Interconnect",
    "KernelDesc",
    "KernelKind",
    "LintReport",
    "MigrationMode",
    "ModelRegistry",
    "MultiStreamReport",
    "MultiStreamServer",
    "Objective",
    "OracleModels",
    "PipelineSim",
    "Policy",
    "QueueKind",
    "Recorder",
    "RepartitionPolicy",
    "ScenarioManifest",
    "Schedule",
    "ScheduleCache",
    "ServeReport",
    "Server",
    "ServingEngine",
    "ServingFleet",
    "Severity",
    "ShardReport",
    "SloController",
    "Snapshot",
    "Stage",
    "StreamSlo",
    "StreamSpec",
    "SweepReport",
    "SystemSpec",
    "TraceRecorder",
    "Workload",
    "baselines",
    "calibrate",
    "generate_trace",
    "gnn",
    "lint_engine_config",
    "lint_fleet",
    "lint_manifest",
    "transformer",
];

/// Pull the re-exported names out of the `pub mod prelude { ... }`
/// block in `lib.rs`: each `pub use` statement contributes either its
/// brace-list members or its final path segment.
fn prelude_names() -> Vec<String> {
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/src/lib.rs");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let start = text.find("pub mod prelude {").expect("lib.rs declares pub mod prelude");
    let mut names = Vec::new();
    for stmt in text[start..].split("pub use ").skip(1) {
        let stmt = stmt.split(';').next().expect("use statement is terminated");
        match stmt.find('{') {
            Some(open) => {
                let close = stmt.rfind('}').expect("use list is closed");
                for n in stmt[open + 1..close].split(',') {
                    let n = n.trim();
                    if !n.is_empty() {
                        names.push(n.to_string());
                    }
                }
            }
            None => names.push(stmt.trim().rsplit("::").next().expect("path").to_string()),
        }
    }
    names
}

#[test]
fn prelude_matches_the_golden_surface() {
    let mut actual = prelude_names();
    actual.sort();
    let mut golden = GOLDEN_PRELUDE.to_vec();
    golden.sort_unstable();
    assert_eq!(
        actual,
        golden,
        "prelude re-exports drifted from the golden list; \
         update GOLDEN_PRELUDE (and DESIGN.md) if the change is deliberate"
    );
}

#[test]
fn golden_list_is_duplicate_free() {
    let mut sorted = GOLDEN_PRELUDE.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), GOLDEN_PRELUDE.len(), "duplicate entries in GOLDEN_PRELUDE");
}

/// The prelude alone is enough to drive the serving stack end to end —
/// the import ergonomics the curation exists to protect.
#[test]
fn prelude_smoke_drives_the_serving_stack() {
    let sys = SystemSpec::reduced_testbed(Interconnect::Pcie4);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let wl = gnn::gcn_workload(&Dataset::synthetic2(), 2, 128);
    let trace = generate_trace(&[(wl, 3)], 6.0, 5);
    let streams = vec![StreamSpec::new("s0", Objective::Performance, trace)];
    let cfg = EngineConfig::builder().event_queue(QueueKind::Heap).build();
    let report = ServingEngine::new(sys, &est).with_config(cfg).serve(&streams);
    assert_eq!(report.total_completed, 3);
}
