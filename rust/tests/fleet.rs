//! Fleet-layer guarantees, pinned differentially against the engine.
//!
//! * A **single-shard fleet is the bare engine, bit for bit**: same
//!   per-request completion times, same metrics, same cache counters,
//!   same telemetry timeline. The fleet layer may only ever add
//!   horizontal structure — shard 0 of a 1-shard fleet must be
//!   indistinguishable from calling [`ServingEngine::serve`] directly.
//! * A **multi-shard fleet conserves requests across migrations**:
//!   every offered request completes or sheds exactly once, and every
//!   stream's final report lives on exactly one shard.
//! * A **registry-prewarmed shard never cold-misses** under static
//!   leases: seeding from expected regimes at spin-up bounds the
//!   first-window miss count at zero.

use dype::coordinator::{MultiStreamReport, ServeReport};
use dype::devices::GroundTruth;
use dype::engine::{EngineConfig, ServingEngine};
use dype::fleet::{FleetConfig, ServingFleet};
use dype::perfmodel::OracleModels;
use dype::scenario::{catalog, ScenarioManifest};
use dype::scheduler::ScheduleCache;
use dype::telemetry::Recorder;

fn assert_serve_reports_equal(a: &ServeReport, b: &ServeReport) {
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.deferrals, b.deferrals);
    assert_eq!(a.slot_preemptions, b.slot_preemptions);
    assert_eq!(a.reschedules, b.reschedules);
    assert_eq!(a.max_queue_depth, b.max_queue_depth);
    assert_eq!(a.completions.len(), b.completions.len());
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
        assert_eq!(x.start.to_bits(), y.start.to_bits());
        assert_eq!(x.finish.to_bits(), y.finish.to_bits());
    }
    for (x, y) in [
        (a.makespan, b.makespan),
        (a.throughput, b.throughput),
        (a.mean_latency, b.mean_latency),
        (a.p50_latency, b.p50_latency),
        (a.p90_latency, b.p90_latency),
        (a.p99_latency, b.p99_latency),
        (a.reschedule_downtime, b.reschedule_downtime),
        (a.energy, b.energy),
        (a.slo_attainment, b.slo_attainment),
        (a.deadline_attainment, b.deadline_attainment),
    ] {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

fn assert_multi_reports_equal(a: &MultiStreamReport, b: &MultiStreamReport) {
    assert_eq!(a.streams.len(), b.streams.len());
    for (x, y) in a.streams.iter().zip(&b.streams) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.partition, y.partition);
        assert_serve_reports_equal(&x.report, &y.report);
    }
    assert_eq!(a.cache, b.cache);
    assert_eq!(a.total_completed, b.total_completed);
    assert_eq!(a.engine, b.engine);
    for (x, y) in [
        (a.makespan, b.makespan),
        (a.aggregate_throughput, b.aggregate_throughput),
        (a.fairness, b.fairness),
        (a.total_energy, b.total_energy),
        (a.throughput_per_joule, b.throughput_per_joule),
    ] {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// Run `m` once through a bare engine and once through a 1-shard fleet
/// under the same engine template, and demand bit-identity on reports,
/// cache counters, and the telemetry timeline.
fn differential(m: &ScenarioManifest, base: EngineConfig) {
    let built = m.build().expect("manifest builds");
    let sys = built.system.clone();
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };

    let rec = Recorder::timeline();
    let mut bare_cfg = built.apply(base.clone());
    bare_cfg.recorder = Some(rec.clone());
    let bare_cache = ScheduleCache::shared(64);
    let mut engine =
        ServingEngine::new(sys.clone(), &est).with_cache(bare_cache.clone()).with_config(bare_cfg);
    let bare = engine.serve(&built.streams);
    let bare_records = rec.drain();
    let bare_stats = bare_cache.lock().unwrap().stats();

    let cfg = FleetConfig { telemetry: true, engine: built.apply(base), ..FleetConfig::default() };
    let mut fleet = ServingFleet::new(sys, &est, cfg);
    let report = fleet.serve(&built.streams);
    assert_eq!(report.shards.len(), 1);
    assert!(report.migrations.is_empty(), "one shard has nowhere to migrate");
    let shard = &report.shards[0];
    let fleet_multi = shard.report.as_ref().expect("the only shard serves every stream");

    assert_multi_reports_equal(&bare, fleet_multi);
    assert_eq!(shard.cache, bare_stats, "{}: cache counters diverge", m.name);
    assert_eq!(shard.timeline, bare_records, "{}: telemetry timelines diverge", m.name);
    assert_eq!(report.total_completed, bare.total_completed);
    assert_eq!(report.total_shed, bare.engine.sheds);
    assert_eq!(report.makespan.to_bits(), bare.makespan.to_bits());
    assert!(report.conserved());
}

#[test]
fn single_shard_fleet_is_bit_identical_to_the_bare_engine() {
    // Adaptive default on the canonical drift mix, and the preemptive
    // policy on the shedding deadline mix — both engine hot paths.
    differential(&catalog::multi_stream(1, 2, 9), EngineConfig::default());
    differential(&catalog::deadline(2, 23), EngineConfig::builder().preemptive(1.0).build());
}

#[test]
fn multi_shard_fleet_conserves_requests_across_migrations() {
    let m = catalog::fleet_skewed();
    let built = m.build().expect("manifest builds");
    let sys = built.system.clone();
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let cfg = FleetConfig {
        shards: 2,
        engine: built.apply(EngineConfig::default()),
        ..FleetConfig::default()
    };
    let mut fleet = ServingFleet::new(sys, &est, cfg);
    let report = fleet.serve(&built.streams);

    assert!(!report.migrations.is_empty(), "the skewed mix must force a migration");
    assert!(report.conserved(), "completed + shed must equal offered across migrations");
    // Every stream's final report lives on exactly one shard, and that
    // report accounts for the stream's whole trace.
    for s in &built.streams {
        let owners: Vec<&ServeReport> = report
            .shards
            .iter()
            .filter_map(|sh| sh.report.as_ref())
            .flat_map(|r| &r.streams)
            .filter(|sr| sr.name == s.name)
            .map(|sr| &sr.report)
            .collect();
        assert_eq!(owners.len(), 1, "stream '{}' must live on exactly one shard", s.name);
        assert_eq!(
            owners[0].completed + owners[0].shed,
            s.trace.len(),
            "stream '{}' must account for every offered request",
            s.name
        );
    }
    for mig in &report.migrations {
        assert_ne!(mig.from, mig.to, "a migration crosses shards");
        let dest = &report.shards[mig.to];
        assert!(dest.streams.contains(&mig.stream), "the migrated stream lands on its target");
    }
}

#[test]
fn registry_prewarmed_shards_never_cold_miss_under_static_leases() {
    let m = catalog::fleet_balanced();
    let built = m.build().expect("manifest builds");
    let sys = built.system.clone();
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let cfg = FleetConfig {
        shards: 4,
        registry_prewarm: true,
        engine: EngineConfig::builder().static_leases().build(),
        ..FleetConfig::default()
    };
    let mut fleet = ServingFleet::new(sys, &est, cfg);
    let report = fleet.serve(&built.streams);
    assert!(report.conserved());
    for shard in &report.shards {
        assert!(shard.prewarm_seeded >= 1, "shard {} seeded nothing at spin-up", shard.shard);
        assert_eq!(
            shard.cache.misses,
            0,
            "shard {} cold-missed despite the registry prewarm",
            shard.shard
        );
        assert!(shard.cache.hits > 0, "shard {} never hit its seeded plans", shard.shard);
    }
}
