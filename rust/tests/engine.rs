//! Engine acceptance tests (ISSUE 2):
//!
//! * **Equivalence property** — a single-stream trace run through the
//!   event-heap engine (`serve_trace` is now its single-stream special
//!   case) must produce *identical* completions, latencies, reschedule
//!   counts, downtime, and energy to the legacy synchronous
//!   discrete-event accounting, which is re-implemented here as an
//!   independent reference. Checked over seeded random traces, cached
//!   and uncached.
//! * **Oversubscription** — more streams than devices completes with a
//!   nonzero Jain fairness index (time-sliced leases, no panic).
//! * **Online re-partitioning** — the demand-skewed two-stream scenario
//!   must migrate at least one device lease, while the static default
//!   migrates none.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::coordinator::server::{generate_trace, serve_trace, RESCHEDULE_DRAIN_COST};
use dype::coordinator::{Completion, Coordinator, Request};
use dype::devices::GroundTruth;
use dype::engine::{EngineConfig, RepartitionPolicy, ServingEngine};
use dype::experiments::{run_multi_stream, run_multi_stream_with, skewed_pair_scenario};
use dype::perfmodel::{OracleModels, PerfEstimator};
use dype::scheduler::{evaluate_plan, PowerTable, Schedule, ScheduleCache};
use dype::util::Rng;
use dype::workload::{gnn, transformer, Dataset, Workload};

fn sys() -> SystemSpec {
    SystemSpec::paper_testbed(Interconnect::Pcie4)
}

fn gcn(edges: u64) -> Workload {
    gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, edges, 200, 0.2), 2, 128)
}

/// The legacy pre-engine accounting, verbatim: one synchronous loop,
/// FIFO admission, one inference per pipeline period, drain cost on
/// reschedule. The engine must reproduce this exactly for a sole tenant.
struct LegacyOutcome {
    completions: Vec<Completion>,
    reschedules: usize,
    downtime: f64,
    max_queue: usize,
    energy: f64,
}

fn legacy_serve<E: PerfEstimator>(
    coordinator: &mut Coordinator<'_, E>,
    sys: &SystemSpec,
    gt: &GroundTruth,
    trace: &[Request],
) -> LegacyOutcome {
    assert!(!trace.is_empty());
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let comm = sys.comm_model();
    let oracle = OracleModels { gt };

    let mut clock = 0.0f64;
    let mut completions: Vec<Completion> = Vec::with_capacity(trace.len());
    let mut queue: std::collections::VecDeque<&Request> = Default::default();
    let mut next_arrival = 0usize;
    let mut current_sig = String::new();
    let mut measured: Option<Schedule> = None;
    let mut reschedules = 0usize;
    let mut downtime = 0.0f64;
    let mut max_queue = 0usize;
    let mut energy = 0.0f64;

    while completions.len() < trace.len() {
        while next_arrival < trace.len() && trace[next_arrival].arrival <= clock {
            queue.push_back(&trace[next_arrival]);
            next_arrival += 1;
        }
        max_queue = max_queue.max(queue.len());

        let Some(req) = queue.pop_front() else {
            clock = trace[next_arrival].arrival;
            continue;
        };

        let sig: String =
            req.workload.kernels.iter().map(|k| format!("{:?};", k.kind)).collect();
        let events_before = coordinator.reschedule_events().len();
        let sched = coordinator.process_batch(&req.workload).clone();
        let rescheduled = coordinator.reschedule_events().len() > events_before;
        if sig != current_sig || rescheduled || measured.is_none() {
            current_sig = sig;
            measured = Some(evaluate_plan(&req.workload, &sched.plan(), &oracle, &comm, &power));
        }
        if rescheduled {
            reschedules += 1;
            downtime += RESCHEDULE_DRAIN_COST;
            clock += RESCHEDULE_DRAIN_COST;
        }
        let m = measured.as_ref().unwrap();

        let start = clock.max(req.arrival);
        let finish = start + m.period.max(1e-12) + m.latency() - m.period;
        clock = start + m.period;
        energy += m.energy_per_inf;
        completions.push(Completion { id: req.id, arrival: req.arrival, start, finish });
    }

    LegacyOutcome { completions, reschedules, downtime, max_queue, energy }
}

/// A seeded random trace over a palette of drifting workloads.
fn random_trace(seed: u64) -> Vec<Request> {
    let palette: Vec<Workload> = vec![
        gcn(2_000_000),
        gcn(20_000_000),
        gcn(150_000_000),
        transformer::transformer_workload(2048, 512, 4),
        transformer::transformer_workload(8192, 512, 4),
    ];
    let mut rng = Rng::seed_from_u64(0xE4E4 ^ seed);
    let n_phases = rng.gen_range_usize(2, 6);
    let phases: Vec<(Workload, usize)> = (0..n_phases)
        .map(|_| {
            let wl = palette[rng.gen_range_usize(0, palette.len())].clone();
            (wl, rng.gen_range_usize(2, 8))
        })
        .collect();
    let rate = [5.0, 20.0, 120.0][rng.gen_range_usize(0, 3)];
    generate_trace(&phases, rate, seed)
}

fn assert_equivalent(seed: u64, cached: bool) {
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let oracle = OracleModels { gt: &gt };
    let trace = random_trace(seed);

    let mut legacy_coord = Coordinator::new(s.clone(), &oracle, Objective::Performance);
    let mut engine_coord = Coordinator::new(s.clone(), &oracle, Objective::Performance);
    if cached {
        legacy_coord = legacy_coord.with_cache(ScheduleCache::shared(16));
        engine_coord = engine_coord.with_cache(ScheduleCache::shared(16));
    }

    let legacy = legacy_serve(&mut legacy_coord, &s, &gt, &trace);
    let report = serve_trace(&mut engine_coord, &s, &gt, &trace);

    let ctx = format!("seed {seed}, cached {cached}");
    assert_eq!(report.completed, trace.len(), "{ctx}");
    assert_eq!(report.completions.len(), legacy.completions.len(), "{ctx}");
    for (a, b) in report.completions.iter().zip(&legacy.completions) {
        assert_eq!(a.id, b.id, "service order diverged ({ctx})");
        assert_eq!(a.arrival, b.arrival, "{ctx}");
        assert!((a.start - b.start).abs() < 1e-9, "start {} vs {} ({ctx})", a.start, b.start);
        assert!(
            (a.finish - b.finish).abs() < 1e-9,
            "finish {} vs {} ({ctx})",
            a.finish,
            b.finish
        );
    }
    assert_eq!(report.reschedules, legacy.reschedules, "{ctx}");
    assert!(
        (report.reschedule_downtime - legacy.downtime).abs() < 1e-9,
        "downtime {} vs {} ({ctx})",
        report.reschedule_downtime,
        legacy.downtime
    );
    assert_eq!(report.max_queue_depth, legacy.max_queue, "{ctx}");
    let tol = legacy.energy.abs() * 1e-9 + 1e-12;
    assert!(
        (report.energy - legacy.energy).abs() < tol,
        "energy {} vs {} ({ctx})",
        report.energy,
        legacy.energy
    );
}

#[test]
fn engine_matches_legacy_accounting_on_random_traces() {
    for seed in 0..5 {
        assert_equivalent(seed, false);
    }
}

#[test]
fn engine_matches_legacy_accounting_with_schedule_cache() {
    for seed in 5..8 {
        assert_equivalent(seed, true);
    }
}

#[test]
fn oversubscribed_pool_serves_with_nonzero_fairness() {
    let s = SystemSpec::reduced_testbed(Interconnect::Pcie4); // 2F + 1G
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let est = OracleModels { gt: &gt };
    let streams: Vec<dype::coordinator::StreamSpec> = (0..8u64)
        .map(|i| {
            let trace = generate_trace(&[(gcn(2_000_000), 5)], 8.0, 200 + i);
            dype::coordinator::StreamSpec::new(
                format!("tenant-{i}"),
                Objective::Performance,
                trace,
            )
        })
        .collect();
    let mut engine = ServingEngine::new(s, &est);
    let r = engine.serve(&streams);
    assert_eq!(r.total_completed, 40, "8 streams on 3 devices all make progress");
    assert!(r.fairness > 0.0, "fairness {}", r.fairness);
    assert!(r.engine.time_sliced_streams >= 5);
    for sr in &r.streams {
        assert!(sr.report.completed == 5, "{} starved", sr.name);
    }
}

#[test]
fn skewed_demand_migrates_leases_static_does_not() {
    let s = sys();
    let streams = skewed_pair_scenario(12, 21);

    let adaptive_cfg = EngineConfig {
        repartition: Some(RepartitionPolicy::reactive(1.0)),
        ..EngineConfig::default()
    };
    let adaptive = run_multi_stream_with(&s, &streams, adaptive_cfg);
    assert_eq!(adaptive.total_completed, 48, "migration must not lose requests");
    assert!(
        adaptive.engine.lease_migrations >= 1,
        "phase-reversed demand skew must migrate at least one lease: {}",
        adaptive.engine
    );
    assert!(adaptive.engine.repartitions >= 1);
    assert!(adaptive.fairness > 0.0);

    let statik = run_multi_stream(&s, &streams);
    assert_eq!(statik.engine.lease_migrations, 0, "static default never migrates");
    assert_eq!(statik.total_completed, 48);
}
