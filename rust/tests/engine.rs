//! Engine acceptance tests (ISSUE 2):
//!
//! * **Equivalence property** — a single-stream trace run through the
//!   event-heap engine (`serve_trace` is now its single-stream special
//!   case) must produce *identical* completions, latencies, reschedule
//!   counts, downtime, and energy to the legacy synchronous
//!   discrete-event accounting, which is re-implemented here as an
//!   independent reference. Checked over seeded random traces, cached
//!   and uncached.
//! * **Oversubscription** — more streams than devices completes with a
//!   nonzero Jain fairness index (time-sliced leases, no panic).
//! * **Online re-partitioning** — the demand-skewed two-stream scenario
//!   must migrate at least one device lease, while the static default
//!   migrates none.
//!
//! Plus the multi-objective acceptance suite (ISSUE 3):
//!
//! * **Budget opt-in** — a generous joule budget with uniform SLOs must
//!   reproduce the unbudgeted run's completions exactly.
//! * **Deferral ordering** — a zero-budget window defers everything
//!   except the highest-priority stream.
//! * **`f_eng` conservation** — joules charged across budget windows
//!   equal the summed per-batch model energy (no double-charging across
//!   deferrals).
//! * **SLO feedback** — a p99-violating stream gains lease weight over
//!   an identical-demand peer.
//! * **Re-lease on completion** — a finished stream's devices return to
//!   the pool, down to a sole survivor holding everything.
//!
//! Plus the adaptive-by-default acceptance suite (ISSUE 4):
//!
//! * **Default migrates on skew** — the *default* config migrates on
//!   phase-reversed demand and prewarms the schedule cache for every
//!   prospective partition.
//! * **Prewarm accounting** — a hand-back migration under a warm cache
//!   reports prewarm hits and *zero* post-migration cold misses for the
//!   migrated stream.
//! * **Preemption refunds** — mid-slot preemption refunds unexecuted
//!   time and `f_eng` joules to the charging budget window, preserving
//!   Σ window_joules == Σ charged − Σ refunded with no negative window.
//!
//! Plus the deadline-aware admission suite (ISSUE 5):
//!
//! * **Shed at admission** — an overloaded deadline stream sheds the
//!   requests that can no longer meet their bound instead of serving
//!   them late; nothing is lost (completed + shed == offered) and
//!   deadline attainment is reported per stream.
//! * **Shed, never budget-deferred** — under a zero-joule budget an
//!   infeasible deadline request is shed the moment the budget wait
//!   blows its bound, instead of deferring forever.
//! * **Per-stream migration modes** — a `Drain` override pins a bulk
//!   lane to draining under a preemptive policy (and vice versa), so
//!   preemption follows stream criticality, not just the policy.
//! * **Neutral knobs are inert** — streams with no deadline and no
//!   per-stream mode (or with explicitly neutral settings) are
//!   bit-identical to the PR-4 adaptive default.
//!
//! Plus the telemetry/observability suite (ISSUE 7):
//!
//! * **Counter consistency** — engine-wide `EngineMetrics` counters
//!   (sheds, deferrals, slot preemptions, cache traffic, prewarm
//!   accounting) equal the sum of the per-stream `ServeReport` counters,
//!   and the hot-path snapshot's per-kind event counts sum to
//!   `events_processed`.
//! * **Live p99 export** — each lane's incremental `P2Quantile` state
//!   is exported on the report and matches `metrics::percentile` on the
//!   same completions exactly through the estimator's exact phase.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::coordinator::server::{generate_trace, serve_trace, RESCHEDULE_DRAIN_COST};
use dype::coordinator::{Completion, Coordinator, MultiStreamReport, Request, StreamSpec};
use dype::devices::GroundTruth;
use dype::engine::{
    EnergyBudget, EngineConfig, MigrationMode, RepartitionPolicy, ServingEngine, StreamSlo,
};
use dype::experiments::{
    deadline_config, deadline_scenario, energy_slo_config, energy_slo_scenario,
    multi_stream_scenario, run_multi_stream, run_multi_stream_static, run_multi_stream_with,
    skewed_pair_scenario,
};
use dype::metrics::percentile;
use dype::perfmodel::{OracleModels, PerfEstimator};
use dype::scheduler::{evaluate_plan, PowerTable, Schedule, ScheduleCache};
use dype::util::Rng;
use dype::workload::{gnn, transformer, Dataset, Workload};

fn sys() -> SystemSpec {
    SystemSpec::paper_testbed(Interconnect::Pcie4)
}

fn gcn(edges: u64) -> Workload {
    gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, edges, 200, 0.2), 2, 128)
}

/// The legacy pre-engine accounting, verbatim: one synchronous loop,
/// FIFO admission, one inference per pipeline period, drain cost on
/// reschedule. The engine must reproduce this exactly for a sole tenant.
struct LegacyOutcome {
    completions: Vec<Completion>,
    reschedules: usize,
    downtime: f64,
    max_queue: usize,
    energy: f64,
}

fn legacy_serve<E: PerfEstimator>(
    coordinator: &mut Coordinator<'_, E>,
    sys: &SystemSpec,
    gt: &GroundTruth,
    trace: &[Request],
) -> LegacyOutcome {
    assert!(!trace.is_empty());
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let comm = sys.comm_model();
    let oracle = OracleModels { gt };

    let mut clock = 0.0f64;
    let mut completions: Vec<Completion> = Vec::with_capacity(trace.len());
    let mut queue: std::collections::VecDeque<&Request> = Default::default();
    let mut next_arrival = 0usize;
    let mut current_sig = String::new();
    let mut measured: Option<Schedule> = None;
    let mut reschedules = 0usize;
    let mut downtime = 0.0f64;
    let mut max_queue = 0usize;
    let mut energy = 0.0f64;

    while completions.len() < trace.len() {
        while next_arrival < trace.len() && trace[next_arrival].arrival <= clock {
            queue.push_back(&trace[next_arrival]);
            next_arrival += 1;
        }
        max_queue = max_queue.max(queue.len());

        let Some(req) = queue.pop_front() else {
            clock = trace[next_arrival].arrival;
            continue;
        };

        let sig: String = req.workload.kernels.iter().map(|k| format!("{:?};", k.kind)).collect();
        let events_before = coordinator.reschedule_events().len();
        let sched = coordinator.process_batch(&req.workload).clone();
        let rescheduled = coordinator.reschedule_events().len() > events_before;
        if sig != current_sig || rescheduled || measured.is_none() {
            current_sig = sig;
            measured = Some(evaluate_plan(&req.workload, &sched.plan(), &oracle, &comm, &power));
        }
        if rescheduled {
            reschedules += 1;
            downtime += RESCHEDULE_DRAIN_COST;
            clock += RESCHEDULE_DRAIN_COST;
        }
        let m = measured.as_ref().unwrap();

        let start = clock.max(req.arrival);
        let finish = start + m.period.max(1e-12) + m.latency() - m.period;
        clock = start + m.period;
        energy += m.energy_per_inf;
        completions.push(Completion { id: req.id, arrival: req.arrival, start, finish });
    }

    LegacyOutcome { completions, reschedules, downtime, max_queue, energy }
}

/// A seeded random trace over a palette of drifting workloads.
fn random_trace(seed: u64) -> Vec<Request> {
    let palette: Vec<Workload> = vec![
        gcn(2_000_000),
        gcn(20_000_000),
        gcn(150_000_000),
        transformer::transformer_workload(2048, 512, 4),
        transformer::transformer_workload(8192, 512, 4),
    ];
    let mut rng = Rng::seed_from_u64(0xE4E4 ^ seed);
    let n_phases = rng.gen_range_usize(2, 6);
    let phases: Vec<(Workload, usize)> = (0..n_phases)
        .map(|_| {
            let wl = palette[rng.gen_range_usize(0, palette.len())].clone();
            (wl, rng.gen_range_usize(2, 8))
        })
        .collect();
    let rate = [5.0, 20.0, 120.0][rng.gen_range_usize(0, 3)];
    generate_trace(&phases, rate, seed)
}

fn assert_equivalent(seed: u64, cached: bool) {
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let oracle = OracleModels { gt: &gt };
    let trace = random_trace(seed);

    let mut legacy_coord = Coordinator::new(s.clone(), &oracle, Objective::Performance);
    let mut engine_coord = Coordinator::new(s.clone(), &oracle, Objective::Performance);
    if cached {
        legacy_coord = legacy_coord.with_cache(ScheduleCache::shared(16));
        engine_coord = engine_coord.with_cache(ScheduleCache::shared(16));
    }

    let legacy = legacy_serve(&mut legacy_coord, &s, &gt, &trace);
    let report = serve_trace(&mut engine_coord, &s, &gt, &trace);

    let ctx = format!("seed {seed}, cached {cached}");
    assert_eq!(report.completed, trace.len(), "{ctx}");
    assert_eq!(report.completions.len(), legacy.completions.len(), "{ctx}");
    for (a, b) in report.completions.iter().zip(&legacy.completions) {
        assert_eq!(a.id, b.id, "service order diverged ({ctx})");
        assert_eq!(a.arrival, b.arrival, "{ctx}");
        assert!((a.start - b.start).abs() < 1e-9, "start {} vs {} ({ctx})", a.start, b.start);
        assert!((a.finish - b.finish).abs() < 1e-9, "finish {} vs {} ({ctx})", a.finish, b.finish);
    }
    assert_eq!(report.reschedules, legacy.reschedules, "{ctx}");
    assert!(
        (report.reschedule_downtime - legacy.downtime).abs() < 1e-9,
        "downtime {} vs {} ({ctx})",
        report.reschedule_downtime,
        legacy.downtime
    );
    assert_eq!(report.max_queue_depth, legacy.max_queue, "{ctx}");
    let tol = legacy.energy.abs() * 1e-9 + 1e-12;
    assert!(
        (report.energy - legacy.energy).abs() < tol,
        "energy {} vs {} ({ctx})",
        report.energy,
        legacy.energy
    );
}

#[test]
fn engine_matches_legacy_accounting_on_random_traces() {
    for seed in 0..5 {
        assert_equivalent(seed, false);
    }
}

#[test]
fn engine_matches_legacy_accounting_with_schedule_cache() {
    for seed in 5..8 {
        assert_equivalent(seed, true);
    }
}

#[test]
fn oversubscribed_pool_serves_with_nonzero_fairness() {
    let s = SystemSpec::reduced_testbed(Interconnect::Pcie4); // 2F + 1G
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let est = OracleModels { gt: &gt };
    let streams: Vec<dype::coordinator::StreamSpec> = (0..8u64)
        .map(|i| {
            let trace = generate_trace(&[(gcn(2_000_000), 5)], 8.0, 200 + i);
            dype::coordinator::StreamSpec::new(format!("tenant-{i}"), Objective::Performance, trace)
        })
        .collect();
    let mut engine = ServingEngine::new(s, &est);
    let r = engine.serve(&streams);
    assert_eq!(r.total_completed, 40, "8 streams on 3 devices all make progress");
    assert!(r.fairness > 0.0, "fairness {}", r.fairness);
    assert!(r.engine.time_sliced_streams >= 5);
    for sr in &r.streams {
        assert!(sr.report.completed == 5, "{} starved", sr.name);
    }
}

#[test]
fn skewed_demand_migrates_leases_static_does_not() {
    let s = sys();
    let streams = skewed_pair_scenario(12, 21);

    let adaptive_cfg = EngineConfig {
        repartition: Some(RepartitionPolicy::reactive(1.0)),
        ..EngineConfig::default()
    };
    let adaptive = run_multi_stream_with(&s, &streams, adaptive_cfg);
    assert_eq!(adaptive.total_completed, 48, "migration must not lose requests");
    assert!(
        adaptive.engine.lease_migrations >= 1,
        "phase-reversed demand skew must migrate at least one lease: {}",
        adaptive.engine
    );
    assert!(adaptive.engine.repartitions >= 1);
    assert!(adaptive.fairness > 0.0);

    let statik = run_multi_stream_static(&s, &streams);
    assert_eq!(statik.engine.lease_migrations, 0, "the static escape hatch never migrates");
    assert_eq!(statik.total_completed, 48);
}

// ---- adaptive-by-default + prewarming + preemption (ISSUE 4) ----------

#[test]
fn default_engine_migrates_on_skew_and_prewarms_the_cache() {
    // The adaptive-by-default acceptance bar: the *default* config (no
    // explicit policy) must notice phase-reversed demand skew, migrate at
    // least one lease, and carry the migrated streams' cached plans onto
    // their new partitions — so recurring regimes stay hits even though
    // every migration re-scopes the cache keys.
    let s = sys();
    let streams = skewed_pair_scenario(20, 21); // 80 requests, ~4 s of arrivals
    let r = run_multi_stream(&s, &streams);

    assert_eq!(r.total_completed, 80, "adaptive default must not lose requests");
    assert!(
        r.engine.lease_migrations >= 1,
        "the default engine must migrate on skew: {}",
        r.engine
    );
    assert!(r.engine.prewarm_hits >= 1, "migrations must prewarm known regimes: {}", r.engine);
    // Cold DP runs are bounded by first sightings (2 regimes × 2 streams)
    // plus the fallout of plans a prewarm could not re-fit (each such
    // regime may re-pay the DP once now and, if another migration lands
    // before it is re-sighted, once more) — prewarming is what keeps
    // migration from re-paying the DP for known regimes.
    assert!(
        r.cache.misses <= 4 + 2 * r.engine.prewarm_misses,
        "misses {} vs {} prewarm misses: prewarming must absorb migrations",
        r.cache.misses,
        r.engine.prewarm_misses
    );
}

#[test]
fn migration_under_a_warm_cache_has_no_post_migration_cold_miss() {
    // Strict prewarm accounting on a hand-back migration: `short` drains
    // early, `long` (a single recurring regime) survives and inherits
    // the whole pool — a per-type superset of its old partition, so the
    // prewarm is guaranteed to re-fit its plan. The migrated stream must
    // report exactly its one first-sighting miss and nothing after the
    // migration.
    let s = sys();
    let streams = vec![
        StreamSpec::new(
            "short",
            Objective::Performance,
            generate_trace(&[(gcn(2_000_000), 8)], 20.0, 141),
        ),
        StreamSpec::new(
            "long",
            Objective::Performance,
            generate_trace(&[(gcn(2_000_000), 40)], 10.0, 142),
        ),
    ];
    let r = run_multi_stream(&s, &streams); // pure defaults: adaptive + prewarm

    assert_eq!(r.total_completed, 48);
    assert!(
        r.engine.lease_migrations >= 1,
        "the hand-back must migrate the survivor: {}",
        r.engine
    );
    assert!(r.engine.prewarm_hits >= 1, "the survivor's regime must carry over: {}", r.engine);
    assert_eq!(r.engine.prewarm_misses, 0, "a superset partition re-fits every plan");
    let long = &r.streams[1];
    assert_eq!(long.name, "long");
    assert_eq!(long.partition, "3F2G", "the survivor ends holding the whole pool");
    assert_eq!(
        long.report.cache.misses, 1,
        "one first-sighting DP, zero post-migration cold misses"
    );
    assert!(long.report.cache.prewarm_hits >= 1, "prewarm attributed to the migrated stream");
}

#[test]
fn preemption_refunds_conserve_energy_across_budget_windows() {
    // Mid-slot preemption under a metered budget: cancelled slots refund
    // the unexecuted fraction of their time and joules to the window
    // that was charged, so Σ window_joules == Σ charged − Σ refunded ==
    // the summed per-stream modeled energy, and no window goes negative.
    let s = sys();
    let streams = skewed_pair_scenario(16, 91);
    let cfg = EngineConfig {
        repartition: Some(RepartitionPolicy::preemptive(1.0)),
        energy_budget: Some(EnergyBudget::new(1e12, 0.1)), // generous, many windows
        ..EngineConfig::default()
    };
    let r = run_multi_stream_with(&s, &streams, cfg);

    let offered: usize = streams.iter().map(|t| t.trace.len()).sum();
    assert_eq!(r.total_completed, offered, "preempted batches must still complete");
    assert!(
        r.engine.slot_preemptions >= 1,
        "busy lanes under a preemptive policy must cancel mid-slot: {}",
        r.engine
    );
    assert!(r.engine.slot_preemptions <= r.engine.preemptions);
    assert!(r.engine.slot_time_refunded > 0.0);
    assert!(r.engine.joules_refunded > 0.0, "cancelled slots must refund joules");
    let charged = r.engine.joules_charged();
    let modeled: f64 = r.streams.iter().map(|sr| sr.report.energy).sum();
    let tol = modeled.abs() * 1e-9 + 1e-12;
    assert!(
        (charged - modeled).abs() < tol,
        "windows {charged} J vs modeled {modeled} J: refunds must keep f_eng conservation"
    );
    assert!(
        r.engine.window_joules.iter().all(|j| *j >= 0.0),
        "a refund may never push its window negative: {:?}",
        r.engine.window_joules
    );
}

#[test]
fn preemptive_and_drain_migrations_agree_on_what_completes() {
    // Preemption changes *when* leases take effect, never *what* is
    // served: same scenario, same completions count, both adaptive.
    let s = sys();
    let streams = skewed_pair_scenario(12, 51);
    let offered: usize = streams.iter().map(|t| t.trace.len()).sum();
    let drain = run_multi_stream_with(
        &s,
        &streams,
        EngineConfig {
            repartition: Some(RepartitionPolicy::reactive(1.0)),
            ..EngineConfig::default()
        },
    );
    let preempt = run_multi_stream_with(
        &s,
        &streams,
        EngineConfig {
            repartition: Some(RepartitionPolicy::preemptive(1.0)),
            ..EngineConfig::default()
        },
    );
    assert_eq!(drain.total_completed, offered);
    assert_eq!(preempt.total_completed, offered);
    assert_eq!(drain.engine.slot_preemptions, 0, "drain mode never cancels slots");
    assert_eq!(drain.engine.joules_refunded, 0.0);
    // Refunds only ever *reduce* the modeled energy bill: the preemptive
    // run re-pays the executed fraction of every cancelled slot, so its
    // total energy is at least the drain run's minus nothing — and both
    // stay positive.
    assert!(preempt.total_energy > 0.0 && drain.total_energy > 0.0);
}

// ---- deadline-aware admission + per-stream preemption (ISSUE 5) -------

#[test]
fn deadline_scenario_sheds_infeasible_requests_and_splits_migration_modes() {
    // The canonical mixed-class scenario: the overloaded interactive
    // lane must shed (its 40 req/s cannot fit a 250 ms deadline on its
    // slice of the pool), best-effort lanes must be untouched by the
    // deadline machinery, and the per-stream migration overrides must
    // hold — the bulk lane never cancels a slot even though the policy
    // mode is Preempt.
    let s = sys();
    let streams = deadline_scenario(12, 101);
    let offered: usize = streams.iter().map(|t| t.trace.len()).sum();
    let r = run_multi_stream_with(&s, &streams, deadline_config());

    assert_eq!(
        r.total_completed + r.engine.sheds,
        offered,
        "every request either completes or is shed — none lost"
    );
    let interactive = &r.streams[0].report;
    assert!(interactive.shed >= 1, "overload must shed: {}", r.engine);
    assert_eq!(interactive.shed + interactive.completed, streams[0].trace.len());
    assert!(
        (0.0..1.0).contains(&interactive.deadline_attainment),
        "sheds must show up in deadline attainment: {}",
        interactive.deadline_attainment
    );
    // Every served-and-on-time completion is inside the bound, so the
    // reported fraction is consistent with the raw completions.
    let met = interactive
        .completions
        .iter()
        .filter(|c| c.latency() <= streams[0].slo.deadline.unwrap())
        .count();
    let expect = met as f64 / (interactive.completed + interactive.shed) as f64;
    assert!((interactive.deadline_attainment - expect).abs() < 1e-12);
    for sr in &r.streams[1..] {
        assert_eq!(sr.report.shed, 0, "{} has no deadline, nothing to shed", sr.name);
        assert_eq!(sr.report.deadline_attainment, 1.0, "{}: vacuous attainment", sr.name);
        assert_eq!(sr.report.completed, sr.report.completions.len());
    }
    // Criticality-tied preemption: the preemptive policy must cancel at
    // least one slot somewhere, the Drain-pinned bulk lane none, and the
    // engine total must be exactly the per-stream sum.
    assert!(r.engine.slot_preemptions >= 1, "preemptive policy never preempted: {}", r.engine);
    let bulk = &r.streams[3];
    assert_eq!(bulk.name, "bulk-drain");
    assert_eq!(bulk.report.slot_preemptions, 0, "the Drain override must hold");
    let per_stream: usize = r.streams.iter().map(|sr| sr.report.slot_preemptions).sum();
    assert_eq!(r.engine.slot_preemptions, per_stream);
}

#[test]
fn infeasible_deadline_requests_shed_instead_of_budget_deferring() {
    // A zero-joule budget defers everything below the top class — but a
    // deferred wait of up to a whole window (0.5 s) can never fit a
    // 20 ms deadline, so the low-priority deadline stream's requests
    // must be shed at the denial point, not parked forever; the
    // high-priority stream is untouched and the run terminates.
    let s = sys();
    let hi_trace = generate_trace(&[(gcn(2_000_000), 12)], 20.0, 171);
    let ddl_trace = generate_trace(&[(gcn(2_000_000), 10)], 20.0, 172);
    let streams = vec![
        StreamSpec::new("hi", Objective::Performance, hi_trace)
            .with_slo(StreamSlo::best_effort(2.0)),
        StreamSpec::new("ddl", Objective::Performance, ddl_trace)
            .with_slo(StreamSlo::best_effort(1.0).with_deadline(0.020)),
    ];
    let cfg = EngineConfig::builder().energy_budget(EnergyBudget::new(0.0, 0.5)).build();
    let r = run_multi_stream_with(&s, &streams, cfg);

    let hi = &r.streams[0].report;
    let ddl = &r.streams[1].report;
    assert_eq!(hi.completed, 12, "the top class is never shed or starved");
    assert_eq!(hi.shed, 0);
    assert_eq!(hi.deferrals, 0);
    assert_eq!(ddl.completed + ddl.shed, 10, "every deadline request is settled");
    assert!(ddl.shed >= 5, "the budget wait must shed most of the deadline lane: {}", ddl.shed);
    assert_eq!(r.engine.sheds, ddl.shed);
    assert_eq!(r.total_completed, 12 + ddl.completed);
    assert!(
        ddl.deadline_attainment <= (ddl.completed as f64) / 10.0,
        "sheds are deadline misses by definition"
    );
}

#[test]
fn drain_override_dissents_from_a_preemptive_policy() {
    // Same skewed pair the preemption acceptance test uses, but the
    // back-loaded stream pins Drain: every mid-slot cancellation must be
    // attributable to the unmarked (policy-mode) stream alone.
    let s = sys();
    let streams: Vec<StreamSpec> = skewed_pair_scenario(16, 91)
        .into_iter()
        .enumerate()
        .map(|(i, spec)| {
            if i == 1 {
                let slo = spec.slo.clone().with_migration(MigrationMode::Drain);
                spec.with_slo(slo)
            } else {
                spec
            }
        })
        .collect();
    let offered: usize = streams.iter().map(|t| t.trace.len()).sum();
    let cfg = EngineConfig {
        repartition: Some(RepartitionPolicy::preemptive(1.0)),
        ..EngineConfig::default()
    };
    let r = run_multi_stream_with(&s, &streams, cfg);

    assert_eq!(r.total_completed, offered, "overrides must not lose requests");
    assert_eq!(
        r.streams[1].report.slot_preemptions, 0,
        "the Drain-pinned lane may never cancel a slot"
    );
    assert_eq!(
        r.engine.slot_preemptions,
        r.streams[0].report.slot_preemptions,
        "every cancellation belongs to the policy-mode lane"
    );
}

#[test]
fn preempt_override_acts_under_a_drain_policy() {
    // The mirror image: a drain-mode policy with one lane opting into
    // preemption — only that lane may ever cancel mid-slot, and the
    // drain-default peer never does.
    let s = sys();
    let streams: Vec<StreamSpec> = skewed_pair_scenario(16, 91)
        .into_iter()
        .enumerate()
        .map(|(i, spec)| {
            if i == 0 {
                let slo = spec
                    .slo
                    .clone()
                    .with_migration(MigrationMode::Preempt { min_remaining: 0.01 });
                spec.with_slo(slo)
            } else {
                spec
            }
        })
        .collect();
    let offered: usize = streams.iter().map(|t| t.trace.len()).sum();
    let cfg = EngineConfig {
        repartition: Some(RepartitionPolicy::reactive(1.0)), // policy mode: Drain
        ..EngineConfig::default()
    };
    let r = run_multi_stream_with(&s, &streams, cfg);

    assert_eq!(r.total_completed, offered);
    assert_eq!(
        r.streams[1].report.slot_preemptions, 0,
        "the policy-default lane drains under a Drain policy"
    );
    assert_eq!(
        r.engine.slot_preemptions,
        r.streams[0].report.slot_preemptions,
        "only the opted-in lane may preempt"
    );
}

#[test]
fn neutral_deadline_knobs_are_bit_identical_to_the_adaptive_default() {
    // The compatibility bar: streams with no deadline and no per-stream
    // mode must serve exactly as the PR-4 engine served them. Sharpest
    // in-repo form: run the same scenario twice — once untouched, once
    // with the new knobs set to *explicitly neutral* values (a deadline
    // no request can miss, a migration override equal to the policy
    // default) so the feasibility check and the per-stream mode lookup
    // actually execute — and require bitwise-equal serving outcomes.
    let s = sys();
    let plain = multi_stream_scenario(2, 4, 9);
    let neutral: Vec<StreamSpec> = plain
        .iter()
        .cloned()
        .map(|spec| {
            let slo = spec.slo.clone().with_deadline(1e9).with_migration(MigrationMode::Drain);
            spec.with_slo(slo)
        })
        .collect();

    let base = run_multi_stream(&s, &plain);
    let r = run_multi_stream(&s, &neutral);

    assert_eq!(r.total_completed, base.total_completed);
    assert_eq!(r.makespan, base.makespan);
    assert_eq!(r.fairness, base.fairness);
    assert_eq!(r.engine.sheds, 0, "an unmissable deadline never sheds");
    assert_eq!(base.engine.sheds, 0);
    assert_eq!(r.engine.lease_migrations, base.engine.lease_migrations);
    assert_eq!(r.engine.repartitions, base.engine.repartitions);
    for (n, b) in r.streams.iter().zip(&base.streams) {
        assert_eq!(n.partition, b.partition);
        assert_eq!(n.report.completions.len(), b.report.completions.len());
        for (cn, cb) in n.report.completions.iter().zip(&b.report.completions) {
            assert_eq!(cn.id, cb.id, "{}: service order diverged", n.name);
            assert_eq!(cn.start, cb.start, "{}: starts diverged", n.name);
            assert_eq!(cn.finish, cb.finish, "{}: finishes diverged", n.name);
        }
        assert_eq!(n.report.reschedules, b.report.reschedules);
        assert_eq!(n.report.energy, b.report.energy);
        assert_eq!(n.report.shed, 0);
        assert_eq!(n.report.deadline_attainment, 1.0, "everything fits a 1e9 s bound");
    }
}

// ---- energy budget + SLO acceptance (ISSUE 3) -------------------------

#[test]
fn generous_budget_and_uniform_slos_change_nothing() {
    // The budget/SLO path is strictly opt-in: with joules to spare and
    // default SLOs, every serving number of the PR-1/PR-2 scenario must
    // be bit-identical to the unbudgeted engine (the extra events on the
    // heap are budget ticks only — they never touch a lane).
    let s = sys();
    let streams = multi_stream_scenario(2, 4, 9);
    let base = run_multi_stream(&s, &streams);
    let cfg = EngineConfig::builder().energy_budget(EnergyBudget::new(1e12, 0.5)).build();
    let budgeted = run_multi_stream_with(&s, &streams, cfg);

    assert_eq!(budgeted.total_completed, base.total_completed);
    assert_eq!(budgeted.makespan, base.makespan);
    assert_eq!(budgeted.fairness, base.fairness);
    for (b, a) in budgeted.streams.iter().zip(&base.streams) {
        assert_eq!(b.partition, a.partition);
        assert_eq!(b.report.completions.len(), a.report.completions.len());
        for (cb, ca) in b.report.completions.iter().zip(&a.report.completions) {
            assert_eq!(cb.id, ca.id);
            assert_eq!(cb.start, ca.start, "{}: starts diverged", b.name);
            assert_eq!(cb.finish, ca.finish, "{}: finishes diverged", b.name);
        }
        assert_eq!(b.report.reschedules, a.report.reschedules);
        assert_eq!(b.report.energy, a.report.energy);
        assert_eq!(b.report.deferrals, 0, "a generous budget never defers");
        assert_eq!(b.report.slo_attainment, 1.0, "no target means vacuous attainment");
    }
    assert_eq!(budgeted.engine.deferrals, 0);
    assert!(budgeted.engine.budget_windows >= 1, "the ledger must have opened a window");
    let charged = budgeted.engine.joules_charged();
    let tol = budgeted.total_energy.abs() * 1e-9 + 1e-12;
    assert!(
        (charged - budgeted.total_energy).abs() < tol,
        "charged {charged} vs modeled {}",
        budgeted.total_energy
    );
    assert!(budgeted.throughput_per_joule > 0.0);
}

#[test]
fn zero_budget_window_defers_everything_below_top_priority() {
    // With zero joules per window nothing is affordable, so only the
    // highest-priority unfinished stream may dispatch: the low-priority
    // stream must not start a single batch until the high-priority
    // stream has dispatched its entire trace.
    let s = sys();
    let lo_trace = generate_trace(&[(gcn(2_000_000), 10)], 20.0, 61);
    let hi_trace = generate_trace(&[(gcn(2_000_000), 10)], 20.0, 62);
    let streams = vec![
        StreamSpec::new("lo", Objective::Performance, lo_trace)
            .with_slo(StreamSlo::best_effort(1.0)),
        StreamSpec::new("hi", Objective::Performance, hi_trace)
            .with_slo(StreamSlo::best_effort(2.0)),
    ];
    let cfg = EngineConfig::builder().energy_budget(EnergyBudget::new(0.0, 0.05)).build();
    let r = run_multi_stream_with(&s, &streams, cfg);

    assert_eq!(r.total_completed, 20, "deferral must not starve anyone forever");
    let lo = &r.streams[0].report;
    let hi = &r.streams[1].report;
    assert_eq!(hi.deferrals, 0, "the top class is never deferred");
    assert!(lo.deferrals >= 1, "zero budget must defer the low class");
    assert!(r.engine.deferrals >= 1);
    let hi_last_start = hi.completions.iter().map(|c| c.start).fold(f64::NEG_INFINITY, f64::max);
    let lo_first_start = lo.completions.iter().map(|c| c.start).fold(f64::INFINITY, f64::min);
    assert!(
        lo_first_start >= hi_last_start,
        "low-priority work started at {lo_first_start} before the high class \
         finished dispatching at {hi_last_start}"
    );
}

#[test]
fn budget_charges_every_batch_exactly_once_across_deferrals() {
    // f_eng conservation: run the canonical energy/SLO scenario under a
    // budget tight enough to defer (30% of the unbudgeted run's average
    // draw) and check the ledger — the joules charged across windows
    // must equal the summed per-batch model energy, i.e. deferrals delay
    // batches but never re-charge them; and only below-priority streams
    // are ever deferred.
    let s = sys();
    let streams = energy_slo_scenario(4, 33);
    let probe = run_multi_stream(&s, &streams);
    let avg_watts = probe.total_energy / probe.makespan;
    let r = run_multi_stream_with(&s, &streams, energy_slo_config(0.3 * avg_watts));

    let offered: usize = streams.iter().map(|t| t.trace.len()).sum();
    assert_eq!(r.total_completed, offered, "every deferred batch still completes");
    assert!(r.engine.deferrals >= 1, "a 30% power cap must defer something");
    assert_eq!(r.streams[0].report.deferrals, 0, "only below-priority streams may be deferred");
    assert!(r.engine.budget_windows >= 2, "the run must span several windows");
    let charged = r.engine.joules_charged();
    let modeled: f64 = r.streams.iter().map(|sr| sr.report.energy).sum();
    let tol = modeled.abs() * 1e-9 + 1e-12;
    assert!(
        (charged - modeled).abs() < tol,
        "ledger charged {charged} J but the batches modeled {modeled} J"
    );
    assert_eq!(r.engine.window_joules.len(), r.engine.budget_windows);
    assert!(r.engine.window_joules.iter().all(|j| *j >= 0.0));
}

#[test]
fn slo_pressure_shifts_lease_weight_toward_the_violating_stream() {
    // Two streams with identical demand: the initial lease split is
    // even, and pure demand feedback keeps it even. Give stream `a` an
    // unattainable p99 target and the SLO controller must bid devices
    // toward it at re-lease time — the control run (same engine, no
    // target) must not migrate at all, and `a` must serve faster than
    // its own control-run self.
    let s = sys();
    let phases = [(gcn(2_000_000), 40)];
    let a_trace = generate_trace(&phases, 20.0, 71);
    let b_trace = generate_trace(&phases, 20.0, 72);
    let with_target = vec![
        StreamSpec::new("a", Objective::Performance, a_trace.clone())
            .with_slo(StreamSlo::target(1e-3, 1.0)),
        StreamSpec::new("b", Objective::Performance, b_trace.clone()),
    ];
    let control = vec![
        StreamSpec::new("a", Objective::Performance, a_trace),
        StreamSpec::new("b", Objective::Performance, b_trace),
    ];
    let cfg = EngineConfig {
        repartition: Some(RepartitionPolicy::reactive(1.0)),
        ..EngineConfig::default()
    };

    let slo_run = run_multi_stream_with(&s, &with_target, cfg.clone());
    let control_run = run_multi_stream_with(&s, &control, cfg);

    assert_eq!(
        control_run.engine.lease_migrations,
        0,
        "balanced demand without SLO pressure must hold the even split"
    );
    assert!(
        slo_run.engine.lease_migrations >= 1,
        "the violated target must pull at least one lease: {}",
        slo_run.engine
    );
    let (a_slo, a_ctl) = (&slo_run.streams[0].report, &control_run.streams[0].report);
    assert!(
        a_slo.mean_latency < a_ctl.mean_latency,
        "extra devices must speed the violating stream: {} vs {}",
        a_slo.mean_latency,
        a_ctl.mean_latency
    );
    assert!(
        (0.0..=1.0).contains(&a_slo.slo_attainment),
        "attainment is a fraction: {}",
        a_slo.slo_attainment
    );
    assert_eq!(slo_run.total_completed, 80);
}

#[test]
fn finished_streams_return_their_devices_to_the_survivors() {
    // Three staggered streams: `short` and `mid` drain quickly, `long`
    // keeps serving heavy batches long after. Each completion must hand
    // devices back — ending with the sole survivor holding the entire
    // pool (the PR-2 engine stopped re-validating leases below two
    // active streams and stranded the survivor on its slice).
    let s = sys();
    let streams = vec![
        StreamSpec::new(
            "short",
            Objective::Performance,
            generate_trace(&[(gcn(2_000_000), 6)], 15.0, 81),
        ),
        StreamSpec::new(
            "mid",
            Objective::Performance,
            generate_trace(&[(gcn(2_000_000), 12)], 10.0, 82),
        ),
        StreamSpec::new(
            "long",
            Objective::Performance,
            generate_trace(&[(gcn(150_000_000), 20)], 8.0, 83),
        ),
    ];
    let cfg = EngineConfig {
        repartition: Some(RepartitionPolicy {
            sample_interval: 0.1,
            lease_term: 0.2,
            ewma_alpha: 0.5,
            hysteresis: 0.02,
            migration: MigrationMode::Drain,
        }),
        ..EngineConfig::default()
    };
    let r = run_multi_stream_with(&s, &streams, cfg);

    assert_eq!(r.total_completed, 38, "re-leasing must not lose requests");
    assert!(
        r.engine.lease_migrations >= 1,
        "completions must trigger device hand-back: {}",
        r.engine
    );
    let survivor = &r.streams[2];
    assert_eq!(survivor.name, "long");
    assert_eq!(survivor.partition, "3F2G", "the sole survivor must end up holding the whole pool");
    assert!(
        r.engine.final_pool_share[2] > 0.99,
        "survivor pool share {}",
        r.engine.final_pool_share[2]
    );
}

// ---- telemetry + counter consistency (ISSUE 7) ------------------------

/// Engine-wide counters must be exactly the sum of the per-stream report
/// counters, and the telemetry snapshot must agree with both — the
/// cross-layer consistency bar: a dashboard reading `EngineMetrics` and
/// one reading per-stream `ServeReport`s may never disagree.
fn assert_counters_consistent(r: &MultiStreamReport, label: &str) {
    let sheds: usize = r.streams.iter().map(|sr| sr.report.shed).sum();
    let deferrals: usize = r.streams.iter().map(|sr| sr.report.deferrals).sum();
    let preempts: usize = r.streams.iter().map(|sr| sr.report.slot_preemptions).sum();
    let completed: usize = r.streams.iter().map(|sr| sr.report.completed).sum();
    assert_eq!(r.engine.sheds, sheds, "{label}: shed counter drift");
    assert_eq!(r.engine.deferrals, deferrals, "{label}: deferral counter drift");
    assert_eq!(r.engine.slot_preemptions, preempts, "{label}: preemption counter drift");
    assert_eq!(r.total_completed, completed, "{label}: completion counter drift");

    let hits: u64 = r.streams.iter().map(|sr| sr.report.cache.hits).sum();
    let probes: u64 =
        r.streams.iter().map(|sr| sr.report.cache.hits + sr.report.cache.misses).sum();
    let pw_hits: u64 = r.streams.iter().map(|sr| sr.report.cache.prewarm_hits).sum();
    let pw_misses: u64 = r.streams.iter().map(|sr| sr.report.cache.prewarm_misses).sum();
    assert_eq!(r.engine.prewarm_hits, pw_hits, "{label}: engine prewarm-hit drift");
    assert_eq!(r.engine.prewarm_misses, pw_misses, "{label}: engine prewarm-miss drift");

    let t = &r.engine.telemetry;
    assert_eq!(t.cache_hits, hits, "{label}: snapshot cache-hit drift");
    assert_eq!(t.cache_probes, probes, "{label}: snapshot cache-probe drift");
    assert_eq!(t.prewarm_hits, pw_hits, "{label}: snapshot prewarm-hit drift");
    assert_eq!(t.prewarm_misses, pw_misses, "{label}: snapshot prewarm-miss drift");
    assert_eq!(t.events_total(), r.engine.events_processed, "{label}: event count drift");
    assert!(t.heap_high_water >= 1, "{label}: a run that popped events saw a non-empty heap");
}

#[test]
fn engine_counters_equal_per_stream_sums_across_scenario_families() {
    // One scenario per counter family: deadline (sheds + preemptions),
    // tight energy budget (deferrals), adaptive skew (migrations +
    // prewarm/cache traffic). Each must exercise its counters, then
    // agree with the per-stream sums.
    let s = sys();

    let deadline = run_multi_stream_with(&s, &deadline_scenario(12, 101), deadline_config());
    assert!(deadline.engine.sheds >= 1 && deadline.engine.slot_preemptions >= 1);
    assert_counters_consistent(&deadline, "deadline");

    let streams = energy_slo_scenario(4, 33);
    let probe = run_multi_stream(&s, &streams);
    assert_counters_consistent(&probe, "energy-slo probe");
    let watts = 0.3 * probe.total_energy / probe.makespan;
    let capped = run_multi_stream_with(&s, &streams, energy_slo_config(watts));
    assert!(capped.engine.deferrals >= 1);
    assert_counters_consistent(&capped, "energy-slo capped");

    let adaptive = run_multi_stream(&s, &skewed_pair_scenario(20, 21));
    assert!(adaptive.engine.prewarm_hits >= 1);
    assert_counters_consistent(&adaptive, "adaptive skew");
}

#[test]
fn live_p99_estimate_matches_the_posthoc_percentile() {
    // Exact phase: with ≤ 5 completions per stream the P² estimator is
    // still exact, so the exported estimate must equal
    // `metrics::percentile` on the same completions to the bit.
    let s = sys();
    let streams = vec![
        StreamSpec::new(
            "four",
            Objective::Performance,
            generate_trace(&[(gcn(2_000_000), 4)], 10.0, 301),
        ),
        StreamSpec::new(
            "five",
            Objective::Performance,
            generate_trace(&[(gcn(2_000_000), 5)], 10.0, 302),
        ),
    ];
    let r = run_multi_stream(&s, &streams);
    for sr in &r.streams {
        let mut lats: Vec<f64> = sr.report.completions.iter().map(Completion::latency).collect();
        lats.sort_by(f64::total_cmp);
        assert_eq!(sr.report.p99_observations, lats.len(), "{}: sample size", sr.name);
        assert_eq!(
            sr.report.p99_estimate,
            Some(percentile(&lats, 0.99)),
            "{}: the exact phase must reproduce the post-hoc percentile",
            sr.name
        );
    }

    // Estimation phase: past the exact window the P² value is an
    // approximation, but it must stay inside the observed latency range
    // and keep counting every completion.
    let big = run_multi_stream(&s, &skewed_pair_scenario(12, 21));
    for sr in &big.streams {
        let lats: Vec<f64> = sr.report.completions.iter().map(Completion::latency).collect();
        assert!(lats.len() > 5, "{}: the scenario must leave the exact phase", sr.name);
        assert_eq!(sr.report.p99_observations, lats.len(), "{}: sample size", sr.name);
        let est = sr.report.p99_estimate.expect("completions were observed");
        let lo = lats.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = lats.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        assert!(
            (lo..=hi).contains(&est),
            "{}: estimate {est} outside the observed range [{lo}, {hi}]",
            sr.name
        );
    }
}
