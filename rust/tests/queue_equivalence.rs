//! Differential property test: the two event-queue implementations
//! ([`QueueKind::Heap`] and [`QueueKind::Calendar`]) must be
//! observationally indistinguishable. The engine's determinism contract
//! is keyed on `(time, seq)` pop order, not on which queue structure
//! delivered it — so every serving outcome, engine counter, telemetry
//! snapshot, and trace record has to match **bit-for-bit** across
//! queues on every scenario × policy cell.
//!
//! A seeded subset of the zoo runs on every `cargo test`; the full
//! catalog × [`Policy::ALL`] grid rides `#[ignore]` and is exercised by
//! CI's sweep-smoke job with `--include-ignored`.

use dype::coordinator::MultiStreamReport;
use dype::engine::{EngineConfig, EngineMetrics, EventKind, QueueKind};
use dype::experiments::run_multi_stream_with;
use dype::scenario::sweep::Policy;
use dype::scenario::{catalog, ScenarioManifest};
use dype::telemetry::{Record, Recorder};

/// Run one scenario × policy cell on the given queue, with a timeline
/// recorder attached so the full trace participates in the comparison.
fn run_cell(
    m: &ScenarioManifest,
    policy: Policy,
    queue: QueueKind,
) -> (MultiStreamReport, Vec<Record>) {
    let built = m.build().expect("manifest builds");
    let mut cfg = built.apply(policy.engine_config());
    cfg.event_queue = queue;
    let rec = Recorder::timeline();
    cfg.recorder = Some(rec.clone());
    let report = run_multi_stream_with(&built.system, &built.streams, cfg);
    (report, rec.drain())
}

/// Zero the host-side snapshot counters (handler timings, allocation
/// count) so the rest of the metrics struct can be compared exactly:
/// those two are feature-gated host measurements and differ run-to-run
/// by design, while everything else is sim-deterministic.
fn sim_side(metrics: &EngineMetrics) -> EngineMetrics {
    let mut m = metrics.clone();
    m.telemetry.handler_ns = [0; EventKind::COUNT];
    m.telemetry.allocations = 0;
    m
}

/// The full bitwise-equivalence check for one scenario × policy cell.
fn assert_equivalent(m: &ScenarioManifest, policy: Policy) {
    let (heap, heap_trace) = run_cell(m, policy, QueueKind::Heap);
    let (cal, cal_trace) = run_cell(m, policy, QueueKind::Calendar);
    let label = format!("{} x {}", m.name, policy.name());

    assert_eq!(heap.total_completed, cal.total_completed, "{label}: total_completed");
    assert_eq!(heap.makespan.to_bits(), cal.makespan.to_bits(), "{label}: makespan");
    assert_eq!(heap.fairness.to_bits(), cal.fairness.to_bits(), "{label}: fairness");
    assert_eq!(heap.total_energy.to_bits(), cal.total_energy.to_bits(), "{label}: total_energy");
    assert_eq!(sim_side(&heap.engine), sim_side(&cal.engine), "{label}: engine metrics");
    assert_eq!(heap_trace, cal_trace, "{label}: trace timelines");

    assert_eq!(heap.streams.len(), cal.streams.len(), "{label}: stream count");
    for (h, c) in heap.streams.iter().zip(&cal.streams) {
        let lane = format!("{label} [{}]", h.name);
        assert_eq!(h.name, c.name, "{label}: stream order");
        assert_eq!(h.partition, c.partition, "{lane}: partition");
        assert_eq!(h.report.completed, c.report.completed, "{lane}: completed");
        assert_eq!(h.report.shed, c.report.shed, "{lane}: sheds");
        assert_eq!(h.report.deferrals, c.report.deferrals, "{lane}: deferrals");
        assert_eq!(h.report.energy.to_bits(), c.report.energy.to_bits(), "{lane}: energy");
        assert_eq!(h.report.p99_latency.to_bits(), c.report.p99_latency.to_bits(), "{lane}: p99");
        assert_eq!(h.report.completions.len(), c.report.completions.len(), "{lane}: completions");
        for (a, b) in h.report.completions.iter().zip(&c.report.completions) {
            assert_eq!(a.id, b.id, "{lane}: completion order");
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits(), "{lane}: req {} arrival", a.id);
            assert_eq!(a.start.to_bits(), b.start.to_bits(), "{lane}: req {} start", a.id);
            assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "{lane}: req {} finish", a.id);
        }
    }
}

#[test]
fn calendar_is_the_default_queue() {
    assert_eq!(EngineConfig::default().event_queue, QueueKind::Calendar);
    assert_eq!(EngineConfig::builder().build().event_queue, QueueKind::Calendar);
}

/// CI-sized seeded subset: one representative of each scenario family
/// (multi-phase drift, skewed pair, energy budget, deadline lanes),
/// crossed with every policy — 16 cells, each run twice.
#[test]
fn queues_agree_on_the_seeded_subset() {
    let subset = vec![
        catalog::multi_stream(1, 2, 9),
        catalog::skewed_pair(3, 11),
        catalog::energy_slo(3, 17),
        catalog::deadline(4, 23),
    ];
    for m in &subset {
        for p in Policy::ALL {
            assert_equivalent(m, p);
        }
    }
}

/// The exhaustive grid: every catalog scenario × every policy, both
/// queues. Too slow for the default test pass, so it rides `#[ignore]`;
/// CI's sweep-smoke job runs it with `--include-ignored`.
#[test]
#[ignore = "full zoo x policy grid; run with --include-ignored"]
fn queues_agree_on_the_full_zoo() {
    for m in catalog::all() {
        for p in Policy::ALL {
            assert_equivalent(&m, p);
        }
    }
}
