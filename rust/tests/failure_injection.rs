//! Failure-injection & robustness tests: the framework must degrade
//! gracefully, never panic on hostile inputs, and keep scheduling validly
//! under pathological estimators.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::devices::{DeviceType, GroundTruth};
use dype::perfmodel::PerfEstimator;
use dype::scheduler::DpScheduler;
use dype::util::{json, Rng};
use dype::workload::{gnn, Dataset, KernelKind};

/// An estimator that returns a constant regardless of input — the
/// degenerate case of a completely uninformative performance model.
struct ConstantEstimator(f64);

impl PerfEstimator for ConstantEstimator {
    fn stage_time(&self, kinds: &[KernelKind], _dev: DeviceType, n: usize) -> f64 {
        self.0 * kinds.len() as f64 / n as f64
    }
}

/// An estimator with a wildly biased view (FPGA 1000× optimistic).
struct BiasedEstimator<'a> {
    gt: &'a GroundTruth,
}

impl PerfEstimator for BiasedEstimator<'_> {
    fn stage_time(&self, kinds: &[KernelKind], dev: DeviceType, n: usize) -> f64 {
        let t = self.gt.group_time(kinds, dev, n);
        match dev {
            DeviceType::Fpga => t / 1000.0,
            DeviceType::Gpu => t,
        }
    }
}

fn sys() -> SystemSpec {
    SystemSpec::paper_testbed(Interconnect::Pcie4)
}

#[test]
fn uninformative_estimator_still_yields_valid_schedules() {
    let s = sys();
    let est = ConstantEstimator(1e-3);
    for obj in Objective::paper_modes() {
        let wl = gnn::gin_workload(&Dataset::ogbn_products(), 2, 128, 2);
        let sched = DpScheduler::new(&s, &est).schedule(&wl, obj);
        sched.validate(wl.len(), s.n_fpga, s.n_gpu).unwrap();
    }
}

#[test]
fn adversarially_biased_estimator_yields_valid_but_lopsided_schedules() {
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let est = BiasedEstimator { gt: &gt };
    let wl = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
    let sched = DpScheduler::new(&s, &est).schedule(&wl, Objective::Performance);
    sched.validate(wl.len(), s.n_fpga, s.n_gpu).unwrap();
    // The bias must show: the scheduler trusts its model and goes FPGA.
    assert!(sched.fpgas_used() > 0, "a 1000x-optimistic FPGA model must attract work");
}

#[test]
fn extreme_degree_skew_never_breaks_scheduling() {
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model()).with_degree_skew(50.0);
    let est = dype::perfmodel::OracleModels { gt: &gt };
    let wl = gnn::gcn_workload(&Dataset::ogbn_products(), 2, 128);
    let sched = DpScheduler::new(&s, &est).schedule(&wl, Objective::Performance);
    sched.validate(wl.len(), s.n_fpga, s.n_gpu).unwrap();
    assert!(sched.period.is_finite() && sched.period > 0.0);
}

#[test]
fn degenerate_workload_shapes_schedule_fine() {
    let s = sys();
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let est = dype::perfmodel::OracleModels { gt: &gt };
    // 1-vertex graph, nnz == 1, feature width 1.
    let ds = Dataset::new("tiny", "tiny", 1, 1, 1, 0.0);
    let wl = gnn::gcn_workload(&ds, 1, 1);
    let sched = DpScheduler::new(&s, &est).schedule(&wl, Objective::Performance);
    sched.validate(wl.len(), s.n_fpga, s.n_gpu).unwrap();
}

#[test]
fn json_parser_never_panics_on_fuzz() {
    let mut rng = Rng::seed_from_u64(0xF022);
    let alphabet: &[u8] = br#"{}[]":,0123456789.eE+-truefalsnul \"abc"#;
    for _ in 0..5000 {
        let len = rng.gen_range_usize(0, 64);
        let bytes: Vec<u8> =
            (0..len).map(|_| alphabet[rng.gen_range_usize(0, alphabet.len())]).collect();
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = json::parse(text); // must return, never panic
        }
    }
}

#[test]
fn json_parser_roundtrips_valid_documents_under_mutation() {
    // Mutating one byte of a valid manifest must yield either a parse
    // error or a different-but-parsed document — never a panic.
    let base = r#"{"artifacts": {"k": {"file": "f", "inputs": [{"shape": [2], "dtype": "f32"}], "output": {"shape": [2], "dtype": "f32"}}}}"#;
    let mut rng = Rng::seed_from_u64(7);
    for _ in 0..2000 {
        let mut b = base.as_bytes().to_vec();
        let i = rng.gen_range_usize(0, b.len());
        b[i] = b"{}[]\",:x0"[rng.gen_range_usize(0, 9)];
        if let Ok(text) = std::str::from_utf8(&b) {
            let _ = json::parse(text);
            let _ = dype::runtime::Manifest::from_json_str(text);
        }
    }
}

#[test]
fn config_parser_never_panics_on_fuzz() {
    let mut rng = Rng::seed_from_u64(0xC0FF);
    let alphabet: &[u8] = b"n_fpga=gpu.123 #\n\".xyz";
    for _ in 0..3000 {
        let len = rng.gen_range_usize(0, 80);
        let bytes: Vec<u8> =
            (0..len).map(|_| alphabet[rng.gen_range_usize(0, alphabet.len())]).collect();
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = SystemSpec::from_config_str(text);
        }
    }
}

#[test]
fn runtime_reports_missing_artifacts_cleanly() {
    let dir = std::path::Path::new("/nonexistent-dype-artifacts");
    let err = match dype::runtime::Runtime::new(dir) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "error must tell the user what to run: {msg}");
}

#[test]
fn corrupt_manifest_is_rejected_with_context() {
    let dir = std::env::temp_dir().join(format!("dype-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), b"{not json").unwrap();
    let err = match dype::runtime::Runtime::new(&dir) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(format!("{err:#}").contains("JSON parse error"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scheduler_handles_huge_device_counts() {
    // 64 devices of each type: DP must stay polynomial and valid.
    let mut s = sys();
    s.n_fpga = 64;
    s.n_gpu = 64;
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let est = dype::perfmodel::OracleModels { gt: &gt };
    let wl = gnn::gin_workload(&Dataset::ogbn_products(), 2, 128, 2);
    let t0 = std::time::Instant::now();
    let sched = DpScheduler::new(&s, &est).schedule(&wl, Objective::Performance);
    sched.validate(wl.len(), s.n_fpga, s.n_gpu).unwrap();
    assert!(t0.elapsed().as_secs_f64() < 10.0, "DP blew up: {:?}", t0.elapsed());
}

#[test]
fn zero_rate_comm_is_never_divided_by() {
    // Interconnect with pathological (tiny) bandwidth still yields finite
    // schedules — transfers dominate but nothing divides by zero.
    let mut s = sys();
    s.gpu.pcie_bw = 1.0; // 1 B/s
    s.fpga.pcie_bw = 1.0;
    let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
    let est = dype::perfmodel::OracleModels { gt: &gt };
    let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
    let sched = DpScheduler::new(&s, &est).schedule(&wl, Objective::Performance);
    assert!(sched.period.is_finite());
    // With transfers this catastrophic, a single stage must win.
    assert_eq!(sched.stages.len(), 1);
}
