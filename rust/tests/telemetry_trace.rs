//! Trace-export acceptance tests (ISSUE 7):
//!
//! * **Byte-stable timelines** — the same seeded scenario run twice
//!   yields byte-identical JSONL, every line strict-parseable, opening
//!   with the earliest arrival (timelines must diff with line tools).
//! * **Strict Perfetto round-trip** — the `trace_events` export passes
//!   the exporter's own validator, survives a strict-parse round-trip
//!   bit-for-bit, and lays out the per-stream, per-lease, and
//!   budget-window tracks with shed/preempt instants attributed to
//!   their cause.
//! * **Recorder neutrality** — attaching a recorder never changes what
//!   the engine does: recorder-on and recorder-off runs of the same
//!   scenario are bitwise-identical in every serving outcome.

use dype::config::{Interconnect, SystemSpec};
use dype::engine::{EnergyBudget, EngineConfig, RepartitionPolicy};
use dype::experiments::{deadline_scenario, run_multi_stream_with};
use dype::telemetry::{export, Record, Recorder, ShedCause};
use dype::util::json::{self, Json};

fn sys() -> SystemSpec {
    SystemSpec::paper_testbed(Interconnect::Pcie4)
}

/// The canonical traced scenario's config: the deadline scenario's
/// preemptive policy (sheds and preemptions guaranteed) plus a generous
/// metered budget (windows tick without ever deferring).
fn metered_deadline_config() -> EngineConfig {
    EngineConfig {
        repartition: Some(RepartitionPolicy::preemptive(1.0)),
        energy_budget: Some(EnergyBudget::new(1e12, 0.1)),
        ..EngineConfig::default()
    }
}

fn traced_run() -> (Vec<Record>, Vec<String>) {
    let streams = deadline_scenario(8, 42);
    let rec = Recorder::timeline();
    let mut cfg = metered_deadline_config();
    cfg.recorder = Some(rec.clone());
    run_multi_stream_with(&sys(), &streams, cfg);
    let names = streams.iter().map(|t| t.name.clone()).collect();
    (rec.drain(), names)
}

#[test]
fn seeded_scenario_timeline_is_byte_stable() {
    let (records, _) = traced_run();
    let (again, _) = traced_run();
    assert!(!records.is_empty(), "the scenario must emit records");
    let text = export::jsonl(&records);
    assert_eq!(text, export::jsonl(&again), "same seed, same bytes");

    for line in text.lines() {
        json::parse(line).expect("every JSONL line is strict JSON");
    }
    // The timeline opens with the earliest arrival across all streams.
    let first = json::parse(text.lines().next().unwrap()).unwrap();
    assert_eq!(first.get("type").and_then(Json::as_str), Some("arrival"));
    let earliest = deadline_scenario(8, 42)
        .iter()
        .map(|t| t.trace[0].arrival)
        .fold(f64::INFINITY, f64::min);
    assert_eq!(first.get("t").and_then(Json::as_f64), Some(earliest));
}

#[test]
fn perfetto_export_round_trips_and_lays_out_all_tracks() {
    let (records, names) = traced_run();
    let doc = export::perfetto(&records, &names);
    export::validate(&doc).expect("the exporter must satisfy its own validator");

    // Strict-parse round-trip: Display → parse → identical tree+bytes.
    let reparsed = json::parse(&doc.to_string()).expect("strict JSON");
    assert_eq!(reparsed, doc);
    assert_eq!(reparsed.to_string(), doc.to_string());

    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let named = |n: &str| -> Vec<&Json> {
        events.iter().filter(|e| e.get("name").and_then(Json::as_str) == Some(n)).collect()
    };
    // Per-stream thread metadata for every stream, plus its lease twin.
    for name in &names {
        assert!(
            named("thread_name").iter().any(|e| {
                e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str)
                    == Some(name.as_str())
            }),
            "missing stream track {name:?}"
        );
    }
    // Slots serve on the stream process, leases snapshot on process 2,
    // the budget counter ticks on process 3.
    assert!(!named("slot").is_empty(), "completed slots must export spans");
    assert!(!named("repartition").is_empty(), "repartition verdicts must export");
    assert!(
        named("lease").iter().all(|e| e.get("pid").and_then(Json::as_u64) == Some(2)),
        "lease snapshots live on the lease process"
    );
    let windows = named("window_joules");
    assert!(!windows.is_empty(), "a metered run must export budget windows");
    assert!(windows.iter().all(|e| e.get("ph").and_then(Json::as_str) == Some("C")));
    assert!(windows.iter().all(|e| e.get("pid").and_then(Json::as_u64) == Some(3)));
    // Shed and preempt instants carry their attribution.
    let causes = [
        ShedCause::QueueAhead.label(),
        ShedCause::Queueing.label(),
        ShedCause::BudgetWait.label(),
        ShedCause::BatchLatency.label(),
    ];
    let sheds = named("shed");
    assert!(!sheds.is_empty(), "the overloaded deadline lane must shed");
    for e in &sheds {
        let cause = e.get("args").and_then(|a| a.get("cause")).and_then(Json::as_str);
        assert!(cause.is_some_and(|c| causes.contains(&c)), "unattributed shed: {e}");
    }
    assert!(!named("preempt").is_empty(), "the preemptive policy must cancel slots");
}

#[test]
fn attaching_a_recorder_changes_no_serving_outcome() {
    // The recorder must be a pure observer: bitwise-identical serving
    // outcomes with and without one attached (the behavioral half of
    // the zero-cost-when-off bar; the bench gates the time half).
    let streams = deadline_scenario(8, 42);
    let rec = Recorder::timeline();
    let mut cfg = metered_deadline_config();
    cfg.recorder = Some(rec.clone());
    let on = run_multi_stream_with(&sys(), &streams, cfg);
    let off = run_multi_stream_with(&sys(), &streams, metered_deadline_config());
    assert!(!rec.drain().is_empty());

    assert_eq!(on.total_completed, off.total_completed);
    assert_eq!(on.makespan, off.makespan);
    assert_eq!(on.engine.events_processed, off.engine.events_processed);
    assert_eq!(on.engine.sheds, off.engine.sheds);
    assert_eq!(on.engine.slot_preemptions, off.engine.slot_preemptions);
    // Snapshot fields, minus the host-clock ones (`handler_ns` and
    // `allocations` are wall-side and may differ when their features
    // are on; everything sim-side must be identical).
    assert_eq!(on.engine.telemetry.events_popped, off.engine.telemetry.events_popped);
    assert_eq!(on.engine.telemetry.heap_high_water, off.engine.telemetry.heap_high_water);
    assert_eq!(on.engine.telemetry.cache_probes, off.engine.telemetry.cache_probes);
    assert_eq!(on.engine.telemetry.cache_hits, off.engine.telemetry.cache_hits);
    for (a, b) in on.streams.iter().zip(&off.streams) {
        assert_eq!(a.partition, b.partition);
        assert_eq!(a.report.completions.len(), b.report.completions.len());
        for (ca, cb) in a.report.completions.iter().zip(&b.report.completions) {
            assert_eq!(ca.id, cb.id, "{}: service order diverged", a.name);
            assert_eq!(ca.start, cb.start, "{}: starts diverged", a.name);
            assert_eq!(ca.finish, cb.finish, "{}: finishes diverged", a.name);
        }
        assert_eq!(a.report.energy, b.report.energy);
        assert_eq!(a.report.p99_estimate, b.report.p99_estimate);
    }
}
