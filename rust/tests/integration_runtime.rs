//! Integration tests over the PJRT runtime + real-execution pipeline.
//!
//! These require `make artifacts` (they are skipped with a clear message
//! when the artifacts are missing, so `cargo test` works pre-AOT; `make
//! test` always builds artifacts first).

use dype::pipeline::{run_pipeline, ArgSource, KernelBinding, StageSpec};
use dype::runtime::{HostTensor, Runtime};
use dype::util::Rng;
use dype::workload::BlockEllGraph;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = dype::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_covers_all_pipeline_kernels() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let kernels = [
        "spmm", "gemm", "gin_mlp", "window_attn", "gcn_layer", "gin_layer", "transformer_layer",
    ];
    for name in kernels {
        assert!(rt.manifest().get(name).is_ok(), "artifact {name} missing");
    }
    assert_eq!(rt.manifest().graph_constant("V").unwrap(), 1024);
}

#[test]
fn gemm_artifact_computes_matmul() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    // a = row-constant matrix, b = identity ⇒ out == a.
    let mut a = vec![0f32; 1024 * 128];
    for (i, v) in a.iter_mut().enumerate() {
        *v = (i / 128) as f32 * 0.001;
    }
    let mut eye = vec![0f32; 128 * 128];
    for i in 0..128 {
        eye[i * 128 + i] = 1.0;
    }
    let out = rt
        .execute(
            "gemm",
            &[HostTensor::f32(a.clone(), &[1024, 128]), HostTensor::f32(eye, &[128, 128])],
        )
        .unwrap();
    let got = out.as_f32().unwrap();
    for (x, y) in got.iter().zip(&a) {
        assert!((x - y).abs() < 1e-5);
    }
}

#[test]
fn spmm_artifact_matches_dense_reference() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let g = BlockEllGraph::generate(8, 4, 128, 128, 9);
    let mut rng = Rng::seed_from_u64(1);
    let x: Vec<f32> = (0..1024 * 128).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
    let out = rt
        .execute(
            "spmm",
            &[
                HostTensor::f32(g.blocks.clone(), &[8, 4, 128, 128]),
                HostTensor::i32(g.indices.clone(), &[8, 4]),
                HostTensor::f32(x.clone(), &[1024, 128]),
            ],
        )
        .unwrap();
    let got = out.as_f32().unwrap();

    // Dense reference.
    let dense = g.to_dense();
    for row in (0..1024).step_by(97) {
        for col in (0..128).step_by(31) {
            let mut acc = 0f64;
            for k in 0..1024 {
                acc += dense[row * 1024 + k] as f64 * x[k * 128 + col] as f64;
            }
            let gotv = got[row * 128 + col] as f64;
            assert!(
                (gotv - acc).abs() < 1e-3 * acc.abs().max(1.0),
                "({row},{col}): {gotv} vs {acc}"
            );
        }
    }
}

#[test]
fn execute_rejects_wrong_arity_and_shape() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    // Wrong arity.
    assert!(rt.execute("gemm", &[HostTensor::f32(vec![0.0; 4], &[2, 2])]).is_err());
    // Wrong element count.
    let bad = rt.execute(
        "gemm",
        &[HostTensor::f32(vec![0.0; 4], &[2, 2]), HostTensor::f32(vec![0.0; 4], &[2, 2])],
    );
    assert!(bad.is_err());
}

#[test]
fn pipeline_streams_and_preserves_order() {
    let Some(dir) = artifact_dir() else { return };
    // Single-stage pipeline: gemm with identity weight — output == input,
    // so ordering is directly observable.
    let mut eye = vec![0f32; 128 * 128];
    for i in 0..128 {
        eye[i * 128 + i] = 1.0;
    }
    let stages = vec![StageSpec {
        name: "identity".into(),
        kernels: vec![KernelBinding {
            artifact: "gemm".into(),
            args: vec![ArgSource::Dynamic, ArgSource::Static(HostTensor::f32(eye, &[128, 128]))],
        }],
    }];
    let inputs: Vec<HostTensor> = (0..5)
        .map(|i| HostTensor::f32(vec![i as f32; 1024 * 128], &[1024, 128]))
        .collect();
    let report = run_pipeline(dir, stages, inputs).unwrap();
    assert_eq!(report.outputs.len(), 5);
    for (i, out) in report.outputs.iter().enumerate() {
        let v = out.as_f32().unwrap();
        assert!((v[0] - i as f32).abs() < 1e-5, "inference {i} out of order");
    }
    assert!(report.throughput > 0.0);
}

#[test]
fn two_stage_pipeline_composes_kernels() {
    let Some(dir) = artifact_dir() else { return };
    let g = BlockEllGraph::generate(8, 4, 128, 128, 42);
    let mut rng = Rng::seed_from_u64(3);
    let theta: Vec<f32> = (0..128 * 128).map(|_| rng.gen_range_f32(-0.05, 0.05)).collect();
    let blocks = HostTensor::f32(g.blocks.clone(), &[8, 4, 128, 128]);
    let indices = HostTensor::i32(g.indices.clone(), &[8, 4]);

    let stages = vec![
        StageSpec {
            name: "spmm".into(),
            kernels: vec![KernelBinding {
                artifact: "spmm".into(),
                args: vec![
                    ArgSource::Static(blocks.clone()),
                    ArgSource::Static(indices.clone()),
                    ArgSource::Dynamic,
                ],
            }],
        },
        StageSpec {
            name: "gemm".into(),
            kernels: vec![KernelBinding {
                artifact: "gemm".into(),
                args: vec![
                    ArgSource::Dynamic,
                    ArgSource::Static(HostTensor::f32(theta.clone(), &[128, 128])),
                ],
            }],
        },
    ];
    let x: Vec<f32> = (0..1024 * 128).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
    let report =
        run_pipeline(dir.clone(), stages, vec![HostTensor::f32(x.clone(), &[1024, 128])]).unwrap();

    // Monolithic re-execution for comparison.
    let mut rt = Runtime::new(&dir).unwrap();
    let y = rt.execute("spmm", &[blocks, indices, HostTensor::f32(x, &[1024, 128])]).unwrap();
    let want = rt.execute("gemm", &[y, HostTensor::f32(theta, &[128, 128])]).unwrap();
    let (got, want) = (report.outputs[0].as_f32().unwrap(), want.as_f32().unwrap());
    for (a, b) in got.iter().zip(want) {
        assert!((a - b).abs() < 1e-4);
    }
}
