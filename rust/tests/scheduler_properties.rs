//! Property-based tests for the scheduler core.
//!
//! The offline build has no proptest crate; these are seeded randomized
//! property sweeps over the in-tree SplitMix64 generator (DESIGN.md
//! §Substitutions) — deterministic, many-case, invariant-asserting.

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::devices::{DeviceType, GroundTruth};
use dype::perfmodel::OracleModels;
use dype::scheduler::{DpScheduler, ExhaustiveScheduler};
use dype::util::Rng;
use dype::workload::{KernelKind, Workload};

/// Random workload chain of 1..=6 kernels with GNN/transformer-like
/// characteristics.
fn random_workload(rng: &mut Rng) -> Workload {
    let n = rng.gen_range_usize(1, 7);
    let kinds: Vec<(String, KernelKind)> = (0..n)
        .map(|i| {
            let kind = match rng.gen_range_usize(0, 3) {
                0 => {
                    let m = rng.log_uniform(1e4, 2e6) as u64;
                    let density = rng.log_uniform(1e-6, 1e-3);
                    KernelKind::SpMM {
                        m,
                        k: m,
                        n: rng.log_uniform(16.0, 512.0) as u64,
                        nnz: ((m as f64 * m as f64 * density) as u64).max(m),
                    }
                }
                1 => KernelKind::Gemm {
                    m: rng.log_uniform(1e4, 2e6) as u64,
                    k: rng.log_uniform(16.0, 1024.0) as u64,
                    n: rng.log_uniform(16.0, 1024.0) as u64,
                },
                _ => {
                    let seq = rng.log_uniform(1024.0, 8192.0) as u64;
                    KernelKind::WindowAttn {
                        seq,
                        window: (rng.log_uniform(256.0, 2048.0) as u64).min(seq),
                        heads: 8,
                        dim: 64,
                    }
                }
            };
            (format!("k{i}"), kind)
        })
        .collect();
    Workload::new("prop", kinds)
}

fn random_system(rng: &mut Rng) -> SystemSpec {
    let ic = [Interconnect::Pcie4, Interconnect::Pcie5, Interconnect::Cxl3]
        [rng.gen_range_usize(0, 3)];
    let mut sys = SystemSpec::paper_testbed(ic);
    sys.n_fpga = rng.gen_range_usize(0, 4);
    sys.n_gpu = rng.gen_range_usize(0, 3);
    if sys.n_fpga == 0 && sys.n_gpu == 0 {
        sys.n_gpu = 1;
    }
    sys
}

/// Every schedule the DP emits is structurally valid, for every objective,
/// across random workloads × systems.
#[test]
fn prop_dp_schedules_always_valid() {
    let mut rng = Rng::seed_from_u64(0xA11CE);
    for case in 0..150 {
        let wl = random_workload(&mut rng);
        let sys = random_system(&mut rng);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let oracle = OracleModels { gt: &gt };
        let sched = DpScheduler::new(&sys, &oracle);
        for obj in Objective::paper_modes() {
            let s = sched.schedule(&wl, obj);
            s.validate(wl.len(), sys.n_fpga, sys.n_gpu).unwrap_or_else(|e| {
                panic!("case {case} ({}F{}G, {} kernels): {e}", sys.n_fpga, sys.n_gpu, wl.len())
            });
        }
    }
}

/// Perf mode dominates energy mode on throughput; energy mode dominates
/// perf mode on energy; balanced sits within its floor.
#[test]
fn prop_objective_ordering() {
    let mut rng = Rng::seed_from_u64(0xB0B);
    for _ in 0..100 {
        let wl = random_workload(&mut rng);
        let sys = random_system(&mut rng);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let oracle = OracleModels { gt: &gt };
        let sched = DpScheduler::new(&sys, &oracle);
        let p = sched.schedule(&wl, Objective::Performance);
        let e = sched.schedule(&wl, Objective::Energy);
        let b = sched.schedule(&wl, Objective::balanced());
        assert!(p.throughput() >= e.throughput() * (1.0 - 1e-9));
        assert!(e.energy_per_inf <= p.energy_per_inf * (1.0 + 1e-9));
        assert!(b.throughput() >= 0.7 * p.throughput() * (1.0 - 1e-6));
        assert!(b.energy_per_inf <= p.energy_per_inf * (1.0 + 1e-9));
    }
}

/// DP vs exhaustive enumeration on small instances: the DP must land on
/// (or within a hair of) the true optimum of the identical design space.
#[test]
fn prop_dp_near_exhaustive_optimum() {
    let mut rng = Rng::seed_from_u64(0xC0DE);
    let mut exact = 0usize;
    let mut total = 0usize;
    for _ in 0..60 {
        let wl = random_workload(&mut rng);
        if wl.len() > 5 {
            continue; // keep enumeration tractable
        }
        let sys = random_system(&mut rng);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let oracle = OracleModels { gt: &gt };
        let dp = DpScheduler::new(&sys, &oracle).schedule(&wl, Objective::Performance);
        let ex = ExhaustiveScheduler::new(&sys, &oracle).best(&wl, Objective::Performance).unwrap();
        total += 1;
        assert!(
            dp.period <= ex.period * 1.05,
            "DP {} ({}) far from optimum {} ({})",
            dp.period,
            dp.mnemonic(),
            ex.period,
            ex.mnemonic()
        );
        if dp.period <= ex.period * (1.0 + 1e-9) {
            exact += 1;
        }
    }
    // The DP's per-state greediness is provably lossy only in contrived
    // tie-structures; random instances should be solved exactly nearly
    // always.
    assert!(exact * 10 >= total * 9, "DP exact on only {exact}/{total} instances");
}

/// Adding devices never reduces the best achievable throughput.
#[test]
fn prop_monotone_in_resources() {
    let mut rng = Rng::seed_from_u64(0xD00D);
    for _ in 0..60 {
        let wl = random_workload(&mut rng);
        let mut small = random_system(&mut rng);
        small.n_fpga = small.n_fpga.min(2);
        small.n_gpu = small.n_gpu.clamp(1, 2);
        let mut big = small.clone();
        big.n_fpga += 1;
        big.n_gpu += 1;
        let gt_s = GroundTruth::new(small.gpu.clone(), small.fpga.clone(), small.comm_model());
        let gt_b = GroundTruth::new(big.gpu.clone(), big.fpga.clone(), big.comm_model());
        let thp_s = DpScheduler::new(&small, &OracleModels { gt: &gt_s })
            .schedule(&wl, Objective::Performance)
            .throughput();
        let thp_b = DpScheduler::new(&big, &OracleModels { gt: &gt_b })
            .schedule(&wl, Objective::Performance)
            .throughput();
        assert!(thp_b >= thp_s * (1.0 - 1e-9), "{thp_b} < {thp_s}");
    }
}

/// Type pins are always honored when feasible.
#[test]
fn prop_type_pins_honored() {
    let mut rng = Rng::seed_from_u64(0xF1A6);
    let mut feasible = 0;
    for _ in 0..80 {
        let wl = random_workload(&mut rng);
        let sys = random_system(&mut rng);
        if sys.n_fpga == 0 || sys.n_gpu == 0 {
            continue;
        }
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let oracle = OracleModels { gt: &gt };
        let pin = dype::scheduler::baselines::natural_type_pin();
        let sched = DpScheduler::new(&sys, &oracle)
            .with_type_pin(pin.clone())
            .try_schedule(&wl, Objective::Performance);
        if let Some(s) = sched {
            feasible += 1;
            for st in &s.stages {
                for k in st.first..=st.last {
                    if let Some(&want) = pin.get(wl.kernels[k].kind.tag()) {
                        assert_eq!(st.dev, want, "pin violated in {}", s.mnemonic());
                    }
                }
            }
        }
    }
    assert!(feasible > 10, "pinning should be feasible in a fair share of cases");
}

/// The DP's reported period and energy always match a from-scratch
/// re-evaluation of its own plan (internal consistency of the
/// incremental bookkeeping).
#[test]
fn prop_dp_bookkeeping_consistent() {
    let mut rng = Rng::seed_from_u64(0x5EED);
    for _ in 0..80 {
        let wl = random_workload(&mut rng);
        let sys = random_system(&mut rng);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let oracle = OracleModels { gt: &gt };
        let sched = DpScheduler::new(&sys, &oracle);
        let s = sched.schedule(&wl, Objective::Energy);
        let re = dype::scheduler::evaluate_plan(&wl, &s.plan(), &oracle, &sched.comm, &sched.power);
        assert!(
            (re.period - s.period).abs() <= 1e-9 * s.period,
            "period drift: dp {} vs re-eval {}",
            s.period,
            re.period
        );
        assert!(
            (re.energy_per_inf - s.energy_per_inf).abs() <= 1e-6 * s.energy_per_inf,
            "energy drift: dp {} vs re-eval {}",
            s.energy_per_inf,
            re.energy_per_inf
        );
    }
}

/// FPGA-pinned stages never run on systems without FPGAs — i.e. the DP
/// never fabricates devices.
#[test]
fn prop_no_device_fabrication() {
    let mut rng = Rng::seed_from_u64(0xFAB);
    for _ in 0..40 {
        let wl = random_workload(&mut rng);
        let mut sys = random_system(&mut rng);
        sys.n_fpga = 0;
        sys.n_gpu = sys.n_gpu.max(1);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let oracle = OracleModels { gt: &gt };
        let s = DpScheduler::new(&sys, &oracle).schedule(&wl, Objective::Performance);
        assert!(s.stages.iter().all(|st| st.dev == DeviceType::Gpu));
    }
}
