//! `dype lint` — integration and differential validation (DESIGN.md
//! §Static Analysis).
//!
//! The analyzer's value is that its verdicts *mean something*: every
//! error-class diagnostic here is validated differentially — a fixture
//! the linter flags, plus a simulator run proving the flagged outcome
//! actually happens (every request sheds, the low-priority lane
//! starves, the builder panics, the fleet constructor asserts). The
//! negative fixtures live in `scenarios/lint/` — deliberately
//! infeasible, excluded from the catalog tree-compare, and exercised by
//! CI's lint-smoke step expecting a nonzero exit.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;

use dype::analysis::{lint_fleet, lint_manifest, Severity};
use dype::devices::GroundTruth;
use dype::engine::{EngineConfig, Perturbation};
use dype::experiments::run_multi_stream_with;
use dype::fleet::{FleetConfig, ServingFleet};
use dype::perfmodel::OracleModels;
use dype::scenario::{catalog, ScenarioManifest};

fn fixture(name: &str) -> ScenarioManifest {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("scenarios/lint").join(name);
    ScenarioManifest::load(&path).unwrap_or_else(|e| panic!("{e:#}"))
}

// ---- the analyzer's verdicts on the checked-in inputs ------------------

#[test]
fn the_deadline_fixture_is_a_dy003_error() {
    let report = lint_manifest(&fixture("deadline_infeasible.json"));
    assert!(!report.is_clean(), "{}", report.render());
    let d = report.diagnostics.iter().find(|d| d.code == "DY003").expect("DY003 fires");
    assert_eq!(d.severity, Severity::Error, "{}", report.render());
    assert_eq!(d.key_path, "streams[0].slo.deadline");
}

#[test]
fn the_budget_fixture_is_a_dy004_error() {
    let report = lint_manifest(&fixture("budget_starved.json"));
    assert!(!report.is_clean(), "{}", report.render());
    let d = report.diagnostics.iter().find(|d| d.code == "DY004").expect("DY004 fires");
    assert_eq!(d.severity, Severity::Error, "{}", report.render());
    assert_eq!(d.key_path, "streams[1].slo.deadline");
}

/// The gate `dype scenario-sweep` runs over the zoo must never refuse
/// it: warnings are allowed, error-severity findings are not.
#[test]
fn the_whole_zoo_is_error_clean() {
    for m in catalog::all() {
        let report = lint_manifest(&m);
        assert!(report.is_clean(), "{}", report.render());
    }
}

// ---- differential validation: the simulator agrees ---------------------

/// DY003's claim is behavioral, not cosmetic: with the deadline below
/// every phase's zero-load batch floor, no request can ever attain it.
#[test]
fn simulator_agrees_the_doomed_deadline_attains_nothing() {
    let built = fixture("deadline_infeasible.json").build().expect("structurally valid");
    let cfg = built.apply(EngineConfig::default());
    let report = run_multi_stream_with(&built.system, &built.streams, cfg);
    let lane = &report.streams[0].report;
    assert_eq!(lane.completed + lane.shed, 12, "conservation");
    assert_eq!(lane.deadline_attainment, 0.0, "no request makes a 5 ms deadline");
    assert!(lane.shed >= 11, "an infeasible deadline sheds the trace, got {}", lane.shed);
}

/// DY004's claim: the top-priority lane drains every window, and the
/// low-priority deadline lane's deferrals become sheds.
#[test]
fn simulator_agrees_the_low_priority_lane_starves_under_the_budget() {
    let built = fixture("budget_starved.json").build().expect("structurally valid");
    let cfg = built.apply(EngineConfig::default());
    let report = run_multi_stream_with(&built.system, &built.streams, cfg);
    let mandatory = &report.streams[0].report;
    let starved = &report.streams[1].report;
    assert_eq!(mandatory.completed, 10, "no deadline: the mandatory lane always finishes");
    assert_eq!(mandatory.shed, 0);
    assert!(starved.completed <= 5, "starved lane completed {}", starved.completed);
    assert!(starved.shed >= 10, "starved lane shed only {} of 15", starved.shed);
    assert!(report.engine.budget_windows >= 1, "the budget was live");
}

/// DY001: a cut that empties the pool is an error, and the engine's
/// answer is the documented clamp — it keeps one GPU and finishes the
/// run rather than stranding it deviceless.
#[test]
fn simulator_survives_the_over_cut_the_linter_flags() {
    let mut m = catalog::device_failure();
    m.perturbations = vec![Perturbation::device_cut(0.6, 3, 2)];
    let report = lint_manifest(&m);
    assert!(report.has_code("DY001"), "{}", report.render());
    assert!(!report.is_clean(), "{}", report.render());

    let built = m.build().expect("an over-cut is value-valid; only lint objects");
    let cfg = built.apply(EngineConfig::default());
    let r = run_multi_stream_with(&built.system, &built.streams, cfg);
    assert_eq!(r.engine.perturbations_applied, 1, "the clamped cut still fires");
    let offered: usize = built.streams.iter().map(|s| s.trace.len()).sum();
    assert_eq!(r.total_completed + r.engine.sheds, offered, "the run still finishes");
}

/// DY007 (blocking): an out-of-range slo-tighten index. The linter
/// refuses it statically; the builder panics on the very same script —
/// the diagnostic exists so nobody has to find out the second way.
#[test]
fn out_of_range_slo_tighten_is_dy007_and_a_build_panic() {
    let mut m = catalog::multi_stream(2, 4, 9);
    m.perturbations.push(Perturbation::slo_tighten(0.5, 99, 0.5, 0.5));
    let report = lint_manifest(&m);
    assert!(report.has_code("DY007"), "{}", report.render());
    assert!(!report.is_clean(), "{}", report.render());
    let panicked = catch_unwind(AssertUnwindSafe(|| m.build())).is_err();
    assert!(panicked, "the builder panics on the same script lint refuses");
}

/// DY007 (non-blocking): scaling a budget the manifest never declares.
/// The engine treats the event as a no-op — it fires and changes
/// nothing — which is exactly why lint calls the script inconsistent.
#[test]
fn budget_scale_without_a_budget_is_dy007_and_an_engine_no_op() {
    let mut m = catalog::multi_stream(2, 4, 9);
    m.perturbations.push(Perturbation::budget_scale(0.5, 0.5));
    let report = lint_manifest(&m);
    assert!(report.has_code("DY007"), "{}", report.render());
    assert!(!report.is_clean(), "{}", report.render());

    let built = m.build().expect("value-valid");
    let cfg = built.apply(EngineConfig::default());
    let r = run_multi_stream_with(&built.system, &built.streams, cfg);
    assert_eq!(r.engine.budget_windows, 0, "no budget ever existed to scale");
    assert_eq!(r.engine.perturbations_applied, 1, "the event fires and does nothing");
}

/// DY009: more shards than devices. `lint_fleet` flags it statically;
/// `ServingFleet::new` asserts on the same shape (`split_pool` needs
/// inventory >= partitions) — the `dype fleet` gate runs the check
/// first so the CLI refuses instead of panicking.
#[test]
fn fleet_shape_errors_match_the_serving_fleet_assertion() {
    let m = catalog::fleet_balanced(); // 8 streams on a 12F + 8G pool
    let over = FleetConfig::new(21);
    let ds = lint_fleet(&m, &over);
    assert!(ds.iter().any(|d| d.code == "DY009" && d.severity == Severity::Error), "{ds:?}");

    let built = m.build().expect("manifest builds");
    let sys = built.system.clone();
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let cfg = FleetConfig {
        shards: 21,
        engine: built.apply(EngineConfig::default()),
        ..FleetConfig::default()
    };
    let panicked = catch_unwind(AssertUnwindSafe(|| ServingFleet::new(sys, &est, cfg))).is_err();
    assert!(panicked, "ServingFleet::new asserts on more shards than devices");

    let ok = lint_fleet(&m, &FleetConfig::new(4));
    assert!(ok.iter().all(|d| d.severity != Severity::Error), "{ok:?}");
}
