//! Property tests for the engine's per-request state machine on seeded
//! random scenarios (DESIGN.md §Scenarios).
//!
//! Every admitted request moves admission → (defer)* → dispatch →
//! (preempt/migrate)* → completion, or is shed exactly once at
//! admission. Whatever random mix of workloads, arrival curves, SLO
//! classes, budgets, and policies a seed produces, the run must uphold:
//!
//! * **conservation** — per stream, completions + sheds == offered, and
//!   completion ids are unique trace positions;
//! * **ordering** — per-stream latency percentiles are finite and
//!   monotone (p50 ≤ p90 ≤ p99);
//! * **energy** — when budgeted, Σ per-stream modeled energy equals the
//!   ledger's Σ window_joules (charged − refunded), and no refund pushes
//!   a window negative;
//! * **no panic** — the engine finishes every seeded scenario.
//!
//! The scenarios are built through [`dype::scenario`] manifests, so
//! this doubles as a fuzz of the manifest → engine lowering path.

use std::collections::BTreeSet;

use dype::config::{Interconnect, Objective};
use dype::engine::{MigrationMode, StreamSlo};
use dype::experiments::run_multi_stream_with;
use dype::scenario::sweep::Policy;
use dype::scenario::{
    Arrival, BudgetCfg, Phase, ScenarioManifest, StreamCfg, SystemCfg, WorkloadCfg,
};
use dype::util::Rng;

fn random_workload(rng: &mut Rng) -> WorkloadCfg {
    match rng.gen_range_usize(0, 3) {
        0 => WorkloadCfg::Gcn {
            code: "TF".to_string(),
            graph: "traffic".to_string(),
            vertices: 1_000_000,
            edges: [2_000_000, 20_000_000, 150_000_000][rng.gen_range_usize(0, 3)],
            feature_len: 200,
            degree_skew: 0.2,
            layers: 2,
            hidden: 128,
        },
        1 => WorkloadCfg::Gin {
            code: "PR".to_string(),
            graph: "products".to_string(),
            vertices: 400_000,
            edges: 1_200_000,
            feature_len: 100,
            degree_skew: 0.6,
            layers: 3,
            hidden: 64,
            mlp_layers: 2,
        },
        _ => WorkloadCfg::Transformer {
            seq: [2048, 4096][rng.gen_range_usize(0, 2)],
            window: 512,
            layers: 8,
        },
    }
}

fn random_arrival(rng: &mut Rng) -> Arrival {
    let rate = rng.gen_range_f64(5.0, 40.0);
    match rng.gen_range_usize(0, 3) {
        0 => Arrival::Poisson { rate },
        1 => Arrival::Diurnal { base_rate: rate, peak_rate: rate * 4.0, period: 1.5 },
        _ => Arrival::FlashCrowd {
            base_rate: rate,
            peak_rate: rate * 6.0,
            start: 0.2,
            duration: 0.4,
        },
    }
}

fn random_slo(rng: &mut Rng) -> StreamSlo {
    let priority = rng.gen_range_f64(1.0, 4.0);
    let mut slo = match rng.gen_range_usize(0, 3) {
        0 => StreamSlo::best_effort(priority),
        1 => StreamSlo::target(rng.gen_range_f64(0.1, 0.4), priority),
        _ => StreamSlo::target(0.15, priority).with_deadline(rng.gen_range_f64(0.25, 2.0)),
    };
    if rng.gen_range_usize(0, 2) == 1 {
        slo = slo.with_migration(match rng.gen_range_usize(0, 2) {
            0 => MigrationMode::Drain,
            _ => MigrationMode::Preempt { min_remaining: 0.005 },
        });
    }
    slo
}

/// A whole random scenario from one seed: 2–4 streams, 4–10 requests
/// each, sometimes a power cap. Small on purpose — 8 seeds must stay
/// CI-speed — but every state-machine transition is reachable.
fn random_manifest(seed: u64) -> ScenarioManifest {
    let mut rng = Rng::seed_from_u64(seed);
    let n_streams = rng.gen_range_usize(2, 5);
    let streams = (0..n_streams)
        .map(|i| StreamCfg {
            name: format!("lane-{i}"),
            objective: Objective::Performance,
            seed: seed * 100 + i as u64,
            arrival: random_arrival(&mut rng),
            phases: vec![Phase {
                workload: random_workload(&mut rng),
                count: rng.gen_range_usize(4, 11),
            }],
            slo: random_slo(&mut rng),
        })
        .collect();
    let budget = if rng.gen_range_usize(0, 2) == 1 {
        Some(BudgetCfg { cap_watts: rng.gen_range_f64(200.0, 600.0), window: 0.25 })
    } else {
        None
    };
    ScenarioManifest {
        name: format!("fuzz-{seed}"),
        description: "seeded random state-machine scenario".to_string(),
        system: SystemCfg { n_fpga: 3, n_gpu: 2, interconnect: Interconnect::Pcie4 },
        streams,
        budget,
        perturbations: vec![],
        telemetry: false,
    }
}

#[test]
fn random_scenarios_uphold_the_state_machine_invariants() {
    for seed in 0..8u64 {
        let m = random_manifest(seed);
        let policy = Policy::ALL[(seed as usize) % Policy::ALL.len()];
        let label = format!("seed {seed} under {}", policy.name());

        let built = m.build().unwrap_or_else(|e| panic!("{label}: {e:#}"));
        let cfg = built.apply(policy.engine_config());
        let budgeted = cfg.energy_budget.is_some();
        let r = run_multi_stream_with(&built.system, &built.streams, cfg);

        for (sr, spec) in r.streams.iter().zip(&built.streams) {
            let lane = format!("{label}/{}", sr.name);
            // Conservation: every request settles exactly once.
            assert_eq!(
                sr.report.completed + sr.report.shed,
                spec.trace.len(),
                "{lane}: {} completed + {} shed != {} offered",
                sr.report.completed,
                sr.report.shed,
                spec.trace.len()
            );
            // Completion ids are unique positions of this stream's trace.
            let ids: BTreeSet<usize> = sr.report.completions.iter().map(|c| c.id).collect();
            assert_eq!(ids.len(), sr.report.completions.len(), "{lane}: duplicate completion");
            assert!(ids.iter().all(|id| *id < spec.trace.len()), "{lane}: alien completion id");
            // Latency percentiles are finite and monotone.
            if sr.report.completed > 0 {
                assert!(sr.report.p50_latency > 0.0, "{lane}");
                assert!(sr.report.p50_latency <= sr.report.p90_latency, "{lane}");
                assert!(sr.report.p90_latency <= sr.report.p99_latency, "{lane}");
                assert!(sr.report.p99_latency.is_finite(), "{lane}");
            }
        }

        if budgeted {
            // f_eng conservation: windows hold exactly what the streams'
            // batches were charged, refunds included, none negative.
            let charged = r.engine.joules_charged();
            let modeled: f64 = r.streams.iter().map(|sr| sr.report.energy).sum();
            let tol = modeled.abs() * 1e-9 + 1e-12;
            assert!(
                (charged - modeled).abs() < tol,
                "{label}: windows {charged} J vs modeled {modeled} J"
            );
            assert!(
                r.engine.window_joules.iter().all(|j| *j >= 0.0),
                "{label}: negative budget window: {:?}",
                r.engine.window_joules
            );
        }
    }
}
