//! Minimal JSON parser + writer — enough for `artifacts/manifest.json`
//! and the scenario manifests under `scenarios/`.
//!
//! The offline build has no `serde_json`; this recursive-descent parser
//! covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) with precise error positions. The writer is
//! the [`fmt::Display`] impl: compact output, object keys in `BTreeMap`
//! order (deterministic), numbers via Rust's shortest-round-trip `f64`
//! formatting — `parse(v.to_string())` always reproduces `v` bit for
//! bit. Both run only on the control path (manifest loading/saving),
//! never per-request.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Escape a string for inclusion in a JSON document (adds the quotes).
fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            '\u{8}' => f.write_str("\\b")?,
            '\u{c}' => f.write_str("\\f")?,
            c if u32::from(c) < 0x20 => write!(f, "\\u{:04x}", u32::from(c))?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

impl fmt::Display for Json {
    /// Compact serialization; `parse(x.to_string()) == x` for any tree
    /// whose numbers are finite (non-finite numbers have no JSON
    /// representation and panic — they never belong in a manifest).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                assert!(n.is_finite(), "non-finite number {n} cannot be serialized as JSON");
                // Rust's f64 Display is the shortest string that parses
                // back to the same bits, so round-trips are exact.
                write!(f, "{n}")
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{x}")?;
                }
                f.write_str("}")
            }
        }
    }
}

/// A dotted key path into a JSON document — `streams[2].slo.deadline` —
/// built incrementally as a strict codec descends its schema. Every
/// codec error carries one of these, so a parse failure names the exact
/// offending node instead of a flat message, and the static analyzer
/// ([`crate::analysis`]) reuses the same notation for its
/// `Diagnostic::key_path`.
///
/// [`fmt::Display`] falls back to the root label (e.g. `manifest`)
/// while the path is still empty, so top-level errors stay readable;
/// [`Self::as_str`] returns the bare path (empty at the root).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KeyPath {
    label: &'static str,
    path: String,
}

impl KeyPath {
    /// A fresh path at the document root. `label` is what [`fmt::Display`]
    /// prints while no keys have been pushed.
    pub fn root(label: &'static str) -> KeyPath {
        KeyPath { label, path: String::new() }
    }

    /// Descend into an object field: `a` → `a.b` (or `b` at the root).
    #[must_use]
    pub fn key(&self, key: &str) -> KeyPath {
        let path = if self.path.is_empty() {
            key.to_string()
        } else {
            format!("{}.{key}", self.path)
        };
        KeyPath { label: self.label, path }
    }

    /// Descend into an array element: `a` → `a[i]`.
    #[must_use]
    pub fn index(&self, i: usize) -> KeyPath {
        KeyPath { label: self.label, path: format!("{}[{i}]", self.path) }
    }

    /// The bare dotted path — empty at the root (no label).
    pub fn as_str(&self) -> &str {
        &self.path
    }
}

impl fmt::Display for KeyPath {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.path.is_empty() {
            f.write_str(self.label)
        } else {
            f.write_str(&self.path)
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = &self.b[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = parse(
            r#"{"artifacts": {"gemm": {"file": "g.hlo.txt", "inputs":
               [{"shape": [1024, 128], "dtype": "float32"}]}},
               "constants": {"graph": {"V": 1024}}}"#,
        )
        .unwrap();
        let gemm = j.get("artifacts").unwrap().get("gemm").unwrap();
        assert_eq!(gemm.get("file").unwrap().as_str(), Some("g.hlo.txt"));
        let shape = gemm.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.idx(0).unwrap().as_u64(), Some(1024));
        assert_eq!(
            j.get("constants").unwrap().get("graph").unwrap().get("V").unwrap().as_u64(),
            Some(1024)
        );
    }

    #[test]
    fn parses_scalars_and_numbers() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("1").unwrap().as_bool(), None);
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn nested_arrays() {
        let j = parse("[[1,2],[3]]").unwrap();
        assert_eq!(j.idx(0).unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(j.idx(1).unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn serializer_round_trips_structures() {
        let src = r#"{"a": [1, 2.5, -3e-2], "b": {"c": true, "d": null}, "e": "x\n\"y\"\\z"}"#;
        let j = parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(parse(&out).unwrap(), j, "parse(serialize(x)) == x");
        // Keys come out in BTreeMap order and output is compact.
        assert!(out.starts_with(r#"{"a":[1,2.5,"#), "got {out}");
        assert!(!out.contains(' '), "compact output, got {out}");
    }

    #[test]
    fn serializer_round_trips_f64_bits() {
        let exp = -(1.0f64 - 0.731).ln() / 40.0;
        for x in [0.1, 1.0 / 3.0, 40.0, exp, f64::MIN_POSITIVE, 1e300] {
            let out = Json::Num(x).to_string();
            let back = parse(&out).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} → {out} → {back}");
        }
    }

    #[test]
    fn serializer_escapes_control_chars() {
        let j = Json::Str("a\u{1}b\u{8}".into());
        let out = j.to_string();
        assert_eq!(out, "\"a\\u0001b\\b\"");
        assert_eq!(parse(&out).unwrap(), j);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn serializer_rejects_non_finite() {
        let _ = Json::Num(f64::NAN).to_string();
    }

    #[test]
    fn key_paths_render_dotted_with_indices() {
        let root = KeyPath::root("manifest");
        assert_eq!(root.to_string(), "manifest", "empty path shows the root label");
        assert_eq!(root.as_str(), "", "bare path is empty at the root");
        let leaf = root.key("streams").index(2).key("slo").key("deadline");
        assert_eq!(leaf.to_string(), "streams[2].slo.deadline");
        assert_eq!(leaf.as_str(), "streams[2].slo.deadline");
        assert_eq!(root.key("rates").index(0).to_string(), "rates[0]");
        // Branching from a shared prefix never mutates the parent.
        let streams = root.key("streams");
        let a = streams.index(0).key("seed");
        let b = streams.index(1).key("arrival").key("rate");
        assert_eq!(a.to_string(), "streams[0].seed");
        assert_eq!(b.to_string(), "streams[1].arrival.rate");
        assert_eq!(streams.to_string(), "streams");
    }
}
