//! A minimal indexed worker pool over `std::thread::scope` — the shared
//! fan-out machinery for the fleet layer ([`crate::fleet`]) and the
//! parallel sweep ([`crate::scenario::sweep::run_grid_parallel`]).
//!
//! Jobs are identified by index; workers pull the next index from one
//! atomic counter (work-stealing in its simplest form — an idle worker
//! takes whatever job is next, so one slow job never serializes the
//! rest), and results are collected **by job index**, never by
//! completion order. That indexing discipline is what makes parallel
//! runs deterministic: as long as each job is itself a pure function of
//! its index, the output vector is byte-identical to a serial loop —
//! the property the sweep's serial/parallel equivalence test pins.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `n` indexed jobs on up to `threads` OS threads and return their
/// results in job-index order. `f` is called exactly once per index in
/// `0..n`, from whichever worker claims it. Panics in a job propagate
/// to the caller (the scope re-raises them on join).
///
/// `threads == 1` degenerates to an in-order serial loop on one spawned
/// worker; the output is identical either way — only wall time varies.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(threads >= 1, "a pool needs at least one worker");
    if n == 0 {
        return Vec::new();
    }
    let workers = threads.min(n);
    let next = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(i)));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
    });
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            debug_assert!(slots[i].is_none(), "job {i} claimed twice");
            slots[i] = Some(v);
        }
    }
    slots.into_iter().map(|s| s.expect("every job index was claimed exactly once")).collect()
}

/// The host's available parallelism, floored at 1 — the default worker
/// count for [`run_indexed`] call sites that take a thread knob.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order() {
        for threads in [1, 2, 8] {
            let out = run_indexed(37, threads, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_oversized_pools_are_fine() {
        let none: Vec<usize> = run_indexed(0, 4, |i| i);
        assert!(none.is_empty());
        let out = run_indexed(2, 64, |i| i + 1);
        assert_eq!(out, vec![1, 2], "more workers than jobs");
    }

    #[test]
    fn every_index_runs_exactly_once() {
        use std::sync::atomic::AtomicU64;
        let calls = AtomicU64::new(0);
        let out = run_indexed(101, 8, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 101);
        assert_eq!(out.len(), 101);
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn job_panics_propagate() {
        run_indexed(4, 2, |i| {
            if i == 2 {
                panic!("job 2 exploded");
            }
            i
        });
    }
}
