//! Micro-benchmark harness — the offline stand-in for criterion.
//!
//! Provides warmup + repeated timed runs with median/mean/stddev
//! reporting. Used by `benches/scheduler_perf.rs` and the per-table
//! harnesses (which are primarily *result generators*: they print the
//! paper's rows, and use this module for the timing-sensitive parts).

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>10} {:>10} {:>10} ± {:>8}   [{} iters]",
            self.name,
            fmt_time(self.median),
            fmt_time(self.min),
            fmt_time(self.mean),
            fmt_time(self.stddev),
            self.iters
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Time `f` with `warmup` discarded runs followed by `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    BenchStats {
        name: name.to_string(),
        iters,
        mean,
        median: samples[samples.len() / 2],
        stddev: var.sqrt(),
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Standard bench-output header (align with `BenchStats::report`).
pub fn header() -> String {
    format!("{:<42} {:>10} {:>10} {:>10}   {:>8}", "benchmark", "median", "min", "mean", "stddev")
}

/// Append `(name, median seconds)` entries to the perf-trajectory file
/// named by the `DYPE_BENCH_JSON` env var, one JSON object per line
/// (`{"bench": ..., "median_ns": ...}`). No-op when the variable is
/// unset, so bench binaries stay silent outside the CI `bench-smoke`
/// job, which concatenates the lines from every bench it runs into the
/// `BENCH_serving.json` artifact. Names are code-supplied identifiers
/// (no escaping is performed).
pub fn record_json(entries: &[(String, f64)]) {
    let Ok(path) = std::env::var("DYPE_BENCH_JSON") else {
        return;
    };
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .unwrap_or_else(|e| panic!("DYPE_BENCH_JSON={path}: {e}"));
    for (name, secs) in entries {
        writeln!(f, "{{\"bench\":\"{}\",\"median_ns\":{:.1}}}", name, secs * 1e9)
            .unwrap_or_else(|e| panic!("DYPE_BENCH_JSON={path}: {e}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let mut x = 0u64;
        let s = bench("noop-ish", 2, 20, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert_eq!(s.iters, 20);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mean > 0.0);
    }

    #[test]
    fn formats_scale() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
