//! Deterministic PRNG — SplitMix64.
//!
//! The offline build has no `rand` crate; this is the project-wide
//! replacement. SplitMix64 passes BigCrush, is seedable, and is more than
//! adequate for synthetic-data generation and measurement-noise hashing.
//! Every consumer seeds explicitly, so all experiments reproduce
//! bit-identically.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        // 53 top bits → [0, 1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn gen_f32(&mut self) -> f32 {
        self.gen_f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.gen_f32() * (hi - lo)
    }

    /// Uniform integer in `[lo, hi)` (`hi > lo`).
    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Log-uniform in `[lo, hi]` — the calibration harness's sampler.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        (lo.ln() + self.gen_f64() * (hi.ln() - lo.ln())).exp()
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn log_uniform_spans_decades() {
        let mut r = Rng::seed_from_u64(2);
        let (mut lo_seen, mut hi_seen) = (f64::INFINITY, 0.0f64);
        for _ in 0..1000 {
            let x = r.log_uniform(1e-7, 1e-2);
            assert!((1e-7..=1e-2).contains(&x));
            lo_seen = lo_seen.min(x);
            hi_seen = hi_seen.max(x);
        }
        assert!(lo_seen < 1e-6 && hi_seen > 1e-3, "should cover the range");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn normal_has_zero_mean_unit_var() {
        let mut r = Rng::seed_from_u64(4);
        let xs: Vec<f64> = (0..20_000).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
