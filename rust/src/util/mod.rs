//! Offline-build utilities: deterministic RNG, JSON parsing, and the
//! micro-bench harness (stand-ins for `rand`, `serde_json`, `criterion` —
//! unavailable in this vendored build; see DESIGN.md §Substitutions).

pub mod bench;
pub mod json;
pub mod pool;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
