//! Mid-run perturbations — scripted "what breaks at t=…" events.
//!
//! The paper's study is static breadth: 86 workload×system cells, each
//! served by one fixed configuration. The scenario zoo adds the dynamic
//! axis: a manifest can schedule perturbations that mutate the *live*
//! system mid-run — devices disappearing from the pool, the energy
//! budget shrinking, an SLO tightening — so the sweep compares policies
//! on how they *re-adapt*, not just on how they start. Each entry in
//! [`super::EngineConfig::perturbations`] becomes one
//! [`super::EventKind::Perturbation`] on the event heap; the handler
//! applies the mutation and forces an immediate lease re-validation.

use super::slo::StreamSlo;

/// What a scheduled perturbation does when it fires.
#[derive(Debug, Clone, PartialEq)]
pub enum PerturbationKind {
    /// Remove devices from the pool (saturating; a cut that would empty
    /// the pool keeps one GPU so the run can still finish). Leases are
    /// re-apportioned over the shrunken pool at the same timestamp.
    DeviceCut { n_fpga: usize, n_gpu: usize },
    /// Multiply the energy budget's per-window refill *and* the open
    /// window's balance by `factor` (see
    /// [`super::budget`]'s scale semantics). A no-op when the engine
    /// runs unbudgeted.
    BudgetScale { factor: f64 },
    /// Tighten (or loosen) stream `stream`'s SLO in place: its p99
    /// target and deadline are multiplied by the respective scale, when
    /// present. Scales of 1.0 leave the knob untouched.
    SloTighten { stream: usize, p99_scale: f64, deadline_scale: f64 },
}

impl PerturbationKind {
    /// Stable short name used by telemetry records and trace exports.
    pub fn label(&self) -> &'static str {
        match self {
            PerturbationKind::DeviceCut { .. } => "device-cut",
            PerturbationKind::BudgetScale { .. } => "budget-scale",
            PerturbationKind::SloTighten { .. } => "slo-tighten",
        }
    }
}

/// One scheduled mid-run perturbation: at engine time `at`, apply `kind`.
#[derive(Debug, Clone, PartialEq)]
pub struct Perturbation {
    /// Engine-clock firing time (s), strictly positive and finite.
    pub at: f64,
    pub kind: PerturbationKind,
}

impl Perturbation {
    pub fn device_cut(at: f64, n_fpga: usize, n_gpu: usize) -> Perturbation {
        Perturbation { at, kind: PerturbationKind::DeviceCut { n_fpga, n_gpu } }
    }

    pub fn budget_scale(at: f64, factor: f64) -> Perturbation {
        Perturbation { at, kind: PerturbationKind::BudgetScale { factor } }
    }

    pub fn slo_tighten(at: f64, stream: usize, p99_scale: f64, deadline_scale: f64) -> Self {
        let kind = PerturbationKind::SloTighten { stream, p99_scale, deadline_scale };
        Perturbation { at, kind }
    }

    /// Panic on malformed perturbations before the run starts (the same
    /// eager-validation stance as [`StreamSlo::validate`]): firing times
    /// must be positive finite, cuts must cut something, scales must be
    /// positive finite (budget scale may be zero — a total blackout),
    /// and stream indices must exist.
    pub fn validate(&self, n_streams: usize) {
        assert!(
            self.at > 0.0 && self.at.is_finite(),
            "perturbation time {} must be positive and finite",
            self.at
        );
        match self.kind {
            PerturbationKind::DeviceCut { n_fpga, n_gpu } => {
                assert!(n_fpga + n_gpu >= 1, "a device cut must remove at least one device");
            }
            PerturbationKind::BudgetScale { factor } => {
                assert!(
                    factor >= 0.0 && factor.is_finite(),
                    "bad budget scale factor {factor}"
                );
            }
            PerturbationKind::SloTighten { stream, p99_scale, deadline_scale } => {
                assert!(stream < n_streams, "perturbation targets stream {stream} of {n_streams}");
                for s in [p99_scale, deadline_scale] {
                    assert!(s > 0.0 && s.is_finite(), "bad SLO scale {s}");
                }
            }
        }
    }

    /// Apply an [`PerturbationKind::SloTighten`] to a lane's SLO in
    /// place, re-validating the result so a degenerate scale fails loudly
    /// instead of feeding the controller a non-positive target.
    pub(crate) fn tighten_slo(slo: &mut StreamSlo, p99_scale: f64, deadline_scale: f64) {
        if let Some(t) = slo.p99_target.as_mut() {
            *t *= p99_scale;
        }
        if let Some(d) = slo.deadline.as_mut() {
            *d *= deadline_scale;
        }
        slo.validate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctors_round_trip_the_kind() {
        let cut = Perturbation::device_cut(1.5, 1, 0);
        assert_eq!(cut.kind, PerturbationKind::DeviceCut { n_fpga: 1, n_gpu: 0 });
        assert_eq!(cut.kind.label(), "device-cut");
        cut.validate(1);
        let cap = Perturbation::budget_scale(2.0, 0.0);
        cap.validate(1); // zero factor = blackout, legal
        assert_eq!(cap.kind.label(), "budget-scale");
        let slo = Perturbation::slo_tighten(1.0, 2, 0.5, 0.5);
        slo.validate(3);
        assert_eq!(slo.kind.label(), "slo-tighten");
    }

    #[test]
    #[should_panic(expected = "must be positive and finite")]
    fn rejects_non_positive_times() {
        Perturbation::device_cut(0.0, 1, 0).validate(1);
    }

    #[test]
    #[should_panic(expected = "must remove at least one device")]
    fn rejects_empty_cuts() {
        Perturbation::device_cut(1.0, 0, 0).validate(1);
    }

    #[test]
    #[should_panic(expected = "targets stream 3 of 3")]
    fn rejects_out_of_range_stream_indices() {
        Perturbation::slo_tighten(1.0, 3, 0.5, 1.0).validate(3);
    }

    #[test]
    fn tighten_scales_only_present_knobs() {
        let mut slo = StreamSlo::target(0.100, 2.0).with_deadline(0.250);
        Perturbation::tighten_slo(&mut slo, 0.5, 0.4);
        assert_eq!(slo.p99_target, Some(0.050));
        assert_eq!(slo.deadline, Some(0.100));
        let mut bare = StreamSlo::best_effort(1.0);
        Perturbation::tighten_slo(&mut bare, 0.5, 0.5);
        assert_eq!(bare.p99_target, None);
        assert_eq!(bare.deadline, None);
    }
}
