//! Per-stream service-level objectives and the feedback controller that
//! turns p99 misses into device-lease weight.
//!
//! PR 1 partitioned the pool on offered FLOP rate alone; a deployment
//! cares about *latency targets* per stream, not just demand. Each
//! [`crate::coordinator::StreamSpec`] now carries a [`StreamSlo`]:
//!
//! * `p99_target` — the stream's tail-latency SLO (s), if any;
//! * `priority` — the QoS class the energy-budget deferral orders by
//!   ([`super::budget`]), and a static multiplier on lease weight.
//!
//! The [`SloController`] closes the loop: at every lease re-validation
//! the engine computes each stream's observed p99 (from its completions,
//! via [`crate::metrics::percentile`]) and scales its demand estimate by
//!
//! ```text
//! wᵢ = priorityᵢ · clamp((p99ᵢ_observed / p99ᵢ_target)^gain,
//!                        1/max_boost, max_boost)
//! ```
//!
//! so a stream missing its target bids for more of the pool and a stream
//! comfortably beating it cedes slack — replacing pure demand shares for
//! both exclusive partitions and oversubscribed time-slice groups
//! (weights flow through [`super::lease::assign`], whose intra-group
//! time shares follow the same weighted demands). With default SLOs
//! (no target, priority 1) every weight is exactly 1 and the engine is
//! bit-identical to the demand-only partitioning.

use crate::metrics::percentile;

/// One stream's service-level objective.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSlo {
    /// Tail-latency target (s): the stream wants `p99 <= p99_target`.
    /// `None` means best-effort (no latency feedback).
    pub p99_target: Option<f64>,
    /// QoS priority, higher is more important. Strictly lower-priority
    /// streams are deferred first when the energy budget is exhausted,
    /// and lease weight scales linearly with priority.
    pub priority: f64,
}

impl Default for StreamSlo {
    /// Best-effort, unit priority — the weight-neutral SLO every legacy
    /// scenario implicitly ran with.
    fn default() -> Self {
        StreamSlo { p99_target: None, priority: 1.0 }
    }
}

impl StreamSlo {
    pub fn new(p99_target: Option<f64>, priority: f64) -> StreamSlo {
        let slo = StreamSlo { p99_target, priority };
        slo.validate();
        slo
    }

    /// Re-check the constructor invariants. The engine calls this on
    /// every stream at serve time because the fields are public — an
    /// instance built by struct literal can smuggle a NaN priority past
    /// [`StreamSlo::new`], and NaN comparisons would wedge the budget
    /// deferral ordering (mirrors the re-validation in
    /// [`super::budget::BudgetLedger`]).
    pub fn validate(&self) {
        if let Some(t) = self.p99_target {
            assert!(t > 0.0 && t.is_finite(), "non-positive p99 target {t}");
        }
        assert!(
            self.priority > 0.0 && self.priority.is_finite(),
            "non-positive priority {}",
            self.priority
        );
    }

    /// A latency-SLO'd stream: p99 target in seconds, with a priority.
    pub fn target(p99_target: f64, priority: f64) -> StreamSlo {
        StreamSlo::new(Some(p99_target), priority)
    }

    /// No latency target, just a QoS priority.
    pub fn best_effort(priority: f64) -> StreamSlo {
        StreamSlo::new(None, priority)
    }
}

/// Proportional feedback from observed-vs-target p99 to lease weight.
/// Always present in [`super::EngineConfig`]; with default [`StreamSlo`]s
/// it is the identity (weight = demand), so it is opt-in per stream, not
/// per engine.
#[derive(Debug, Clone)]
pub struct SloController {
    /// Exponent on the observed/target p99 ratio. 1.0 = proportional.
    pub gain: f64,
    /// Clamp on the pressure term: weights stay within
    /// `[priority/max_boost, priority·max_boost]` so one violating
    /// stream cannot starve the rest of the pool.
    pub max_boost: f64,
}

impl Default for SloController {
    fn default() -> Self {
        SloController { gain: 1.0, max_boost: 4.0 }
    }
}

impl SloController {
    /// The lease weight multiplier for one stream: its priority times the
    /// clamped SLO pressure. Streams without a target, or without enough
    /// completions to observe a p99, weigh in at exactly `priority`.
    pub fn weight(&self, slo: &StreamSlo, observed_p99: Option<f64>) -> f64 {
        assert!(self.gain > 0.0 && self.gain.is_finite(), "non-positive gain {}", self.gain);
        assert!(self.max_boost >= 1.0, "max_boost {} below 1", self.max_boost);
        let pressure = match (slo.p99_target, observed_p99) {
            (Some(target), Some(p99)) => {
                (p99 / target).powf(self.gain).clamp(1.0 / self.max_boost, self.max_boost)
            }
            _ => 1.0,
        };
        slo.priority * pressure
    }
}

/// Observed p99 of a latency sample (any order), `None` when empty —
/// the controller's measurement side.
pub fn observed_p99(latencies: &[f64]) -> Option<f64> {
    if latencies.is_empty() {
        return None;
    }
    let mut xs = latencies.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(percentile(&xs, 0.99))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_slo_is_weight_neutral() {
        let c = SloController::default();
        assert_eq!(c.weight(&StreamSlo::default(), None), 1.0);
        assert_eq!(c.weight(&StreamSlo::default(), Some(10.0)), 1.0, "no target, no feedback");
        assert_eq!(c.weight(&StreamSlo::target(0.1, 1.0), None), 1.0, "no sample, no feedback");
    }

    #[test]
    fn violating_stream_gains_weight_meeting_stream_cedes_it() {
        let c = SloController::default();
        let slo = StreamSlo::target(0.100, 1.0);
        let missing = c.weight(&slo, Some(0.200)); // 2x over target
        let meeting = c.weight(&slo, Some(0.050)); // 2x under target
        assert!((missing - 2.0).abs() < 1e-12, "missing {missing}");
        assert!((meeting - 0.5).abs() < 1e-12, "meeting {meeting}");
    }

    #[test]
    fn pressure_is_clamped_and_priority_scales() {
        let c = SloController::default();
        let slo = StreamSlo::target(1e-6, 3.0);
        let w = c.weight(&slo, Some(10.0)); // 1e7x over target
        assert!((w - 3.0 * 4.0).abs() < 1e-12, "boost must clamp at max_boost: {w}");
        let floor = c.weight(&StreamSlo::target(1e6, 2.0), Some(1e-3));
        assert!((floor - 2.0 / 4.0).abs() < 1e-12, "cede clamps at 1/max_boost: {floor}");
    }

    #[test]
    fn observed_p99_is_the_tail_not_the_median() {
        assert_eq!(observed_p99(&[]), None);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(observed_p99(&xs), Some(99.0));
    }

    #[test]
    #[should_panic(expected = "non-positive priority")]
    fn rejects_zero_priority() {
        StreamSlo::best_effort(0.0);
    }

    #[test]
    #[should_panic(expected = "non-positive priority")]
    fn validate_catches_struct_literal_nan_priority() {
        // The fields are public; the engine re-validates at serve time.
        StreamSlo { p99_target: None, priority: f64::NAN }.validate();
    }

    #[test]
    #[should_panic(expected = "non-positive p99 target")]
    fn rejects_zero_target() {
        StreamSlo::target(0.0, 1.0);
    }
}
