//! Per-stream service-level objectives and the feedback controller that
//! turns p99 misses into device-lease weight.
//!
//! PR 1 partitioned the pool on offered FLOP rate alone; a deployment
//! cares about *latency targets* per stream, not just demand. Each
//! [`crate::coordinator::StreamSpec`] now carries a [`StreamSlo`]:
//!
//! * `p99_target` — the stream's tail-latency SLO (s), if any;
//! * `deadline` — a hard per-request latency bound (s), if any: a request
//!   that can no longer finish inside it is **shed** at admission time
//!   instead of served late or budget-deferred (the engine's feasibility
//!   check, see `engine/mod.rs`) — the "shed instead of defer" SLO class
//!   the p99 feedback controller cannot express;
//! * `priority` — the QoS class the energy-budget deferral orders by
//!   ([`super::budget`]), and a static multiplier on lease weight;
//! * `migration` — an optional per-stream override of the repartition
//!   policy's [`MigrationMode`]: a latency-critical lane can preempt its
//!   in-flight slot at migration while a bulk lane on the same policy
//!   drains, tying handoff aggressiveness to task criticality the way
//!   HTS does.
//!
//! The [`SloController`] closes the loop: at every lease re-validation
//! the engine computes each stream's observed p99 (from its completions,
//! via [`crate::metrics::percentile`]) and scales its demand estimate by
//!
//! ```text
//! wᵢ = priorityᵢ · clamp((p99ᵢ_observed / p99ᵢ_target)^gain,
//!                        1/max_boost, max_boost)
//! ```
//!
//! so a stream missing its target bids for more of the pool and a stream
//! comfortably beating it cedes slack — replacing pure demand shares for
//! both exclusive partitions and oversubscribed time-slice groups
//! (weights flow through [`super::lease::assign`], whose intra-group
//! time shares follow the same weighted demands). An optional clamped
//! **integral term** ([`SloController::integral_gain`]) accumulates
//! persistent violations too small for the proportional term to push
//! past the re-partitioning hysteresis, so they eventually shift weight
//! anyway. With default SLOs (no target, priority 1) every weight is
//! exactly 1 and the engine is bit-identical to the demand-only
//! partitioning.

use super::repartition::MigrationMode;
use crate::metrics::percentile;

/// One stream's service-level objective.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSlo {
    /// Tail-latency target (s): the stream wants `p99 <= p99_target`.
    /// `None` means best-effort (no latency feedback).
    pub p99_target: Option<f64>,
    /// Hard per-request latency bound (s): a request that cannot finish
    /// within `deadline` of its arrival is shed at admission instead of
    /// served late (and instead of being budget-deferred past its bound).
    /// `None` means no request is ever shed — the historical behavior.
    pub deadline: Option<f64>,
    /// QoS priority, higher is more important. Strictly lower-priority
    /// streams are deferred first when the energy budget is exhausted,
    /// and lease weight scales linearly with priority.
    pub priority: f64,
    /// Per-stream override of the repartition policy's migration mode:
    /// `Some(Preempt { .. })` lets this lane cancel its in-flight slot at
    /// a migration even under a drain-mode policy (and `Some(Drain)`
    /// pins a bulk lane to draining under a preemptive policy). `None`
    /// inherits [`super::repartition::RepartitionPolicy::migration`].
    pub migration: Option<MigrationMode>,
}

impl Default for StreamSlo {
    /// Best-effort, unit priority, no deadline, policy-default migration
    /// — the weight-neutral SLO every legacy scenario implicitly ran
    /// with.
    fn default() -> Self {
        StreamSlo { p99_target: None, deadline: None, priority: 1.0, migration: None }
    }
}

impl StreamSlo {
    pub fn new(p99_target: Option<f64>, priority: f64) -> StreamSlo {
        let slo = StreamSlo { p99_target, priority, ..StreamSlo::default() };
        slo.validate();
        slo
    }

    /// Re-check the constructor invariants. The engine calls this on
    /// every stream at serve time because the fields are public — an
    /// instance built by struct literal can smuggle a NaN priority past
    /// [`StreamSlo::new`], and NaN comparisons would wedge the budget
    /// deferral ordering (mirrors the re-validation in
    /// [`super::budget::BudgetLedger`]).
    pub fn validate(&self) {
        if let Some(t) = self.p99_target {
            assert!(t > 0.0 && t.is_finite(), "non-positive p99 target {t}");
        }
        if let Some(d) = self.deadline {
            assert!(d > 0.0 && d.is_finite(), "non-positive deadline {d}");
        }
        if let Some(MigrationMode::Preempt { min_remaining }) = self.migration {
            assert!(
                min_remaining >= 0.0 && min_remaining.is_finite(),
                "bad per-stream min_remaining {min_remaining}"
            );
        }
        assert!(
            self.priority > 0.0 && self.priority.is_finite(),
            "non-positive priority {}",
            self.priority
        );
    }

    /// A latency-SLO'd stream: p99 target in seconds, with a priority.
    pub fn target(p99_target: f64, priority: f64) -> StreamSlo {
        StreamSlo::new(Some(p99_target), priority)
    }

    /// No latency target, just a QoS priority.
    pub fn best_effort(priority: f64) -> StreamSlo {
        StreamSlo::new(None, priority)
    }

    /// Attach a hard per-request deadline (s, relative to arrival):
    /// requests that cannot meet it are shed at admission.
    pub fn with_deadline(mut self, deadline: f64) -> StreamSlo {
        self.deadline = Some(deadline);
        self.validate();
        self
    }

    /// Override the repartition policy's migration mode for this stream
    /// alone (criticality-tied preemption: critical lanes preempt, bulk
    /// lanes drain, whatever the policy default says).
    pub fn with_migration(mut self, mode: MigrationMode) -> StreamSlo {
        self.migration = Some(mode);
        self.validate();
        self
    }
}

/// Proportional-integral feedback from observed-vs-target p99 to lease
/// weight. Always present in [`super::EngineConfig`]; with default
/// [`StreamSlo`]s it is the identity (weight = demand), so it is opt-in
/// per stream, not per engine.
///
/// The proportional term alone has a blind spot: a violation small
/// enough that the weighted share shift stays below the re-partitioning
/// hysteresis *never* migrates, no matter how long it persists. The
/// integral term closes it — each re-validation accumulates the relative
/// violation `(p99_obs/p99_target − 1)` into a per-stream error sum
/// (clamped to `±integral_clamp` for anti-windup), and
/// `integral_gain × error_sum` is added to the pressure before the final
/// clamp. Defaults are weight-neutral: `integral_gain = 0` reproduces
/// the proportional-only controller exactly.
#[derive(Debug, Clone)]
pub struct SloController {
    /// Exponent on the observed/target p99 ratio. 1.0 = proportional.
    pub gain: f64,
    /// Clamp on the pressure term: weights stay within
    /// `[priority/max_boost, priority·max_boost]` so one violating
    /// stream cannot starve the rest of the pool.
    pub max_boost: f64,
    /// Weight of the accumulated violation term; 0 (the default)
    /// disables integral action entirely.
    pub integral_gain: f64,
    /// Anti-windup bound on the accumulated relative violation: the
    /// error sum stays within `±integral_clamp`, so pressure recovers
    /// within a bounded number of re-validations once the violation
    /// clears instead of unwinding a run-length's worth of history.
    pub integral_clamp: f64,
    /// Fraction of the error accumulator retained by a re-validation
    /// *without* a p99 observation, in [0, 1]. Without this decay a lane
    /// that went idle (or observation-less) right after violating kept
    /// its full integral pressure indefinitely — the accumulator was
    /// only ever touched when an observation existed. 1.0 reproduces
    /// that (buggy) hold; the 0.5 default halves the stale pressure per
    /// idle re-validation, so it unwinds in a handful of lease terms.
    pub idle_decay: f64,
}

impl Default for SloController {
    fn default() -> Self {
        SloController {
            gain: 1.0,
            max_boost: 4.0,
            integral_gain: 0.0,
            integral_clamp: 8.0,
            idle_decay: 0.5,
        }
    }
}

impl SloController {
    fn validate(&self) {
        assert!(self.gain > 0.0 && self.gain.is_finite(), "non-positive gain {}", self.gain);
        assert!(self.max_boost >= 1.0, "max_boost {} below 1", self.max_boost);
        assert!(
            self.integral_gain >= 0.0 && self.integral_gain.is_finite(),
            "negative or non-finite integral_gain {}",
            self.integral_gain
        );
        assert!(
            self.integral_clamp >= 0.0 && self.integral_clamp.is_finite(),
            "negative or non-finite integral_clamp {}",
            self.integral_clamp
        );
        assert!(
            (0.0..=1.0).contains(&self.idle_decay),
            "idle_decay {} outside [0, 1]",
            self.idle_decay
        );
    }

    /// The stateless lease weight multiplier for one stream: its priority
    /// times the clamped *proportional-only* SLO pressure — no integral
    /// contribution, whatever `integral_gain` is set to, because there is
    /// no error history to integrate. Streams without a target, or
    /// without enough completions to observe a p99, weigh in at exactly
    /// `priority`. Used for initial leases.
    pub fn weight(&self, slo: &StreamSlo, observed_p99: Option<f64>) -> f64 {
        self.validate();
        let pressure = match (slo.p99_target, observed_p99) {
            (Some(target), Some(p99)) => {
                (p99 / target).powf(self.gain).clamp(1.0 / self.max_boost, self.max_boost)
            }
            _ => 1.0,
        };
        slo.priority * pressure
    }

    /// The full PI lease weight: fold this re-validation's relative
    /// violation into `error_sum` (the caller's per-stream accumulator,
    /// clamped for anti-windup), then weigh priority × clamp(proportional
    /// + integral). With `integral_gain = 0` (the default) the
    /// accumulator still updates but contributes nothing — bit-identical
    /// to [`SloController::weight`] in that case.
    ///
    /// A re-validation **without** an observation decays the accumulator
    /// by [`SloController::idle_decay`] instead of freezing it: a lane
    /// that violated and then went observation-less must not carry its
    /// full integral pressure forever.
    pub fn weight_integrating(
        &self,
        slo: &StreamSlo,
        observed_p99: Option<f64>,
        error_sum: &mut f64,
    ) -> f64 {
        self.validate();
        let pressure = match (slo.p99_target, observed_p99) {
            (Some(target), Some(p99)) => {
                let clamp = self.integral_clamp;
                *error_sum = (*error_sum + (p99 / target - 1.0)).clamp(-clamp, clamp);
                ((p99 / target).powf(self.gain) + self.integral_gain * *error_sum)
                    .clamp(1.0 / self.max_boost, self.max_boost)
            }
            _ => {
                *error_sum *= self.idle_decay;
                1.0
            }
        };
        slo.priority * pressure
    }
}

/// Exact observed p99 of a latency sample (any order), `None` when
/// empty. The engine's serving path now feeds an incremental
/// [`crate::metrics::P2Quantile`] instead (O(1) per completion); this
/// full-sort variant survives as the exact reference the estimator is
/// unit-tested against and for offline analysis of completed runs.
pub fn observed_p99(latencies: &[f64]) -> Option<f64> {
    if latencies.is_empty() {
        return None;
    }
    let mut xs = latencies.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(percentile(&xs, 0.99))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_slo_is_weight_neutral() {
        let c = SloController::default();
        assert_eq!(c.weight(&StreamSlo::default(), None), 1.0);
        assert_eq!(c.weight(&StreamSlo::default(), Some(10.0)), 1.0, "no target, no feedback");
        assert_eq!(c.weight(&StreamSlo::target(0.1, 1.0), None), 1.0, "no sample, no feedback");
    }

    #[test]
    fn violating_stream_gains_weight_meeting_stream_cedes_it() {
        let c = SloController::default();
        let slo = StreamSlo::target(0.100, 1.0);
        let missing = c.weight(&slo, Some(0.200)); // 2x over target
        let meeting = c.weight(&slo, Some(0.050)); // 2x under target
        assert!((missing - 2.0).abs() < 1e-12, "missing {missing}");
        assert!((meeting - 0.5).abs() < 1e-12, "meeting {meeting}");
    }

    #[test]
    fn pressure_is_clamped_and_priority_scales() {
        let c = SloController::default();
        let slo = StreamSlo::target(1e-6, 3.0);
        let w = c.weight(&slo, Some(10.0)); // 1e7x over target
        assert!((w - 3.0 * 4.0).abs() < 1e-12, "boost must clamp at max_boost: {w}");
        let floor = c.weight(&StreamSlo::target(1e6, 2.0), Some(1e-3));
        assert!((floor - 2.0 / 4.0).abs() < 1e-12, "cede clamps at 1/max_boost: {floor}");
    }

    #[test]
    fn integral_term_is_weight_neutral_at_defaults() {
        let c = SloController::default();
        let slo = StreamSlo::target(0.100, 1.0);
        let mut acc = 0.0;
        for _ in 0..20 {
            let w = c.weight_integrating(&slo, Some(0.110), &mut acc);
            assert!((w - 1.1).abs() < 1e-12, "default integral_gain must add nothing: {w}");
        }
        assert!(acc > 0.0, "the accumulator still tracks the violation");
    }

    #[test]
    fn persistent_small_violation_accumulates_weight() {
        // A 5% violation boosts the proportional weight by only 1.05 —
        // too little to clear a typical migration hysteresis. With
        // integral action the weight keeps growing until it can.
        let c = SloController { integral_gain: 0.5, ..SloController::default() };
        let slo = StreamSlo::target(0.100, 1.0);
        let mut acc = 0.0;
        let first = c.weight_integrating(&slo, Some(0.105), &mut acc);
        let mut last = first;
        for _ in 0..30 {
            last = c.weight_integrating(&slo, Some(0.105), &mut acc);
        }
        assert!(first < 1.1, "one observation stays near the proportional weight: {first}");
        assert!(last > first * 1.5, "persistence must compound: {first} -> {last}");
        assert!(last <= c.max_boost + 1e-12, "the overall clamp still bounds the weight");
    }

    #[test]
    fn stateless_weight_never_applies_integral_action() {
        // `weight` is the documented proportional-only path: even with a
        // nonzero integral gain it must not sneak in a one-step integral
        // contribution (the initial-lease path relies on this).
        let c = SloController { integral_gain: 0.5, ..SloController::default() };
        let slo = StreamSlo::target(0.100, 1.0);
        let w = c.weight(&slo, Some(0.200));
        assert!((w - 2.0).abs() < 1e-12, "proportional only: {w}");
    }

    #[test]
    fn anti_windup_bounds_the_accumulator_and_recovery() {
        let c = SloController { integral_gain: 1.0, integral_clamp: 2.0, ..Default::default() };
        let slo = StreamSlo::target(0.100, 1.0);
        let mut acc = 0.0;
        // A huge sustained violation saturates the accumulator at the
        // clamp instead of integrating without bound…
        for _ in 0..100 {
            c.weight_integrating(&slo, Some(1.0), &mut acc);
        }
        assert!((acc - 2.0).abs() < 1e-12, "accumulator must saturate at the clamp: {acc}");
        // …so once the stream meets its target (ratio 0.5 → error −0.5
        // per step), the boost unwinds within clamp/|error| steps, not a
        // run-length's worth.
        let mut recovered = false;
        for _ in 0..10 {
            let w = c.weight_integrating(&slo, Some(0.050), &mut acc);
            if w <= 1.0 {
                recovered = true;
                break;
            }
        }
        assert!(recovered, "bounded windup must unwind quickly (acc {acc})");
    }

    #[test]
    fn idle_revalidations_decay_the_accumulator() {
        // The windup-across-idle-gaps regression: violate hard enough to
        // saturate the accumulator, then re-validate without observations
        // (the lane went idle). The accumulator — and with it the
        // integral boost — must decay back toward neutral instead of
        // holding the stale pressure indefinitely.
        let c = SloController { integral_gain: 1.0, integral_clamp: 2.0, ..Default::default() };
        let slo = StreamSlo::target(0.100, 1.0);
        let mut acc = 0.0;
        for _ in 0..50 {
            c.weight_integrating(&slo, Some(1.0), &mut acc);
        }
        assert!((acc - 2.0).abs() < 1e-12, "saturated at the clamp: {acc}");
        for k in 1..=10 {
            let w = c.weight_integrating(&slo, None, &mut acc);
            assert_eq!(w, 1.0, "no observation, no pressure");
            let expect = 2.0 * c.idle_decay.powi(k);
            assert!((acc - expect).abs() < 1e-12, "idle step {k}: acc {acc} vs {expect}");
        }
        assert!(acc < 0.01, "ten idle re-validations must erase the windup: {acc}");
        // Back under observation at the target: the weight is neutral
        // immediately, not after unwinding a run-length of history.
        let w = c.weight_integrating(&slo, Some(0.100), &mut acc);
        assert!(w < 1.01, "recovered lane must weigh ~priority: {w}");
    }

    #[test]
    fn idle_decay_of_one_reproduces_the_frozen_accumulator() {
        let c = SloController { integral_gain: 1.0, idle_decay: 1.0, ..Default::default() };
        let slo = StreamSlo::target(0.100, 1.0);
        let mut acc = 1.5;
        c.weight_integrating(&slo, None, &mut acc);
        assert_eq!(acc, 1.5, "decay 1.0 is the historical freeze");
    }

    #[test]
    #[should_panic(expected = "integral_gain")]
    fn rejects_negative_integral_gain() {
        let c = SloController { integral_gain: -0.1, ..Default::default() };
        c.weight(&StreamSlo::default(), None);
    }

    #[test]
    #[should_panic(expected = "idle_decay")]
    fn rejects_out_of_range_idle_decay() {
        let c = SloController { idle_decay: 1.5, ..Default::default() };
        c.weight(&StreamSlo::default(), None);
    }

    #[test]
    fn observed_p99_is_the_tail_not_the_median() {
        assert_eq!(observed_p99(&[]), None);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(observed_p99(&xs), Some(99.0));
    }

    #[test]
    #[should_panic(expected = "non-positive priority")]
    fn rejects_zero_priority() {
        StreamSlo::best_effort(0.0);
    }

    #[test]
    #[should_panic(expected = "non-positive priority")]
    fn validate_catches_struct_literal_nan_priority() {
        // The fields are public; the engine re-validates at serve time.
        StreamSlo { priority: f64::NAN, ..Default::default() }.validate();
    }

    #[test]
    #[should_panic(expected = "non-positive p99 target")]
    fn rejects_zero_target() {
        StreamSlo::target(0.0, 1.0);
    }

    #[test]
    fn deadline_and_migration_ride_along_as_options() {
        let slo = StreamSlo::target(0.050, 2.0)
            .with_deadline(0.250)
            .with_migration(MigrationMode::Preempt { min_remaining: 0.01 });
        assert_eq!(slo.deadline, Some(0.250));
        assert_eq!(slo.migration, Some(MigrationMode::Preempt { min_remaining: 0.01 }));
        assert_eq!(slo.p99_target, Some(0.050), "the p99 target is untouched");
        let plain = StreamSlo::default();
        assert!(plain.deadline.is_none() && plain.migration.is_none(), "both default off");
    }

    #[test]
    #[should_panic(expected = "non-positive deadline")]
    fn rejects_zero_deadline() {
        StreamSlo::default().with_deadline(0.0);
    }

    #[test]
    #[should_panic(expected = "min_remaining")]
    fn validate_catches_nan_per_stream_preemption_threshold() {
        let mode = MigrationMode::Preempt { min_remaining: f64::NAN };
        StreamSlo { migration: Some(mode), ..Default::default() }.validate();
    }
}
