//! Online re-partitioning: demand tracking and the migration policy.
//!
//! The intra-stream reschedule policy (hysteresis over estimated gain,
//! `coordinator`) has an inter-stream analogue: the *lease table* itself
//! can become stale when one stream's observed load drifts away from the
//! offered-rate estimate its lease was sized on. The engine tracks each
//! stream's completed-FLOP rate with an EWMA, and at every lease expiry
//! compares the lease table it *would* build from the observed rates
//! against the one in force. When the pool-share apportionment has
//! shifted past a hysteresis threshold (total-variation distance), the
//! leases migrate — and every stream whose device inventory changed pays
//! an explicit drain cost before its next admission, mirroring the
//! intra-stream reschedule drain. Per migration, [`MigrationMode`]
//! decides what happens to an in-flight slot: *drain* it to completion
//! on the old lease, or *preempt* it mid-term with a partial refund of
//! its unexecuted time and `f_eng` joules (HTS-style task handoff).
//!
//! The rates this module tracks are scaled by the SLO controller's
//! p99-pressure weights before they reach [`super::lease::assign`]
//! (see [`super::slo`]), and a stream that has dispatched its whole
//! trace drops out of the apportionment so its devices return to the
//! survivors — lease re-validation continues down to a sole survivor.

/// How a migration treats a stream's in-flight admission slot — the
/// per-migration choice between PR-2's drain semantics and true mid-slot
/// preemption.
///
/// The [`RepartitionPolicy::migration`] field is only the *default*: a
/// stream may pin its own mode via
/// [`super::slo::StreamSlo::migration`], so one repartition can preempt
/// a latency-critical lane while a bulk lane drains (criticality-tied
/// handoff, HTS-style).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum MigrationMode {
    /// The in-flight slot finishes on the old lease; the migration takes
    /// effect at the stream's next admission (plus the migration drain)
    /// — the conservative PR-2 behavior and the default; preemption is a
    /// policy choice.
    #[default]
    Drain,
    /// Cancel the in-flight slot mid-term when its unexecuted remainder
    /// exceeds `min_remaining` seconds of lease time: the request goes
    /// back to the front of its queue and re-admits immediately on the
    /// new lease, the unexecuted fraction of the slot's time *and* its
    /// `f_eng` joules are refunded (the executed fraction is lost work
    /// and stays charged), and the freed remainder is handed to the
    /// migration's *other* incoming lease owners as a drain rebate
    /// ([`super::lease::hand_off_remainder`]). Slots with a remainder at
    /// or below `min_remaining` drain as usual — cancelling an
    /// almost-done slot only wastes its re-run.
    Preempt {
        /// Minimum unexecuted slot remainder (s) worth preempting.
        min_remaining: f64,
    },
}

/// Knobs of the online re-partitioning policy. `None` in
/// [`super::EngineConfig`] disables re-partitioning entirely (static
/// leases for the whole run — the
/// [`super::EngineConfigBuilder::static_leases`] escape hatch).
#[derive(Debug, Clone)]
pub struct RepartitionPolicy {
    /// Interval between demand-sampling ticks (s): each tick folds the
    /// completed-FLOP window into the EWMA.
    pub sample_interval: f64,
    /// Lease term (s): how often expiry re-validates the apportionment.
    pub lease_term: f64,
    /// EWMA smoothing weight on the newest sample, in (0, 1].
    pub ewma_alpha: f64,
    /// Minimum total-variation shift of the pool-share vector before a
    /// migration is worth its drain cost.
    pub hysteresis: f64,
    /// What happens to a migrating stream's in-flight slot, unless the
    /// stream overrides it ([`super::slo::StreamSlo::migration`]).
    pub migration: MigrationMode,
}

impl Default for RepartitionPolicy {
    fn default() -> Self {
        RepartitionPolicy {
            sample_interval: 0.5,
            lease_term: 2.0,
            ewma_alpha: 0.4,
            hysteresis: 0.15,
            migration: MigrationMode::Drain,
        }
    }
}

impl RepartitionPolicy {
    /// A policy that reacts within roughly `horizon` seconds: samples at
    /// `horizon/8`, re-validates leases at `horizon/4`.
    pub fn reactive(horizon: f64) -> RepartitionPolicy {
        assert!(horizon > 0.0 && horizon.is_finite());
        RepartitionPolicy {
            sample_interval: horizon / 8.0,
            lease_term: horizon / 4.0,
            ewma_alpha: 0.5,
            hysteresis: 0.1,
            migration: MigrationMode::Drain,
        }
    }

    /// [`RepartitionPolicy::reactive`] with mid-slot preemption: slots
    /// whose unexecuted remainder exceeds 1% of the horizon are cancelled
    /// and refunded instead of drained.
    pub fn preemptive(horizon: f64) -> RepartitionPolicy {
        RepartitionPolicy {
            migration: MigrationMode::Preempt { min_remaining: horizon / 100.0 },
            ..RepartitionPolicy::reactive(horizon)
        }
    }
}

/// Per-stream EWMA of observed demand (settled FLOP/s — completed *and*
/// shed batches both count; a deadline lane shedding under overload is
/// at peak demand, not idle, and must keep bidding for devices), seeded
/// with the offered-rate estimate the initial leases were sized on.
#[derive(Debug, Clone)]
pub struct DemandTracker {
    alpha: f64,
    rates: Vec<f64>,
    last_tick: f64,
}

impl DemandTracker {
    pub fn new(initial_rates: &[f64], alpha: f64) -> DemandTracker {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha {alpha} outside (0, 1]");
        DemandTracker { alpha, rates: initial_rates.to_vec(), last_tick: 0.0 }
    }

    /// Fold one sampling window into the EWMAs. `windows[i]` is the FLOPs
    /// stream `i` settled (completed or shed) since the previous tick;
    /// `now` is the tick's global-clock time. No-op for a zero-length
    /// window.
    pub fn tick(&mut self, now: f64, windows: &[f64]) {
        assert_eq!(windows.len(), self.rates.len());
        let dt = now - self.last_tick;
        if dt <= 0.0 {
            return;
        }
        for (rate, w) in self.rates.iter_mut().zip(windows) {
            *rate = self.alpha * (w / dt) + (1.0 - self.alpha) * *rate;
        }
        self.last_tick = now;
    }

    /// The current demand estimate for stream `i` (FLOP/s).
    pub fn rate(&self, i: usize) -> f64 {
        self.rates[i]
    }

    pub fn rates(&self) -> &[f64] {
        &self.rates
    }
}

/// Total-variation distance between two pool-share vectors (each
/// non-negative, typically summing to ≤ 1): `½·Σ|aᵢ − bᵢ|`, in [0, 1].
pub(crate) fn share_shift(current: &[f64], desired: &[f64]) -> f64 {
    assert_eq!(current.len(), desired.len());
    0.5 * current.iter().zip(desired).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_to_observed_rate() {
        let mut t = DemandTracker::new(&[100.0], 0.5);
        // Stream actually completes 10 FLOP/s over repeated 1s windows.
        for k in 1..=12 {
            t.tick(k as f64, &[10.0]);
        }
        assert!((t.rate(0) - 10.0).abs() < 0.1, "rate {}", t.rate(0));
    }

    #[test]
    fn idle_stream_demand_decays() {
        let mut t = DemandTracker::new(&[1e9, 1e9], 0.4);
        for k in 1..=20 {
            t.tick(k as f64, &[1e9, 0.0]);
        }
        assert!(t.rate(1) < t.rate(0) * 1e-3, "idle stream must decay");
    }

    #[test]
    fn zero_dt_tick_is_a_noop() {
        let mut t = DemandTracker::new(&[5.0], 0.5);
        t.tick(0.0, &[1e12]);
        assert_eq!(t.rate(0), 5.0);
    }

    #[test]
    fn share_shift_is_total_variation() {
        assert_eq!(share_shift(&[0.5, 0.5], &[0.5, 0.5]), 0.0);
        assert!((share_shift(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((share_shift(&[0.6, 0.4], &[0.4, 0.6]) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn reactive_policy_scales_with_horizon() {
        let p = RepartitionPolicy::reactive(8.0);
        assert_eq!(p.sample_interval, 1.0);
        assert_eq!(p.lease_term, 2.0);
        assert!(p.lease_term > p.sample_interval);
        assert_eq!(p.migration, MigrationMode::Drain, "preemption is opt-in");
    }

    #[test]
    fn preemptive_policy_sets_a_horizon_scaled_threshold() {
        let p = RepartitionPolicy::preemptive(8.0);
        assert_eq!(p.sample_interval, 1.0, "timing knobs follow reactive()");
        assert_eq!(p.migration, MigrationMode::Preempt { min_remaining: 0.08 });
    }
}
