//! The event heap — the engine's single source of time.
//!
//! Every cause of state change in the serving engine is an [`Event`] on
//! one global clock: a request arriving, a batch's admission slot
//! completing, a request shed by the deadline feasibility check, a
//! device lease reaching the end of its term, a demand-sampling tick, or
//! an energy-budget window boundary. The queue
//! is a binary min-heap ordered by
//! `(time, push sequence)`, so simultaneous events resolve in push order
//! — deterministically, with no dependence on hash state or thread
//! interleaving. Arrivals are pushed before any run-time event, which
//! reproduces the legacy loop's "admit everything that has arrived by
//! `clock`, then dispatch" semantics at equal timestamps.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happened. Stream/request indices refer to the engine's lane and
/// trace vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A request entered a stream's admission queue.
    RequestArrival { stream: usize, index: usize },
    /// A stream's in-flight admission slot finished; its lease can accept
    /// the next request. `epoch` is the lane's dispatch generation at the
    /// time the slot was scheduled: a mid-slot preemption bumps the
    /// lane's generation, so the cancelled slot's completion pops as a
    /// stale no-op instead of corrupting the lane (the same request may
    /// legitimately be in flight again by then).
    BatchComplete { stream: usize, epoch: u64 },
    /// A lease migration cancelled the stream's in-flight slot mid-term
    /// (see [`crate::engine::repartition::MigrationMode::Preempt`]): the
    /// cancelled request is back at the front of its queue and the lane
    /// should re-admit immediately on its new lease.
    Preempt { stream: usize },
    /// The admission-time deadline feasibility check
    /// ([`crate::engine::slo::StreamSlo::deadline`]) rejected request
    /// `index`: it can no longer finish inside its latency bound, so it
    /// is **shed** — removed from the queue, counted against the
    /// stream's deadline attainment, and never dispatched (and never
    /// budget-deferred). The handler settles the accounting and lets the
    /// lane consider the next queued request at the same timestamp.
    Shed { stream: usize, index: usize },
    /// A device-lease term ended: the lease manager re-validates the
    /// apportionment and either renews every lease or migrates.
    LeaseExpiry,
    /// Demand-sampling tick: fold each stream's completed-FLOP window
    /// into its EWMA demand estimate.
    RepartitionTick,
    /// An energy-budget window ended: the ledger closes the window's
    /// `f_eng` account, refills the joule budget, and admissions deferred
    /// by budget exhaustion resume highest-priority-first
    /// (see [`crate::engine::budget`]).
    BudgetWindowTick,
    /// A scheduled mid-run perturbation fires: `index` points into the
    /// engine config's perturbation list (see [`crate::engine::perturb`]).
    /// The handler mutates the live system — device-pool cut, budget
    /// scale, SLO tightening — and forces a lease re-validation so the
    /// policies under test must adapt, not merely start well.
    Perturbation { index: usize },
}

impl EventKind {
    /// Number of event kinds — sizes the per-kind telemetry counter
    /// arrays (see [`crate::telemetry::Snapshot`]).
    pub const COUNT: usize = 8;

    /// Stable per-kind labels, indexed by [`EventKind::index`]. These
    /// name counters in exported telemetry, so changing one breaks
    /// downstream dashboards the same way renaming a metric would.
    pub const NAMES: [&'static str; EventKind::COUNT] = [
        "arrival",
        "batch-complete",
        "preempt",
        "shed",
        "lease-expiry",
        "repartition-tick",
        "budget-window-tick",
        "perturbation",
    ];

    /// Dense index of this kind in declaration order; always
    /// `< EventKind::COUNT`.
    pub fn index(&self) -> usize {
        match self {
            EventKind::RequestArrival { .. } => 0,
            EventKind::BatchComplete { .. } => 1,
            EventKind::Preempt { .. } => 2,
            EventKind::Shed { .. } => 3,
            EventKind::LeaseExpiry => 4,
            EventKind::RepartitionTick => 5,
            EventKind::BudgetWindowTick => 6,
            EventKind::Perturbation { .. } => 7,
        }
    }
}

/// A timestamped event. `seq` is the queue's push counter — the
/// deterministic tie-breaker for equal timestamps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Global-clock timestamp (s). Always finite.
    pub time: f64,
    /// Push order, unique per queue.
    pub seq: u64,
    pub kind: EventKind,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    /// Reversed so `BinaryHeap` (a max-heap) pops the *earliest* event;
    /// equal times pop in push order.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap of pending events plus the push/pop counters the engine
/// reports as overhead metrics.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
    processed: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `kind` at `time`. Times must be finite; they need not be
    /// monotone with respect to previous pushes (the heap orders them),
    /// but the engine never schedules into the past.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        assert!(time.is_finite(), "non-finite event time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    /// Pop the earliest event (ties in push order).
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop();
        if ev.is_some() {
            self.processed += 1;
        }
        ev
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Events popped so far (the engine's per-event overhead denominator).
    pub fn processed(&self) -> u64 {
        self.processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::LeaseExpiry);
        q.push(0.5, EventKind::RequestArrival { stream: 0, index: 0 });
        q.push(1.0, EventKind::BatchComplete { stream: 0, epoch: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(times, vec![0.5, 1.0, 2.0]);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn equal_times_pop_in_push_order() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.push(1.0, EventKind::RequestArrival { stream: 0, index: i });
        }
        q.push(1.0, EventKind::BatchComplete { stream: 0, epoch: 9 });
        let mut kinds = Vec::new();
        while let Some(e) = q.pop() {
            kinds.push(e.kind);
        }
        for (i, k) in kinds.iter().take(5).enumerate() {
            assert_eq!(*k, EventKind::RequestArrival { stream: 0, index: i });
        }
        assert_eq!(kinds[5], EventKind::BatchComplete { stream: 0, epoch: 9 });
    }

    #[test]
    fn interleaved_pushes_stay_deterministic() {
        // Push order is the tie-breaker even when pushes interleave pops.
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::RepartitionTick);
        q.push(0.0, EventKind::RequestArrival { stream: 0, index: 0 });
        assert_eq!(
            q.pop().unwrap().kind,
            EventKind::RequestArrival { stream: 0, index: 0 }
        );
        q.push(1.0, EventKind::LeaseExpiry);
        assert_eq!(q.pop().unwrap().kind, EventKind::RepartitionTick);
        assert_eq!(q.pop().unwrap().kind, EventKind::LeaseExpiry);
        assert!(q.pop().is_none());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_non_finite_times() {
        EventQueue::new().push(f64::NAN, EventKind::RepartitionTick);
    }

    #[test]
    fn kind_indices_are_dense_and_named() {
        let kinds = [
            EventKind::RequestArrival { stream: 0, index: 0 },
            EventKind::BatchComplete { stream: 0, epoch: 0 },
            EventKind::Preempt { stream: 0 },
            EventKind::Shed { stream: 0, index: 0 },
            EventKind::LeaseExpiry,
            EventKind::RepartitionTick,
            EventKind::BudgetWindowTick,
            EventKind::Perturbation { index: 0 },
        ];
        assert_eq!(kinds.len(), EventKind::COUNT);
        for (i, k) in kinds.iter().enumerate() {
            assert_eq!(k.index(), i, "declaration order is the index contract");
        }
        assert_eq!(EventKind::NAMES[EventKind::LeaseExpiry.index()], "lease-expiry");
    }

    #[test]
    fn shed_events_order_like_any_other_event() {
        // A shed at `now` pops after same-time events pushed earlier and
        // before later ones — no special-casing on the heap.
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::RequestArrival { stream: 1, index: 3 });
        q.push(1.0, EventKind::Shed { stream: 0, index: 2 });
        q.push(0.5, EventKind::Shed { stream: 0, index: 1 });
        assert_eq!(q.pop().unwrap().kind, EventKind::Shed { stream: 0, index: 1 });
        assert_eq!(q.pop().unwrap().kind, EventKind::RequestArrival { stream: 1, index: 3 });
        assert_eq!(q.pop().unwrap().kind, EventKind::Shed { stream: 0, index: 2 });
    }

    #[test]
    fn budget_ticks_order_with_the_rest_of_the_heap() {
        // A window boundary coinciding with an arrival resolves in push
        // order like any other tie — budget refills never jump the queue.
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::RequestArrival { stream: 0, index: 0 });
        q.push(1.0, EventKind::BudgetWindowTick);
        q.push(0.5, EventKind::BudgetWindowTick);
        assert_eq!(q.pop().unwrap().kind, EventKind::BudgetWindowTick);
        assert_eq!(q.pop().unwrap().kind, EventKind::RequestArrival { stream: 0, index: 0 });
        assert_eq!(q.pop().unwrap().kind, EventKind::BudgetWindowTick);
    }
}
