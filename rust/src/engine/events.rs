//! The event queue — the engine's single source of time.
//!
//! Every cause of state change in the serving engine is an [`Event`] on
//! one global clock: a request arriving, a batch's admission slot
//! completing, a request shed by the deadline feasibility check, a
//! device lease reaching the end of its term, a demand-sampling tick, or
//! an energy-budget window boundary. Whatever the backing store, the
//! queue contract is total order by `(time, push sequence)`: the
//! earliest event pops first and simultaneous events resolve in push
//! order — deterministically, with no dependence on hash state, thread
//! interleaving, or the queue implementation chosen. Arrivals are pushed
//! before any run-time event, which reproduces the legacy loop's "admit
//! everything that has arrived by `clock`, then dispatch" semantics at
//! equal timestamps.
//!
//! Two interchangeable implementations live behind the [`EventQueue`]
//! trait, selected per run by the [`QueueKind`] config knob:
//!
//! * [`BinaryHeapQueue`] — the original binary min-heap. `O(log n)`
//!   push/pop, allocation-free after its backing buffer warms up.
//! * [`CalendarQueue`] — a calendar queue (Brown 1988): events live in a
//!   slab addressed by typed [`EventId`] indices and are bucketed into a
//!   power-of-two ring of "days" of width `bucket_width`. In the dense-
//!   timestamp regime the serving engine produces (arrival/completion
//!   pairs spaced about one pipeline period apart), push and pop touch
//!   one short bucket — amortized `O(1)` — and the slab plus bucket
//!   vectors retain their capacity, so the steady state allocates
//!   nothing. **The default** since the hot-path rewrite.
//!
//! Determinism is preserved by construction, not by luck: the calendar
//! pop selects the minimum `(time, seq)` within the scanned day by a
//! linear scan, so the result is independent of in-bucket insertion
//! order (and therefore of `swap_remove` shuffling). The two
//! implementations are property-tested to pop bit-identical sequences
//! under adversarial interleavings, and `rust/tests/queue_equivalence.rs`
//! pins full engine runs equal across the zoo.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happened. Stream/request indices refer to the engine's lane and
/// trace vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A request entered a stream's admission queue.
    RequestArrival { stream: usize, index: usize },
    /// A stream's in-flight admission slot finished; its lease can accept
    /// the next request. `epoch` is the lane's dispatch generation at the
    /// time the slot was scheduled: a mid-slot preemption bumps the
    /// lane's generation, so the cancelled slot's completion pops as a
    /// stale no-op instead of corrupting the lane (the same request may
    /// legitimately be in flight again by then).
    BatchComplete { stream: usize, epoch: u64 },
    /// A lease migration cancelled the stream's in-flight slot mid-term
    /// (see [`crate::engine::repartition::MigrationMode::Preempt`]): the
    /// cancelled request is back at the front of its queue and the lane
    /// should re-admit immediately on its new lease.
    Preempt { stream: usize },
    /// The admission-time deadline feasibility check
    /// ([`crate::engine::slo::StreamSlo::deadline`]) rejected request
    /// `index`: it can no longer finish inside its latency bound, so it
    /// is **shed** — removed from the queue, counted against the
    /// stream's deadline attainment, and never dispatched (and never
    /// budget-deferred). The handler settles the accounting and lets the
    /// lane consider the next queued request at the same timestamp.
    Shed { stream: usize, index: usize },
    /// A device-lease term ended: the lease manager re-validates the
    /// apportionment and either renews every lease or migrates.
    LeaseExpiry,
    /// Demand-sampling tick: fold each stream's completed-FLOP window
    /// into its EWMA demand estimate.
    RepartitionTick,
    /// An energy-budget window ended: the ledger closes the window's
    /// `f_eng` account, refills the joule budget, and admissions deferred
    /// by budget exhaustion resume highest-priority-first
    /// (see [`crate::engine::budget`]).
    BudgetWindowTick,
    /// A scheduled mid-run perturbation fires: `index` points into the
    /// engine config's perturbation list (see [`crate::engine::perturb`]).
    /// The handler mutates the live system — device-pool cut, budget
    /// scale, SLO tightening — and forces a lease re-validation so the
    /// policies under test must adapt, not merely start well.
    Perturbation { index: usize },
}

impl EventKind {
    /// Number of event kinds — sizes the per-kind telemetry counter
    /// arrays (see [`crate::telemetry::Snapshot`]).
    pub const COUNT: usize = 8;

    /// Stable per-kind labels, indexed by [`EventKind::index`]. These
    /// name counters in exported telemetry, so changing one breaks
    /// downstream dashboards the same way renaming a metric would.
    pub const NAMES: [&'static str; EventKind::COUNT] = [
        "arrival",
        "batch-complete",
        "preempt",
        "shed",
        "lease-expiry",
        "repartition-tick",
        "budget-window-tick",
        "perturbation",
    ];

    /// Dense index of this kind in declaration order; always
    /// `< EventKind::COUNT`.
    pub fn index(&self) -> usize {
        match self {
            EventKind::RequestArrival { .. } => 0,
            EventKind::BatchComplete { .. } => 1,
            EventKind::Preempt { .. } => 2,
            EventKind::Shed { .. } => 3,
            EventKind::LeaseExpiry => 4,
            EventKind::RepartitionTick => 5,
            EventKind::BudgetWindowTick => 6,
            EventKind::Perturbation { .. } => 7,
        }
    }
}

/// Which [`EventQueue`] implementation a run uses — an
/// [`crate::engine::EngineConfig`] knob so benches can A/B the two
/// in-tree. Both orderings are bit-identical by contract
/// (property-tested); the choice is purely a performance trade.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueKind {
    /// The original binary min-heap: `O(log n)`, a safe all-rounder.
    Heap,
    /// Slab-backed calendar queue: amortized `O(1)` in the engine's
    /// dense-timestamp regime, zero allocations at steady state. The
    /// default.
    #[default]
    Calendar,
}

/// A timestamped event. `seq` is the queue's push counter — the
/// deterministic tie-breaker for equal timestamps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct Event {
    /// Global-clock timestamp (s). Always finite.
    pub time: f64,
    /// Push order, unique per queue.
    pub seq: u64,
    pub kind: EventKind,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    /// Reversed so `BinaryHeap` (a max-heap) pops the *earliest* event;
    /// equal times pop in push order.
    fn cmp(&self, other: &Self) -> Ordering {
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Typed index of an event slot in the [`CalendarQueue`] slab — events
/// are addressed, never boxed or cloned, so bucket moves are `u32`
/// copies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct EventId(u32);

impl EventId {
    #[inline]
    fn index(self) -> usize {
        self.0 as usize
    }
}

/// Typed index of a lane in the engine's lane slab (`Vec<Lane>` — lanes
/// are stored once and addressed by index; nothing in the hot path
/// clones one).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct LaneId(pub u32);

impl LaneId {
    #[inline]
    pub(crate) fn index(self) -> usize {
        self.0 as usize
    }
}

/// The queue contract every implementation honors: total `(time, seq)`
/// order, push-order ties, pop counting. The engine itself dispatches
/// statically through [`EngineQueue`]; the trait exists so tests can
/// drive any implementation through one harness.
pub(crate) trait EventQueue {
    /// Schedule `kind` at `time`. Times must be finite; they need not be
    /// monotone with respect to previous pushes (the queue orders them),
    /// but the engine never schedules into the past.
    fn push(&mut self, time: f64, kind: EventKind);

    /// Pop the earliest event (ties in push order).
    fn pop(&mut self) -> Option<Event>;

    /// Pop the earliest event only if `pred` accepts it; otherwise leave
    /// the queue untouched. This is the same-tick coalescing primitive:
    /// the lease-expiry handler peels off a coinciding repartition tick
    /// (and vice versa) without disturbing any other event that may sort
    /// between them.
    fn pop_if(&mut self, pred: impl FnMut(&Event) -> bool) -> Option<Event>;

    /// Pending events.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events popped so far (the engine's per-event overhead denominator).
    fn processed(&self) -> u64;
}

/// Min-heap of pending events plus the push/pop counters the engine
/// reports as overhead metrics — the original queue, kept as the
/// [`QueueKind::Heap`] option.
#[derive(Debug, Default)]
pub(crate) struct BinaryHeapQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
    processed: u64,
}

impl BinaryHeapQueue {
    pub(crate) fn new() -> BinaryHeapQueue {
        BinaryHeapQueue::default()
    }
}

impl EventQueue for BinaryHeapQueue {
    fn push(&mut self, time: f64, kind: EventKind) {
        assert!(time.is_finite(), "non-finite event time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop();
        if ev.is_some() {
            self.processed += 1;
        }
        ev
    }

    fn pop_if(&mut self, mut pred: impl FnMut(&Event) -> bool) -> Option<Event> {
        if pred(self.heap.peek()?) {
            self.pop()
        } else {
            None
        }
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

/// Buckets start at this power-of-two count and never shrink below it.
const MIN_BUCKETS: usize = 64;
/// Bucket width before the first adaptive rebuild (s) — one pipeline
/// period of a millisecond-scale serving workload.
const DEFAULT_WIDTH: f64 = 1e-3;
/// Floor on the adaptive bucket width, so day indices stay well inside
/// `u64` for any reachable clock value.
const MIN_WIDTH: f64 = 1e-9;

/// Slab-backed calendar queue (Brown 1988), tuned for the engine's
/// dense-timestamp regime.
///
/// Events live in a free-listed slab (`Vec<Event>` addressed by
/// [`EventId`]); the ring holds a power-of-two number of buckets, each a
/// `Vec<EventId>`, where an event at time `t` lives in bucket
/// `⌊t / bucket_width⌋ mod n_buckets`. A pop scans forward from the
/// cursor's day: the first day (within one "year" — a full ring
/// revolution) holding a due event contains the global minimum, because
/// day order is time order across days; *within* the day a linear scan
/// selects the minimum `(time, seq)`, making the result independent of
/// bucket insertion order. When a whole year is empty (sparse far-future
/// tail), a global min-scan fallback finds the event and re-anchors the
/// cursor. The ring resizes by rebuild — doubling when occupancy passes
/// 2× the bucket count, halving below 1/8 — re-deriving the width from
/// the live event span so a year keeps covering the pending horizon.
/// After warm-up the slab, free list, and bucket vectors all retain
/// capacity: the steady state allocates nothing.
#[derive(Debug)]
pub(crate) struct CalendarQueue {
    slab: Vec<Event>,
    free: Vec<u32>,
    buckets: Vec<Vec<EventId>>,
    bucket_width: f64,
    /// Day index (`⌊t / width⌋`) the pop scan starts from. Invariant:
    /// `cursor_day <= day_of(e.time)` for every stored event `e`.
    cursor_day: u64,
    len: usize,
    next_seq: u64,
    processed: u64,
}

impl Default for CalendarQueue {
    fn default() -> Self {
        CalendarQueue {
            slab: Vec::new(),
            free: Vec::new(),
            buckets: vec![Vec::new(); MIN_BUCKETS],
            bucket_width: DEFAULT_WIDTH,
            cursor_day: 0,
            len: 0,
            next_seq: 0,
            processed: 0,
        }
    }
}

impl CalendarQueue {
    pub(crate) fn new() -> CalendarQueue {
        CalendarQueue::default()
    }

    #[inline]
    fn day_of(&self, time: f64) -> u64 {
        // `as` saturates, so even an absurd clock cannot overflow.
        (time.max(0.0) / self.bucket_width) as u64
    }

    #[inline]
    fn bucket_of(&self, day: u64) -> usize {
        (day & (self.buckets.len() as u64 - 1)) as usize
    }

    /// Locate the global minimum `(time, seq)` as `(bucket, position)`.
    fn locate_min(&self) -> Option<(usize, usize)> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len() as u64;
        for step in 0..n {
            let day = self.cursor_day + step;
            let b = self.bucket_of(day);
            let mut best: Option<(usize, f64, u64)> = None;
            for (pos, &id) in self.buckets[b].iter().enumerate() {
                let ev = &self.slab[id.index()];
                if self.day_of(ev.time) != day {
                    continue; // an earlier or later year sharing the bucket
                }
                let better = match best {
                    None => true,
                    Some((_, t, s)) => ev.time < t || (ev.time == t && ev.seq < s),
                };
                if better {
                    best = Some((pos, ev.time, ev.seq));
                }
            }
            if let Some((pos, _, _)) = best {
                return Some((b, pos));
            }
        }
        // A whole year from the cursor is empty: the remaining events sit
        // in a sparse far-future tail. One global scan finds the minimum
        // (day order across days no longer helps, so compare directly).
        let mut best: Option<(usize, usize, f64, u64)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (pos, &id) in bucket.iter().enumerate() {
                let ev = &self.slab[id.index()];
                let better = match best {
                    None => true,
                    Some((_, _, t, s)) => ev.time < t || (ev.time == t && ev.seq < s),
                };
                if better {
                    best = Some((b, pos, ev.time, ev.seq));
                }
            }
        }
        best.map(|(b, pos, _, _)| (b, pos))
    }

    fn remove_at(&mut self, bucket: usize, pos: usize) -> Event {
        let id = self.buckets[bucket].swap_remove(pos);
        let ev = self.slab[id.index()];
        self.free.push(id.0);
        self.len -= 1;
        ev
    }

    /// Re-bucket every live event into `n_buckets` (a power of two),
    /// re-deriving the width from the live span so occupancy stays near
    /// one event per day. The slab and free list are untouched — only
    /// bucket membership moves.
    fn rebuild(&mut self, n_buckets: usize) {
        debug_assert!(n_buckets.is_power_of_two());
        let mut min_t = f64::INFINITY;
        let mut max_t = 0.0f64;
        for bucket in &self.buckets {
            for &id in bucket {
                let t = self.slab[id.index()].time;
                min_t = min_t.min(t);
                max_t = max_t.max(t);
            }
        }
        let span = (max_t - min_t).max(0.0);
        self.bucket_width = if self.len > 1 && span > 0.0 {
            (span / self.len as f64).max(MIN_WIDTH)
        } else {
            DEFAULT_WIDTH
        };
        let mut old = std::mem::take(&mut self.buckets);
        self.buckets = vec![Vec::new(); n_buckets];
        let n = n_buckets as u64;
        for bucket in &mut old {
            for id in bucket.drain(..) {
                let day = self.day_of(self.slab[id.index()].time);
                self.buckets[(day & (n - 1)) as usize].push(id);
            }
        }
        self.cursor_day = if self.len == 0 { 0 } else { self.day_of(min_t.max(0.0)) };
    }
}

impl EventQueue for CalendarQueue {
    fn push(&mut self, time: f64, kind: EventKind) {
        assert!(time.is_finite(), "non-finite event time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        let ev = Event { time, seq, kind };
        let id = match self.free.pop() {
            Some(i) => {
                self.slab[i as usize] = ev;
                EventId(i)
            }
            None => {
                self.slab.push(ev);
                EventId((self.slab.len() - 1) as u32)
            }
        };
        let day = self.day_of(time);
        // A past-time push (relative to the cursor) must pull the cursor
        // back, or the scan would skip it for a whole year.
        if self.len == 0 || day < self.cursor_day {
            self.cursor_day = day;
        }
        let b = self.bucket_of(day);
        self.buckets[b].push(id);
        self.len += 1;
        if self.len > self.buckets.len() * 2 {
            self.rebuild(self.buckets.len() * 2);
        }
    }

    fn pop(&mut self) -> Option<Event> {
        let (b, pos) = self.locate_min()?;
        let ev = self.remove_at(b, pos);
        // The popped event was the global minimum, so every survivor's
        // day is >= its day: advancing the cursor is safe and skips the
        // empty prefix on the next pop.
        self.cursor_day = self.day_of(ev.time);
        self.processed += 1;
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 8 {
            let target = (self.buckets.len() / 2).max(MIN_BUCKETS);
            self.rebuild(target);
        }
        Some(ev)
    }

    fn pop_if(&mut self, mut pred: impl FnMut(&Event) -> bool) -> Option<Event> {
        let (b, pos) = self.locate_min()?;
        if !pred(&self.slab[self.buckets[b][pos].index()]) {
            return None;
        }
        let ev = self.remove_at(b, pos);
        self.cursor_day = self.day_of(ev.time);
        self.processed += 1;
        Some(ev)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

/// The engine's queue: a closed enum over the two implementations, so
/// the hot loop dispatches statically (match, no vtable) while the
/// choice stays a runtime config knob.
#[derive(Debug)]
pub(crate) enum EngineQueue {
    Heap(BinaryHeapQueue),
    Calendar(CalendarQueue),
}

impl EngineQueue {
    pub(crate) fn new(kind: QueueKind) -> EngineQueue {
        match kind {
            QueueKind::Heap => EngineQueue::Heap(BinaryHeapQueue::new()),
            QueueKind::Calendar => EngineQueue::Calendar(CalendarQueue::new()),
        }
    }

    pub(crate) fn push(&mut self, time: f64, kind: EventKind) {
        match self {
            EngineQueue::Heap(q) => q.push(time, kind),
            EngineQueue::Calendar(q) => q.push(time, kind),
        }
    }

    pub(crate) fn pop(&mut self) -> Option<Event> {
        match self {
            EngineQueue::Heap(q) => q.pop(),
            EngineQueue::Calendar(q) => q.pop(),
        }
    }

    pub(crate) fn pop_if(&mut self, pred: impl FnMut(&Event) -> bool) -> Option<Event> {
        match self {
            EngineQueue::Heap(q) => q.pop_if(pred),
            EngineQueue::Calendar(q) => q.pop_if(pred),
        }
    }

    pub(crate) fn len(&self) -> usize {
        match self {
            EngineQueue::Heap(q) => q.len(),
            EngineQueue::Calendar(q) => q.len(),
        }
    }

    pub(crate) fn processed(&self) -> u64 {
        match self {
            EngineQueue::Heap(q) => q.processed(),
            EngineQueue::Calendar(q) => q.processed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every queue the contract tests must hold for.
    fn queues() -> Vec<(&'static str, Box<dyn FnMut() -> TestQueue>)> {
        vec![
            ("heap", Box::new(|| TestQueue::Heap(BinaryHeapQueue::new()))),
            ("calendar", Box::new(|| TestQueue::Calendar(CalendarQueue::new()))),
        ]
    }

    /// Test-side mirror of [`EngineQueue`] (kept separate so the tests
    /// exercise the trait impls directly).
    enum TestQueue {
        Heap(BinaryHeapQueue),
        Calendar(CalendarQueue),
    }

    impl TestQueue {
        fn push(&mut self, t: f64, k: EventKind) {
            match self {
                TestQueue::Heap(q) => q.push(t, k),
                TestQueue::Calendar(q) => q.push(t, k),
            }
        }
        fn pop(&mut self) -> Option<Event> {
            match self {
                TestQueue::Heap(q) => q.pop(),
                TestQueue::Calendar(q) => q.pop(),
            }
        }
        fn pop_if(&mut self, pred: impl FnMut(&Event) -> bool) -> Option<Event> {
            match self {
                TestQueue::Heap(q) => q.pop_if(pred),
                TestQueue::Calendar(q) => q.pop_if(pred),
            }
        }
        fn processed(&self) -> u64 {
            match self {
                TestQueue::Heap(q) => q.processed(),
                TestQueue::Calendar(q) => q.processed(),
            }
        }
    }

    #[test]
    fn pops_in_time_order() {
        for (name, mut mk) in queues() {
            let mut q = mk();
            q.push(2.0, EventKind::LeaseExpiry);
            q.push(0.5, EventKind::RequestArrival { stream: 0, index: 0 });
            q.push(1.0, EventKind::BatchComplete { stream: 0, epoch: 0 });
            let times: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
            assert_eq!(times, vec![0.5, 1.0, 2.0], "{name}");
            assert_eq!(q.processed(), 3, "{name}");
        }
    }

    #[test]
    fn equal_times_pop_in_push_order() {
        for (name, mut mk) in queues() {
            let mut q = mk();
            for i in 0..5 {
                q.push(1.0, EventKind::RequestArrival { stream: 0, index: i });
            }
            q.push(1.0, EventKind::BatchComplete { stream: 0, epoch: 9 });
            let mut kinds = Vec::new();
            while let Some(e) = q.pop() {
                kinds.push(e.kind);
            }
            for (i, k) in kinds.iter().take(5).enumerate() {
                assert_eq!(*k, EventKind::RequestArrival { stream: 0, index: i }, "{name}");
            }
            assert_eq!(kinds[5], EventKind::BatchComplete { stream: 0, epoch: 9 }, "{name}");
        }
    }

    #[test]
    fn interleaved_pushes_stay_deterministic() {
        // Push order is the tie-breaker even when pushes interleave pops.
        for (name, mut mk) in queues() {
            let mut q = mk();
            q.push(1.0, EventKind::RepartitionTick);
            q.push(0.0, EventKind::RequestArrival { stream: 0, index: 0 });
            assert_eq!(
                q.pop().unwrap().kind,
                EventKind::RequestArrival { stream: 0, index: 0 },
                "{name}"
            );
            q.push(1.0, EventKind::LeaseExpiry);
            assert_eq!(q.pop().unwrap().kind, EventKind::RepartitionTick, "{name}");
            assert_eq!(q.pop().unwrap().kind, EventKind::LeaseExpiry, "{name}");
            assert!(q.pop().is_none(), "{name}");
        }
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn heap_rejects_non_finite_times() {
        BinaryHeapQueue::new().push(f64::NAN, EventKind::RepartitionTick);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn calendar_rejects_non_finite_times() {
        CalendarQueue::new().push(f64::NAN, EventKind::RepartitionTick);
    }

    #[test]
    fn kind_indices_are_dense_and_named() {
        let kinds = [
            EventKind::RequestArrival { stream: 0, index: 0 },
            EventKind::BatchComplete { stream: 0, epoch: 0 },
            EventKind::Preempt { stream: 0 },
            EventKind::Shed { stream: 0, index: 0 },
            EventKind::LeaseExpiry,
            EventKind::RepartitionTick,
            EventKind::BudgetWindowTick,
            EventKind::Perturbation { index: 0 },
        ];
        assert_eq!(kinds.len(), EventKind::COUNT);
        for (i, k) in kinds.iter().enumerate() {
            assert_eq!(k.index(), i, "declaration order is the index contract");
        }
        assert_eq!(EventKind::NAMES[EventKind::LeaseExpiry.index()], "lease-expiry");
    }

    #[test]
    fn shed_events_order_like_any_other_event() {
        // A shed at `now` pops after same-time events pushed earlier and
        // before later ones — no special-casing in the queue.
        for (name, mut mk) in queues() {
            let mut q = mk();
            q.push(1.0, EventKind::RequestArrival { stream: 1, index: 3 });
            q.push(1.0, EventKind::Shed { stream: 0, index: 2 });
            q.push(0.5, EventKind::Shed { stream: 0, index: 1 });
            assert_eq!(q.pop().unwrap().kind, EventKind::Shed { stream: 0, index: 1 }, "{name}");
            assert_eq!(
                q.pop().unwrap().kind,
                EventKind::RequestArrival { stream: 1, index: 3 },
                "{name}"
            );
            assert_eq!(q.pop().unwrap().kind, EventKind::Shed { stream: 0, index: 2 }, "{name}");
        }
    }

    #[test]
    fn budget_ticks_order_with_the_rest_of_the_queue() {
        // A window boundary coinciding with an arrival resolves in push
        // order like any other tie — budget refills never jump the queue.
        for (name, mut mk) in queues() {
            let mut q = mk();
            q.push(1.0, EventKind::RequestArrival { stream: 0, index: 0 });
            q.push(1.0, EventKind::BudgetWindowTick);
            q.push(0.5, EventKind::BudgetWindowTick);
            assert_eq!(q.pop().unwrap().kind, EventKind::BudgetWindowTick, "{name}");
            assert_eq!(
                q.pop().unwrap().kind,
                EventKind::RequestArrival { stream: 0, index: 0 },
                "{name}"
            );
            assert_eq!(q.pop().unwrap().kind, EventKind::BudgetWindowTick, "{name}");
        }
    }

    #[test]
    fn pop_if_peels_only_a_matching_head() {
        for (name, mut mk) in queues() {
            let mut q = mk();
            q.push(1.0, EventKind::LeaseExpiry);
            q.push(1.0, EventKind::RepartitionTick);
            // Head is the expiry (pushed first): a tick-only predicate
            // must leave the queue untouched...
            assert!(
                q.pop_if(|e| e.kind == EventKind::RepartitionTick).is_none(),
                "{name}: pop_if must not skip past the head"
            );
            // ...and an expiry predicate pops exactly it.
            let ev = q.pop_if(|e| e.kind == EventKind::LeaseExpiry).unwrap();
            assert_eq!(ev.kind, EventKind::LeaseExpiry, "{name}");
            assert_eq!(q.pop().unwrap().kind, EventKind::RepartitionTick, "{name}");
            assert_eq!(q.processed(), 2, "{name}: pop_if pops count as processed");
        }
    }

    #[test]
    fn calendar_survives_resize_and_sparse_tails() {
        // Push enough same-width events to force at least one grow
        // rebuild, plus a far-future straggler that needs the sparse
        // fallback, then drain and check global order.
        let mut q = CalendarQueue::new();
        let mut expect: Vec<(f64, u64)> = Vec::new();
        for i in 0..500u64 {
            let t = (i % 97) as f64 * 1e-3;
            q.push(t, EventKind::RequestArrival { stream: 0, index: i as usize });
            expect.push((t, i));
        }
        q.push(1e6, EventKind::LeaseExpiry); // years past everything else
        expect.push((1e6, 500));
        expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let got: Vec<(f64, u64)> =
            std::iter::from_fn(|| q.pop()).map(|e| (e.time, e.seq)).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn calendar_accepts_pushes_before_the_cursor() {
        // Popping at t=1.0 advances the cursor; a later push at t=0.5
        // (the engine never does this, but the contract allows it) must
        // still pop first.
        let mut q = CalendarQueue::new();
        q.push(1.0, EventKind::LeaseExpiry);
        q.push(2.0, EventKind::RepartitionTick);
        assert_eq!(q.pop().unwrap().time, 1.0);
        q.push(0.5, EventKind::BudgetWindowTick);
        assert_eq!(q.pop().unwrap().kind, EventKind::BudgetWindowTick);
        assert_eq!(q.pop().unwrap().kind, EventKind::RepartitionTick);
    }

    /// Deterministic xorshift — the differential tests need adversarial
    /// but reproducible interleavings.
    struct Rng(u64);
    impl Rng {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
        fn f64(&mut self) -> f64 {
            (self.next() >> 11) as f64 / (1u64 << 53) as f64
        }
    }

    #[test]
    fn heap_and_calendar_pop_bit_identical_sequences() {
        // The core determinism property: under random interleavings of
        // pushes and pops — mixed timescales, duplicate timestamps,
        // bursts dense enough to force calendar rebuilds — both
        // implementations yield the exact same (time, seq, kind) stream.
        for seed in 1..=8u64 {
            let mut rng = Rng(0x9e37_79b9_7f4a_7c15 ^ seed);
            let mut heap = BinaryHeapQueue::new();
            let mut cal = CalendarQueue::new();
            let mut clock = 0.0f64;
            for step in 0..4_000 {
                let r = rng.next() % 100;
                if r < 60 || heap.is_empty() {
                    // Mixed horizons: mostly dense (≈ms), sometimes a
                    // far-future tick, sometimes an exact duplicate of
                    // "now" to stress tie-breaking.
                    let dt = match rng.next() % 10 {
                        0 => 0.0,
                        1..=7 => rng.f64() * 5e-3,
                        8 => rng.f64() * 2.0,
                        _ => rng.f64() * 500.0,
                    };
                    let t = clock + dt;
                    let kind = match rng.next() % 4 {
                        0 => EventKind::RequestArrival { stream: step % 7, index: step },
                        1 => EventKind::BatchComplete { stream: step % 7, epoch: step as u64 },
                        2 => EventKind::RepartitionTick,
                        _ => EventKind::LeaseExpiry,
                    };
                    heap.push(t, kind);
                    cal.push(t, kind);
                } else {
                    let a = heap.pop();
                    let b = cal.pop();
                    assert_eq!(a, b, "seed {seed} step {step}");
                    if let Some(ev) = a {
                        clock = ev.time; // future pushes stay >= popped time
                    }
                }
            }
            loop {
                let a = heap.pop();
                let b = cal.pop();
                assert_eq!(a, b, "seed {seed} drain");
                if a.is_none() {
                    break;
                }
            }
            assert_eq!(heap.processed(), cal.processed(), "seed {seed}");
        }
    }
}
