//! Device leases: the pool-sharing layer under the serving engine.
//!
//! PR 1's `partition_system` handed every stream an *exclusive* slice of
//! the [`SystemSpec`] inventory and panicked when streams outnumbered
//! devices. Leases generalize that: the pool is split into at most
//! `min(streams, devices)` partitions, and each partition is **leased**
//! to one or more streams. A partition with several tenants is
//! time-sliced by weighted round-robin — tenant `i` holds the partition
//! for a fraction `share_i` of every lease term, so its effective service
//! period stretches by `1/share_i` while every tenant keeps making
//! progress. With at least as many devices as streams every group is a
//! singleton with `share = 1`, and the assignment degenerates to exactly
//! the spatial partitioning of PR 1 — which is what keeps the engine
//! bit-compatible with the legacy per-stream accounting in that regime.
//!
//! Grouping (oversubscribed case) is longest-processing-time greedy:
//! streams are placed heaviest-demand-first onto the group with the
//! least total demand, with deterministic ties (member count, then group
//! index), so twin runs produce identical leases.
//!
//! The `demands` vector [`assign`] apportions by need not be raw FLOP
//! rates: the engine passes *SLO-weighted* demands (offered or observed
//! rate × the [`super::slo::SloController`] weight), so both the device
//! split and the intra-group time shares follow SLO pressure and QoS
//! priority, not offered load alone.

use crate::config::SystemSpec;

/// Spatial partitioning cannot give every stream a whole device.
/// (The engine answers this case with time-sliced leases instead; the
/// error survives for callers of the strict
/// [`crate::coordinator::partition_system`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OverSubscribed {
    pub streams: usize,
    pub devices: usize,
}

impl std::fmt::Display for OverSubscribed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "more streams ({}) than devices ({}): spatial partitioning infeasible, \
             time-sliced leases required",
            self.streams, self.devices
        )
    }
}

impl std::error::Error for OverSubscribed {}

/// Largest-remainder apportionment of `total` identical devices over
/// normalized `weights` (Σ = 1). Conserves `total` exactly.
pub(crate) fn apportion(total: usize, weights: &[f64]) -> Vec<usize> {
    let quotas: Vec<f64> = weights.iter().map(|w| w * total as f64).collect();
    let mut alloc: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let mut remainder = total - alloc.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    for &i in &order {
        if remainder == 0 {
            break;
        }
        alloc[i] += 1;
        remainder -= 1;
    }
    alloc
}

/// Split a device pool over `demands.len()` partitions,
/// demand-proportionally per device type, guaranteeing every partition at
/// least one device. Requires `demands.len() <= devices` (the caller —
/// [`assign`] or [`crate::coordinator::partition_system`] — enforces it).
pub(crate) fn split_pool(sys: &SystemSpec, demands: &[f64]) -> Vec<SystemSpec> {
    let k = demands.len();
    assert!(k >= 1, "no partitions requested");
    assert!(sys.n_fpga + sys.n_gpu >= k, "split_pool needs inventory >= partitions ({k})");
    let total: f64 = demands.iter().sum();
    let weights: Vec<f64> = if total > 0.0 {
        demands.iter().map(|d| d / total).collect()
    } else {
        vec![1.0 / k as f64; k]
    };
    let mut fpgas = apportion(sys.n_fpga, &weights);
    let mut gpus = apportion(sys.n_gpu, &weights);

    // Fix-up: a low-demand partition can be apportioned zero devices;
    // donate one from the richest (preserving the donor's progress).
    loop {
        let Some(poor) = (0..k).find(|&i| fpgas[i] + gpus[i] == 0) else { break };
        let rich = (0..k).max_by_key(|&i| fpgas[i] + gpus[i]).expect("non-empty");
        assert!(fpgas[rich] + gpus[rich] > 1, "inventory >= partitions => a donor exists");
        if fpgas[rich] >= gpus[rich] {
            fpgas[rich] -= 1;
            fpgas[poor] += 1;
        } else {
            gpus[rich] -= 1;
            gpus[poor] += 1;
        }
    }

    (0..k)
        .map(|i| SystemSpec { n_fpga: fpgas[i], n_gpu: gpus[i], ..sys.clone() })
        .collect()
}

/// A full lease table: which partition each stream holds and what
/// fraction of its term the stream owns.
#[derive(Debug, Clone)]
pub struct LeaseAssignment {
    /// The disjoint device partitions (inventory is conserved).
    pub partitions: Vec<SystemSpec>,
    /// Stream indices leasing each partition.
    pub members: Vec<Vec<usize>>,
    /// Stream index → partition index.
    pub part_of: Vec<usize>,
    /// Stream index → time share of its partition, in (0, 1]. Exactly
    /// 1.0 for a sole tenant.
    pub share: Vec<f64>,
}

impl LeaseAssignment {
    pub fn n_streams(&self) -> usize {
        self.part_of.len()
    }

    /// The partition and time share stream `i` holds.
    pub fn lease_of(&self, i: usize) -> (&SystemSpec, f64) {
        (&self.partitions[self.part_of[i]], self.share[i])
    }

    /// Stream `i`'s fraction of the whole pool: its time share of its
    /// partition, weighted by the partition's fraction of the device
    /// inventory. Sums to 1 over all streams. This is the quantity the
    /// re-partitioning hysteresis compares.
    pub fn pool_share(&self, i: usize, sys: &SystemSpec) -> f64 {
        let part = &self.partitions[self.part_of[i]];
        let d = (sys.n_fpga + sys.n_gpu) as f64;
        self.share[i] * (part.n_fpga + part.n_gpu) as f64 / d
    }
}

/// Lease the pool to `demands.len()` streams. Never fails for a non-empty
/// pool: with enough devices every stream gets an exclusive partition
/// (identical to [`crate::coordinator::partition_system`]); otherwise
/// streams are grouped onto `devices` partitions and time-sliced by
/// demand weight.
pub(crate) fn assign(sys: &SystemSpec, demands: &[f64]) -> LeaseAssignment {
    let k = demands.len();
    assert!(k >= 1, "no streams");
    let d = sys.n_fpga + sys.n_gpu;
    assert!(d >= 1, "no devices in the pool");

    let g = k.min(d);
    let (members, part_of) = if k <= d {
        // Exclusive leases, one partition per stream in stream order.
        ((0..k).map(|i| vec![i]).collect::<Vec<_>>(), (0..k).collect::<Vec<_>>())
    } else {
        // Oversubscribed: LPT-greedy grouping, heaviest stream first onto
        // the least-loaded group (ties: fewer members, then lower index).
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| demands[b].partial_cmp(&demands[a]).unwrap().then(a.cmp(&b)));
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); g];
        let mut load = vec![0.0f64; g];
        let mut part_of = vec![0usize; k];
        for &s in &order {
            let gi = (0..g)
                .min_by(|&x, &y| {
                    load[x]
                        .partial_cmp(&load[y])
                        .unwrap()
                        .then(members[x].len().cmp(&members[y].len()))
                        .then(x.cmp(&y))
                })
                .expect("g >= 1");
            members[gi].push(s);
            load[gi] += demands[s];
            part_of[s] = gi;
        }
        (members, part_of)
    };

    let group_demand: Vec<f64> =
        members.iter().map(|m| m.iter().map(|&s| demands[s]).sum()).collect();
    let partitions = split_pool(sys, &group_demand);
    let mut share: Vec<f64> = (0..k)
        .map(|s| {
            let gd = group_demand[part_of[s]];
            if gd > 0.0 {
                demands[s] / gd
            } else {
                1.0 / members[part_of[s]].len() as f64
            }
        })
        .collect();

    // No-starvation floor: a zero-demand stream grouped with
    // nonzero-demand peers would get `share = 0/gd = 0`, stretch its
    // admission slots by 1/0 and never be scheduled (the engine would
    // even panic pushing the infinite completion time). Floor every
    // member of a multi-tenant group at MIN_SHARE and renormalize the
    // group; groups already above the floor are left bit-identical.
    for m in &members {
        if m.len() < 2 || m.iter().all(|&s| share[s] >= MIN_SHARE) {
            continue;
        }
        let total: f64 = m.iter().map(|&s| share[s].max(MIN_SHARE)).sum();
        for &s in m {
            share[s] = share[s].max(MIN_SHARE) / total;
        }
    }

    LeaseAssignment { partitions, members, part_of, share }
}

/// Pre-normalization floor on a multi-tenant lease share: members below
/// it are raised to `MIN_SHARE` *before* the group renormalizes, so the
/// effective post-normalization minimum is
/// `MIN_SHARE / (1 + MIN_SHARE·(n−1))` for an `n`-tenant group —
/// slightly under 1% but always strictly positive and bounded away from
/// zero for any realistic group size. Small enough not to distort
/// demand-weighted shares; large enough that a floored tenant's slots
/// stretch by a bounded factor (≈ `100·(1 + MIN_SHARE·(n−1))`), not ∞.
pub(crate) const MIN_SHARE: f64 = 0.01;

/// Hand a preempted slot's freed remainder to the migration's *other*
/// incoming lease owners: a cancelled slot leaves its old devices idle
/// until its would-have-been completion, and the streams inheriting
/// hardware in the same repartition overlap their migration load with
/// that idle window. All quantities are **wall-clock seconds** — the
/// caller converts share-scaled `pending_drain` values out and back, and
/// excludes the preempting lane itself (its own cancelled slot cannot
/// subsidize its own move). `freed` is consumed against `drains` in
/// order (the engine passes migrated lanes in stream order —
/// deterministic, since device identity is not modeled below the
/// partition level); each drain absorbs at most its own length. Returns
/// the unconsumed remainder (idle time nobody could overlap with).
pub(crate) fn hand_off_remainder(mut freed: f64, drains: &mut [f64]) -> f64 {
    debug_assert!(freed >= 0.0 && freed.is_finite(), "bad freed remainder {freed}");
    for d in drains.iter_mut() {
        if freed <= 0.0 {
            break;
        }
        let rebate = freed.min(*d);
        *d -= rebate;
        freed -= rebate;
    }
    freed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Interconnect;

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4) // 3F + 2G
    }

    #[test]
    fn exclusive_leases_match_spatial_partitioning() {
        let s = sys();
        for demands in [vec![1.0, 1.0], vec![10.0, 1.0], vec![5.0, 3.0, 1.0]] {
            let a = assign(&s, &demands);
            let parts = split_pool(&s, &demands);
            assert_eq!(a.partitions.len(), demands.len());
            for (i, p) in parts.iter().enumerate() {
                let (lease, share) = a.lease_of(i);
                assert_eq!((lease.n_fpga, lease.n_gpu), (p.n_fpga, p.n_gpu));
                assert_eq!(share, 1.0, "sole tenant holds the full term");
            }
        }
    }

    #[test]
    fn oversubscribed_pool_is_time_sliced_not_rejected() {
        let s = sys(); // 5 devices
        let demands = vec![1.0; 8];
        let a = assign(&s, &demands);
        assert_eq!(a.partitions.len(), 5, "one partition per device at most");
        assert_eq!(a.partitions.iter().map(|p| p.n_fpga).sum::<usize>(), s.n_fpga);
        assert_eq!(a.partitions.iter().map(|p| p.n_gpu).sum::<usize>(), s.n_gpu);
        for i in 0..8 {
            let (lease, share) = a.lease_of(i);
            assert!(lease.n_fpga + lease.n_gpu >= 1, "every lease holds hardware");
            assert!(share > 0.0 && share <= 1.0);
        }
        // Per-partition shares are a partition of the term.
        for (g, m) in a.members.iter().enumerate() {
            assert!(!m.is_empty(), "partition {g} has no tenants");
            let total: f64 = m.iter().map(|&i| a.share[i]).sum();
            assert!((total - 1.0).abs() < 1e-9, "shares sum to {total}");
        }
        // Pool shares partition the whole pool.
        let total: f64 = (0..8).map(|i| a.pool_share(i, &s)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn heavier_stream_gets_larger_pool_share() {
        let s = sys();
        let a = assign(&s, &[9.0, 1.0]);
        assert!(a.pool_share(0, &s) > a.pool_share(1, &s));
        // Oversubscribed too: 6 streams, one dominant.
        let demands = vec![10.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let b = assign(&s, &demands);
        for i in 1..6 {
            assert!(b.pool_share(0, &s) >= b.pool_share(i, &s), "stream 0 vs {i}");
        }
    }

    #[test]
    fn grouping_is_deterministic() {
        let s = SystemSpec { n_fpga: 2, n_gpu: 1, ..sys() };
        let demands = vec![3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let a = assign(&s, &demands);
        let b = assign(&s, &demands);
        assert_eq!(a.part_of, b.part_of);
        assert_eq!(a.share, b.share);
    }

    #[test]
    fn zero_demand_streams_share_equally() {
        let s = SystemSpec { n_fpga: 1, n_gpu: 0, ..sys() };
        let a = assign(&s, &[0.0, 0.0, 0.0]);
        for i in 0..3 {
            assert!((a.share[i] - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_demand_member_of_a_mixed_group_is_never_starved() {
        // The starvation regression: one device forces both streams into
        // one group, and the zero-demand member used to get share
        // 0/1.0 = 0 — an infinitely stretched slot, never scheduled.
        let s = SystemSpec { n_fpga: 1, n_gpu: 0, ..sys() };
        let a = assign(&s, &[1.0, 0.0]);
        assert_eq!(a.part_of[0], a.part_of[1], "one device ⇒ one group");
        assert!(a.share[1] >= MIN_SHARE / 2.0, "floored share {}", a.share[1]);
        assert!(a.share[0] > a.share[1], "demand still dominates the split");
        let total: f64 = a.share.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "renormalized shares sum to {total}");

        // Oversubscribed, several zero-demand members mixed with heavy
        // peers: every member of every group keeps a live share.
        let b = assign(&s, &[5.0, 0.0, 3.0, 0.0, 0.0]);
        for (g, m) in b.members.iter().enumerate() {
            let sum: f64 = m.iter().map(|&i| b.share[i]).sum();
            assert!((sum - 1.0).abs() < 1e-9, "group {g} shares sum to {sum}");
        }
        for i in 0..5 {
            assert!(b.share[i] >= MIN_SHARE / 2.0, "stream {i} share {}", b.share[i]);
        }
    }

    #[test]
    fn share_floor_leaves_healthy_groups_bit_identical() {
        // The floor must be a no-op when every member is already above
        // it — the demand-proportional shares the rest of the test suite
        // (and the PR-4 equality bar) depends on.
        let s = SystemSpec { n_fpga: 2, n_gpu: 1, ..sys() };
        let demands = vec![3.0, 1.0, 4.0, 1.0, 5.0, 9.0];
        let a = assign(&s, &demands);
        for (g, m) in a.members.iter().enumerate() {
            let gd: f64 = m.iter().map(|&i| demands[i]).sum();
            for &i in m {
                assert_eq!(a.share[i], demands[i] / gd, "group {g} stream {i} perturbed");
            }
        }
    }

    #[test]
    fn hand_off_consumes_drains_in_order_and_returns_the_rest() {
        let mut drains = [0.05, 0.08, 0.02];
        let rest = hand_off_remainder(0.10, &mut drains);
        assert!((drains[0] - 0.0).abs() < 1e-12, "first drain fully rebated");
        assert!((drains[1] - 0.03).abs() < 1e-12, "second partially rebated");
        assert!((drains[2] - 0.02).abs() < 1e-12, "nothing left for the third");
        assert!((rest - 0.0).abs() < 1e-12);

        let mut small = [0.01];
        let rest = hand_off_remainder(0.10, &mut small);
        assert_eq!(small[0], 0.0);
        assert!((rest - 0.09).abs() < 1e-12, "a drain absorbs at most its own length");

        let mut none: [f64; 0] = [];
        assert_eq!(hand_off_remainder(0.5, &mut none), 0.5, "no takers, full remainder back");
    }

    #[test]
    fn apportion_is_exact() {
        assert_eq!(apportion(5, &[0.5, 0.5]).iter().sum::<usize>(), 5);
        assert_eq!(apportion(3, &[0.9, 0.05, 0.05]).iter().sum::<usize>(), 3);
        assert_eq!(apportion(0, &[1.0]), vec![0]);
    }
}
