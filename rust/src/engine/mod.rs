//! The event-driven serving engine — one global clock for every stream.
//!
//! PR 1's serving layer ran one synchronous discrete-event loop *per
//! stream* and pinned device partitions for the whole call, so
//! "concurrency" was an accounting convention and a pool with fewer
//! devices than streams was simply rejected. This subsystem replaces
//! that with a single engine in the style of runtime schedulers such as
//! DS3 (Mack et al.) and hardware task-queue managers (HTS):
//!
//! * **[`events`]** — every state change ([`EventKind::RequestArrival`],
//!   [`EventKind::BatchComplete`], [`EventKind::LeaseExpiry`],
//!   [`EventKind::RepartitionTick`]) is an entry in one event queue
//!   ordered by a global clock with deterministic tie-breaking. Two
//!   interchangeable backends — the original binary heap and a
//!   slab-backed calendar queue, the zero-allocation default — sit
//!   behind the [`QueueKind`] config knob, property-tested to pop
//!   bit-identical sequences.
//! * **[`lease`]** — devices are *leased*, not owned: with enough
//!   devices every stream gets an exclusive partition (bit-compatible
//!   with the legacy spatial partitioning); when streams outnumber
//!   devices a partition is time-sliced over its tenants by weighted
//!   round-robin, so the engine serves arbitrarily many streams.
//! * **[`repartition`]** — per-stream demand is tracked online (EWMA
//!   over completed FLOPs) and leases migrate between streams when the
//!   apportionment shifts past a hysteresis threshold — the inter-stream
//!   analogue of the coordinator's intra-stream reschedule policy.
//!   **On by default** since the adaptive-by-default flip: a migration
//!   prewarms the schedule cache for the prospective partition (known
//!   regimes re-time instead of re-running Algorithm 1) and, per
//!   [`repartition::MigrationMode`], either drains the in-flight slot or
//!   preempts it mid-term ([`EventKind::Preempt`]) with a partial refund
//!   of its time and `f_eng` joules.
//! * **[`budget`]** — the `f_eng` account at admission time: every
//!   dispatch charges its batch's modeled energy against a per-window
//!   joule budget, and when the window is exhausted strictly
//!   lower-priority streams are deferred to the next
//!   [`EventKind::BudgetWindowTick`] (QoS-style, highest-priority-first).
//! * **[`slo`]** — per-stream p99 targets close the loop on
//!   partitioning: a feedback controller scales each stream's lease
//!   weight by its observed-vs-target p99, so SLO pressure — not offered
//!   FLOP rate alone — decides both exclusive partitions and
//!   oversubscribed time-slice shares. Streams may also carry a hard
//!   per-request **deadline**: admission runs a feasibility check
//!   (elapsed queueing + budget wait + modeled batch latency) and
//!   **sheds** a request that can no longer make it
//!   ([`EventKind::Shed`]) instead of serving it late or deferring it
//!   past its bound — and a per-stream [`repartition::MigrationMode`]
//!   override ties preemption to criticality (critical lanes preempt,
//!   bulk lanes drain).
//!
//! The driver ([`ServingEngine`]) feeds each stream's
//! [`Coordinator`] (schedule cache included) and emits the
//! existing [`MultiStreamReport`] plus [`EngineMetrics`].
//! [`crate::coordinator::server::serve_trace`] is the single-stream
//! special case of the same loop — there is exactly one event loop in
//! the codebase.

pub mod budget;
pub mod events;
pub mod lease;
pub mod perturb;
pub mod repartition;
pub mod slo;

pub use budget::EnergyBudget;
pub use events::{EventKind, QueueKind};
pub use lease::{LeaseAssignment, OverSubscribed};
pub use perturb::{Perturbation, PerturbationKind};
pub use repartition::{DemandTracker, MigrationMode, RepartitionPolicy};
pub use slo::{SloController, StreamSlo};

use std::collections::VecDeque;

use crate::config::SystemSpec;
use crate::coordinator::multi::{MultiStreamReport, StreamReport, StreamSpec};
use crate::coordinator::server::{Completion, Request, ServeReport, RESCHEDULE_DRAIN_COST};
use crate::coordinator::Coordinator;
use crate::devices::{CommModel, GroundTruth};
use crate::metrics::{jain_index, LatencySummary, P2Quantile};
use crate::perfmodel::{OracleModels, PerfEstimator};
use crate::scheduler::{
    evaluate_plan_into, CacheStats, EvalScratch, PowerTable, Schedule, ScheduleCache,
    SharedScheduleCache, StagePlan,
};
use crate::telemetry::{self, LeaseSnapshot, Record, Recorder, ShedCause, Snapshot};
use crate::workload::Workload;

use budget::BudgetLedger;
use events::{EngineQueue, LaneId};
use repartition::share_shift;

/// Engine-wide knobs. The default is **adaptive**: online
/// re-partitioning with the default [`RepartitionPolicy`] and
/// migration-aware cache prewarming, so
/// [`crate::coordinator::MultiStreamServer::serve`] lives the paper's
/// dynamic-beats-static thesis out of the box — a migrated stream's
/// known regimes stay warm ([`crate::scheduler::ScheduleCache::prewarm`]
/// via [`Coordinator::retarget`]), which is what made the flip safe for
/// the historical acceptance scenarios. Freeze the leases with
/// [`EngineConfigBuilder::static_leases`] (the PR-1/PR-2 default) when
/// reproducing the static numbers.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Online re-partitioning policy; `None` freezes the initial leases.
    pub repartition: Option<RepartitionPolicy>,
    /// Drain cost (s of lease time) charged to a stream whose device
    /// inventory changes in a migration: the old pipeline drains and the
    /// new partition's static data loads. Deliberately above the
    /// intra-stream [`RESCHEDULE_DRAIN_COST`] — moving hardware is more
    /// disruptive than remapping on fixed hardware.
    pub migration_drain: f64,
    /// Per-window joule budget for admissions ([`budget`]); `None`
    /// disables energy metering (the historical latency-only mode).
    pub energy_budget: Option<EnergyBudget>,
    /// Feedback from observed-vs-target p99 to lease weight ([`slo`]).
    /// Always applied, but the identity for default [`StreamSlo`]s, so
    /// SLO pressure is opt-in per stream.
    pub slo: SloController,
    /// Scripted mid-run perturbations ([`perturb`]): each becomes one
    /// [`EventKind::Perturbation`] on the heap; a device cut shrinks the
    /// live pool and forces a lease re-apportionment (hysteresis
    /// bypassed — the hardware *did* change) under every policy, static
    /// included. Empty by default — the historical engine, bit for bit.
    pub perturbations: Vec<Perturbation>,
    /// Trace recorder handle ([`crate::telemetry`]); `None` — the
    /// default — records nothing and keeps the hot path at one `Option`
    /// branch per would-be record (the record is built inside a closure
    /// that never runs). Cloning the config shares the handle, so the
    /// caller keeps one to drain after the run.
    pub recorder: Option<Recorder>,
    /// Which event-queue backend drives the run ([`events`]): the
    /// slab-backed calendar queue by default, the original binary heap
    /// as the conservative alternative. The two are property-tested to
    /// pop bit-identical sequences, so this knob is purely a
    /// performance trade — benches compare them in-tree.
    pub event_queue: QueueKind,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            repartition: Some(RepartitionPolicy::default()),
            migration_drain: 80e-3,
            energy_budget: None,
            slo: SloController::default(),
            perturbations: Vec::new(),
            recorder: None,
            event_queue: QueueKind::default(),
        }
    }
}

/// Builder for [`EngineConfig`] — the one construction surface since
/// the hot-path redesign (the accreted constructors are deprecated
/// shims over it). Every method overwrites one knob and returns the
/// builder, so configs read as a sentence:
///
/// ```
/// use dype::engine::{EngineConfig, QueueKind};
///
/// let cfg = EngineConfig::builder()
///     .static_leases()
///     .event_queue(QueueKind::Heap)
///     .build();
/// assert!(cfg.repartition.is_none());
/// assert_eq!(cfg.event_queue, QueueKind::Heap);
/// ```
#[derive(Debug, Clone, Default)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    /// Demand-adaptive migration with the default
    /// [`RepartitionPolicy`] — a no-op spelling of the default, kept so
    /// call sites can say what they mean.
    pub fn adaptive(mut self) -> Self {
        self.cfg.repartition = Some(RepartitionPolicy::default());
        self
    }

    /// Freeze the initial leases for the whole run — the historical
    /// PR-1/PR-2 behavior, the escape hatch for reproducing the static
    /// acceptance numbers and for A/B-ing what adaptivity buys.
    pub fn static_leases(mut self) -> Self {
        self.cfg.repartition = None;
        self
    }

    /// Adaptive migration under a specific policy.
    pub fn repartition(mut self, pol: RepartitionPolicy) -> Self {
        self.cfg.repartition = Some(pol);
        self
    }

    /// Adaptive migration with mid-slot preemption, reacting on the
    /// given horizon (see [`RepartitionPolicy::preemptive`]).
    pub fn preemptive(mut self, horizon: f64) -> Self {
        self.cfg.repartition = Some(RepartitionPolicy::preemptive(horizon));
        self
    }

    /// Drain cost (s of lease time) charged when a migration changes a
    /// stream's device inventory (see [`EngineConfig::migration_drain`]).
    pub fn migration_drain(mut self, seconds: f64) -> Self {
        self.cfg.migration_drain = seconds;
        self
    }

    /// Attach a per-window joule budget ([`budget`]).
    pub fn energy_budget(mut self, b: EnergyBudget) -> Self {
        self.cfg.energy_budget = Some(b);
        self
    }

    /// Replace the SLO feedback controller ([`slo`]).
    pub fn slo(mut self, controller: SloController) -> Self {
        self.cfg.slo = controller;
        self
    }

    /// Script mid-run perturbations ([`perturb`]).
    pub fn perturbations(mut self, perturbations: Vec<Perturbation>) -> Self {
        self.cfg.perturbations = perturbations;
        self
    }

    /// Attach a trace recorder: every engine decision emits a typed
    /// [`Record`] through it (see [`crate::telemetry`]). The handle is
    /// shared — clone it before attaching to drain the timeline after
    /// the run.
    pub fn recorder(mut self, rec: Recorder) -> Self {
        self.cfg.recorder = Some(rec);
        self
    }

    /// Select the event-queue backend ([`QueueKind`]).
    pub fn event_queue(mut self, kind: QueueKind) -> Self {
        self.cfg.event_queue = kind;
        self
    }

    pub fn build(self) -> EngineConfig {
        self.cfg
    }
}

impl EngineConfig {
    /// Start building a config from the adaptive default:
    ///
    /// ```
    /// use dype::engine::EngineConfig;
    ///
    /// let cfg = EngineConfig::builder().adaptive().build();
    /// assert!(cfg.repartition.is_some(), "adaptive is the default");
    /// ```
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder::default()
    }

    /// Demand-adaptive migration with the default policy. Since the
    /// adaptive-by-default flip this *is* [`EngineConfig::default`];
    /// retained one release as a shim.
    #[deprecated(note = "use EngineConfig::builder().adaptive().build()")]
    pub fn adaptive() -> EngineConfig {
        EngineConfig::default()
    }

    /// Freeze the initial leases for the whole run — the historical
    /// PR-1/PR-2 default.
    #[deprecated(note = "use EngineConfig::builder().static_leases().build()")]
    pub fn static_leases() -> EngineConfig {
        EngineConfig::builder().static_leases().build()
    }

    /// The default (adaptive) config with a per-window joule budget
    /// attached.
    #[deprecated(note = "use EngineConfig::builder().energy_budget(b).build()")]
    pub fn budgeted(b: EnergyBudget) -> EngineConfig {
        EngineConfig::builder().energy_budget(b).build()
    }

    /// Attach a trace recorder to an existing config.
    #[deprecated(note = "use EngineConfig::builder().recorder(rec).build() \
                         or set the `recorder` field")]
    pub fn with_recorder(mut self, rec: Recorder) -> EngineConfig {
        self.recorder = Some(rec);
        self
    }

    /// Emit one trace record if (and only if) a recorder is attached.
    /// The closure defers record construction, so the recorder-off path
    /// costs exactly the `Option` branch.
    #[inline]
    fn trace(&self, f: impl FnOnce() -> Record) {
        if let Some(r) = &self.recorder {
            r.push(f());
        }
    }
}

/// What the engine did beyond serving requests — the observability the
/// per-stream reports cannot carry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EngineMetrics {
    /// Events popped from the heap (arrivals + completions + ticks).
    pub events_processed: u64,
    /// Lease-expiry evaluations that changed the lease table.
    pub repartitions: usize,
    /// Streams whose device inventory changed across all repartitions.
    pub lease_migrations: usize,
    /// Migrations that disturbed a stream with queued or in-flight work.
    pub preemptions: usize,
    /// In-flight slots cancelled mid-term by a migration
    /// ([`repartition::MigrationMode::Preempt`]) — a strict subset of
    /// `preemptions`.
    pub slot_preemptions: usize,
    /// Unexecuted wall-clock slot time (s) refunded by mid-slot
    /// preemptions and handed to the migration's *other* incoming lease
    /// owners as drain rebates ([`lease::hand_off_remainder`]).
    pub slot_time_refunded: f64,
    /// Modeled `f_eng` joules refunded by mid-slot preemptions — also
    /// credited back to the charging budget window when a budget is
    /// attached, so `window_joules` sums to charged − refunded.
    pub joules_refunded: f64,
    /// Cached plans carried onto prospective partitions at migration time
    /// ([`crate::scheduler::ScheduleCache::prewarm`]).
    pub prewarm_hits: u64,
    /// Plans a migration prewarm could not re-fit to the new inventory
    /// (those regimes go cold and re-run the DP once).
    pub prewarm_misses: u64,
    /// Streams that started on a time-sliced (share < 1) lease.
    pub time_sliced_streams: usize,
    /// Per-stream lease occupancy over the run's wall clock — measured on
    /// the one global clock, so streams are directly comparable (no
    /// per-stream clock skew).
    pub utilization: Vec<f64>,
    /// Requests shed by the admission-time deadline feasibility check
    /// ([`slo::StreamSlo::deadline`]): they could no longer finish
    /// inside their latency bound, so they were dropped instead of
    /// served late or budget-deferred. Zero when no stream carries a
    /// deadline.
    pub sheds: usize,
    /// Admissions deferred by energy-budget exhaustion, summed over
    /// every denial decision (a stream deferred across several window
    /// boundaries counts once per denial). Zero without a budget.
    pub deferrals: usize,
    /// Energy-budget windows the run touched (including the trailing
    /// partial window). Zero without a budget.
    pub budget_windows: usize,
    /// Net joules charged to the `f_eng` account per budget window, in
    /// window order; sums to the total modeled energy of every
    /// dispatched batch minus preemption refunds (each batch is charged
    /// exactly once and refunded at most once, against the window that
    /// charged it — no entry can go negative). Empty without a budget.
    pub window_joules: Vec<f64>,
    /// Each stream's fraction of the device pool (time share × device
    /// fraction) under the last lease it held — the end state the SLO
    /// controller and re-partitioner steered toward. Measured against
    /// the pool as it ended the run (a device-cut perturbation shrinks
    /// it). A finished stream keeps reporting the lease it ended on even
    /// after its devices were handed back, so the entries need not sum
    /// to 1. Empty for the single-stream path.
    pub final_pool_share: Vec<f64>,
    /// Scheduled perturbations that actually fired before the last
    /// request settled (one past the makespan never fires).
    pub perturbations_applied: usize,
    /// Hot-path telemetry counters ([`crate::telemetry::Snapshot`]):
    /// events popped per kind, the event-heap high-water mark, cache
    /// probe totals, and the feature-gated handler-timing/allocation
    /// figures. Maintained unconditionally — no recorder required.
    pub telemetry: Snapshot,
}

impl EngineMetrics {
    /// Net joules charged against the energy budget — charges minus
    /// preemption refunds (0 without a budget).
    pub fn joules_charged(&self) -> f64 {
        self.window_joules.iter().sum()
    }
}

impl std::fmt::Display for EngineMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} events, {} repartitions, {} lease migrations, {} preemptions \
             ({} mid-slot), {}/{} prewarmed, {} time-sliced streams, {} budget deferrals, \
             {} deadline sheds",
            self.events_processed,
            self.repartitions,
            self.lease_migrations,
            self.preemptions,
            self.slot_preemptions,
            self.prewarm_hits,
            self.prewarm_hits + self.prewarm_misses,
            self.time_sliced_streams,
            self.deferrals,
            self.sheds
        )
    }
}

/// The slot a lane currently occupies its lease with: everything a
/// mid-slot preemption needs to cancel it — when it would end, what it
/// cost, and which request it carries.
#[derive(Debug, Clone, Copy)]
struct InflightSlot {
    /// Trace index of the dispatched request (requeued on preemption).
    index: usize,
    /// Share-stretched slot end on the global clock (s).
    slot_end: f64,
    /// The slot's share-stretched length (s) — the refund denominator.
    eff_period: f64,
    /// Modeled `f_eng` joules charged for the batch.
    energy: f64,
    /// FLOPs credited to the demand window at completion.
    flops: f64,
    /// Budget-window index the batch was charged to (`None` without a
    /// ledger) — where a preemption refund must land.
    charge_window: Option<usize>,
}

/// The slice of a ground-truth measurement the dispatch math consumes —
/// copied out of the evaluated schedule so the steady state never
/// clones stage vectors or workload names.
#[derive(Debug, Clone, Copy)]
struct Measured {
    /// Pipeline initiation interval (s).
    period: f64,
    /// End-to-end pipeline latency (s).
    latency: f64,
    /// Modeled `f_eng` joules per inference.
    energy_per_inf: f64,
}

/// Order-sensitive FNV-1a hash of a workload's kernel-kind sequence —
/// the lane's "did the observed data characteristics change?" signal.
/// Replaces the per-dispatch `String` the old loop built from the same
/// `Debug` stream: the hash distinguishes exactly what the string did,
/// with no allocation, and a 2⁻⁶⁴ collision merely skips one
/// re-measurement.
fn workload_sig(wl: &Workload) -> u64 {
    use std::fmt::Write as _;
    struct Fnv(u64);
    impl std::fmt::Write for Fnv {
        fn write_str(&mut self, s: &str) -> std::fmt::Result {
            for b in s.bytes() {
                self.0 = (self.0 ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
            }
            Ok(())
        }
    }
    let mut h = Fnv(0xcbf2_9ce4_8422_2325);
    for k in &wl.kernels {
        let _ = write!(h, "{:?};", k.kind);
    }
    h.0
}

/// One stream's runtime state inside the engine: its lease, its
/// measurement apparatus, its admission queue, and its counters.
struct Lane<'c, 'a, E: PerfEstimator> {
    coord: &'c mut Coordinator<'a, E>,
    part: SystemSpec,
    share: f64,
    gt: GroundTruth,
    power: PowerTable,
    comm: CommModel,
    queue: VecDeque<usize>,
    /// The occupied admission slot, if any (`None` = lease idle).
    inflight: Option<InflightSlot>,
    /// Dispatch generation: bumped at every dispatch *and* preemption, so
    /// a cancelled slot's [`EventKind::BatchComplete`] pops stale.
    epoch: u64,
    /// [`workload_sig`] of the last measured batch (0 = none yet).
    sig: u64,
    measured: Option<Measured>,
    /// Reusable plan buffer the coordinator fills at every dispatch.
    plan_buf: Vec<StagePlan>,
    /// Reusable ground-truth evaluation target (+ its scratch): cleared
    /// and refilled in place on re-measurement, so the steady state
    /// reuses the stage and string capacity.
    timed: Schedule,
    eval_scratch: EvalScratch,
    completions: Vec<Completion>,
    reschedules: usize,
    downtime: f64,
    energy: f64,
    max_queue: usize,
    busy_time: f64,
    /// Migration drain owed before the next admission (lease seconds).
    pending_drain: f64,
    /// FLOPs *settled* since the last demand-sampling tick: completed
    /// batches plus shed requests — shed work is demand the lane had
    /// (shed-aware lease bidding), so overload never reads as idleness.
    flops_window: f64,
    cache: CacheStats,
    /// The stream's service-level objective (target + QoS priority).
    slo: StreamSlo,
    /// Incremental tail-latency estimate over completed batches — O(1)
    /// per completion, replacing the full-history re-sort at every lease
    /// re-validation.
    p99: P2Quantile,
    /// Accumulated SLO violation for the controller's integral term
    /// ([`SloController::weight_integrating`]), clamped there.
    slo_error_sum: f64,
    /// Whether the lane is waiting out an exhausted energy-budget window
    /// (idle with queued work it was denied admission for).
    deferred: bool,
    /// Admission denials the energy budget charged this lane.
    deferrals: usize,
    /// Requests the deadline feasibility check shed from this lane.
    shed: usize,
    /// In-flight slots of this lane cancelled mid-term by migrations.
    slot_preempts: usize,
}

/// A lane's final accounting, lifted into the public report types.
struct LaneOutcome {
    partition: String,
    busy_time: f64,
    report: ServeReport,
}

impl<'c, 'a, E: PerfEstimator> Lane<'c, 'a, E> {
    /// A lane whose ground truth is derived from its partition (the
    /// multi-stream path — matches the legacy per-partition harness).
    fn new(
        coord: &'c mut Coordinator<'a, E>,
        part: SystemSpec,
        share: f64,
        slo: StreamSlo,
    ) -> Self {
        let gt = GroundTruth::new(part.gpu.clone(), part.fpga.clone(), part.comm_model());
        let mut lane = Lane::with_ground_truth(coord, part, share, gt);
        lane.slo = slo;
        lane
    }

    /// A lane measuring against a caller-supplied ground truth (the
    /// single-stream path, where the harness may carry degree skew).
    fn with_ground_truth(
        coord: &'c mut Coordinator<'a, E>,
        part: SystemSpec,
        share: f64,
        gt: GroundTruth,
    ) -> Self {
        let power = PowerTable::new(part.gpu.clone(), part.fpga.clone());
        let comm = part.comm_model();
        Lane {
            coord,
            part,
            share,
            gt,
            power,
            comm,
            queue: VecDeque::new(),
            inflight: None,
            epoch: 0,
            sig: 0,
            measured: None,
            plan_buf: Vec::new(),
            timed: Schedule::default(),
            eval_scratch: EvalScratch::default(),
            completions: Vec::new(),
            reschedules: 0,
            downtime: 0.0,
            energy: 0.0,
            max_queue: 0,
            busy_time: 0.0,
            pending_drain: 0.0,
            flops_window: 0.0,
            cache: CacheStats::default(),
            slo: StreamSlo::default(),
            p99: P2Quantile::new(0.99),
            slo_error_sum: 0.0,
            deferred: false,
            deferrals: 0,
            shed: 0,
            slot_preempts: 0,
        }
    }

    /// Whether the lane's lease is occupied by an admission slot.
    fn busy(&self) -> bool {
        self.inflight.is_some()
    }

    /// The tail latency observed so far (`None` before any completion) —
    /// what the SLO controller feeds back into lease weight. Read from
    /// the incremental P² estimator fed at every batch completion, so
    /// long-running streams pay O(1) here instead of re-sorting their
    /// whole completion history at every lease re-validation.
    fn observed_p99(&self) -> Option<f64> {
        self.p99.value()
    }

    /// This lane's fraction of the whole pool under its current lease —
    /// the same quantity as [`lease::LeaseAssignment::pool_share`], kept
    /// in sync with it (the hysteresis compares the two directly).
    fn pool_share(&self, pool: &SystemSpec) -> f64 {
        let d = (pool.n_fpga + pool.n_gpu) as f64;
        self.share * (self.part.n_fpga + self.part.n_gpu) as f64 / d
    }

    /// Admission-time estimate of one batch's end-to-end service time on
    /// the current lease (s): the pending migration drain plus the
    /// share-stretched slot and pipeline fill of the last ground-truth
    /// measurement — exactly the terms [`Lane::dispatch`] would charge,
    /// minus the unknowable reschedule drain. The deadline feasibility
    /// check adds this to the time already queued (and any budget wait)
    /// before deciding to shed. Deliberately does **not** consult the
    /// coordinator: feasibility must not disturb cache statistics or
    /// reschedule hysteresis, so a lane with no measurement yet (first
    /// admission, or right after a migration dropped it) contributes
    /// only its drain — the first batch is admitted optimistically and
    /// seeds the estimate.
    fn estimated_batch_latency(&self) -> f64 {
        let drain = self.pending_drain / self.share;
        match self.measured {
            Some(m) => {
                let eff_period = m.period / self.share;
                drain + eff_period.max(1e-12) + m.latency - m.period
            }
            None => drain,
        }
    }

    /// Admit the front request at global time `now`: consult the
    /// coordinator (data-aware reschedule behind its hysteresis),
    /// re-measure on ground truth when the schedule or signature changed,
    /// pay any drain, occupy the lease for one admission slot, and
    /// schedule the [`EventKind::BatchComplete`]. Returns the batch's
    /// modeled energy (J) so the caller can charge the `f_eng` budget —
    /// exactly once per batch, at its (possibly deferred) dispatch.
    fn dispatch(&mut self, trace: &[Request], stream: usize, now: f64, q: &mut EngineQueue) -> f64 {
        debug_assert!(!self.busy(), "dispatch on a busy lane");
        let idx = self.queue.pop_front().expect("dispatch on an empty queue");
        let req = &trace[idx];
        let share = self.share;

        // Data-aware scheduling: feed the observed characteristics to the
        // coordinator; it reschedules only past its hysteresis. The plan
        // lands in this lane's reusable buffer — a steady-state cache hit
        // round-trips through the coordinator without one allocation.
        let sig = workload_sig(&req.workload);
        let cache_before = self.coord.cache_stats().unwrap_or_default();
        let rescheduled = self.coord.process_batch_into(&req.workload, &mut self.plan_buf);
        let cache_after = self.coord.cache_stats().unwrap_or_default();
        self.cache.accumulate(&cache_after.since(&cache_before));

        if sig != self.sig || rescheduled || self.measured.is_none() {
            self.sig = sig;
            // Re-measure the (possibly new) schedule on ground truth,
            // in place — `timed` and the evaluation scratch keep their
            // capacity across re-measurements.
            let oracle = OracleModels { gt: &self.gt };
            evaluate_plan_into(
                &req.workload,
                &self.plan_buf,
                &oracle,
                &self.comm,
                &self.power,
                &mut self.eval_scratch,
                &mut self.timed,
            );
            self.measured = Some(Measured {
                period: self.timed.period,
                latency: self.timed.latency(),
                energy_per_inf: self.timed.energy_per_inf,
            });
        }

        let mut start = now;
        if rescheduled {
            self.reschedules += 1;
            let drain = RESCHEDULE_DRAIN_COST / share;
            self.downtime += drain;
            start += drain;
        }
        if self.pending_drain > 0.0 {
            let drain = self.pending_drain / share;
            self.pending_drain = 0.0;
            self.downtime += drain;
            start += drain;
        }

        let (period, latency, energy) = {
            let m = self.measured.expect("measured above");
            (m.period, m.latency, m.energy_per_inf)
        };
        // Weighted round-robin time slicing: a tenant holding `share` of
        // its partition's term sees every slot stretched by 1/share. A
        // sole tenant (share = 1) reproduces the legacy steady-state
        // accounting bit for bit.
        let eff_period = period / share;
        let slot_end = start + eff_period;
        let finish = start + eff_period.max(1e-12) + latency - period; // queue + fill
        self.energy += energy;
        // Demand is tracked over *completed* FLOPs: remember the batch's
        // work and credit it when BatchComplete fires, so a long-running
        // batch is not front-loaded into the dispatch-time window.
        self.inflight = Some(InflightSlot {
            index: idx,
            slot_end,
            eff_period,
            energy,
            flops: req.workload.total_flops(),
            charge_window: None,
        });
        self.epoch += 1;
        self.busy_time += slot_end - now;
        self.completions.push(Completion { id: req.id, arrival: req.arrival, start, finish });
        q.push(slot_end, EventKind::BatchComplete { stream, epoch: self.epoch });
        energy
    }

    /// Record which budget window the in-flight batch was charged to, so
    /// a later preemption can refund the right window.
    fn note_charge_window(&mut self, window: usize) {
        if let Some(slot) = self.inflight.as_mut() {
            slot.charge_window = Some(window);
        }
    }

    /// Cancel the in-flight slot mid-term at global time `now` when its
    /// unexecuted remainder exceeds `min_remaining`
    /// ([`repartition::MigrationMode::Preempt`]); `None` when the lane is
    /// idle or the slot is nearly done (cancelling an almost-finished
    /// slot only wastes its re-run). On cancellation the request goes
    /// back to the front of the queue, the unexecuted remainder of the
    /// slot's wall-clock time and the matching fraction of its `f_eng`
    /// joules are refunded (the executed fraction is lost work and stays
    /// charged), and the pending [`EventKind::BatchComplete`] is
    /// invalidated by bumping the dispatch generation. Returns the
    /// cancelled slot with its (remainder, joules) refund — the caller
    /// settles the budget refund and re-admission.
    fn try_preempt(&mut self, now: f64, min_remaining: f64) -> Option<(InflightSlot, f64, f64)> {
        let slot = self.inflight?;
        let remainder = (slot.slot_end - now).clamp(0.0, slot.eff_period);
        if remainder <= min_remaining {
            return None;
        }
        self.inflight = None;
        let frac = if slot.eff_period > 0.0 { remainder / slot.eff_period } else { 0.0 };
        let joules = frac * slot.energy;
        self.busy_time -= remainder;
        self.energy -= joules;
        self.completions.pop().expect("in flight implies a provisional record");
        self.queue.push_front(slot.index);
        self.epoch += 1; // the stale BatchComplete now misses this lane
        Some((slot, remainder, joules))
    }

    /// Move this lane onto a new device partition: retarget the
    /// coordinator (its cache keys re-scope via the system fingerprint
    /// and its memoized regimes are *prewarmed* onto the new one),
    /// rebuild the measurement harness, and owe the migration drain.
    /// Returns the prewarm outcome, which the caller folds into the
    /// engine metrics and this lane's cache attribution.
    fn migrate(&mut self, part: SystemSpec, drain: f64) -> crate::scheduler::PrewarmReport {
        let prewarm = self.coord.retarget(part.clone());
        self.gt = GroundTruth::new(part.gpu.clone(), part.fpga.clone(), part.comm_model());
        self.power = PowerTable::new(part.gpu.clone(), part.fpga.clone());
        self.comm = part.comm_model();
        self.measured = None;
        self.sig = 0;
        self.pending_drain += drain;
        self.part = part;
        self.cache.prewarm_hits += prewarm.hits;
        self.cache.prewarm_misses += prewarm.misses;
        prewarm
    }

    fn into_outcome(self) -> LaneOutcome {
        let completed = self.completions.len();
        let makespan = self.completions.iter().map(|c| c.finish).fold(0.0, f64::max);
        let raw_lats: Vec<f64> = self.completions.iter().map(Completion::latency).collect();
        let slo_attainment = match self.slo.p99_target {
            Some(target) => crate::metrics::attainment(&raw_lats, target),
            None => 1.0,
        };
        let deadline_attainment = match self.slo.deadline {
            Some(d) => crate::metrics::deadline_attainment(&raw_lats, d, self.shed),
            None => 1.0,
        };
        // A deadline stream can legally shed its *entire* trace (e.g.
        // starved below a zero-joule budget), leaving no completions to
        // summarize.
        let lats = if raw_lats.is_empty() {
            LatencySummary { p50: 0.0, p90: 0.0, p99: 0.0, mean: 0.0, max: 0.0 }
        } else {
            LatencySummary::from_unsorted(raw_lats)
        };
        let partition = if self.share < 1.0 {
            format!("{}F{}G@{:.0}%", self.part.n_fpga, self.part.n_gpu, self.share * 100.0)
        } else {
            format!("{}F{}G", self.part.n_fpga, self.part.n_gpu)
        };
        LaneOutcome {
            partition,
            busy_time: self.busy_time,
            report: ServeReport {
                completed,
                makespan,
                throughput: if makespan > 0.0 { completed as f64 / makespan } else { 0.0 },
                mean_latency: lats.mean,
                p50_latency: lats.p50,
                p90_latency: lats.p90,
                p99_latency: lats.p99,
                max_queue_depth: self.max_queue,
                reschedules: self.reschedules,
                reschedule_downtime: self.downtime,
                energy: self.energy,
                slo_attainment,
                deadline_attainment,
                shed: self.shed,
                deferrals: self.deferrals,
                slot_preemptions: self.slot_preempts,
                p99_estimate: self.p99.value(),
                p99_observations: self.p99.count(),
                cache: self.cache,
                completions: self.completions,
            },
        }
    }
}

/// Whether the energy budget admits a dispatch for `stream` right now:
/// always, while the open window has joules left; once exhausted, only
/// when no *unfinished* stream (one that has not yet dispatched — or
/// shed — its whole trace) holds strictly higher priority. The top
/// pending class is work-conserving, so the loop always makes progress —
/// even a zero-joule budget serves everything eventually, in priority
/// order.
fn admission_allowed<E: PerfEstimator>(
    ledger: &Option<BudgetLedger>,
    lanes: &[Lane<'_, '_, E>],
    traces: &[&[Request]],
    stream: usize,
) -> bool {
    let Some(led) = ledger else { return true };
    if !led.exhausted() {
        return true;
    }
    let p = lanes[stream].slo.priority;
    lanes
        .iter()
        .zip(traces)
        .all(|(l, t)| l.completions.len() + l.shed >= t.len() || l.slo.priority <= p)
}

/// Admit the front of `stream`'s queue if the energy budget allows it
/// (charging the ledger), or mark the lane deferred — the one admission
/// path shared by the arrival, completion, window-tick, preemption, and
/// shed handlers.
///
/// When the stream carries a [`StreamSlo::deadline`], admission runs a
/// **feasibility check** first: the front request's elapsed queueing
/// time, plus the budget wait a denial would impose (at least until
/// `next_budget_tick`), plus the lane's modeled batch latency
/// ([`Lane::estimated_batch_latency`]) must fit inside the deadline —
/// otherwise the request is **shed** via an [`EventKind::Shed`] event at
/// the current timestamp and neither dispatched nor deferred. The shed
/// handler settles the accounting and re-enters this function for the
/// next queued request, so a backlog of infeasible requests drains as a
/// same-time event cascade.
#[allow(clippy::too_many_arguments)]
fn try_admit<E: PerfEstimator>(
    stream: usize,
    now: f64,
    lanes: &mut [Lane<'_, '_, E>],
    traces: &[&[Request]],
    ledger: &mut Option<BudgetLedger>,
    q: &mut EngineQueue,
    remaining: &mut usize,
    next_budget_tick: Option<f64>,
    cfg: &EngineConfig,
) {
    let allowed = admission_allowed(&*ledger, lanes, traces, stream);
    let front = lanes[stream].queue.front().copied();
    if let (Some(deadline), Some(idx)) = (lanes[stream].slo.deadline, front) {
        let elapsed = now - traces[stream][idx].arrival;
        // A denied admission waits at least until the next window tick;
        // the true wait can be longer (the refilled window may still
        // defer this class), so this is a conservative lower bound — if
        // even it blows the deadline, the request can never make it.
        let budget_wait = match next_budget_tick {
            Some(t) if !allowed => (t - now).max(0.0),
            _ => 0.0,
        };
        let batch = lanes[stream].estimated_batch_latency();
        if elapsed + budget_wait + batch > deadline {
            lanes[stream].queue.pop_front();
            // Attribute the shed to the dominant feasibility term — the
            // "why" a trace post-mortem needs.
            cfg.trace(|| {
                let cause = if budget_wait >= elapsed && budget_wait >= batch {
                    ShedCause::BudgetWait
                } else if elapsed >= batch {
                    ShedCause::Queueing
                } else {
                    ShedCause::BatchLatency
                };
                Record::Shed { t: now, stream, index: idx, cause }
            });
            q.push(now, EventKind::Shed { stream, index: idx });
            return; // the Shed handler re-considers the next request
        }
    }
    if allowed {
        lanes[stream].deferred = false;
        let joules = lanes[stream].dispatch(traces[stream], stream, now, q);
        if let Some(led) = ledger.as_mut() {
            let window = led.charge(joules);
            lanes[stream].note_charge_window(window);
        }
        *remaining -= 1;
    } else {
        lanes[stream].deferred = true;
        lanes[stream].deferrals += 1;
        cfg.trace(|| Record::Deferral { t: now, stream });
    }
}

/// The one event loop. Drains every trace through its lane on a single
/// global clock; with a re-partitioning policy, also samples demand and
/// migrates leases; with an energy budget, also meters the `f_eng`
/// account and defers below-priority admissions across window
/// boundaries; with scheduled perturbations, also mutates the live
/// system when they fire. Returns the engine metrics (utilization and
/// final pool shares left empty — the caller fills them in) plus the
/// pool as the run ended it (shrunken by any device-cut perturbation —
/// what final pool shares must be measured against).
fn run_event_loop<E: PerfEstimator>(
    pool: &SystemSpec,
    traces: &[&[Request]],
    lanes: &mut [Lane<'_, '_, E>],
    initial_demands: &[f64],
    cfg: &EngineConfig,
) -> (EngineMetrics, SystemSpec) {
    assert_eq!(traces.len(), lanes.len());
    let mut pool = pool.clone();
    let mut q = EngineQueue::new(cfg.event_queue);
    let mut remaining = 0usize;
    for (s, trace) in traces.iter().enumerate() {
        assert!(!trace.is_empty(), "empty stream trace");
        remaining += trace.len();
        for (i, req) in trace.iter().enumerate() {
            q.push(req.arrival, EventKind::RequestArrival { stream: s, index: i });
        }
    }
    for (i, p) in cfg.perturbations.iter().enumerate() {
        p.validate(lanes.len());
        q.push(p.at, EventKind::Perturbation { index: i });
    }

    let mut metrics = EngineMetrics {
        time_sliced_streams: lanes.iter().filter(|l| l.share < 1.0).count(),
        ..EngineMetrics::default()
    };

    let mut tracker = cfg.repartition.as_ref().map(|pol| {
        // A non-positive interval would re-push its own event at the same
        // timestamp forever and starve every later event — reject it.
        assert!(
            pol.sample_interval > 0.0 && pol.sample_interval.is_finite(),
            "non-positive sample_interval {}",
            pol.sample_interval
        );
        assert!(
            pol.lease_term > 0.0 && pol.lease_term.is_finite(),
            "non-positive lease_term {}",
            pol.lease_term
        );
        assert!(pol.hysteresis >= 0.0, "negative hysteresis {}", pol.hysteresis);
        q.push(pol.sample_interval, EventKind::RepartitionTick);
        q.push(pol.lease_term, EventKind::LeaseExpiry);
        DemandTracker::new(initial_demands, pol.ewma_alpha)
    });

    let mut ledger = cfg.energy_budget.clone().map(|b| {
        q.push(b.window, EventKind::BudgetWindowTick);
        BudgetLedger::new(b)
    });
    // The next BudgetWindowTick's timestamp — the wait a budget denial
    // imposes, which the deadline feasibility check prices in.
    let mut next_tick = cfg.energy_budget.as_ref().map(|b| b.window);

    // Hot-path telemetry: always-on counters (a few integer ops per
    // event) plus the feature-gated timing/allocation figures.
    let mut snap = Snapshot::default();
    let alloc_before = telemetry::alloc::allocations();
    let mut windows_closed = 0usize;

    // Handler scratch, hoisted so periodic ticks reuse capacity instead
    // of allocating a fresh vector each firing.
    let mut windows_scratch: Vec<f64> = Vec::with_capacity(lanes.len());
    let mut resume_order: Vec<LaneId> = Vec::with_capacity(lanes.len());

    while remaining > 0 {
        let ev = q.pop().expect("pending requests imply pending events");
        snap.events_popped[ev.kind.index()] += 1;
        snap.heap_high_water = snap.heap_high_water.max(q.len() + 1);
        let now = ev.time;
        #[cfg(feature = "telemetry-timing")]
        let handler_start = std::time::Instant::now();
        match ev.kind {
            EventKind::RequestArrival { stream, index } => {
                cfg.trace(|| Record::Arrival { t: now, stream, index });
                let lane = &mut lanes[stream];
                // Queue-ahead feasibility (early shedding): the front-only
                // check in `try_admit` prices only the head of the queue,
                // so under overload a hopeless request would sit in a deep
                // queue for its whole deadline before shedding at the
                // front. Price the work *ahead* of it instead — one
                // share-stretched slot per queued (and in-flight)
                // predecessor — and shed on arrival when even that lower
                // bound blows the deadline, which bounds queue depth to
                // the deadline-feasibility horizon. A lane with no
                // measurement yet admits optimistically, as at the front.
                if let (Some(deadline), Some(m)) = (lane.slo.deadline, lane.measured) {
                    let ahead = lane.queue.len() + usize::from(lane.busy());
                    let queue_wait = ahead as f64 * (m.period / lane.share).max(1e-12);
                    if queue_wait + lane.estimated_batch_latency() > deadline {
                        cfg.trace(|| Record::Shed {
                            t: now,
                            stream,
                            index,
                            cause: ShedCause::QueueAhead,
                        });
                        q.push(now, EventKind::Shed { stream, index });
                        continue; // never enqueued; the Shed handler settles it
                    }
                }
                lane.queue.push_back(index);
                lane.max_queue = lane.max_queue.max(lane.queue.len());
                if !lanes[stream].busy() {
                    try_admit(
                        stream,
                        now,
                        lanes,
                        traces,
                        &mut ledger,
                        &mut q,
                        &mut remaining,
                        next_tick,
                        cfg,
                    );
                }
            }
            EventKind::BatchComplete { stream, epoch } => {
                let lane = &mut lanes[stream];
                if lane.epoch != epoch {
                    continue; // a mid-slot preemption cancelled this slot
                }
                let slot = lane.inflight.take().expect("live epoch implies an occupied slot");
                lane.flops_window += slot.flops;
                // Feed the incremental tail estimator with the finished
                // batch's latency (the record a preemption would have
                // cancelled is gone by now, so only real completions
                // count).
                let latency =
                    lane.completions.last().expect("completion recorded at dispatch").latency();
                lane.p99.observe(latency);
                cfg.trace(|| Record::Slot {
                    start: slot.slot_end - slot.eff_period,
                    end: now,
                    stream,
                    epoch,
                });
                if !lanes[stream].queue.is_empty() {
                    try_admit(
                        stream,
                        now,
                        lanes,
                        traces,
                        &mut ledger,
                        &mut q,
                        &mut remaining,
                        next_tick,
                        cfg,
                    );
                }
            }
            EventKind::Preempt { stream } => {
                // The preempted request sits at the front of its queue;
                // re-admit it on the new lease right away (or mark it
                // deferred if the budget objects — it resumes at the next
                // window tick like any deferred lane).
                if !lanes[stream].busy() && !lanes[stream].queue.is_empty() {
                    try_admit(
                        stream,
                        now,
                        lanes,
                        traces,
                        &mut ledger,
                        &mut q,
                        &mut remaining,
                        next_tick,
                        cfg,
                    );
                }
            }
            EventKind::Shed { stream, index } => {
                // Settle a deadline shed: the request already left the
                // queue when the feasibility check rejected it (or, for an
                // arrival shed, never entered it); count it and let the
                // lane consider its next queued request at the same
                // timestamp (which may shed again — a stale backlog drains
                // as an event cascade). Shed work still counts as
                // *demand*: credit its FLOPs to the sampling window, so an
                // overloaded lane shedding heavily keeps bidding for
                // devices instead of looking idle and ceding its share to
                // better-off tenants (shed-aware lease bidding).
                lanes[stream].shed += 1;
                lanes[stream].flops_window += traces[stream][index].workload.total_flops();
                remaining -= 1;
                if !lanes[stream].busy() && !lanes[stream].queue.is_empty() {
                    try_admit(
                        stream,
                        now,
                        lanes,
                        traces,
                        &mut ledger,
                        &mut q,
                        &mut remaining,
                        next_tick,
                        cfg,
                    );
                }
            }
            EventKind::RepartitionTick => {
                if let (Some(pol), Some(tr)) = (cfg.repartition.as_ref(), tracker.as_mut()) {
                    windows_scratch.clear();
                    windows_scratch
                        .extend(lanes.iter_mut().map(|l| std::mem::take(&mut l.flops_window)));
                    tr.tick(now, &windows_scratch);
                    q.push(now + pol.sample_interval, EventKind::RepartitionTick);
                }
                // Same-tick coalescing: when the lease term lands on the
                // sampling interval's timestamp (the default policy's
                // term is a multiple of its interval, so this is the
                // common case), the expiry is the immediate next event —
                // fold it into this pass instead of paying a second
                // pop/dispatch round-trip. `pop_if` only ever inspects
                // the queue head, so any other same-time event pushed
                // between the two still pops in exactly its old order.
                if let Some(co) = q.pop_if(|e| e.time == now && e.kind == EventKind::LeaseExpiry) {
                    snap.events_popped[co.kind.index()] += 1;
                    snap.heap_high_water = snap.heap_high_water.max(q.len() + 1);
                    if tracker.is_some() {
                        maybe_migrate(
                            &pool,
                            traces,
                            lanes,
                            tracker.as_ref(),
                            initial_demands,
                            cfg,
                            now,
                            &mut q,
                            &mut ledger,
                            &mut remaining,
                            &mut metrics,
                            false,
                        );
                        let pol = cfg.repartition.as_ref().expect("tracker implies a policy");
                        q.push(now + pol.lease_term, EventKind::LeaseExpiry);
                    }
                }
            }
            EventKind::LeaseExpiry => {
                if tracker.is_some() {
                    maybe_migrate(
                        &pool,
                        traces,
                        lanes,
                        tracker.as_ref(),
                        initial_demands,
                        cfg,
                        now,
                        &mut q,
                        &mut ledger,
                        &mut remaining,
                        &mut metrics,
                        false,
                    );
                    let pol = cfg.repartition.as_ref().expect("tracker implies a policy");
                    q.push(now + pol.lease_term, EventKind::LeaseExpiry);
                }
                // The mirror coalesce: a sampling tick coinciding with
                // this expiry (and pushed after it) is the next event —
                // fold the demand-window roll into this pass.
                let coalesced =
                    q.pop_if(|e| e.time == now && e.kind == EventKind::RepartitionTick);
                if let Some(co) = coalesced {
                    snap.events_popped[co.kind.index()] += 1;
                    snap.heap_high_water = snap.heap_high_water.max(q.len() + 1);
                    if let (Some(pol), Some(tr)) = (cfg.repartition.as_ref(), tracker.as_mut()) {
                        windows_scratch.clear();
                        windows_scratch
                            .extend(lanes.iter_mut().map(|l| std::mem::take(&mut l.flops_window)));
                        tr.tick(now, &windows_scratch);
                        q.push(now + pol.sample_interval, EventKind::RepartitionTick);
                    }
                }
            }
            EventKind::BudgetWindowTick => {
                let Some((window, closed)) = ledger.as_mut().map(|led| {
                    let closed = led.roll_window();
                    (led.window(), closed)
                }) else {
                    continue; // ticks are only ever scheduled with a ledger
                };
                cfg.trace(|| Record::BudgetWindow {
                    t: now,
                    index: windows_closed,
                    joules: closed,
                });
                windows_closed += 1;
                // Resume deferred lanes highest-priority-first (ties in
                // stream order) until the refilled window objects again.
                // The order buffer is hoisted scratch; the unstable sort
                // allocates nothing and its comparator is a total order
                // (index-tied), so it equals the stable result.
                resume_order.clear();
                resume_order.extend(
                    (0..lanes.len())
                        .filter(|&i| {
                            lanes[i].deferred && !lanes[i].busy() && !lanes[i].queue.is_empty()
                        })
                        .map(|i| LaneId(i as u32)),
                );
                resume_order.sort_unstable_by(|&a, &b| {
                    let (pa, pb) = (lanes[a.index()].slo.priority, lanes[b.index()].slo.priority);
                    pb.partial_cmp(&pa).expect("finite priorities").then(a.index().cmp(&b.index()))
                });
                // Price future denials against the *next* boundary.
                next_tick = Some(now + window);
                for &s in &resume_order {
                    try_admit(
                        s.index(),
                        now,
                        lanes,
                        traces,
                        &mut ledger,
                        &mut q,
                        &mut remaining,
                        next_tick,
                        cfg,
                    );
                }
                if remaining > 0 {
                    q.push(now + window, EventKind::BudgetWindowTick);
                }
            }
            EventKind::Perturbation { index } => {
                match cfg.perturbations[index].kind {
                    PerturbationKind::DeviceCut { n_fpga, n_gpu } => {
                        pool.n_fpga = pool.n_fpga.saturating_sub(n_fpga);
                        pool.n_gpu = pool.n_gpu.saturating_sub(n_gpu);
                        if pool.n_fpga + pool.n_gpu == 0 {
                            pool.n_gpu = 1; // a cut cannot strand the run deviceless
                        }
                        // The hardware *did* change: re-apportion with the
                        // hysteresis bypassed, static leases included — no
                        // policy can keep serving on devices that left.
                        maybe_migrate(
                            &pool,
                            traces,
                            lanes,
                            tracker.as_ref(),
                            initial_demands,
                            cfg,
                            now,
                            &mut q,
                            &mut ledger,
                            &mut remaining,
                            &mut metrics,
                            true,
                        );
                    }
                    PerturbationKind::BudgetScale { factor } => {
                        // A no-op without a ledger: scaling a budget the
                        // run never had cannot change anything.
                        if let Some(led) = ledger.as_mut() {
                            led.scale(factor);
                        }
                    }
                    PerturbationKind::SloTighten { stream, p99_scale, deadline_scale } => {
                        let slo = &mut lanes[stream].slo;
                        Perturbation::tighten_slo(slo, p99_scale, deadline_scale);
                    }
                }
                cfg.trace(|| Record::Perturbation {
                    t: now,
                    index,
                    label: cfg.perturbations[index].kind.label(),
                });
                metrics.perturbations_applied += 1;
            }
        }
        #[cfg(feature = "telemetry-timing")]
        {
            // Host-clock time in the handler (arms that `continue` are
            // not timed — see `Snapshot::handler_ns`).
            snap.handler_ns[ev.kind.index()] += handler_start.elapsed().as_nanos() as u64;
        }
    }
    if let Some(led) = ledger {
        metrics.window_joules = led.into_window_joules();
        metrics.budget_windows = metrics.window_joules.len();
    }
    metrics.deferrals = lanes.iter().map(|l| l.deferrals).sum();
    metrics.sheds = lanes.iter().map(|l| l.shed).sum();
    metrics.events_processed = q.processed();
    snap.allocations = telemetry::alloc::allocations().saturating_sub(alloc_before);
    for l in lanes.iter() {
        snap.cache_probes += l.cache.hits + l.cache.misses;
        snap.cache_hits += l.cache.hits;
        snap.prewarm_hits += l.cache.prewarm_hits;
        snap.prewarm_misses += l.cache.prewarm_misses;
    }
    debug_assert_eq!(snap.events_total(), metrics.events_processed);
    metrics.telemetry = snap;
    (metrics, pool)
}

/// Lease-expiry handler: rebuild the lease table from the observed EWMA
/// demands of the still-active streams — each scaled by the SLO
/// controller's PI p99-pressure weight, so a stream missing its target
/// bids for more of the pool than its raw FLOP rate alone — and migrate
/// only when the pool-share apportionment shifted past the policy's
/// hysteresis. A *finished* stream drops out of the apportionment
/// entirely, so its devices return to the survivors (down to a sole
/// survivor inheriting the whole pool).
///
/// Also the device-cut perturbation handler, with `force` set: the
/// hysteresis comparison is skipped (the pool itself changed — the old
/// shares are measured against hardware that no longer exists) and,
/// without a repartition policy (static leases, hence no `tracker`),
/// demand falls back to the offered `initial_demands` and the migration
/// mode to [`MigrationMode::Drain`].
///
/// Per migrating stream the effective [`repartition::MigrationMode`] —
/// the stream's own [`StreamSlo::migration`] override when set, the
/// policy mode otherwise, so a latency-critical lane can preempt while a
/// bulk lane in the same repartition drains —
/// decides what happens to an in-flight slot: *drain* lets it finish on
/// the old lease (the migration takes effect at the next admission);
/// *preempt* cancels it mid-term when enough of it is left, refunds the
/// unexecuted time and joules (budget window included), requeues the
/// request, and schedules an immediate [`EventKind::Preempt`]
/// re-admission on the new lease. The freed remainders are handed to the
/// migration's other incoming lease owners as drain rebates
/// ([`lease::hand_off_remainder`]). Every migration prewarms the
/// schedule cache for the prospective partition through
/// [`Coordinator::retarget`], so known regimes stay hits.
#[allow(clippy::too_many_arguments)]
fn maybe_migrate<E: PerfEstimator>(
    pool: &SystemSpec,
    traces: &[&[Request]],
    lanes: &mut [Lane<'_, '_, E>],
    tracker: Option<&DemandTracker>,
    initial_demands: &[f64],
    cfg: &EngineConfig,
    now: f64,
    q: &mut EngineQueue,
    ledger: &mut Option<BudgetLedger>,
    remaining: &mut usize,
    metrics: &mut EngineMetrics,
    force: bool,
) {
    // "Active" = still has trace left to dispatch; shed requests count as
    // disposed of, so a fully-shed stream hands its devices back exactly
    // like a finished one.
    let active: Vec<usize> = (0..lanes.len())
        .filter(|&i| lanes[i].completions.len() + lanes[i].shed < traces[i].len())
        .collect();
    if active.is_empty() {
        return; // the run is draining its final in-flight slots
    }
    let demands: Vec<f64> = active
        .iter()
        .map(|&i| {
            let l = &mut lanes[i];
            // The incremental P² estimate makes the observation O(1);
            // untargeted lanes still skip it (the controller would
            // ignore it anyway).
            let p99 = if l.slo.p99_target.is_some() { l.observed_p99() } else { None };
            let rate = tracker.map_or(initial_demands[i], |t| t.rate(i));
            rate * cfg.slo.weight_integrating(&l.slo, p99, &mut l.slo_error_sum)
        })
        .collect();
    let desired = lease::assign(pool, &demands);
    // The apportionment shift is computed on the forced path too — it is
    // cheap (two short Vecs), and the trace record attributes every
    // repartition to the delta that (would have) triggered it.
    let current: Vec<f64> = active.iter().map(|&i| lanes[i].pool_share(pool)).collect();
    let next: Vec<f64> = (0..active.len()).map(|l| desired.pool_share(l, pool)).collect();
    let shift = share_shift(&current, &next);
    if !force {
        let pol = cfg.repartition.as_ref().expect("unforced migration requires a policy");
        if shift <= pol.hysteresis {
            return; // renewal: the table in force is still close enough
        }
    }
    metrics.repartitions += 1;
    let mut freed = 0.0f64; // preempted slot remainders, wall-clock seconds
    let mut incoming: Vec<usize> = Vec::new(); // migrated lanes, stream order
    let mut preempted: Vec<usize> = Vec::new(); // lanes whose slot was cancelled
    for (l, &s) in active.iter().enumerate() {
        let part = desired.partitions[desired.part_of[l]].clone();
        let share = desired.share[l];
        let lane = &mut lanes[s];
        if (part.n_fpga, part.n_gpu) != (lane.part.n_fpga, lane.part.n_gpu) {
            metrics.lease_migrations += 1;
            if lane.busy() || !lane.queue.is_empty() {
                metrics.preemptions += 1;
            }
            // Criticality-tied preemption: the stream's own migration
            // mode wins over the policy default when set (Drain when no
            // policy is in force — forced cuts under static leases).
            let mode = lane
                .slo
                .migration
                .unwrap_or(cfg.repartition.as_ref().map_or(MigrationMode::Drain, |p| p.migration));
            if let repartition::MigrationMode::Preempt { min_remaining } = mode {
                if let Some((slot, remainder, joules)) = lane.try_preempt(now, min_remaining) {
                    *remaining += 1; // the cancelled batch re-dispatches
                    freed += remainder;
                    preempted.push(s);
                    lane.slot_preempts += 1;
                    metrics.slot_preemptions += 1;
                    metrics.slot_time_refunded += remainder;
                    metrics.joules_refunded += joules;
                    if let (Some(led), Some(w)) = (ledger.as_mut(), slot.charge_window) {
                        led.refund(w, joules);
                    }
                    cfg.trace(|| Record::Preempt {
                        t: now,
                        stream: s,
                        refunded_time: remainder,
                        refunded_joules: joules,
                    });
                    q.push(now, EventKind::Preempt { stream: s });
                }
            }
            let prewarm = lane.migrate(part, cfg.migration_drain);
            metrics.prewarm_hits += prewarm.hits;
            metrics.prewarm_misses += prewarm.misses;
            incoming.push(s);
        } else {
            lane.part = part;
        }
        lane.share = share;
    }
    // Hand the freed remainders to the *other* incoming lease owners:
    // their migration loads overlap the idle window a cancelled slot
    // left on the hardware they inherit. The preempting lanes are
    // excluded — a lane's own cancelled slot cannot subsidize its own
    // move. Everything is settled in wall-clock seconds: a lane pays
    // `pending_drain / share` wall seconds at its next dispatch, and the
    // freed remainders are wall-clock idle windows, so drains are
    // converted out and back around the hand-off.
    if freed > 0.0 {
        let takers: Vec<usize> =
            incoming.iter().copied().filter(|s| !preempted.contains(s)).collect();
        let mut wall_drains: Vec<f64> =
            takers.iter().map(|&s| lanes[s].pending_drain / lanes[s].share).collect();
        lease::hand_off_remainder(freed, &mut wall_drains);
        for (&s, wall) in takers.iter().zip(wall_drains) {
            lanes[s].pending_drain = wall * lanes[s].share;
        }
    }
    // One repartition record with the applied lease table — the
    // per-stream rows become the lease tracks in the Perfetto export.
    cfg.trace(|| Record::Repartition {
        t: now,
        shift,
        hysteresis: cfg.repartition.as_ref().map_or(0.0, |p| p.hysteresis),
        forced: force,
        leases: active
            .iter()
            .map(|&s| LeaseSnapshot {
                stream: s,
                n_fpga: lanes[s].part.n_fpga,
                n_gpu: lanes[s].part.n_gpu,
                share: lanes[s].share,
            })
            .collect(),
    });
}

/// Single-stream entry point backing
/// [`crate::coordinator::server::serve_trace`]: one lane, an exclusive
/// full-pool lease, the caller's coordinator and ground truth.
pub(crate) fn run_single<E: PerfEstimator>(
    coordinator: &mut Coordinator<'_, E>,
    sys: &SystemSpec,
    gt: &GroundTruth,
    trace: &[Request],
) -> ServeReport {
    assert!(!trace.is_empty());
    // A sole tenant owns the whole pool for the whole run: there is
    // nothing to re-partition, so the static config skips the tick and
    // expiry machinery (and keeps the legacy-equivalence property exact).
    let cfg = EngineConfig::builder().static_leases().build();
    let mut lanes = vec![Lane::with_ground_truth(coordinator, sys.clone(), 1.0, gt.clone())];
    let traces: [&[Request]; 1] = [trace];
    let _ = run_event_loop(sys, &traces, &mut lanes, &[0.0], &cfg);
    lanes.pop().expect("one lane").into_outcome().report
}

/// The serving-engine driver: leases the pool to the streams, builds one
/// cached [`Coordinator`] per stream, and drains every trace through the
/// global event loop.
pub struct ServingEngine<'a, E: PerfEstimator> {
    sys: SystemSpec,
    est: &'a E,
    cache: SharedScheduleCache,
    cfg: EngineConfig,
}

impl<'a, E: PerfEstimator> ServingEngine<'a, E> {
    /// An engine over `sys` with a default 64-entry shared schedule cache
    /// and the adaptive default config (see [`EngineConfig`]).
    pub fn new(sys: SystemSpec, est: &'a E) -> Self {
        ServingEngine {
            sys,
            est,
            cache: ScheduleCache::shared(64),
            cfg: EngineConfig::default(),
        }
    }

    /// Share an externally-owned schedule cache (e.g. one prewarmed via
    /// [`ScheduleCache::load_from`]).
    pub fn with_cache(mut self, cache: SharedScheduleCache) -> Self {
        self.cache = cache;
        self
    }

    /// Replace the engine configuration (build one with
    /// [`EngineConfig::builder`]).
    pub fn with_config(mut self, cfg: EngineConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Handle to the shared cache (e.g. for persistence after a run).
    pub fn cache(&self) -> SharedScheduleCache {
        self.cache.clone()
    }

    /// Serve every stream's trace to completion on one global clock.
    pub fn serve(&mut self, streams: &[StreamSpec]) -> MultiStreamReport {
        assert!(!streams.is_empty(), "no streams");
        for s in streams {
            // SLO fields are public: catch a struct-literal NaN priority
            // here, before it can wedge the budget deferral ordering.
            s.slo.validate();
        }
        let cache_before = self.cache.lock().unwrap().stats();
        let demands: Vec<f64> = streams.iter().map(StreamSpec::demand).collect();
        // Initial leases weigh offered demand by SLO priority (no p99
        // observations exist yet); with default SLOs the weights are all
        // 1 and this is exactly the demand-proportional split. The
        // demand *tracker* is seeded with the raw FLOP rates — the SLO
        // weight is re-applied at every re-lease, never compounded.
        let weighted: Vec<f64> = streams
            .iter()
            .zip(&demands)
            .map(|(s, d)| d * self.cfg.slo.weight(&s.slo, None))
            .collect();
        let assignment = lease::assign(&self.sys, &weighted);

        let mut coords: Vec<Coordinator<'a, E>> = streams
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let (part, _) = assignment.lease_of(i);
                Coordinator::new(part.clone(), self.est, spec.objective)
                    .with_cache(self.cache.clone())
            })
            .collect();
        let mut lanes: Vec<Lane<'_, 'a, E>> = coords
            .iter_mut()
            .enumerate()
            .map(|(i, coord)| {
                let (part, share) = assignment.lease_of(i);
                Lane::new(coord, part.clone(), share, streams[i].slo.clone())
            })
            .collect();
        let traces: Vec<&[Request]> = streams.iter().map(|s| s.trace.as_slice()).collect();

        let (mut metrics, final_pool) =
            run_event_loop(&self.sys, &traces, &mut lanes, &demands, &self.cfg);
        metrics.final_pool_share = lanes.iter().map(|l| l.pool_share(&final_pool)).collect();

        let outcomes: Vec<LaneOutcome> = lanes.into_iter().map(Lane::into_outcome).collect();
        let makespan = outcomes.iter().map(|o| o.report.makespan).fold(0.0, f64::max);
        metrics.utilization = outcomes.iter().map(|o| o.busy_time / makespan.max(1e-12)).collect();

        let total_completed: usize = outcomes.iter().map(|o| o.report.completed).sum();
        let ratios: Vec<f64> = outcomes
            .iter()
            .zip(streams)
            .map(|(o, spec)| o.report.throughput / spec.offered_rate().max(1e-9))
            .collect();
        let fairness = jain_index(&ratios);
        let streams_out: Vec<StreamReport> = outcomes
            .into_iter()
            .zip(streams)
            .map(|(o, spec)| StreamReport {
                name: spec.name.clone(),
                partition: o.partition,
                report: o.report,
            })
            .collect();
        let total_energy: f64 = streams_out.iter().map(|s| s.report.energy).sum();
        let cache = self.cache.lock().unwrap().stats().since(&cache_before);
        MultiStreamReport {
            streams: streams_out,
            cache,
            makespan,
            total_completed,
            aggregate_throughput: total_completed as f64 / makespan.max(1e-12),
            fairness,
            total_energy,
            throughput_per_joule: total_completed as f64 / total_energy.max(1e-12),
            engine: metrics,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Interconnect, Objective};
    use crate::coordinator::server::generate_trace;
    use crate::perfmodel::OracleModels;
    use crate::workload::{gnn, Dataset, Workload};

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4) // 3F + 2G
    }

    fn gcn(edges: u64) -> Workload {
        gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, edges, 200, 0.2), 2, 128)
    }

    // Oversubscription (more streams than devices) is covered by the
    // lease-level unit tests (`lease::tests`), the positive satellite
    // test in `coordinator::multi`, and the acceptance test in
    // `rust/tests/engine.rs` — not duplicated here.

    #[test]
    #[should_panic(expected = "sample_interval")]
    fn rejects_non_positive_repartition_intervals() {
        // A zero interval would re-push its own tick at the same
        // timestamp forever; the engine must refuse it up front.
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let streams = vec![StreamSpec::new(
            "a",
            Objective::Performance,
            generate_trace(&[(gcn(2_000_000), 2)], 10.0, 5),
        )];
        let cfg = EngineConfig {
            repartition: Some(RepartitionPolicy {
                sample_interval: 0.0,
                lease_term: 1.0,
                ..RepartitionPolicy::default()
            }),
            ..EngineConfig::default()
        };
        ServingEngine::new(s, &est).with_config(cfg).serve(&streams);
    }

    #[test]
    fn static_leases_never_migrate() {
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let streams = vec![
            StreamSpec::new(
                "a",
                Objective::Performance,
                generate_trace(&[(gcn(2_000_000), 8)], 20.0, 1),
            ),
            StreamSpec::new(
                "b",
                Objective::Performance,
                generate_trace(&[(gcn(150_000_000), 8)], 20.0, 2),
            ),
        ];
        let mut engine = ServingEngine::new(s, &est)
            .with_config(EngineConfig::builder().static_leases().build());
        let r = engine.serve(&streams);
        assert_eq!(r.engine.lease_migrations, 0);
        assert_eq!(r.engine.repartitions, 0);
        assert_eq!(r.engine.utilization.len(), 2);
        for u in &r.engine.utilization {
            assert!(*u > 0.0 && *u <= 1.0 + 1e-9, "utilization {u}");
        }
    }

    #[test]
    fn phase_reversed_demand_skew_migrates_leases() {
        // Both streams offer the same *total* demand, so the initial
        // leases split the pool evenly — but stream a is heavy in the
        // first half and light in the second, b the mirror image. The
        // demand tracker must notice and migrate devices at least once.
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let heavy = gcn(150_000_000);
        let light = gcn(2_000_000);
        let a = generate_trace(&[(heavy.clone(), 10), (light.clone(), 10)], 10.0, 3);
        let b = generate_trace(&[(light, 10), (heavy, 10)], 10.0, 4);
        let streams = vec![
            StreamSpec::new("a", Objective::Performance, a),
            StreamSpec::new("b", Objective::Performance, b),
        ];
        let cfg = EngineConfig {
            repartition: Some(RepartitionPolicy {
                sample_interval: 0.05,
                lease_term: 0.1,
                ewma_alpha: 0.6,
                hysteresis: 0.05,
                migration: MigrationMode::Drain,
            }),
            ..EngineConfig::default()
        };
        let mut engine = ServingEngine::new(s, &est).with_config(cfg);
        let r = engine.serve(&streams);
        assert_eq!(r.total_completed, 40, "migration must not lose requests");
        assert!(
            r.engine.lease_migrations >= 1,
            "skewed demand must migrate at least one lease: {}",
            r.engine
        );
        assert!(r.engine.repartitions >= 1);
        assert!(r.fairness > 0.0);
    }

    #[test]
    fn device_cut_perturbation_shrinks_the_pool_and_forces_migration() {
        // 3F+2G cut down to 1F+1G mid-run, under *static* leases: the
        // forced re-apportionment must still happen (no policy can keep
        // serving on devices that left), every request must still settle,
        // and the final pool shares must be measured against the shrunken
        // pool — valid fractions of 2 devices, not of the original 5.
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let streams = vec![
            StreamSpec::new(
                "a",
                Objective::Performance,
                generate_trace(&[(gcn(150_000_000), 12)], 20.0, 5),
            ),
            StreamSpec::new(
                "b",
                Objective::Performance,
                generate_trace(&[(gcn(2_000_000), 12)], 20.0, 6),
            ),
        ];
        let cfg = EngineConfig::builder()
            .static_leases()
            .perturbations(vec![Perturbation::device_cut(0.05, 2, 1)])
            .build();
        let mut engine = ServingEngine::new(s, &est).with_config(cfg);
        let r = engine.serve(&streams);
        assert_eq!(r.total_completed, 24, "a device cut must not lose requests");
        assert_eq!(r.engine.perturbations_applied, 1);
        assert!(r.engine.repartitions >= 1, "a cut forces a re-apportionment: {}", r.engine);
        assert!(r.engine.lease_migrations >= 1, "5 devices shrank to 2: {}", r.engine);
        for share in &r.engine.final_pool_share {
            assert!(*share > 0.0 && *share <= 1.0 + 1e-9, "post-cut pool share {share}");
        }
    }

    #[test]
    fn budget_scale_without_a_ledger_is_a_counted_noop() {
        // Scaling a budget the run never had changes nothing observable —
        // except the applied-perturbations counter.
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let mk = || {
            vec![StreamSpec::new(
                "a",
                Objective::Performance,
                generate_trace(&[(gcn(2_000_000), 6)], 20.0, 9),
            )]
        };
        let base = ServingEngine::new(s.clone(), &est)
            .with_config(EngineConfig::builder().static_leases().build())
            .serve(&mk());
        let cfg = EngineConfig::builder()
            .static_leases()
            .perturbations(vec![Perturbation::budget_scale(0.01, 0.5)])
            .build();
        let pert = ServingEngine::new(s, &est).with_config(cfg).serve(&mk());
        assert_eq!(pert.engine.perturbations_applied, 1);
        assert_eq!(base.total_completed, pert.total_completed);
        assert_eq!(base.makespan, pert.makespan, "an unbudgeted scale must not perturb timing");
        assert_eq!(base.total_energy, pert.total_energy);
    }

    #[test]
    fn slo_tighten_perturbation_starts_shedding_mid_run() {
        // A deadline so loose it never sheds, tightened mid-run to one so
        // hard nothing queued or arriving can make it: completions before
        // the perturbation, sheds after, nothing lost.
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let trace = generate_trace(&[(gcn(2_000_000), 10)], 40.0, 11);
        let offered = trace.len();
        let streams = vec![StreamSpec::new("a", Objective::Performance, trace)
            .with_slo(StreamSlo::target(0.100, 2.0).with_deadline(10.0))];
        let cfg = EngineConfig {
            perturbations: vec![Perturbation::slo_tighten(0.05, 0, 1.0, 1e-6)],
            ..EngineConfig::default()
        };
        let mut engine = ServingEngine::new(s, &est).with_config(cfg);
        let r = engine.serve(&streams);
        let rep = &r.streams[0].report;
        assert_eq!(r.engine.perturbations_applied, 1);
        assert_eq!(rep.completed + rep.shed, offered, "every request settles exactly once");
        assert!(rep.shed >= 1, "a 10 microsecond deadline must shed: {rep:?}");
        assert!(rep.completed >= 1, "work admitted before the tightening completes");
    }

    #[test]
    fn default_config_is_adaptive_with_drain_migrations() {
        let cfg = EngineConfig::default();
        let pol = cfg.repartition.expect("adaptive by default");
        assert_eq!(pol.migration, MigrationMode::Drain);
        assert_eq!(cfg.event_queue, QueueKind::Calendar, "calendar queue is the default");
        assert!(EngineConfig::builder().static_leases().build().repartition.is_none());
        assert!(EngineConfig::builder().adaptive().build().repartition.is_some());
    }

    /// The deprecated constructor shims must keep producing exactly what
    /// their builder spellings produce for the one release they survive.
    #[test]
    #[allow(deprecated)]
    fn deprecated_constructor_shims_match_the_builder() {
        assert!(EngineConfig::adaptive().repartition.is_some());
        assert!(EngineConfig::static_leases().repartition.is_none());
        let cfg = EngineConfig::budgeted(EnergyBudget::new(50.0, 1.0));
        assert_eq!(
            cfg.energy_budget.as_ref().map(|e| (e.joules_per_window, e.window)),
            Some((50.0, 1.0)),
            "budgeted() must attach the budget"
        );
        let rec = crate::telemetry::Recorder::timeline();
        assert!(EngineConfig::default().with_recorder(rec).recorder.is_some());
    }

    #[test]
    fn builder_covers_every_knob() {
        let cfg = EngineConfig::builder()
            .preemptive(2.0)
            .migration_drain(0.123)
            .energy_budget(EnergyBudget::new(10.0, 0.5))
            .event_queue(QueueKind::Heap)
            .build();
        let pol = cfg.repartition.expect("preemptive implies a policy");
        assert!(matches!(pol.migration, MigrationMode::Preempt { .. }));
        assert_eq!(cfg.migration_drain, 0.123);
        assert_eq!(cfg.event_queue, QueueKind::Heap);
        assert!(cfg.energy_budget.is_some());
    }
}
