//! Energy-budgeted admission — the `f_eng` account threaded into the
//! engine's dispatch path.
//!
//! DyPe's design space is multi-objective: energy is a first-class
//! constraint, not a post-hoc report. The engine therefore meters the
//! *modeled* energy of every admitted batch (the same
//! [`crate::scheduler::energy`] `f_eng` account the DP optimizes —
//! `Schedule::energy_per_inf` as re-timed on ground truth) against a
//! per-window joule budget:
//!
//! * Time is cut into fixed windows of [`EnergyBudget::window`] seconds;
//!   each window opens with [`EnergyBudget::joules_per_window`] joules.
//! * Every dispatch charges its batch's modeled energy to the open
//!   window. Budgets are enforced at admission granularity — a batch is
//!   never split — so among the *deferrable* classes a window overdraws
//!   by at most its final admitted batch. The highest pending priority
//!   class is exempt (work-conserving, see below) and keeps charging an
//!   exhausted window, so the cap bounds everything below it, not the
//!   top class itself. There is no debt carry-over; the next window
//!   opens with a full refill.
//! * Once the window is exhausted, a stream may only dispatch if no
//!   *unfinished* stream has strictly higher
//!   [`super::slo::StreamSlo::priority`] (QoS-style: the top class is
//!   work-conserving, everything below it is deferred). Deferred work
//!   resumes at the next [`super::EventKind::BudgetWindowTick`],
//!   highest-priority-first.
//!
//! Because the highest-priority pending stream is never deferred, the
//! event loop always makes progress — even a zero-joule budget serves
//! every stream eventually, in strict priority order (the property the
//! acceptance tests pin down). Streams of *equal* priority are never
//! deferred against each other: deferral discriminates only strictly
//! lower priorities.

/// Per-window joule budget for the serving engine. `None` in
/// [`super::EngineConfig`] disables energy metering entirely (the
/// latency-only mode, bit-identical to the pre-budget engine).
#[derive(Debug, Clone)]
pub struct EnergyBudget {
    /// Joules available per window. Zero is legal and means "defer
    /// everything below the highest pending priority".
    pub joules_per_window: f64,
    /// Window length (s).
    pub window: f64,
}

impl EnergyBudget {
    pub fn new(joules_per_window: f64, window: f64) -> EnergyBudget {
        assert!(
            joules_per_window >= 0.0 && joules_per_window.is_finite(),
            "negative or non-finite joule budget {joules_per_window}"
        );
        assert!(window > 0.0 && window.is_finite(), "non-positive budget window {window}");
        EnergyBudget { joules_per_window, window }
    }

    /// A budget expressed as a sustained power cap: `cap_watts` joules
    /// per second, metered in `window`-second windows. Pair with
    /// [`crate::scheduler::PowerTable::pool_power_cap`] to derive the cap
    /// from the device inventory's worst-case draw.
    pub fn from_power_cap(cap_watts: f64, window: f64) -> EnergyBudget {
        assert!(cap_watts >= 0.0 && cap_watts.is_finite(), "bad power cap {cap_watts}");
        EnergyBudget::new(cap_watts * window, window)
    }
}

/// Run-time account of one serve call: how many joules the open window
/// has left and what every closed window was charged. Total charged
/// energy equals the sum of per-batch model energies — each batch is
/// charged exactly once, at its (possibly deferred) dispatch.
#[derive(Debug)]
pub(crate) struct BudgetLedger {
    budget: EnergyBudget,
    remaining: f64,
    charged_in_window: f64,
    /// Joules charged per closed window, in window order.
    window_joules: Vec<f64>,
}

impl BudgetLedger {
    pub(crate) fn new(budget: EnergyBudget) -> BudgetLedger {
        // Re-validate here too: the config struct has public fields, so a
        // caller can bypass `EnergyBudget::new`.
        assert!(
            budget.joules_per_window >= 0.0 && budget.joules_per_window.is_finite(),
            "negative or non-finite joule budget {}",
            budget.joules_per_window
        );
        assert!(
            budget.window > 0.0 && budget.window.is_finite(),
            "non-positive budget window {}",
            budget.window
        );
        let remaining = budget.joules_per_window;
        BudgetLedger { budget, remaining, charged_in_window: 0.0, window_joules: Vec::new() }
    }

    pub(crate) fn window(&self) -> f64 {
        self.budget.window
    }

    /// Whether the open window has no joules left (admissions beyond
    /// this point are deferrable).
    pub(crate) fn exhausted(&self) -> bool {
        self.remaining <= 0.0
    }

    /// Charge one batch's modeled energy to the open window.
    pub(crate) fn charge(&mut self, joules: f64) {
        debug_assert!(joules >= 0.0 && joules.is_finite(), "bad charge {joules}");
        self.remaining -= joules;
        self.charged_in_window += joules;
    }

    /// Close the open window and refill the budget (no debt carry-over).
    pub(crate) fn roll_window(&mut self) {
        self.window_joules.push(self.charged_in_window);
        self.charged_in_window = 0.0;
        self.remaining = self.budget.joules_per_window;
    }

    /// Close the trailing partial window and return the per-window
    /// charge record; its sum is the run's total charged energy.
    pub(crate) fn into_window_joules(mut self) -> Vec<f64> {
        self.window_joules.push(self.charged_in_window);
        self.window_joules
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_charges_each_joule_exactly_once() {
        let mut l = BudgetLedger::new(EnergyBudget::new(10.0, 1.0));
        l.charge(4.0);
        l.charge(8.0); // overdraw by the final admitted batch is legal
        assert!(l.exhausted());
        l.roll_window();
        assert!(!l.exhausted(), "refill restores the full budget");
        l.charge(3.0);
        let windows = l.into_window_joules();
        assert_eq!(windows, vec![12.0, 3.0]);
        assert!((windows.iter().sum::<f64>() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn zero_budget_is_exhausted_from_the_start() {
        let l = BudgetLedger::new(EnergyBudget::new(0.0, 0.5));
        assert!(l.exhausted());
    }

    #[test]
    fn power_cap_scales_with_window() {
        let b = EnergyBudget::from_power_cap(200.0, 0.5);
        assert!((b.joules_per_window - 100.0).abs() < 1e-12);
        assert_eq!(b.window, 0.5);
    }

    #[test]
    #[should_panic(expected = "non-positive budget window")]
    fn rejects_zero_window() {
        EnergyBudget::new(1.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "negative or non-finite joule budget")]
    fn rejects_negative_budget() {
        EnergyBudget::new(-1.0, 1.0);
    }
}
