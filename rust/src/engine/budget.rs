//! Energy-budgeted admission — the `f_eng` account threaded into the
//! engine's dispatch path.
//!
//! DyPe's design space is multi-objective: energy is a first-class
//! constraint, not a post-hoc report. The engine therefore meters the
//! *modeled* energy of every admitted batch (the same
//! [`crate::scheduler::energy`] `f_eng` account the DP optimizes —
//! `Schedule::energy_per_inf` as re-timed on ground truth) against a
//! per-window joule budget:
//!
//! * Time is cut into fixed windows of [`EnergyBudget::window`] seconds;
//!   each window opens with [`EnergyBudget::joules_per_window`] joules.
//! * Every dispatch charges its batch's modeled energy to the open
//!   window. Budgets are enforced at admission granularity — a batch is
//!   never split — so among the *deferrable* classes a window overdraws
//!   by at most its final admitted batch. The highest pending priority
//!   class is exempt (work-conserving, see below) and keeps charging an
//!   exhausted window, so the cap bounds everything below it, not the
//!   top class itself. The open window's balance **carries over**: an
//!   overdraft is deducted from the next window's refill, and unused
//!   joules bank — capped at one extra window's worth — so the long-run
//!   cap holds over any horizon, not just per window (the highest
//!   pending class stays exempt, so a carried debt never livelocks).
//! * A mid-slot preemption ([`super::repartition::MigrationMode`])
//!   **refunds** the unexecuted fraction of a cancelled batch's joules to
//!   the window that was charged for it, so
//!   `Σ window_joules == Σ charged − Σ refunded` holds exactly and no
//!   window's record can go negative (a refund never exceeds what its
//!   batch charged that window).
//! * Once the window is exhausted, a stream may only dispatch if no
//!   *unfinished* stream has strictly higher
//!   [`super::slo::StreamSlo::priority`] (QoS-style: the top class is
//!   work-conserving, everything below it is deferred). Deferred work
//!   resumes at the next [`super::EventKind::BudgetWindowTick`],
//!   highest-priority-first.
//!
//! Because the highest-priority pending stream is never deferred, the
//! event loop always makes progress — even a zero-joule budget serves
//! every stream eventually, in strict priority order (the property the
//! acceptance tests pin down). Streams of *equal* priority are never
//! deferred against each other: deferral discriminates only strictly
//! lower priorities.
//!
//! Deferral interacts with **deadlines**
//! ([`super::slo::StreamSlo::deadline`]): before a denial parks a
//! request, the engine's feasibility check prices the wait to the next
//! [`super::EventKind::BudgetWindowTick`] against the request's bound —
//! a request that cannot survive even that lower-bound wait is **shed**
//! at the denial point instead of deferred past its deadline, and a
//! stream that has shed its whole trace counts as finished for the
//! deferral ordering above (it can no longer block lower classes).

/// Per-window joule budget for the serving engine. `None` in
/// [`super::EngineConfig`] disables energy metering entirely (the
/// latency-only mode, bit-identical to the pre-budget engine).
#[derive(Debug, Clone)]
pub struct EnergyBudget {
    /// Joules available per window. Zero is legal and means "defer
    /// everything below the highest pending priority".
    pub joules_per_window: f64,
    /// Window length (s).
    pub window: f64,
}

impl EnergyBudget {
    pub fn new(joules_per_window: f64, window: f64) -> EnergyBudget {
        assert!(
            joules_per_window >= 0.0 && joules_per_window.is_finite(),
            "negative or non-finite joule budget {joules_per_window}"
        );
        assert!(window > 0.0 && window.is_finite(), "non-positive budget window {window}");
        EnergyBudget { joules_per_window, window }
    }

    /// A budget expressed as a sustained power cap: `cap_watts` joules
    /// per second, metered in `window`-second windows. Pair with
    /// [`crate::scheduler::PowerTable::pool_power_cap`] to derive the cap
    /// from the device inventory's worst-case draw.
    pub fn from_power_cap(cap_watts: f64, window: f64) -> EnergyBudget {
        assert!(cap_watts >= 0.0 && cap_watts.is_finite(), "bad power cap {cap_watts}");
        EnergyBudget::new(cap_watts * window, window)
    }
}

/// Run-time account of one serve call: how many joules the open window
/// has left and what every closed window was (net) charged. Each batch
/// is charged exactly once, at its (possibly deferred) dispatch, and
/// refunded at most once, against the window that charged it — so the
/// per-window record sums to `Σ charged − Σ refunded` exactly.
#[derive(Debug)]
pub(crate) struct BudgetLedger {
    budget: EnergyBudget,
    remaining: f64,
    charged_in_window: f64,
    /// Net joules charged per closed window, in window order.
    window_joules: Vec<f64>,
    /// Total joules handed back by mid-slot preemptions.
    refunded: f64,
}

impl BudgetLedger {
    pub(crate) fn new(budget: EnergyBudget) -> BudgetLedger {
        // Re-validate here too: the config struct has public fields, so a
        // caller can bypass `EnergyBudget::new`.
        assert!(
            budget.joules_per_window >= 0.0 && budget.joules_per_window.is_finite(),
            "negative or non-finite joule budget {}",
            budget.joules_per_window
        );
        assert!(
            budget.window > 0.0 && budget.window.is_finite(),
            "non-positive budget window {}",
            budget.window
        );
        let remaining = budget.joules_per_window;
        BudgetLedger {
            budget,
            remaining,
            charged_in_window: 0.0,
            window_joules: Vec::new(),
            refunded: 0.0,
        }
    }

    pub(crate) fn window(&self) -> f64 {
        self.budget.window
    }

    /// Whether the open window has no joules left (admissions beyond
    /// this point are deferrable).
    pub(crate) fn exhausted(&self) -> bool {
        self.remaining <= 0.0
    }

    /// Charge one batch's modeled energy to the open window. Returns the
    /// open window's index — the handle a later [`BudgetLedger::refund`]
    /// must target so refunds land on the window that was charged.
    pub(crate) fn charge(&mut self, joules: f64) -> usize {
        debug_assert!(joules >= 0.0 && joules.is_finite(), "bad charge {joules}");
        self.remaining -= joules;
        self.charged_in_window += joules;
        self.window_joules.len()
    }

    /// Hand back part of a batch's charge (a mid-slot preemption's
    /// unexecuted fraction). `window` is the index [`BudgetLedger::charge`]
    /// returned for that batch: refunding the still-open window also
    /// restores its admission headroom; a closed window only has its
    /// record corrected (its joules were already "spent" as cap headroom
    /// and cannot be re-granted to a later window).
    pub(crate) fn refund(&mut self, window: usize, joules: f64) {
        debug_assert!(joules >= 0.0 && joules.is_finite(), "bad refund {joules}");
        self.refunded += joules;
        if window == self.window_joules.len() {
            self.charged_in_window -= joules;
            self.remaining += joules;
        } else {
            self.window_joules[window] -= joules;
            debug_assert!(
                self.window_joules[window] >= -1e-9,
                "refund pushed window {window} negative: {}",
                self.window_joules[window]
            );
        }
    }

    /// Total joules handed back by preemption refunds so far.
    pub(crate) fn refunded(&self) -> f64 {
        self.refunded
    }

    /// Scale the budget mid-run (a [`crate::engine::perturb`] budget-cut
    /// perturbation): the per-window refill and the open window's balance
    /// both scale by `factor`, so banked headroom and carried debt shrink
    /// (or grow) proportionally. The window *duration* is untouched — the
    /// tick cadence already on the event heap stays valid.
    pub(crate) fn scale(&mut self, factor: f64) {
        assert!(factor >= 0.0 && factor.is_finite(), "bad budget scale factor {factor}");
        self.budget.joules_per_window *= factor;
        self.remaining *= factor;
    }

    /// Close the open window and refill the budget, carrying the balance
    /// over: an overdraft (negative remainder) is deducted from the
    /// refill, unused joules bank up to one extra window's worth.
    /// Returns the closed window's net charge (what telemetry plots on
    /// the budget-window track).
    pub(crate) fn roll_window(&mut self) -> f64 {
        let closed = self.charged_in_window;
        self.window_joules.push(closed);
        self.charged_in_window = 0.0;
        let carry = self.remaining.min(self.budget.joules_per_window);
        self.remaining = self.budget.joules_per_window + carry;
        closed
    }

    /// Close the trailing partial window and return the per-window net
    /// charge record; its sum is the run's total charged minus refunded
    /// energy.
    pub(crate) fn into_window_joules(mut self) -> Vec<f64> {
        self.window_joules.push(self.charged_in_window);
        self.window_joules
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_charges_each_joule_exactly_once() {
        let mut l = BudgetLedger::new(EnergyBudget::new(10.0, 1.0));
        l.charge(4.0);
        l.charge(8.0); // overdraw by the final admitted batch is legal
        assert!(l.exhausted());
        l.roll_window();
        assert!(!l.exhausted(), "the refill re-opens the account");
        l.charge(3.0);
        let windows = l.into_window_joules();
        assert_eq!(windows, vec![12.0, 3.0]);
        assert!((windows.iter().sum::<f64>() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn overdraft_carries_into_the_next_refill() {
        let mut l = BudgetLedger::new(EnergyBudget::new(10.0, 1.0));
        l.charge(25.0); // 15 J of debt
        l.roll_window();
        // Refill 10 − debt 15 = still 5 J in the red.
        assert!(l.exhausted(), "a carried overdraft keeps the window closed");
        l.roll_window();
        // Second refill clears the remaining debt: 10 − 5 = 5 J free.
        assert!(!l.exhausted());
        let windows = l.into_window_joules();
        assert_eq!(windows, vec![25.0, 0.0, 0.0]);
    }

    #[test]
    fn unused_joules_bank_at_most_one_window() {
        let mut l = BudgetLedger::new(EnergyBudget::new(10.0, 1.0));
        l.roll_window(); // nothing charged: bank caps at one window
        l.roll_window(); // still capped — banking is not unbounded
        l.charge(19.0);
        assert!(!l.exhausted(), "refill + one banked window covers 19 J");
        l.charge(1.0);
        assert!(l.exhausted(), "the 20 J ceiling (refill + bank cap) holds");
    }

    #[test]
    fn refund_targets_the_charged_window() {
        let mut l = BudgetLedger::new(EnergyBudget::new(10.0, 1.0));
        let w0 = l.charge(9.0);
        assert_eq!(w0, 0);
        // Refund into the still-open window restores admission headroom.
        l.refund(w0, 4.0);
        assert!(!l.exhausted());
        l.roll_window();
        let w1 = l.charge(6.0);
        l.roll_window();
        // Refunding a closed window corrects its record only.
        l.refund(w1, 2.0);
        assert!((l.refunded() - 6.0).abs() < 1e-12);
        let windows = l.into_window_joules();
        assert_eq!(windows, vec![5.0, 4.0, 0.0]);
        assert!(windows.iter().all(|j| *j >= 0.0), "refunds never push a window negative");
        // Conservation: Σ windows == Σ charged − Σ refunded.
        assert!((windows.iter().sum::<f64>() - (15.0 - 6.0)).abs() < 1e-12);
    }

    #[test]
    fn scale_cuts_refill_and_open_balance_but_not_cadence() {
        let mut l = BudgetLedger::new(EnergyBudget::new(10.0, 0.5));
        l.charge(4.0); // 6 J left in the open window
        l.scale(0.5); // budget cut: refill 5 J/window, balance 3 J
        assert_eq!(l.window(), 0.5, "the tick cadence never changes");
        l.charge(2.0);
        assert!(!l.exhausted(), "3 J scaled balance covers a 2 J batch");
        l.charge(2.0);
        assert!(l.exhausted(), "the scaled balance is gone");
        l.roll_window();
        l.charge(4.0);
        assert!(l.exhausted(), "the refill itself is scaled: 5 J − 1 J debt < 4.1 J");
        // Charges are recorded gross — scaling meters admission, it never
        // rewrites what batches actually drew.
        assert_eq!(l.into_window_joules(), vec![8.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "bad budget scale factor")]
    fn scale_rejects_negative_factors() {
        BudgetLedger::new(EnergyBudget::new(10.0, 0.5)).scale(-0.5);
    }

    #[test]
    fn zero_budget_is_exhausted_from_the_start() {
        let mut l = BudgetLedger::new(EnergyBudget::new(0.0, 0.5));
        assert!(l.exhausted());
        l.roll_window();
        assert!(l.exhausted(), "a zero budget carries nothing to bank");
    }

    #[test]
    fn power_cap_scales_with_window() {
        let b = EnergyBudget::from_power_cap(200.0, 0.5);
        assert!((b.joules_per_window - 100.0).abs() < 1e-12);
        assert_eq!(b.window, 0.5);
    }

    #[test]
    #[should_panic(expected = "non-positive budget window")]
    fn rejects_zero_window() {
        EnergyBudget::new(1.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "negative or non-finite joule budget")]
    fn rejects_negative_budget() {
        EnergyBudget::new(-1.0, 1.0);
    }
}
