//! # DYPE — Data-aware Dynamic Execution of Irregular Workloads on Heterogeneous Systems
//!
//! Production-grade reproduction of the DYPE scheduling framework
//! (Bai et al., CS.DC 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a dynamic-programming
//!   scheduler ([`scheduler`]) that jointly groups kernels into pipeline
//!   stages and allocates heterogeneous devices (GPUs/FPGAs) per stage,
//!   driven by data-aware kernel performance models ([`perfmodel`]) over a
//!   simulated heterogeneous testbed ([`devices`]); plus the streaming
//!   pipeline executor ([`pipeline`]) and the serving layer
//!   ([`coordinator`]): drift-aware rescheduling with hysteresis, a
//!   quantized-feature schedule cache ([`scheduler::ScheduleCache`],
//!   persistable across restarts) that turns reschedules on recurring
//!   drift into cache hits, and the event-heap serving engine
//!   ([`engine`]): one global discrete-event clock for every concurrent
//!   request stream, devices handed out as time-sliced *leases*
//!   (arbitrarily many streams per pool) and — by default — re-leased
//!   online when observed demand drifts past a hysteresis, each
//!   migration prewarming the schedule cache for its prospective
//!   partition and optionally preempting in-flight slots with partial
//!   time/energy refunds —
//!   [`coordinator::MultiStreamServer`] and the single-stream
//!   [`coordinator::Server`] are both front-ends over it, and the
//!   sharded fleet layer ([`fleet`]) scales it out: N engines on
//!   parallel OS threads over disjoint pool slices, behind an SLO- and
//!   cache-affinity-aware admission router with cross-shard migration.
//! * **L2/L1 (build time, `python/`)** — the workloads' actual compute
//!   (GCN / GIN / sliding-window transformer layers composed from Pallas
//!   kernels), AOT-lowered to HLO text artifacts executed by [`runtime`]
//!   via PJRT. Python never runs on the request path.
//!
//! See `DESIGN.md` for the system inventory, the hardware-substitution
//! table, and the experiment index mapping every table/figure of the paper
//! to a bench target.

// The one sanctioned unsafe block in the workspace is the counting
// global allocator behind `telemetry-alloc`; every other configuration
// forbids unsafe outright.
#![cfg_attr(not(feature = "telemetry-alloc"), forbid(unsafe_code))]
#![cfg_attr(feature = "telemetry-alloc", deny(unsafe_code))]

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod devices;
pub mod engine;
pub mod experiments;
pub mod fleet;
pub mod metrics;
pub mod perfmodel;
pub mod pipeline;
pub mod runtime;
pub mod scenario;
pub mod scheduler;
pub mod telemetry;
pub mod util;
pub mod workload;

/// Convenience re-exports for examples and downstream users.
///
/// Scheduling one workload end to end:
///
/// ```
/// use dype::prelude::*;
///
/// let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
/// let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
/// let est = OracleModels { gt: &gt };
/// let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
/// let sched = DpScheduler::new(&sys, &est).schedule(&wl, Objective::Performance);
/// assert!(sched.validate(wl.len(), sys.n_fpga, sys.n_gpu).is_ok());
/// assert!(sched.throughput() > 0.0);
/// ```
///
/// Serving a drifting request stream with a schedule cache attached —
/// recurring drift re-hits memoized plans instead of re-running the DP:
///
/// ```
/// use dype::prelude::*;
///
/// let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
/// let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
/// let est = OracleModels { gt: &gt };
/// let night = gnn::gcn_workload(&Dataset::synthetic2(), 2, 128);
/// let rush = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
/// let trace = generate_trace(&[(night.clone(), 5), (rush, 5), (night, 5)], 20.0, 1);
///
/// let mut server = Server::new(sys, &est, Objective::Performance)
///     .with_cache(ScheduleCache::shared(16));
/// let report = server.serve(&trace);
/// assert_eq!(report.completed, 15);
/// assert!(report.p50_latency <= report.p99_latency);
/// assert!(report.cache.hit_rate() > 0.5, "recurring drift is served from cache");
/// ```
///
/// Serving more streams than devices — the engine time-slices device
/// leases instead of rejecting the overflow:
///
/// ```
/// use dype::prelude::*;
///
/// let sys = SystemSpec::reduced_testbed(Interconnect::Pcie4); // 2F + 1G
/// let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
/// let est = OracleModels { gt: &gt };
/// let wl = gnn::gcn_workload(&Dataset::synthetic2(), 2, 128);
/// let streams: Vec<StreamSpec> = (0..4u64)
///     .map(|i| {
///         let trace = generate_trace(&[(wl.clone(), 4)], 10.0, i);
///         StreamSpec::new(format!("s{i}"), Objective::Performance, trace)
///     })
///     .collect();
/// let mut engine = ServingEngine::new(sys, &est);
/// let report = engine.serve(&streams);
/// assert_eq!(report.total_completed, 16, "no stream starves on a small pool");
/// assert!(report.fairness > 0.0);
/// assert!(report.engine.time_sliced_streams >= 1);
/// ```
///
/// Configuring the engine through the builder — policies, budgets, the
/// recorder, and the event-queue implementation are knobs on one fluent
/// surface, and both queue implementations serve bit-identically:
///
/// ```
/// use dype::prelude::*;
///
/// let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
/// let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
/// let est = OracleModels { gt: &gt };
/// let wl = gnn::gcn_workload(&Dataset::synthetic2(), 2, 128);
/// let streams = vec![StreamSpec::new(
///     "lane",
///     Objective::Performance,
///     generate_trace(&[(wl, 6)], 10.0, 7),
/// )];
/// let cfg = EngineConfig::builder()
///     .preemptive(1.0)
///     .energy_budget(EnergyBudget::new(1e12, 0.5))
///     .event_queue(QueueKind::Heap)
///     .build();
/// let heap = ServingEngine::new(sys.clone(), &est).with_config(cfg.clone()).serve(&streams);
/// let cal_cfg = EngineConfig { event_queue: QueueKind::Calendar, ..cfg };
/// let calendar = ServingEngine::new(sys, &est).with_config(cal_cfg).serve(&streams);
/// assert_eq!(heap.total_completed, calendar.total_completed);
/// assert_eq!(heap.makespan, calendar.makespan);
/// ```
pub mod prelude {
    pub use crate::analysis::{
        lint_engine_config, lint_fleet, lint_manifest, Diagnostic, LintReport, Severity,
    };
    pub use crate::config::{Interconnect, Objective, SystemSpec};
    pub use crate::coordinator::{
        generate_trace, Coordinator, MultiStreamReport, MultiStreamServer, ServeReport, Server,
        StreamSpec,
    };
    pub use crate::devices::{DeviceType, GroundTruth};
    pub use crate::engine::{
        EnergyBudget, EngineConfig, EngineConfigBuilder, MigrationMode, QueueKind,
        RepartitionPolicy, ServingEngine, SloController, StreamSlo,
    };
    pub use crate::fleet::{FleetConfig, FleetMigration, FleetReport, ServingFleet, ShardReport};
    pub use crate::perfmodel::{calibrate, ModelRegistry, OracleModels};
    pub use crate::pipeline::sim::PipelineSim;
    pub use crate::scenario::sweep::{Policy, SweepReport};
    pub use crate::scenario::{Arrival, ScenarioManifest};
    pub use crate::scheduler::{baselines, CacheStats, DpScheduler, Schedule, ScheduleCache, Stage};
    pub use crate::telemetry::{Recorder, Snapshot, TraceRecorder};
    pub use crate::workload::{gnn, transformer, Dataset, KernelDesc, KernelKind, Workload};
}
