//! # DYPE — Data-aware Dynamic Execution of Irregular Workloads on Heterogeneous Systems
//!
//! Production-grade reproduction of the DYPE scheduling framework
//! (Bai et al., CS.DC 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a dynamic-programming
//!   scheduler ([`scheduler`]) that jointly groups kernels into pipeline
//!   stages and allocates heterogeneous devices (GPUs/FPGAs) per stage,
//!   driven by data-aware kernel performance models ([`perfmodel`]) over a
//!   simulated heterogeneous testbed ([`devices`]); plus the streaming
//!   pipeline executor ([`pipeline`]) and the serving coordinator
//!   ([`coordinator`]) that reschedules when input characteristics drift.
//! * **L2/L1 (build time, `python/`)** — the workloads' actual compute
//!   (GCN / GIN / sliding-window transformer layers composed from Pallas
//!   kernels), AOT-lowered to HLO text artifacts executed by [`runtime`]
//!   via PJRT. Python never runs on the request path.
//!
//! See `DESIGN.md` for the system inventory, the hardware-substitution
//! table, and the experiment index mapping every table/figure of the paper
//! to a bench target.

pub mod config;
pub mod coordinator;
pub mod devices;
pub mod experiments;
pub mod metrics;
pub mod perfmodel;
pub mod pipeline;
pub mod runtime;
pub mod scheduler;
pub mod util;
pub mod workload;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::config::{Interconnect, Objective, SystemSpec};
    pub use crate::devices::{DeviceType, GroundTruth};
    pub use crate::perfmodel::{calibrate, ModelRegistry};
    pub use crate::pipeline::sim::PipelineSim;
    pub use crate::scheduler::{baselines, DpScheduler, Schedule, Stage};
    pub use crate::workload::{gnn, transformer, Dataset, KernelDesc, KernelKind, Workload};
}
