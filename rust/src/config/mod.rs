//! System specification & design objectives — the scheduler's §II inputs
//! (2) "system specifications" and (4) "design objectives", loadable from
//! a flat `key = value` config file so deployments configure DYPE without
//! recompiling. (The offline build has no TOML crate; the format below is
//! the TOML subset `key = value` with `#` comments.)

use anyhow::{bail, Context, Result};

pub use crate::devices::Interconnect;
use crate::devices::{CommModel, FpgaConfig, GpuConfig};

/// Design objective (§II "Design Objectives", §VI-A "Scheduling
/// Objectives").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Maximize throughput, energy ignored (*performance-optimized*).
    Performance,
    /// Maximize energy efficiency, throughput ignored (*energy-optimized*).
    Energy,
    /// Most energy-efficient schedule whose throughput stays within
    /// `min_throughput_frac` of the performance-optimized maximum
    /// (*balanced*; the paper's predefined mode uses 0.7).
    Balanced { min_throughput_frac: f64 },
    /// Most energy-efficient schedule meeting an *absolute* throughput
    /// floor (inferences/s) — §II's "achieving a specific Quality of
    /// Service target … such as minimizing energy consumption after
    /// achieving a certain throughput". Falls back to the performance
    /// optimum when the floor is unreachable (best effort).
    QoS { min_throughput: f64 },
}

impl Objective {
    /// The paper's predefined balanced mode: ≥70% of max throughput.
    pub fn balanced() -> Self {
        Objective::Balanced { min_throughput_frac: 0.7 }
    }

    /// The three evaluation modes of §VI-A, in the paper's column order.
    pub fn paper_modes() -> [Objective; 3] {
        [Objective::Performance, Objective::balanced(), Objective::Energy]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Performance => "perf-opt",
            Objective::Energy => "energy-opt",
            Objective::Balanced { .. } => "balanced",
            Objective::QoS { .. } => "qos",
        }
    }

    pub fn parse(s: &str) -> Result<Objective> {
        Ok(match s {
            "perf" | "perf-opt" | "performance" => Objective::Performance,
            "energy" | "energy-opt" => Objective::Energy,
            "balanced" => Objective::balanced(),
            qos if qos.starts_with("qos:") => Objective::QoS {
                min_throughput: qos[4..]
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad QoS floor in '{s}': {e}"))?,
            },
            _ => bail!("unknown objective '{s}' (perf|energy|balanced|qos:<inf/s>)"),
        })
    }
}

impl Interconnect {
    pub fn parse(s: &str) -> Result<Interconnect> {
        Ok(match s.to_lowercase().as_str() {
            "pcie4" | "pcie4.0" => Interconnect::Pcie4,
            "pcie5" | "pcie5.0" => Interconnect::Pcie5,
            "cxl3" | "cxl3.0" | "cxl" => Interconnect::Cxl3,
            _ => bail!("unknown interconnect '{s}' (pcie4|pcie5|cxl3)"),
        })
    }
}

/// Full system description: device inventory + interconnect + device
/// parameters (the paper's Table II + Fig 5 topology).
#[derive(Debug, Clone)]
pub struct SystemSpec {
    /// Number of FPGAs installed (paper testbed: 3).
    pub n_fpga: usize,
    /// Number of GPUs installed (paper testbed: 2).
    pub n_gpu: usize,
    pub interconnect: Interconnect,
    pub gpu: GpuConfig,
    pub fpga: FpgaConfig,
}

impl SystemSpec {
    /// The paper's prototype: 3 U280 FPGAs + 2 MI210 GPUs (§III-A).
    pub fn paper_testbed(interconnect: Interconnect) -> Self {
        SystemSpec {
            n_fpga: 3,
            n_gpu: 2,
            interconnect,
            gpu: GpuConfig::default(),
            fpga: FpgaConfig::default(),
        }
    }

    /// Smaller installation used in the system-size sensitivity cases.
    pub fn reduced_testbed(interconnect: Interconnect) -> Self {
        SystemSpec { n_fpga: 2, n_gpu: 1, ..Self::paper_testbed(interconnect) }
    }

    /// Build the transfer-time model for this system.
    pub fn comm_model(&self) -> CommModel {
        let mut c = CommModel::new(self.interconnect);
        c.gpu_link_bw = self.gpu.pcie_bw;
        c.fpga_link_bw = self.fpga.pcie_bw;
        c
    }

    /// Load from a flat `key = value` config file. Unknown keys error so
    /// typos never silently fall back to defaults. Recognized keys:
    /// `n_fpga`, `n_gpu`, `interconnect`, `gpu.dynamic_power`,
    /// `gpu.static_power`, `gpu.peak_flops`, `gpu.mem_bw`, `gpu.pcie_bw`,
    /// `fpga.spmm_dynamic_power`, `fpga.attn_dynamic_power`,
    /// `fpga.static_power`, `fpga.pcie_bw`, `fpga.spmm_freq`,
    /// `fpga.spmm_macs`.
    pub fn from_config_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading system spec {}", path.display()))?;
        Self::from_config_str(&text)
    }

    pub fn from_config_str(text: &str) -> Result<Self> {
        let mut spec = SystemSpec::paper_testbed(Interconnect::Pcie4);
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected 'key = value'", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim().trim_matches('"'));
            let f = |v: &str| -> Result<f64> {
                v.parse::<f64>().with_context(|| format!("line {}: bad number '{v}'", lineno + 1))
            };
            match k {
                "n_fpga" => spec.n_fpga = f(v)? as usize,
                "n_gpu" => spec.n_gpu = f(v)? as usize,
                "interconnect" => spec.interconnect = Interconnect::parse(v)?,
                "gpu.dynamic_power" => spec.gpu.dynamic_power = f(v)?,
                "gpu.static_power" => spec.gpu.static_power = f(v)?,
                "gpu.peak_flops" => spec.gpu.peak_flops = f(v)?,
                "gpu.mem_bw" => spec.gpu.mem_bw = f(v)?,
                "gpu.pcie_bw" => spec.gpu.pcie_bw = f(v)?,
                "fpga.spmm_dynamic_power" => spec.fpga.spmm_dynamic_power = f(v)?,
                "fpga.attn_dynamic_power" => spec.fpga.attn_dynamic_power = f(v)?,
                "fpga.static_power" => spec.fpga.static_power = f(v)?,
                "fpga.pcie_bw" => spec.fpga.pcie_bw = f(v)?,
                "fpga.spmm_freq" => spec.fpga.spmm_freq = f(v)?,
                "fpga.spmm_macs" => spec.fpga.spmm_macs = f(v)?,
                _ => bail!("line {}: unknown key '{k}'", lineno + 1),
            }
        }
        Ok(spec)
    }

    /// Serialize to the same flat format.
    pub fn to_config_string(&self) -> String {
        format!(
            "# DYPE system specification\n\
             n_fpga = {}\nn_gpu = {}\ninterconnect = \"{}\"\n\
             gpu.dynamic_power = {}\ngpu.static_power = {}\n\
             gpu.peak_flops = {}\ngpu.mem_bw = {}\ngpu.pcie_bw = {}\n\
             fpga.spmm_dynamic_power = {}\nfpga.attn_dynamic_power = {}\n\
             fpga.static_power = {}\nfpga.pcie_bw = {}\n\
             fpga.spmm_freq = {}\nfpga.spmm_macs = {}\n",
            self.n_fpga,
            self.n_gpu,
            match self.interconnect {
                Interconnect::Pcie4 => "pcie4",
                Interconnect::Pcie5 => "pcie5",
                Interconnect::Cxl3 => "cxl3",
            },
            self.gpu.dynamic_power,
            self.gpu.static_power,
            self.gpu.peak_flops,
            self.gpu.mem_bw,
            self.gpu.pcie_bw,
            self.fpga.spmm_dynamic_power,
            self.fpga.attn_dynamic_power,
            self.fpga.static_power,
            self.fpga.pcie_bw,
            self.fpga.spmm_freq,
            self.fpga.spmm_macs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_inventory() {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        assert_eq!((s.n_fpga, s.n_gpu), (3, 2));
    }

    #[test]
    fn config_roundtrip() {
        let mut s = SystemSpec::paper_testbed(Interconnect::Cxl3);
        s.n_fpga = 5;
        s.gpu.dynamic_power = 250.0;
        let text = s.to_config_string();
        let back = SystemSpec::from_config_str(&text).unwrap();
        assert_eq!(back.n_fpga, 5);
        assert_eq!(back.interconnect, Interconnect::Cxl3);
        assert_eq!(back.gpu.dynamic_power, 250.0);
    }

    #[test]
    fn unknown_keys_are_rejected() {
        assert!(SystemSpec::from_config_str("n_fpgas = 3").is_err());
    }

    #[test]
    fn comments_and_blanks_ok() {
        let s = SystemSpec::from_config_str("# hi\n\nn_fpga = 1 # trailing\n").unwrap();
        assert_eq!(s.n_fpga, 1);
    }

    #[test]
    fn balanced_mode_default_is_70_percent() {
        match Objective::balanced() {
            Objective::Balanced { min_throughput_frac } => {
                assert!((min_throughput_frac - 0.7).abs() < 1e-12)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn objective_parsing() {
        assert_eq!(Objective::parse("perf").unwrap(), Objective::Performance);
        assert_eq!(Objective::parse("energy-opt").unwrap(), Objective::Energy);
        assert!(Objective::parse("warp").is_err());
        assert_eq!(Interconnect::parse("CXL3").unwrap(), Interconnect::Cxl3);
    }
}
