//! Timeline serialization: Chrome/Perfetto `trace_events` JSON and a
//! compact JSONL, plus the strict validator CI runs on exported files.
//!
//! The Perfetto export lays the engine's run out on three processes so
//! the trace viewer groups tracks the way the engine thinks:
//!
//! * **pid 1 "streams"** — one thread per stream (`tid = stream + 1`):
//!   `slot` spans for completed admission slots, with `arrival`,
//!   `shed` (cause-attributed), `deferral`, and `preempt` instants.
//! * **pid 2 "leases"** — `tid 0` carries `repartition` verdict
//!   instants (shift vs hysteresis, forced or not) and fired
//!   perturbations; each stream's thread carries its `lease` snapshots
//!   (device counts + share) as instants.
//! * **pid 3 "budget"** — a `window_joules` counter track, one sample
//!   per closed energy-budget window.
//!
//! Timestamps are sim-time microseconds (the `trace_events` unit), so a
//! seeded scenario exports byte-identically run over run; the JSONL
//! format ([`jsonl`]) is one [`Record::to_json`] object per line for
//! programmatic diffing of the same timeline.

use crate::util::json::Json;

use super::{obj, Record};

/// Convert sim-time seconds to the `trace_events` microsecond unit.
fn us(t: f64) -> Json {
    Json::Num(t * 1e6)
}

fn instant(name: &str, pid: usize, tid: usize, t: f64, args: Vec<(&str, Json)>) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("i".to_string())),
        ("s", Json::Str("t".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", us(t)),
        ("args", obj(args)),
    ])
}

fn span(name: &str, pid: usize, tid: usize, t0: f64, t1: f64, args: Vec<(&str, Json)>) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", us(t0)),
        ("dur", Json::Num((t1 - t0).max(0.0) * 1e6)),
        ("args", obj(args)),
    ])
}

fn counter(name: &str, pid: usize, t: f64, args: Vec<(&str, Json)>) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("C".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        ("ts", us(t)),
        ("args", obj(args)),
    ])
}

fn metadata(kind: &str, pid: usize, tid: usize, name: &str) -> Json {
    obj(vec![
        ("name", Json::Str(kind.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
    ])
}

/// Serialize a recorded timeline as Chrome/Perfetto `trace_events` JSON
/// (`{"traceEvents": [...]}`; load it in Perfetto or chrome://tracing).
/// `stream_names` labels the per-stream threads; streams beyond its
/// length fall back to `stream-N`. Events are emitted timestamp-sorted
/// (metadata first), so [`validate`] accepts every export by
/// construction.
pub fn perfetto(records: &[Record], stream_names: &[String]) -> Json {
    let mut meta = Vec::new();
    let mut timed = Vec::new();
    emit_timeline(records, stream_names, 1, "", &mut meta, &mut timed);
    finish_document(meta, timed)
}

/// Serialize several shards' timelines into **one** Perfetto document,
/// namespaced per shard: shard `s` owns pids `3s+1..3s+3` and its three
/// process names carry a `shardS:` prefix (`shard0:streams`,
/// `shard0:leases`, `shard0:budget`, …), so a fleet run's parallel
/// engines land as side-by-side process groups in one trace view
/// instead of colliding on the single-engine pids. Timed events are
/// globally timestamp-sorted across shards (ties keep shard order, then
/// per-shard emission order — deterministic for seeded runs), so
/// [`validate`] accepts fleet exports by construction too. One shard in,
/// and the document is the single-engine [`perfetto`] layout with a
/// `shard0:` prefix.
pub fn perfetto_fleet(shards: &[(Vec<Record>, Vec<String>)]) -> Json {
    let mut meta = Vec::new();
    let mut timed = Vec::new();
    for (s, (records, names)) in shards.iter().enumerate() {
        emit_timeline(records, names, 3 * s + 1, &format!("shard{s}:"), &mut meta, &mut timed);
    }
    finish_document(meta, timed)
}

/// Stable-sort the timed events behind the metadata block and wrap the
/// result as the single-key `trace_events` document [`validate`] expects.
fn finish_document(mut meta: Vec<Json>, mut timed: Vec<(f64, Json)>) -> Json {
    // Stable sort: equal timestamps keep emission (= engine event) order.
    timed.sort_by(|a, b| a.0.total_cmp(&b.0));
    meta.extend(timed.into_iter().map(|(_, j)| j));
    obj(vec![("traceEvents", Json::Arr(meta))])
}

/// Lay one recorded timeline out on three processes rooted at
/// `pid_base` (streams, leases, budget — see the module docs), pushing
/// process/thread metadata into `meta` and `(timestamp, event)` pairs
/// into `timed`. `prefix` namespaces the process names (empty for the
/// single-engine export, `"shardN:"` for fleet shards).
fn emit_timeline(
    records: &[Record],
    stream_names: &[String],
    pid_base: usize,
    prefix: &str,
    meta: &mut Vec<Json>,
    timed: &mut Vec<(f64, Json)>,
) {
    let (streams_pid, leases_pid, budget_pid) = (pid_base, pid_base + 1, pid_base + 2);
    let n_streams = records
        .iter()
        .filter_map(|r| match r {
            Record::Arrival { stream, .. }
            | Record::Slot { stream, .. }
            | Record::Shed { stream, .. }
            | Record::Deferral { stream, .. }
            | Record::Preempt { stream, .. } => Some(*stream + 1),
            Record::Repartition { leases, .. } => leases.iter().map(|l| l.stream + 1).max(),
            _ => None,
        })
        .max()
        .unwrap_or(0)
        .max(stream_names.len());
    let name_of =
        |s: usize| stream_names.get(s).cloned().unwrap_or_else(|| format!("stream-{s}"));

    meta.push(metadata("process_name", streams_pid, 0, &format!("{prefix}streams")));
    meta.push(metadata("process_name", leases_pid, 0, &format!("{prefix}leases")));
    meta.push(metadata("process_name", budget_pid, 0, &format!("{prefix}budget")));
    meta.push(metadata("thread_name", leases_pid, 0, "repartitions"));
    for s in 0..n_streams {
        meta.push(metadata("thread_name", streams_pid, s + 1, &name_of(s)));
        meta.push(metadata("thread_name", leases_pid, s + 1, &format!("lease:{}", name_of(s))));
    }

    for r in records {
        match r {
            Record::Arrival { t, stream, index } => {
                let args = vec![("index", Json::Num(*index as f64))];
                timed.push((*t, instant("arrival", streams_pid, stream + 1, *t, args)));
            }
            Record::Slot { start, end, stream, epoch } => {
                let args = vec![("epoch", Json::Num(*epoch as f64))];
                timed.push((*start, span("slot", streams_pid, stream + 1, *start, *end, args)));
            }
            Record::Shed { t, stream, index, cause } => {
                let args = vec![
                    ("cause", Json::Str(cause.label().to_string())),
                    ("index", Json::Num(*index as f64)),
                ];
                timed.push((*t, instant("shed", streams_pid, stream + 1, *t, args)));
            }
            Record::Deferral { t, stream } => {
                timed.push((*t, instant("deferral", streams_pid, stream + 1, *t, vec![])));
            }
            Record::Preempt { t, stream, refunded_time, refunded_joules } => {
                let args = vec![
                    ("refunded_time", Json::Num(*refunded_time)),
                    ("refunded_joules", Json::Num(*refunded_joules)),
                ];
                timed.push((*t, instant("preempt", streams_pid, stream + 1, *t, args)));
            }
            Record::Repartition { t, shift, hysteresis, forced, leases } => {
                let args = vec![
                    ("shift", Json::Num(*shift)),
                    ("hysteresis", Json::Num(*hysteresis)),
                    ("forced", Json::Bool(*forced)),
                ];
                timed.push((*t, instant("repartition", leases_pid, 0, *t, args)));
                for l in leases {
                    let args = vec![
                        ("fpga", Json::Num(l.n_fpga as f64)),
                        ("gpu", Json::Num(l.n_gpu as f64)),
                        ("share", Json::Num(l.share)),
                    ];
                    timed.push((*t, instant("lease", leases_pid, l.stream + 1, *t, args)));
                }
            }
            Record::BudgetWindow { t, index, joules } => {
                let args =
                    vec![("index", Json::Num(*index as f64)), ("joules", Json::Num(*joules))];
                timed.push((*t, counter("window_joules", budget_pid, *t, args)));
            }
            Record::Perturbation { t, index, label } => {
                let args = vec![
                    ("index", Json::Num(*index as f64)),
                    ("label", Json::Str(label.to_string())),
                ];
                timed.push((*t, instant("perturbation", leases_pid, 0, *t, args)));
            }
        }
    }
}

/// Serialize a timeline as compact JSONL: one [`Record::to_json`]
/// object per line, in emission order — byte-stable across runs of the
/// same seeded scenario, so timelines diff with line tools.
pub fn jsonl(records: &[Record]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json().to_string());
        out.push('\n');
    }
    out
}

/// The event keys `trace_events` consumers understand — anything else
/// in an exported file is a bug, not an extension.
const EVENT_KEYS: [&str; 8] = ["args", "dur", "name", "ph", "pid", "s", "tid", "ts"];

/// Strictly validate a Perfetto `trace_events` document: the shape CI
/// asserts on every `--trace` output (`dype trace-validate`). Checks
/// the single `traceEvents` top-level key, per-event key allow-list and
/// required fields, known phase codes, scoped instants, non-negative
/// span durations, **monotone timestamps** (metadata first), and
/// balanced `B`/`E` begin/end pairs per `(pid, tid)` track.
pub fn validate(doc: &Json) -> Result<(), String> {
    let top = doc.as_obj().ok_or("top level must be an object")?;
    if top.len() != 1 || !top.contains_key("traceEvents") {
        let keys: Vec<&String> = top.keys().collect();
        return Err(format!("top level must hold exactly \"traceEvents\", got {keys:?}"));
    }
    let events = top["traceEvents"].as_arr().ok_or("traceEvents must be an array")?;
    let mut last_ts = f64::NEG_INFINITY;
    let mut seen_timed = false;
    let mut open: std::collections::BTreeMap<(u64, u64), i64> = std::collections::BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let e = ev.as_obj().ok_or_else(|| format!("event {i}: not an object"))?;
        for k in e.keys() {
            if !EVENT_KEYS.contains(&k.as_str()) {
                return Err(format!("event {i}: unknown key {k:?}"));
            }
        }
        let field = |k: &str| e.get(k).ok_or_else(|| format!("event {i}: missing {k:?}"));
        field("name")?.as_str().ok_or_else(|| format!("event {i}: name not a string"))?;
        let ph = field("ph")?.as_str().ok_or_else(|| format!("event {i}: ph not a string"))?;
        let pid = field("pid")?.as_u64().ok_or_else(|| format!("event {i}: pid not integral"))?;
        let tid = e.get("tid").and_then(Json::as_u64).unwrap_or(0);
        if !["B", "E", "X", "i", "M", "C"].contains(&ph) {
            return Err(format!("event {i}: unknown phase {ph:?}"));
        }
        if ph == "M" {
            if seen_timed {
                return Err(format!("event {i}: metadata must precede timed events"));
            }
            continue;
        }
        seen_timed = true;
        let ts = field("ts")?
            .as_f64()
            .filter(|t| t.is_finite() && *t >= 0.0)
            .ok_or_else(|| format!("event {i}: ts must be finite and non-negative"))?;
        if ts < last_ts {
            return Err(format!("event {i}: timestamp {ts} regresses below {last_ts}"));
        }
        last_ts = ts;
        match ph {
            "X" => {
                field("dur")?
                    .as_f64()
                    .filter(|d| d.is_finite() && *d >= 0.0)
                    .ok_or_else(|| format!("event {i}: span dur must be >= 0"))?;
            }
            "i" => {
                let s = field("s")?
                    .as_str()
                    .ok_or_else(|| format!("event {i}: instant scope not a string"))?;
                if !["t", "p", "g"].contains(&s) {
                    return Err(format!("event {i}: unknown instant scope {s:?}"));
                }
            }
            "B" => *open.entry((pid, tid)).or_insert(0) += 1,
            "E" => {
                let depth = open.entry((pid, tid)).or_insert(0);
                *depth -= 1;
                if *depth < 0 {
                    return Err(format!("event {i}: end without begin on track ({pid},{tid})"));
                }
            }
            _ => {}
        }
    }
    if let Some(((pid, tid), n)) = open.iter().find(|(_, n)| **n != 0) {
        return Err(format!("{n} unbalanced begin/end span(s) on track ({pid},{tid})"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{LeaseSnapshot, ShedCause};
    use super::*;
    use crate::util::json;

    fn sample() -> Vec<Record> {
        vec![
            Record::Arrival { t: 0.010, stream: 0, index: 0 },
            Record::Slot { start: 0.010, end: 0.060, stream: 0, epoch: 1 },
            Record::Shed { t: 0.020, stream: 1, index: 0, cause: ShedCause::QueueAhead },
            Record::Deferral { t: 0.030, stream: 1 },
            Record::Preempt { t: 0.040, stream: 0, refunded_time: 0.01, refunded_joules: 2.0 },
            Record::Repartition {
                t: 0.050,
                shift: 0.4,
                hysteresis: 0.15,
                forced: false,
                leases: vec![
                    LeaseSnapshot { stream: 0, n_fpga: 2, n_gpu: 1, share: 1.0 },
                    LeaseSnapshot { stream: 1, n_fpga: 1, n_gpu: 1, share: 1.0 },
                ],
            },
            Record::BudgetWindow { t: 0.250, index: 0, joules: 42.5 },
            Record::Perturbation { t: 0.300, index: 0, label: "device-cut" },
        ]
    }

    #[test]
    fn perfetto_export_passes_its_own_strict_validator() {
        let doc = perfetto(&sample(), &["interactive".to_string(), "bulk".to_string()]);
        validate(&doc).unwrap();
        // Round-trip through the strict parser: Display → parse → equal.
        let reparsed = json::parse(&doc.to_string()).unwrap();
        assert_eq!(reparsed, doc);
        assert_eq!(reparsed.to_string(), doc.to_string());
    }

    #[test]
    fn perfetto_lays_out_the_three_processes() {
        let doc = perfetto(&sample(), &["a".to_string(), "b".to_string()]);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let pids: Vec<u64> = events.iter().filter_map(|e| e.get("pid")?.as_u64()).collect();
        for pid in [1, 2, 3] {
            assert!(pids.contains(&pid), "missing process {pid}");
        }
        // The shed instant carries its cause attribution.
        let shed = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("shed"))
            .expect("shed instant");
        assert_eq!(shed.get("args").unwrap().get("cause").unwrap().as_str(), Some("queue-ahead"));
        // Timestamps are microseconds.
        assert_eq!(shed.get("ts").unwrap().as_f64(), Some(0.020 * 1e6));
    }

    #[test]
    fn fleet_export_namespaces_shards_and_stays_valid() {
        let shards = vec![
            (sample(), vec!["interactive".to_string(), "bulk".to_string()]),
            (sample(), vec!["east".to_string(), "west".to_string()]),
        ];
        let doc = perfetto_fleet(&shards);
        validate(&doc).expect("fleet export must satisfy the strict validator");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Each shard owns its own three-process pid block…
        let process_names: Vec<&str> = events
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("process_name"))
            .filter_map(|e| e.get("args")?.get("name")?.as_str())
            .collect();
        for name in
            ["shard0:streams", "shard0:leases", "shard0:budget", "shard1:streams", "shard1:budget"]
        {
            assert!(process_names.contains(&name), "missing process {name:?}");
        }
        // …on disjoint pids (shard 0: 1-3, shard 1: 4-6).
        let pids: std::collections::BTreeSet<u64> =
            events.iter().filter_map(|e| e.get("pid")?.as_u64()).collect();
        assert_eq!(pids, (1..=6).collect());
        // A single-shard fleet is the bare export modulo the prefix: the
        // same events in the same order, byte-for-byte.
        let solo = perfetto_fleet(&shards[..1]);
        let bare = perfetto(&sample(), &shards[0].1);
        assert_eq!(solo.to_string().replace("shard0:", ""), bare.to_string());
    }

    #[test]
    fn jsonl_is_one_stable_line_per_record() {
        let text = jsonl(&sample());
        assert_eq!(text, jsonl(&sample()), "export must be deterministic");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), sample().len());
        for line in &lines {
            json::parse(line).unwrap();
        }
        assert_eq!(
            lines[0],
            r#"{"index":0,"stream":0,"t":0.01,"type":"arrival"}"#,
            "line format is pinned — changing it breaks timeline diffing"
        );
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        let good = perfetto(&sample(), &[]);
        // Unknown event key.
        let mut doc = good.clone();
        if let Json::Obj(top) = &mut doc {
            if let Some(Json::Arr(evs)) = top.get_mut("traceEvents") {
                if let Some(Json::Obj(e)) = evs.last_mut() {
                    e.insert("rogue".to_string(), Json::Null);
                }
            }
        }
        assert!(validate(&doc).unwrap_err().contains("unknown key"));
        // Timestamp regression.
        let mut doc = good.clone();
        if let Json::Obj(top) = &mut doc {
            if let Some(Json::Arr(evs)) = top.get_mut("traceEvents") {
                if let Some(Json::Obj(e)) = evs.last_mut() {
                    e.insert("ts".to_string(), Json::Num(0.0));
                }
            }
        }
        assert!(validate(&doc).unwrap_err().contains("regresses"));
        // Unbalanced begin/end spans.
        let mut doc = good;
        if let Json::Obj(top) = &mut doc {
            if let Some(Json::Arr(evs)) = top.get_mut("traceEvents") {
                let mut b = std::collections::BTreeMap::new();
                b.insert("name".to_string(), Json::Str("open".to_string()));
                b.insert("ph".to_string(), Json::Str("B".to_string()));
                b.insert("pid".to_string(), Json::Num(1.0));
                b.insert("tid".to_string(), Json::Num(1.0));
                b.insert("ts".to_string(), Json::Num(1e9));
                evs.push(Json::Obj(b));
            }
        }
        assert!(validate(&doc).unwrap_err().contains("unbalanced"));
        // Stray top-level keys.
        let mut top = std::collections::BTreeMap::new();
        top.insert("traceEvents".to_string(), Json::Arr(vec![]));
        top.insert("extra".to_string(), Json::Null);
        assert!(validate(&Json::Obj(top)).is_err());
    }
}
