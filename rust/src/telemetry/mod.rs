//! Engine telemetry — structured event tracing and hot-path counters
//! (DESIGN.md §Observability).
//!
//! DyPe's thesis is that scheduling should follow *observed* runtime
//! behavior, and the same goes for working on the scheduler itself: the
//! engine now has five interacting subsystems (leases, budgets, the SLO
//! controller, deadline shedding, perturbations) whose interplay the
//! end-of-run aggregates in [`crate::engine::EngineMetrics`] cannot
//! explain, and the ROADMAP's profile-driven hot-path rewrite needs to
//! see where the per-event microseconds go. This module supplies both
//! views:
//!
//! * **Tracing** — every engine decision emits a typed [`Record`]
//!   carrying its sim-time, stream, and *cause* (which feasibility term
//!   shed a request, which hysteresis delta triggered a repartition, how
//!   much a preemption refunded). Records flow through a
//!   [`TraceRecorder`] attached via
//!   [`crate::engine::EngineConfigBuilder::recorder`]; [`export`] turns
//!   the collected timeline into Chrome/Perfetto `trace_events` JSON
//!   (one track per stream, per device-lease, and a budget-window
//!   track) or a compact JSONL for programmatic diffing.
//! * **Counters** — a [`Snapshot`] of cheap always-on counters the
//!   event loop maintains regardless of any recorder: events popped per
//!   [`crate::engine::EventKind`], the event-heap high-water mark,
//!   schedule-cache probe/hit and prewarm totals, plus feature-gated
//!   host-clock handler timings (`telemetry-timing`) and an
//!   allocations-per-run count from a global-allocator hook
//!   (`telemetry-alloc`).
//!
//! **Zero-cost when off** is the design constraint: the default engine
//! config carries no recorder, so every would-be record costs one
//! `Option` branch (the record itself is built inside a closure that
//! never runs), and `benches/telemetry_overhead.rs` holds the
//! recorder-off path to within noise of the pre-telemetry engine.

pub mod export;

use std::sync::{Arc, Mutex};

use crate::engine::EventKind;
use crate::util::json::Json;

/// Which feasibility term dominated a deadline shed — the attribution a
/// post-mortem needs to tell "arrived hopeless" from "starved by the
/// budget" from "the batch itself no longer fits".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedCause {
    /// Arrival-time queue-ahead bound: the work already queued (plus any
    /// in-flight slot) could not drain inside the deadline, so the
    /// request was shed before ever entering the queue.
    QueueAhead,
    /// Front-of-queue check: time already spent queueing dominated.
    Queueing,
    /// Front-of-queue check: the wait a budget denial imposes (at least
    /// until the next window tick) dominated.
    BudgetWait,
    /// Front-of-queue check: the lane's modeled batch latency dominated
    /// — the request was infeasible even with an empty queue.
    BatchLatency,
}

impl ShedCause {
    /// Stable string spelling used by both export formats.
    pub fn label(&self) -> &'static str {
        match self {
            ShedCause::QueueAhead => "queue-ahead",
            ShedCause::Queueing => "queueing",
            ShedCause::BudgetWait => "budget-wait",
            ShedCause::BatchLatency => "batch-latency",
        }
    }
}

/// One stream's lease as a repartition left it: device counts plus the
/// time-slice share — the per-stream row of a [`Record::Repartition`].
#[derive(Debug, Clone, PartialEq)]
pub struct LeaseSnapshot {
    pub stream: usize,
    pub n_fpga: usize,
    pub n_gpu: usize,
    /// Weighted round-robin share of the partition's term (1.0 =
    /// exclusive).
    pub share: f64,
}

/// A typed trace record. Timestamps are **sim-time seconds** on the
/// engine's global clock — never the host clock — so two runs of the
/// same seeded scenario produce byte-identical timelines.
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    /// A request reached the engine (it may still be shed on arrival).
    Arrival { t: f64, stream: usize, index: usize },
    /// A completed admission slot: stream `stream` occupied its lease
    /// over `[start, end)` (preempted slots never produce one — they are
    /// cancelled before completing).
    Slot { start: f64, end: f64, stream: usize, epoch: u64 },
    /// The deadline feasibility check shed request `index`, attributed
    /// to the dominant term that made it infeasible.
    Shed { t: f64, stream: usize, index: usize, cause: ShedCause },
    /// The energy budget denied an admission; the lane parks until the
    /// next window tick.
    Deferral { t: f64, stream: usize },
    /// A migration cancelled the stream's in-flight slot mid-term,
    /// refunding the unexecuted wall-clock remainder and `f_eng` joules.
    Preempt { t: f64, stream: usize, refunded_time: f64, refunded_joules: f64 },
    /// An applied lease re-apportionment: the total-variation share
    /// `shift` that crossed (or, when `forced`, bypassed) the policy's
    /// `hysteresis`, plus every active stream's resulting lease.
    Repartition { t: f64, shift: f64, hysteresis: f64, forced: bool, leases: Vec<LeaseSnapshot> },
    /// An energy-budget window closed with `joules` net charge.
    BudgetWindow { t: f64, index: usize, joules: f64 },
    /// A scripted perturbation fired (`index` into the config's list).
    Perturbation { t: f64, index: usize, label: &'static str },
}

impl Record {
    /// Stable record-type tag used by both export formats.
    pub fn kind(&self) -> &'static str {
        match self {
            Record::Arrival { .. } => "arrival",
            Record::Slot { .. } => "slot",
            Record::Shed { .. } => "shed",
            Record::Deferral { .. } => "deferral",
            Record::Preempt { .. } => "preempt",
            Record::Repartition { .. } => "repartition",
            Record::BudgetWindow { .. } => "budget-window",
            Record::Perturbation { .. } => "perturbation",
        }
    }

    /// The record's timestamp (a span reports its start).
    pub fn time(&self) -> f64 {
        match self {
            Record::Slot { start, .. } => *start,
            Record::Arrival { t, .. }
            | Record::Shed { t, .. }
            | Record::Deferral { t, .. }
            | Record::Preempt { t, .. }
            | Record::Repartition { t, .. }
            | Record::BudgetWindow { t, .. }
            | Record::Perturbation { t, .. } => *t,
        }
    }

    /// One compact JSON object per record (the JSONL line format).
    /// Key order is the codec's deterministic BTreeMap order, so equal
    /// records serialize byte-identically.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("type", Json::Str(self.kind().to_string()))];
        match self {
            Record::Arrival { t, stream, index } => {
                pairs.push(("t", Json::Num(*t)));
                pairs.push(("stream", Json::Num(*stream as f64)));
                pairs.push(("index", Json::Num(*index as f64)));
            }
            Record::Slot { start, end, stream, epoch } => {
                pairs.push(("start", Json::Num(*start)));
                pairs.push(("end", Json::Num(*end)));
                pairs.push(("stream", Json::Num(*stream as f64)));
                pairs.push(("epoch", Json::Num(*epoch as f64)));
            }
            Record::Shed { t, stream, index, cause } => {
                pairs.push(("t", Json::Num(*t)));
                pairs.push(("stream", Json::Num(*stream as f64)));
                pairs.push(("index", Json::Num(*index as f64)));
                pairs.push(("cause", Json::Str(cause.label().to_string())));
            }
            Record::Deferral { t, stream } => {
                pairs.push(("t", Json::Num(*t)));
                pairs.push(("stream", Json::Num(*stream as f64)));
            }
            Record::Preempt { t, stream, refunded_time, refunded_joules } => {
                pairs.push(("t", Json::Num(*t)));
                pairs.push(("stream", Json::Num(*stream as f64)));
                pairs.push(("refunded_time", Json::Num(*refunded_time)));
                pairs.push(("refunded_joules", Json::Num(*refunded_joules)));
            }
            Record::Repartition { t, shift, hysteresis, forced, leases } => {
                pairs.push(("t", Json::Num(*t)));
                pairs.push(("shift", Json::Num(*shift)));
                pairs.push(("hysteresis", Json::Num(*hysteresis)));
                pairs.push(("forced", Json::Bool(*forced)));
                let rows = leases
                    .iter()
                    .map(|l| {
                        obj(vec![
                            ("stream", Json::Num(l.stream as f64)),
                            ("fpga", Json::Num(l.n_fpga as f64)),
                            ("gpu", Json::Num(l.n_gpu as f64)),
                            ("share", Json::Num(l.share)),
                        ])
                    })
                    .collect();
                pairs.push(("leases", Json::Arr(rows)));
            }
            Record::BudgetWindow { t, index, joules } => {
                pairs.push(("t", Json::Num(*t)));
                pairs.push(("index", Json::Num(*index as f64)));
                pairs.push(("joules", Json::Num(*joules)));
            }
            Record::Perturbation { t, index, label } => {
                pairs.push(("t", Json::Num(*t)));
                pairs.push(("index", Json::Num(*index as f64)));
                pairs.push(("label", Json::Str(label.to_string())));
            }
        }
        obj(pairs)
    }
}

/// Build a [`Json::Obj`] from string/value pairs (the codec's BTreeMap
/// re-sorts keys deterministically).
pub(crate) fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Sink for engine trace records. Object-safe so the engine can carry
/// any implementation behind one handle; `drain` exists so callers can
/// retrieve a timeline without downcasting.
pub trait TraceRecorder {
    /// Accept one record. Called on the engine's hot path — implementors
    /// must stay O(1) amortized.
    fn record(&mut self, rec: Record);

    /// Hand back (and clear) everything recorded so far. Recorders that
    /// keep nothing return an empty timeline.
    fn drain(&mut self) -> Vec<Record> {
        Vec::new()
    }
}

/// The do-nothing recorder: every call inlines to nothing. The engine's
/// *default* is cheaper still — no recorder handle at all, one `Option`
/// branch per would-be record — so this type exists for call sites that
/// want to pass "a recorder" unconditionally.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullRecorder;

impl TraceRecorder for NullRecorder {
    #[inline(always)]
    fn record(&mut self, _rec: Record) {}
}

/// The in-memory timeline recorder: appends every record in emission
/// order (emission order is deterministic — the engine's event loop is).
#[derive(Debug, Default)]
pub struct TimelineRecorder {
    records: Vec<Record>,
}

impl TimelineRecorder {
    pub fn new() -> TimelineRecorder {
        TimelineRecorder::default()
    }

    pub fn records(&self) -> &[Record] {
        &self.records
    }
}

impl TraceRecorder for TimelineRecorder {
    fn record(&mut self, rec: Record) {
        self.records.push(rec);
    }

    fn drain(&mut self) -> Vec<Record> {
        std::mem::take(&mut self.records)
    }
}

/// Shared handle to a [`TraceRecorder`], cheap to clone — what
/// [`crate::engine::EngineConfig`] carries so the config stays `Clone`
/// while the caller keeps a handle to drain after the run. Cloning
/// shares the underlying recorder (both handles see the same timeline).
///
/// `Send` by construction (an `Arc<Mutex<..>>` over a `Send` recorder),
/// so a config carrying one can cross a thread boundary — what the
/// fleet layer ([`crate::fleet`]) and the parallel sweep rely on. An
/// engine run drives its recorder from one thread at a time, so the
/// mutex is uncontended on the hot path.
#[derive(Clone)]
pub struct Recorder(Arc<Mutex<dyn TraceRecorder + Send>>);

impl Recorder {
    /// Wrap any recorder implementation.
    pub fn new(recorder: impl TraceRecorder + Send + 'static) -> Recorder {
        Recorder(Arc::new(Mutex::new(recorder)))
    }

    /// A fresh in-memory [`TimelineRecorder`].
    pub fn timeline() -> Recorder {
        Recorder::new(TimelineRecorder::new())
    }

    /// Record one event (the engine's emission path).
    #[inline]
    pub fn push(&self, rec: Record) {
        self.0.lock().unwrap().record(rec);
    }

    /// Drain the recorded timeline (empty for recorders that keep none).
    pub fn drain(&self) -> Vec<Record> {
        self.0.lock().unwrap().drain()
    }
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Recorder(..)")
    }
}

/// Cheap hot-path counters the event loop maintains unconditionally
/// (recorder or not) and snapshots into
/// [`crate::engine::EngineMetrics::telemetry`] — the profile the
/// hot-path rewrite steers by, attached per sweep cell by
/// [`crate::scenario::sweep`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    /// Events popped per [`EventKind`], indexed by [`EventKind::index`]
    /// (labels in [`EventKind::NAMES`]); sums to
    /// [`crate::engine::EngineMetrics::events_processed`].
    pub events_popped: [u64; EventKind::COUNT],
    /// Largest pending-event count the heap reached (measured at pop,
    /// including the popped event).
    pub heap_high_water: usize,
    /// Host-clock nanoseconds spent in each event handler, per kind.
    /// All-zero unless the `telemetry-timing` feature is on — host time
    /// is non-deterministic, so it never participates in golden tests.
    /// Handlers that bail early (stale completions, ledger-less budget
    /// ticks) are not timed.
    pub handler_ns: [u64; EventKind::COUNT],
    /// Heap allocations over the run, from the `telemetry-alloc` global
    /// allocator hook; 0 when the feature is off. Divide by
    /// `events_popped` totals for the allocations-per-event figure the
    /// ROADMAP's hot-path item tracks.
    pub allocations: u64,
    /// Schedule-cache lookups across every lane (hits + misses).
    pub cache_probes: u64,
    /// Schedule-cache hits across every lane.
    pub cache_hits: u64,
    /// Plans migrations successfully prewarmed onto new partitions.
    pub prewarm_hits: u64,
    /// Plans migrations failed to re-fit (those regimes re-run the DP).
    pub prewarm_misses: u64,
}

impl Snapshot {
    /// Total events popped — equals the engine's `events_processed`.
    pub fn events_total(&self) -> u64 {
        self.events_popped.iter().sum()
    }

    /// Events popped for one kind, by its stable label (see
    /// [`EventKind::NAMES`]). Panics on an unknown label — counter names
    /// are an API, not a guess.
    pub fn popped(&self, label: &str) -> u64 {
        let i = EventKind::NAMES
            .iter()
            .position(|n| *n == label)
            .unwrap_or_else(|| panic!("unknown event-kind label {label:?}"));
        self.events_popped[i]
    }

    /// The snapshot as a JSON object (per-kind counts keyed by label) —
    /// what sweep tooling diffs across cells.
    pub fn to_json(&self) -> Json {
        let popped = EventKind::NAMES
            .iter()
            .zip(self.events_popped)
            .map(|(n, c)| (n.to_string(), Json::Num(c as f64)))
            .collect();
        obj(vec![
            ("events_popped", Json::Obj(popped)),
            ("heap_high_water", Json::Num(self.heap_high_water as f64)),
            ("allocations", Json::Num(self.allocations as f64)),
            ("cache_probes", Json::Num(self.cache_probes as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("prewarm_hits", Json::Num(self.prewarm_hits as f64)),
            ("prewarm_misses", Json::Num(self.prewarm_misses as f64)),
        ])
    }
}

/// Allocation counting behind the `telemetry-alloc` feature: a global
/// allocator that delegates to [`std::alloc::System`] and counts every
/// allocation in a relaxed atomic. Off by default — installing a global
/// allocator is a whole-process decision, so it is strictly opt-in.
pub mod alloc {
    #[cfg(feature = "telemetry-alloc")]
    #[allow(unsafe_code)] // the GlobalAlloc impl below is the crate's one exception
    mod counting {
        use std::alloc::{GlobalAlloc, Layout, System};
        use std::sync::atomic::{AtomicU64, Ordering};

        pub(super) static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

        /// [`System`] wrapper counting allocations (the default
        /// `alloc_zeroed`/`realloc` route through `alloc`, so one count
        /// site covers them).
        struct CountingAlloc;

        // SAFETY: delegates 1:1 to `System`; the relaxed counter has no
        // effect on allocation behavior.
        unsafe impl GlobalAlloc for CountingAlloc {
            unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
                ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
                System.alloc(layout)
            }

            unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
                System.dealloc(ptr, layout)
            }
        }

        #[global_allocator]
        static COUNTING: CountingAlloc = CountingAlloc;
    }

    /// Process-wide allocation count so far. The engine samples it
    /// before and after a run and reports the difference, so concurrent
    /// allocator traffic outside the run is the caller's noise to
    /// control (the benches run single-threaded).
    #[cfg(feature = "telemetry-alloc")]
    pub fn allocations() -> u64 {
        counting::ALLOCATIONS.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Always 0 without the `telemetry-alloc` feature.
    #[cfg(not(feature = "telemetry-alloc"))]
    pub fn allocations() -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_kinds_and_times_are_stable() {
        let r = Record::Slot { start: 1.5, end: 2.0, stream: 0, epoch: 3 };
        assert_eq!(r.kind(), "slot");
        assert_eq!(r.time(), 1.5);
        let s = Record::Shed { t: 0.25, stream: 1, index: 4, cause: ShedCause::BudgetWait };
        assert_eq!(s.kind(), "shed");
        assert_eq!(s.time(), 0.25);
        assert_eq!(ShedCause::QueueAhead.label(), "queue-ahead");
    }

    #[test]
    fn record_json_is_deterministic_and_typed() {
        let r = Record::Arrival { t: 0.5, stream: 2, index: 7 };
        assert_eq!(r.to_json().to_string(), r#"{"index":7,"stream":2,"t":0.5,"type":"arrival"}"#);
        let p = Record::Preempt { t: 1.0, stream: 0, refunded_time: 0.25, refunded_joules: 3.5 };
        let j = p.to_json();
        assert_eq!(j.get("type").unwrap().as_str(), Some("preempt"));
        assert_eq!(j.get("refunded_joules").unwrap().as_f64(), Some(3.5));
    }

    #[test]
    fn timeline_recorder_keeps_emission_order_and_drains_once() {
        let rec = Recorder::timeline();
        rec.push(Record::Arrival { t: 0.1, stream: 0, index: 0 });
        rec.push(Record::Deferral { t: 0.2, stream: 0 });
        let shared = rec.clone(); // handles share the timeline
        shared.push(Record::Arrival { t: 0.3, stream: 1, index: 0 });
        let drained = rec.drain();
        assert_eq!(drained.len(), 3);
        assert_eq!(drained[0].time(), 0.1);
        assert_eq!(drained[2].time(), 0.3);
        assert!(rec.drain().is_empty(), "drain empties the timeline");
    }

    #[test]
    fn null_recorder_records_nothing() {
        let rec = Recorder::new(NullRecorder);
        rec.push(Record::Deferral { t: 1.0, stream: 0 });
        assert!(rec.drain().is_empty());
    }

    #[test]
    fn snapshot_labels_resolve_per_kind_counts() {
        let mut s = Snapshot::default();
        s.events_popped[0] = 5;
        s.events_popped[3] = 2;
        assert_eq!(s.popped("arrival"), 5);
        assert_eq!(s.popped("shed"), 2);
        assert_eq!(s.events_total(), 7);
        let j = s.to_json();
        let popped = j.get("events_popped").unwrap();
        assert_eq!(popped.get("arrival").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    #[should_panic(expected = "unknown event-kind label")]
    fn snapshot_rejects_unknown_counter_names() {
        Snapshot::default().popped("no-such-kind");
    }

    #[test]
    fn alloc_counter_is_zero_or_monotone() {
        // With `telemetry-alloc` off this pins the 0 stub; with it on,
        // the counter can only grow.
        let a = alloc::allocations();
        let _v: Vec<u64> = (0..64).collect();
        assert!(alloc::allocations() >= a);
    }
}
