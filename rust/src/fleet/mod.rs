//! Sharded fleet serving: horizontal scale-out over the serving engine.
//!
//! One [`crate::engine::ServingEngine`] multiplexes arbitrarily many
//! streams over one device pool — but it is a single discrete-event
//! clock, so wall-clock serving capacity stops at one core. The fleet
//! layer shards the pool: `shards` engines run in parallel on real OS
//! threads (the [`crate::util::pool`] scoped-thread fan-out), each
//! owning a **disjoint** slice of the device inventory (carved by the
//! same largest-remainder apportionment as lease partitioning,
//! `lease::split_pool`) and its **own** schedule cache — caches are
//! never shared across shards, so the hot path never contends on one
//! mutex and cache warmth becomes a *placement* signal instead of a
//! global side effect.
//!
//! Streams are placed at admission by a deterministic router
//! ([`ServingFleet::route`]): SLO class first (priority descending —
//! latency-critical lanes pick their shard before bulk does), demand
//! estimate second, then greedy least-relative-load with a
//! **cache-affinity discount** — a shard whose cache already holds a
//! plan for one of the stream's expected regimes (shape + objective
//! match under any system fingerprint, [`ScheduleCache::affinity`])
//! scores cheaper than a cold one.
//!
//! After a serve pass the fleet inspects per-shard health: when one
//! shard's deadline-shed rate degrades past `shed_threshold` *and*
//! exceeds the coldest shard's by more than `hysteresis` (or the
//! deadline-attainment analogue), the most-shedding stream drains from
//! the hot shard and re-admits on the coldest shard. The destination
//! cache is prewarmed through the existing re-keying path: the victim's
//! plans are carried across caches
//! ([`ScheduleCache::copy_fingerprint_into`]) and re-fitted onto its
//! prospective partition ([`ScheduleCache::prewarm`]), so known regimes
//! re-admit as hits, not cold DP runs. Each stream migrates at most
//! once per serve and rounds are capped, so placement always converges.
//!
//! A single-shard fleet is the degenerate case and is **bit-identical**
//! to driving the bare engine: the one shard owns the whole pool, the
//! router has one choice, streams stay in admission order, and no
//! migration can trigger (`rust/tests/fleet.rs` pins this
//! differentially — reports, metrics, and telemetry timeline).

use std::path::Path;

use crate::config::{Objective, SystemSpec};
use crate::coordinator::{MultiStreamReport, StreamSpec};
use crate::engine::{lease, EngineConfig, ServingEngine};
use crate::metrics::Table;
use crate::perfmodel::PerfEstimator;
use crate::scheduler::{
    system_fingerprint, CacheKey, CacheStats, DpScheduler, PrewarmReport, ScheduleCache,
    SharedScheduleCache,
};
use crate::telemetry::{Record, Recorder};
use crate::util::pool::{default_threads, run_indexed};

/// Projected-load multiplier for a shard whose cache is already warm
/// for one of the candidate stream's regimes: a 25% discount, enough to
/// win ties and near-ties without overriding a real load imbalance.
const AFFINITY_FACTOR: f64 = 0.75;

/// Fleet-level configuration. `engine` is the per-shard template —
/// every shard serves under a clone of it, so policy knobs
/// (repartitioning, budgets, event queue) apply fleet-wide.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of engine shards (each needs at least one device).
    pub shards: usize,
    /// Per-shard schedule-cache capacity (the bare engine's default 64).
    pub cache_capacity: usize,
    /// Worker threads for parallel shard runs; shards beyond this queue.
    pub threads: usize,
    /// Per-shard engine configuration template.
    pub engine: EngineConfig,
    /// Attach a fresh timeline recorder to every shard run and surface
    /// the drained records per shard ([`ShardReport::timeline`],
    /// exported via [`crate::telemetry::export::perfetto_fleet`]).
    /// Overrides any recorder on the `engine` template.
    pub telemetry: bool,
    /// Seed each shard's cache from its streams' expected regimes at
    /// spin-up (the DP runs once per distinct regime × lane partition
    /// *before* the clock starts), so first admissions hit without any
    /// prior run or persisted cache file. Off by default — the
    /// cold-start path is the bare engine's, bit for bit.
    pub registry_prewarm: bool,
    /// Deadline-shed rate above which a shard counts as degraded.
    pub shed_threshold: f64,
    /// A migration triggers only when hot and cold shard health differ
    /// by more than this — the anti-flap band.
    pub hysteresis: f64,
    /// Migration rounds per serve (one stream moves per round).
    pub max_migrations: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 1,
            cache_capacity: 64,
            threads: default_threads(),
            engine: EngineConfig::default(),
            telemetry: false,
            registry_prewarm: false,
            shed_threshold: 0.02,
            hysteresis: 0.01,
            max_migrations: 2,
        }
    }
}

impl FleetConfig {
    /// The default configuration over `shards` shards.
    pub fn new(shards: usize) -> FleetConfig {
        FleetConfig { shards, ..FleetConfig::default() }
    }
}

/// One completed cross-shard stream migration.
#[derive(Debug, Clone)]
pub struct FleetMigration {
    /// Name of the migrated stream.
    pub stream: String,
    /// Source (hot) shard index.
    pub from: usize,
    /// Destination (cold) shard index.
    pub to: usize,
    /// Outcome of prewarming the destination cache with the stream's
    /// carried-over plans, re-keyed onto its new lane partition.
    pub prewarm: PrewarmReport,
}

/// One shard's slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shard: usize,
    /// The shard's disjoint device slice.
    pub n_fpga: usize,
    pub n_gpu: usize,
    /// Final resident stream names, in admission order.
    pub streams: Vec<String>,
    /// Plans seeded by the spin-up registry prewarm (0 when disabled).
    pub prewarm_seeded: usize,
    /// The shard's serve report; `None` for a shard left with no
    /// streams (possible after a migration drains its only one).
    pub report: Option<MultiStreamReport>,
    /// The shard cache's cumulative counters after the run.
    pub cache: CacheStats,
    /// Drained telemetry records (empty unless [`FleetConfig::telemetry`]).
    pub timeline: Vec<Record>,
}

/// Aggregate of every shard's serve pass plus the migration log.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub shards: Vec<ShardReport>,
    pub migrations: Vec<FleetMigration>,
    /// Total requests offered across all streams.
    pub offered: usize,
    pub total_completed: usize,
    pub total_shed: usize,
    pub total_energy: f64,
    /// Max over shard makespans — shards run concurrently.
    pub makespan: f64,
    /// `total_completed / makespan`.
    pub aggregate_throughput: f64,
}

impl FleetReport {
    /// Every offered request completes or sheds exactly once, across
    /// all shards and migrations — the fleet-level conservation law.
    pub fn conserved(&self) -> bool {
        self.total_completed + self.total_shed == self.offered
    }

    /// Per-shard `(timeline, stream names)` pairs in shard order — the
    /// input shape of [`crate::telemetry::export::perfetto_fleet`].
    pub fn timelines(&self) -> Vec<(Vec<Record>, Vec<String>)> {
        self.shards.iter().map(|s| (s.timeline.clone(), s.streams.clone())).collect()
    }

    /// Human-readable per-shard table plus the migration log.
    pub fn render(&self) -> String {
        let mut t =
            Table::new(&["shard", "devices", "streams", "completed", "shed", "J", "mkspan s"]);
        for s in &self.shards {
            let (completed, shed, energy, makespan) = s
                .report
                .as_ref()
                .map(|r| (r.total_completed, r.engine.sheds, r.total_energy, r.makespan))
                .unwrap_or((0, 0, 0.0, 0.0));
            t.row(vec![
                format!("{}", s.shard),
                format!("{}F{}G", s.n_fpga, s.n_gpu),
                s.streams.join(","),
                format!("{completed}"),
                format!("{shed}"),
                format!("{energy:.1}"),
                format!("{makespan:.3}"),
            ]);
        }
        let mut out = t.render();
        for m in &self.migrations {
            out.push_str(&format!(
                "migrated '{}' shard {} -> {} ({} plans prewarmed, {} cold)\n",
                m.stream, m.from, m.to, m.prewarm.hits, m.prewarm.misses
            ));
        }
        out.push_str(&format!(
            "fleet: {}/{} completed, {} shed, {:.1} J, makespan {:.3} s, {:.1} inf/s\n",
            self.total_completed,
            self.offered,
            self.total_shed,
            self.total_energy,
            self.makespan,
            self.aggregate_throughput
        ));
        out
    }
}

/// N parallel [`ServingEngine`] shards behind an SLO- and
/// affinity-aware admission router. See the module docs for the
/// placement and migration machinery.
pub struct ServingFleet<'a, E: PerfEstimator> {
    est: &'a E,
    cfg: FleetConfig,
    /// Disjoint, inventory-conserving device slices, one per shard.
    pools: Vec<SystemSpec>,
    /// Per-shard schedule caches — never shared across shards.
    caches: Vec<SharedScheduleCache>,
}

impl<'a, E: PerfEstimator + Sync> ServingFleet<'a, E> {
    /// Carve `sys` into `cfg.shards` disjoint slices (equal-weight
    /// largest-remainder split: inventory is conserved and every shard
    /// gets at least one device) and stand up one cold cache per shard.
    pub fn new(sys: SystemSpec, est: &'a E, cfg: FleetConfig) -> Self {
        assert!(cfg.shards >= 1, "a fleet needs at least one shard");
        let pools = lease::split_pool(&sys, &vec![1.0; cfg.shards]);
        let caches = (0..cfg.shards).map(|_| ScheduleCache::shared(cfg.cache_capacity)).collect();
        ServingFleet { est, cfg, pools, caches }
    }

    /// The per-shard device slices, in shard order.
    pub fn pools(&self) -> &[SystemSpec] {
        &self.pools
    }

    /// Handle to one shard's schedule cache.
    pub fn cache(&self, shard: usize) -> SharedScheduleCache {
        self.caches[shard].clone()
    }

    /// Warm-start shard caches from `dir/shard<i>.json` files persisted
    /// by [`Self::save_caches`]; missing files leave that shard cold.
    /// Returns how many shards loaded a file.
    pub fn load_caches(&mut self, dir: impl AsRef<Path>) -> anyhow::Result<usize> {
        let mut loaded = 0;
        for s in 0..self.cfg.shards {
            let path = dir.as_ref().join(format!("shard{s}.json"));
            if path.exists() {
                *self.caches[s].lock().unwrap() =
                    ScheduleCache::load_from(&path, self.cfg.cache_capacity)?;
                loaded += 1;
            }
        }
        Ok(loaded)
    }

    /// Persist every shard cache to `dir/shard<i>.json`.
    pub fn save_caches(&self, dir: impl AsRef<Path>) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir.as_ref())?;
        for s in 0..self.cfg.shards {
            self.caches[s].lock().unwrap().save_to(dir.as_ref().join(format!("shard{s}.json")))?;
        }
        Ok(())
    }

    /// Place every stream on a shard. Deterministic: streams place in
    /// (SLO priority desc, demand desc, index) order, each onto the
    /// shard minimizing projected relative load — demand already placed
    /// plus this stream, over the shard's device count — discounted by
    /// [`AFFINITY_FACTOR`] when the shard's cache is already warm for
    /// any of the stream's expected regimes. Ties go to the lowest
    /// shard index. Returns `stream index -> shard index`.
    pub fn route(&self, streams: &[StreamSpec]) -> Vec<usize> {
        let demand: Vec<f64> = streams.iter().map(StreamSpec::demand).collect();
        let regimes: Vec<Vec<CacheKey>> = streams.iter().map(expected_regimes).collect();
        let mut order: Vec<usize> = (0..streams.len()).collect();
        order.sort_by(|&a, &b| {
            let pri = streams[b].slo.priority.total_cmp(&streams[a].slo.priority);
            pri.then(demand[b].total_cmp(&demand[a])).then(a.cmp(&b))
        });
        let caps: Vec<f64> = self.pools.iter().map(|p| (p.n_fpga + p.n_gpu) as f64).collect();
        let mut load = vec![0.0f64; self.pools.len()];
        let mut shard_of = vec![0usize; streams.len()];
        for &i in &order {
            let warm: Vec<bool> = self
                .caches
                .iter()
                .map(|c| {
                    let cache = c.lock().unwrap();
                    regimes[i].iter().any(|k| cache.affinity(k) > 0)
                })
                .collect();
            let score = |s: usize| {
                let projected = (load[s] + demand[i]) / caps[s];
                if warm[s] {
                    projected * AFFINITY_FACTOR
                } else {
                    projected
                }
            };
            let best = (0..self.pools.len())
                .min_by(|&x, &y| score(x).total_cmp(&score(y)).then(x.cmp(&y)))
                .expect("a fleet has at least one shard");
            shard_of[i] = best;
            load[best] += demand[i];
        }
        shard_of
    }

    /// Serve every stream to completion across the fleet: route, run
    /// all shards in parallel, then drain-and-re-admit streams off
    /// degraded shards (re-running only the two affected shards per
    /// round) until health is inside the hysteresis band or the round
    /// cap is hit.
    pub fn serve(&mut self, streams: &[StreamSpec]) -> FleetReport {
        assert!(!streams.is_empty(), "no streams");
        let k = self.cfg.shards;
        let shard_of = self.route(streams);
        let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (i, &s) in shard_of.iter().enumerate() {
            assigned[s].push(i);
        }

        let seeded: Vec<usize> = if self.cfg.registry_prewarm {
            (0..k).map(|s| self.registry_prewarm(streams, &assigned[s], s)).collect()
        } else {
            vec![0; k]
        };

        let mut reports: Vec<Option<MultiStreamReport>> = vec![None; k];
        let mut timelines: Vec<Vec<Record>> = vec![Vec::new(); k];
        let all: Vec<usize> = (0..k).collect();
        self.run_shards(streams, &assigned, &all, &mut reports, &mut timelines);

        let mut migrations: Vec<FleetMigration> = Vec::new();
        let mut moved: Vec<usize> = Vec::new();
        while migrations.len() < self.cfg.max_migrations {
            let next = self.pick_migration(streams, &assigned, &reports, &moved);
            let Some((victim, from, to)) = next else {
                break;
            };
            // The victim's admission-time lane partition on the source
            // shard keys the plans worth carrying across caches.
            let (old_fp, _, _) = self.lane_partition(streams, &assigned[from], victim, from);
            assigned[from].retain(|&i| i != victim);
            assigned[to].push(victim);
            assigned[to].sort_unstable();
            let (new_fp, nf, ng) = self.lane_partition(streams, &assigned[to], victim, to);
            let prewarm = {
                let src = self.caches[from].lock().unwrap();
                let mut dst = self.caches[to].lock().unwrap();
                src.copy_fingerprint_into(&mut dst, old_fp);
                drop(src);
                dst.prewarm(old_fp, new_fp, nf, ng)
            };
            self.run_shards(streams, &assigned, &[from, to], &mut reports, &mut timelines);
            moved.push(victim);
            migrations.push(FleetMigration {
                stream: streams[victim].name.clone(),
                from,
                to,
                prewarm,
            });
        }

        let offered: usize = streams.iter().map(|s| s.trace.len()).sum();
        let mut total_completed = 0;
        let mut total_shed = 0;
        let mut total_energy = 0.0;
        let mut makespan = 0.0f64;
        let mut shards = Vec::with_capacity(k);
        for s in 0..k {
            let report = reports[s].take();
            if let Some(r) = &report {
                total_completed += r.total_completed;
                total_shed += r.engine.sheds;
                total_energy += r.total_energy;
                makespan = makespan.max(r.makespan);
            }
            shards.push(ShardReport {
                shard: s,
                n_fpga: self.pools[s].n_fpga,
                n_gpu: self.pools[s].n_gpu,
                streams: assigned[s].iter().map(|&i| streams[i].name.clone()).collect(),
                prewarm_seeded: seeded[s],
                report,
                cache: self.caches[s].lock().unwrap().stats(),
                timeline: std::mem::take(&mut timelines[s]),
            });
        }
        let aggregate_throughput =
            if makespan > 0.0 { total_completed as f64 / makespan } else { 0.0 };
        FleetReport {
            shards,
            migrations,
            offered,
            total_completed,
            total_shed,
            total_energy,
            makespan,
            aggregate_throughput,
        }
    }

    /// Run the shards named in `which` (in parallel, up to
    /// `cfg.threads` workers) and write results back by shard index.
    /// Each worker stands up its own engine over the shard's pool,
    /// cache, and a clone of the engine template; streams stay in
    /// admission order, so a one-shard fleet is exactly one bare
    /// `ServingEngine::serve` call.
    fn run_shards(
        &self,
        streams: &[StreamSpec],
        assigned: &[Vec<usize>],
        which: &[usize],
        reports: &mut [Option<MultiStreamReport>],
        timelines: &mut [Vec<Record>],
    ) {
        let results = run_indexed(which.len(), self.cfg.threads.max(1), |j| {
            let shard = which[j];
            let members = &assigned[shard];
            if members.is_empty() {
                return (None, Vec::new());
            }
            let specs: Vec<StreamSpec> = members.iter().map(|&i| streams[i].clone()).collect();
            let mut cfg = self.cfg.engine.clone();
            let rec = if self.cfg.telemetry { Some(Recorder::timeline()) } else { None };
            if let Some(r) = &rec {
                cfg.recorder = Some(r.clone());
            }
            let mut engine = ServingEngine::new(self.pools[shard].clone(), self.est)
                .with_cache(self.caches[shard].clone())
                .with_config(cfg);
            let report = engine.serve(&specs);
            (Some(report), rec.map(|r| r.drain()).unwrap_or_default())
        });
        for (j, (report, timeline)) in results.into_iter().enumerate() {
            reports[which[j]] = report;
            timelines[which[j]] = timeline;
        }
    }

    /// Seed one shard's cache at spin-up: mirror the engine's initial
    /// lease apportionment (SLO-weighted demand, `lease::assign`), then
    /// run the DP once per distinct (lane partition, regime, objective)
    /// key the shard's streams will look up on first admission, and
    /// insert the plans — exactly what each lane's coordinator would
    /// compute on its first cold miss, done before the clock starts.
    /// `Balanced`-objective lanes bypass the cache and are skipped.
    /// Returns the number of plans seeded.
    fn registry_prewarm(&self, streams: &[StreamSpec], members: &[usize], shard: usize) -> usize {
        if members.is_empty() {
            return 0;
        }
        let weighted: Vec<f64> = members
            .iter()
            .map(|&i| streams[i].demand() * self.cfg.engine.slo.weight(&streams[i].slo, None))
            .collect();
        let assignment = lease::assign(&self.pools[shard], &weighted);
        let mut cache = self.caches[shard].lock().unwrap();
        let mut seeded = 0;
        for (j, &i) in members.iter().enumerate() {
            let s = &streams[i];
            if matches!(s.objective, Objective::Balanced { .. }) {
                continue;
            }
            let (part, _) = assignment.lease_of(j);
            let fp = system_fingerprint(part);
            for r in &s.trace {
                let key = CacheKey::new(fp, &r.workload, s.objective);
                if cache.contains(&key) {
                    continue;
                }
                let sched = DpScheduler::new(part, self.est).schedule(&r.workload, s.objective);
                cache.insert(key, sched.plan());
                seeded += 1;
            }
        }
        seeded
    }

    /// A stream's admission-time lane partition on `shard` given the
    /// member set: the same SLO-weighted demand split the engine runs
    /// at t=0, which is where that lane's cache entries are keyed.
    fn lane_partition(
        &self,
        streams: &[StreamSpec],
        members: &[usize],
        stream: usize,
        shard: usize,
    ) -> (u64, usize, usize) {
        let weighted: Vec<f64> = members
            .iter()
            .map(|&i| streams[i].demand() * self.cfg.engine.slo.weight(&streams[i].slo, None))
            .collect();
        let assignment = lease::assign(&self.pools[shard], &weighted);
        let j = members.iter().position(|&i| i == stream).expect("stream is a member");
        let (part, _) = assignment.lease_of(j);
        (system_fingerprint(part), part.n_fpga, part.n_gpu)
    }

    /// Decide the next migration, if any: the shard with the worst
    /// deadline-shed rate is hot, the one with the lowest (shed rate,
    /// demand load) is cold, and a move triggers only past both the
    /// absolute threshold and the hot-cold hysteresis band (on the shed
    /// rate, or its deadline-attainment analogue). The victim is the
    /// hot shard's most-shedding not-yet-moved stream. Returns
    /// `(stream index, from, to)`.
    fn pick_migration(
        &self,
        streams: &[StreamSpec],
        assigned: &[Vec<usize>],
        reports: &[Option<MultiStreamReport>],
        moved: &[usize],
    ) -> Option<(usize, usize, usize)> {
        let k = assigned.len();
        if k < 2 {
            return None;
        }
        // Per-shard health: (shed rate, min deadline attainment, load).
        let health: Vec<Option<(f64, f64, f64)>> = (0..k)
            .map(|s| {
                let r = reports[s].as_ref()?;
                let offered: usize = assigned[s].iter().map(|&i| streams[i].trace.len()).sum();
                let shed_rate = r.engine.sheds as f64 / offered.max(1) as f64;
                let dl =
                    r.streams.iter().map(|sr| sr.report.deadline_attainment).fold(1.0, f64::min);
                let load: f64 = assigned[s].iter().map(|&i| streams[i].demand()).sum();
                Some((shed_rate, dl, load))
            })
            .collect();
        let hot = (0..k)
            .filter(|&s| health[s].is_some() && assigned[s].iter().any(|i| !moved.contains(i)))
            .max_by(|&a, &b| {
                let (sa, da, _) = health[a].unwrap();
                let (sb, db, _) = health[b].unwrap();
                sa.total_cmp(&sb).then(db.total_cmp(&da)).then(b.cmp(&a))
            })?;
        let cold = (0..k)
            .filter(|&s| s != hot)
            .min_by(|&a, &b| {
                let ka = health[a].map(|(sr, _, ld)| (sr, ld)).unwrap_or((0.0, 0.0));
                let kb = health[b].map(|(sr, _, ld)| (sr, ld)).unwrap_or((0.0, 0.0));
                ka.0.total_cmp(&kb.0).then(ka.1.total_cmp(&kb.1)).then(a.cmp(&b))
            })?;
        let (hot_shed, hot_dl, _) = health[hot].unwrap();
        let (cold_shed, cold_dl) = health[cold].map(|(s, d, _)| (s, d)).unwrap_or((0.0, 1.0));
        let shed_trigger =
            hot_shed > self.cfg.shed_threshold && hot_shed - cold_shed > self.cfg.hysteresis;
        let dl_trigger =
            hot_dl < 1.0 - self.cfg.shed_threshold && cold_dl - hot_dl > self.cfg.hysteresis;
        if !(shed_trigger || dl_trigger) {
            return None;
        }
        let r = reports[hot].as_ref().expect("hot shard has a report");
        let shed_of = |j: usize| r.streams[j].report.shed;
        let dl_of = |j: usize| r.streams[j].report.deadline_attainment;
        let victim = (0..assigned[hot].len())
            .filter(|&j| !moved.contains(&assigned[hot][j]))
            .max_by(|&a, &b| {
                let worst = shed_of(a).cmp(&shed_of(b)).then(dl_of(b).total_cmp(&dl_of(a)));
                worst.then(assigned[hot][b].cmp(&assigned[hot][a]))
            })
            .map(|j| assigned[hot][j])?;
        Some((victim, hot, cold))
    }
}

/// The distinct schedule-cache shapes a stream's trace will look up —
/// its expected regimes, keyed under a placeholder system fingerprint
/// (affinity probes ignore the system half by design).
fn expected_regimes(s: &StreamSpec) -> Vec<CacheKey> {
    let mut out: Vec<CacheKey> = Vec::new();
    for r in &s.trace {
        let key = CacheKey::new(0, &r.workload, s.objective);
        if !out.contains(&key) {
            out.push(key);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Interconnect;
    use crate::coordinator::generate_trace;
    use crate::devices::GroundTruth;
    use crate::perfmodel::OracleModels;
    use crate::workload::{gnn, Dataset};

    fn pool(n_fpga: usize, n_gpu: usize) -> SystemSpec {
        SystemSpec { n_fpga, n_gpu, ..SystemSpec::paper_testbed(Interconnect::Pcie4) }
    }

    fn lane(name: &str, small: bool, seed: u64, n: usize) -> StreamSpec {
        let ds = if small { Dataset::synthetic2() } else { Dataset::synthetic1() };
        let wl = gnn::gcn_workload(&ds, 2, 128);
        StreamSpec::new(name, Objective::Performance, generate_trace(&[(wl, n)], 10.0, seed))
    }

    #[test]
    fn single_shard_owns_the_whole_pool_and_admission_order() {
        let sys = pool(3, 2);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let est = OracleModels { gt: &gt };
        let fleet = ServingFleet::new(sys.clone(), &est, FleetConfig::default());
        assert_eq!(fleet.pools().len(), 1);
        assert_eq!(fleet.pools()[0].n_fpga, sys.n_fpga);
        assert_eq!(fleet.pools()[0].n_gpu, sys.n_gpu);
        let streams: Vec<StreamSpec> =
            (0..3).map(|i| lane(&format!("s{i}"), true, i as u64, 4)).collect();
        assert_eq!(fleet.route(&streams), vec![0, 0, 0]);
    }

    #[test]
    fn routing_balances_near_equal_lanes_across_shards() {
        let sys = pool(12, 8);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let est = OracleModels { gt: &gt };
        let fleet = ServingFleet::new(sys, &est, FleetConfig::new(4));
        for p in fleet.pools() {
            assert_eq!((p.n_fpga, p.n_gpu), (3, 2), "equal-weight split carves even slices");
        }
        let streams: Vec<StreamSpec> =
            (0..8).map(|i| lane(&format!("s{i}"), true, i as u64, 4)).collect();
        let shard_of = fleet.route(&streams);
        let mut counts = [0usize; 4];
        for &s in &shard_of {
            counts[s] += 1;
        }
        assert_eq!(counts, [2, 2, 2, 2], "near-equal lanes spread evenly: {shard_of:?}");
    }

    #[test]
    fn affinity_pulls_a_stream_onto_the_warm_shard() {
        let sys = pool(6, 6);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let est = OracleModels { gt: &gt };
        let fleet = ServingFleet::new(sys, &est, FleetConfig::new(2));
        let s = lane("warmth", true, 7, 4);
        assert_eq!(fleet.route(std::slice::from_ref(&s)), vec![0], "cold tie goes to shard 0");
        // Warm shard 1 with the stream's regime under an arbitrary
        // system fingerprint — affinity matches shape + objective only.
        let key = CacheKey::new(0xFEED, &s.trace[0].workload, s.objective);
        fleet.cache(1).lock().unwrap().insert(key, Vec::new());
        assert_eq!(fleet.route(std::slice::from_ref(&s)), vec![1], "warmth wins the tie");
    }

    #[test]
    fn registry_prewarm_turns_first_admissions_into_hits() {
        let sys = pool(3, 2);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let est = OracleModels { gt: &gt };
        let cfg = FleetConfig {
            registry_prewarm: true,
            // Static leases: partitions never change mid-run, so every
            // lookup stays under the seeded fingerprints.
            engine: EngineConfig::builder().static_leases().build(),
            ..FleetConfig::default()
        };
        let mut fleet = ServingFleet::new(sys, &est, cfg);
        let streams = vec![lane("a", true, 1, 6), lane("b", false, 2, 6)];
        let report = fleet.serve(&streams);
        let shard = &report.shards[0];
        assert!(shard.prewarm_seeded >= 2, "one plan per distinct regime per lane");
        assert_eq!(shard.cache.misses, 0, "a warm-started shard never cold-misses");
        assert!(shard.cache.hits > 0);
        assert!(report.conserved());
    }

    #[test]
    fn fleet_report_conserves_and_renders() {
        let sys = pool(4, 2);
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        let est = OracleModels { gt: &gt };
        let cfg = FleetConfig { shards: 2, telemetry: true, ..FleetConfig::default() };
        let mut fleet = ServingFleet::new(sys, &est, cfg);
        let streams: Vec<StreamSpec> =
            (0..4).map(|i| lane(&format!("s{i}"), i % 2 == 0, 10 + i as u64, 4)).collect();
        let report = fleet.serve(&streams);
        assert_eq!(report.offered, 16);
        assert!(report.conserved(), "completed + shed must equal offered");
        assert!(report.aggregate_throughput > 0.0);
        let names: usize = report.shards.iter().map(|s| s.streams.len()).sum();
        assert_eq!(names, 4, "every stream lands on exactly one shard");
        for s in &report.shards {
            if s.report.is_some() {
                assert!(!s.timeline.is_empty(), "telemetry captures every occupied shard");
            }
        }
        let rendered = report.render();
        assert!(rendered.contains("fleet:"), "{rendered}");
        assert!(rendered.contains("2F1G"), "{rendered}");
    }
}
