//! Graph dataset registry — Table I of the paper, plus a synthetic graph
//! generator for the real-execution examples.
//!
//! The paper evaluates on two OGB graphs and four synthetic graphs chosen
//! to diversify sparsity / feature-length / scale. The scheduler consumes
//! only the *characteristics* (vertices, edges, feature length), so the
//! registry stores exactly Table I; the generator materializes small
//! concrete graphs (block-ELL) only for the end-to-end PJRT examples.


/// A GNN input graph's data characteristics (one row of Table I).
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    /// Short name used in the paper's tables (e.g. "OA", "S1").
    pub code: String,
    pub name: String,
    pub vertices: u64,
    pub edges: u64,
    /// Input feature length (Table I "Feature Len.").
    pub feature_len: u64,
    /// Degree skew exponent for the ground-truth load-imbalance model:
    /// 0.0 = uniform degrees, larger = heavier power-law tail. OGB graphs
    /// are skewed; the paper's synthetics are near-uniform.
    pub degree_skew: f64,
}

impl Dataset {
    pub fn new(
        code: &str,
        name: &str,
        vertices: u64,
        edges: u64,
        feature_len: u64,
        degree_skew: f64,
    ) -> Self {
        Dataset {
            code: code.into(),
            name: name.into(),
            vertices,
            edges,
            feature_len,
            degree_skew,
        }
    }

    /// Sparsity of the adjacency matrix, as reported in Table I:
    /// `1 − edges / vertices²`.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.edges as f64 / (self.vertices as f64 * self.vertices as f64)
    }

    /// Density (`nnz / (M·K)`), the complement of sparsity.
    pub fn density(&self) -> f64 {
        1.0 - self.sparsity()
    }

    // ---- Table I rows -----------------------------------------------------

    pub fn synthetic1() -> Self {
        Dataset::new("S1", "synthetic 1", 230_000, 120_000_000, 600, 0.1)
    }
    pub fn synthetic2() -> Self {
        Dataset::new("S2", "synthetic 2", 230_000, 15_000_000, 600, 0.1)
    }
    pub fn synthetic3() -> Self {
        Dataset::new("S3", "synthetic 3", 700_000, 15_000_000, 300, 0.1)
    }
    pub fn synthetic4() -> Self {
        Dataset::new("S4", "synthetic 4", 3_500_000, 5_000_000, 20, 0.1)
    }
    pub fn ogbn_arxiv() -> Self {
        Dataset::new("OA", "ogbn-arxiv", 170_000, 1_100_000, 128, 0.8)
    }
    pub fn ogbn_products() -> Self {
        Dataset::new("OP", "ogbn-products", 2_400_000, 61_000_000, 100, 0.8)
    }

    /// All six evaluation datasets in the paper's order.
    pub fn table1() -> Vec<Dataset> {
        vec![
            Dataset::synthetic1(),
            Dataset::synthetic2(),
            Dataset::synthetic3(),
            Dataset::synthetic4(),
            Dataset::ogbn_arxiv(),
            Dataset::ogbn_products(),
        ]
    }

    /// The tiny concrete graph matching the lowered artifacts
    /// (`artifacts/manifest.json` constants: V=1024, F=128, ell=4).
    pub fn e2e_demo() -> Self {
        // 1024 vertices, block-ELL with 8 row tiles × 4 slots of 128×128
        // blocks ⇒ up to 8·4·128·128 potential nnz; we target ~2% density.
        Dataset::new("E2E", "e2e-demo-graph", 1024, 20_000, 128, 0.3)
    }
}

/// Concrete synthetic graph in block-ELL form for the real-execution path.
///
/// Mirrors `python/compile/kernels/formats.py::BlockEll` — same layout, so
/// the Rust side can feed the lowered SpMM artifact directly.
#[derive(Debug, Clone)]
pub struct BlockEllGraph {
    /// `(nrt, ell, tm, tk)` flattened row-major.
    pub blocks: Vec<f32>,
    /// `(nrt, ell)` flattened row-major.
    pub indices: Vec<i32>,
    pub nrt: usize,
    pub ell: usize,
    pub tm: usize,
    pub tk: usize,
}

impl BlockEllGraph {
    pub fn vertices(&self) -> usize {
        self.nrt * self.tm
    }

    /// Deterministically generate a normalized-adjacency-like block-ELL
    /// matrix (row-stochastic-ish values) for `nrt×tm` vertices.
    pub fn generate(nrt: usize, ell: usize, tm: usize, tk: usize, seed: u64) -> Self {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let nkb = nrt * tm / tk; // square adjacency: k == m
        let mut blocks = vec![0f32; nrt * ell * tm * tk];
        let mut indices = vec![0i32; nrt * ell];
        for rt in 0..nrt {
            // Distinct K-block indices per row tile.
            let mut cols: Vec<usize> = (0..nkb).collect();
            rng.shuffle(&mut cols);
            for s in 0..ell {
                indices[rt * ell + s] = cols[s] as i32;
                for e in 0..tm * tk {
                    // Sparse-ish inside the block: ~20% of entries non-zero,
                    // small positive weights (degree-normalized adjacency).
                    let v = if rng.gen_f32() < 0.2 {
                        rng.gen_range_f32(0.01, 0.1)
                    } else {
                        0.0
                    };
                    blocks[((rt * ell + s) * tm + e / tk) * tk + e % tk] = v;
                }
            }
        }
        BlockEllGraph { blocks, indices, nrt, ell, tm, tk }
    }

    /// Densify (test helper / reference semantics).
    pub fn to_dense(&self) -> Vec<f32> {
        let m = self.nrt * self.tm;
        let k = m;
        let mut a = vec![0f32; m * k];
        for rt in 0..self.nrt {
            for s in 0..self.ell {
                let c0 = self.indices[rt * self.ell + s] as usize * self.tk;
                for r in 0..self.tm {
                    for c in 0..self.tk {
                        a[(rt * self.tm + r) * k + c0 + c] +=
                            self.blocks[((rt * self.ell + s) * self.tm + r) * self.tk + c];
                    }
                }
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_sparsities_match_paper() {
        // Paper Table I reports sparsity to 5-7 significant digits.
        let close = |d: Dataset, s: f64| (d.sparsity() - s).abs() < 5e-4;
        assert!(close(Dataset::synthetic1(), 0.9977315));
        assert!(close(Dataset::synthetic2(), 0.9995274));
        assert!(close(Dataset::synthetic3(), 0.9999693));
        assert!(close(Dataset::synthetic4(), 0.9999995));
        assert!(close(Dataset::ogbn_arxiv(), 0.9999593));
        assert!(close(Dataset::ogbn_products(), 0.9999793));
    }

    #[test]
    fn table1_has_six_rows() {
        assert_eq!(Dataset::table1().len(), 6);
    }

    #[test]
    fn block_ell_generation_is_deterministic_and_valid() {
        let g1 = BlockEllGraph::generate(8, 4, 128, 128, 42);
        let g2 = BlockEllGraph::generate(8, 4, 128, 128, 42);
        assert_eq!(g1.blocks, g2.blocks);
        assert_eq!(g1.indices, g2.indices);
        assert_eq!(g1.vertices(), 1024);
        let nkb = 1024 / 128;
        for &i in &g1.indices {
            assert!((i as usize) < nkb);
        }
        // Distinct indices per row tile (no accidental duplicate columns).
        for rt in 0..8 {
            let mut seen = std::collections::HashSet::new();
            for s in 0..4 {
                assert!(seen.insert(g1.indices[rt * 4 + s]));
            }
        }
    }

    #[test]
    fn densify_shape_and_mass() {
        let g = BlockEllGraph::generate(2, 2, 64, 64, 7);
        let dense = g.to_dense();
        assert_eq!(dense.len(), 128 * 128);
        let mass: f32 = dense.iter().sum();
        let block_mass: f32 = g.blocks.iter().sum();
        assert!((mass - block_mass).abs() < 1e-3);
    }
}
