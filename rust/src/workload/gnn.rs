//! GNN workload builders (§IV-A): GCN and GIN inference chains.
//!
//! * GCN layer (Eq 1): `X' = Â X Θ`  →  SpMM(Â·X) then GEMM(Y·Θ).
//! * GIN layer (Eq 2): `X' = MLP(A'X)` →  SpMM then `mlp_layers` GEMMs.
//!
//! Both paper models have 2 layers with hidden length 128 (§IV-A); the
//! builders generalize to any depth/width for the extension benches.

use super::datasets::Dataset;
use super::kernel::{KernelDesc, KernelKind, Workload};

/// Build an `layers`-layer GCN inference workload over `ds`.
///
/// Feature flow: `ds.feature_len → hidden → … → hidden`.
pub fn gcn_workload(ds: &Dataset, layers: usize, hidden: u64) -> Workload {
    let v = ds.vertices;
    let nnz = ds.edges + v; // self-loops inserted (Â = D^-½(I+A)D^-½)
    let mut kernels = Vec::new();
    let mut feat = ds.feature_len;
    for l in 1..=layers {
        kernels.push(KernelDesc {
            id: kernels.len(),
            name: format!("SpMM{l}"),
            kind: KernelKind::SpMM { m: v, k: v, n: feat, nnz },
            artifact: None,
        });
        kernels.push(KernelDesc {
            id: kernels.len(),
            name: format!("GeMM{l}"),
            kind: KernelKind::Gemm { m: v, k: feat, n: hidden },
            artifact: None,
        });
        feat = hidden;
    }
    Workload { name: format!("GCN-{}", ds.code), kernels }
}

/// Build a `layers`-layer GIN inference workload with `mlp_layers`-deep
/// MLPs (paper uses 2-layer MLPs → 2 GEMMs per GIN layer).
pub fn gin_workload(ds: &Dataset, layers: usize, hidden: u64, mlp_layers: usize) -> Workload {
    let v = ds.vertices;
    let nnz = ds.edges + v; // A' = A + (1+ε)I
    let mut kernels = Vec::new();
    let mut feat = ds.feature_len;
    for l in 1..=layers {
        kernels.push(KernelDesc {
            id: kernels.len(),
            name: format!("SpMM{l}"),
            kind: KernelKind::SpMM { m: v, k: v, n: feat, nnz },
            artifact: None,
        });
        for m in 1..=mlp_layers {
            kernels.push(KernelDesc {
                id: kernels.len(),
                name: format!("GeMM{l}.{m}"),
                kind: KernelKind::Gemm { m: v, k: feat, n: hidden },
                artifact: None,
            });
            feat = hidden;
        }
    }
    Workload { name: format!("GIN-{}", ds.code), kernels }
}

/// The paper's benchmark pair: 2-layer GCN and 2-layer GIN (2-layer MLP),
/// hidden 128 (§IV-A).
pub fn paper_gnn_workloads(ds: &Dataset) -> Vec<Workload> {
    vec![gcn_workload(ds, 2, 128), gin_workload(ds, 2, 128, 2)]
}

/// The e2e demo workload whose shapes match the lowered artifacts
/// (V=1024, F=128): kernels carry artifact names so the real-execution
/// pipeline can run them via PJRT.
pub fn e2e_gcn_workload() -> Workload {
    let ds = Dataset::e2e_demo();
    let mut wl = gcn_workload(&ds, 2, 128);
    for k in &mut wl.kernels {
        k.artifact = Some(
            match k.kind {
                KernelKind::SpMM { .. } => "spmm",
                KernelKind::Gemm { .. } => "gemm",
                KernelKind::WindowAttn { .. } => unreachable!(),
            }
            .to_string(),
        );
    }
    wl.name = "GCN-E2E".into();
    wl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcn_two_layers_is_four_kernels() {
        let wl = gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        assert_eq!(wl.len(), 4);
        assert_eq!(wl.kernels[0].name, "SpMM1");
        assert_eq!(wl.kernels[3].name, "GeMM2");
        // Layer-2 SpMM consumes the hidden width, not the input features.
        match wl.kernels[2].kind {
            KernelKind::SpMM { n, .. } => assert_eq!(n, 128),
            _ => panic!("expected SpMM"),
        }
    }

    #[test]
    fn gin_two_layers_two_mlp_is_six_kernels() {
        let wl = gin_workload(&Dataset::ogbn_products(), 2, 128, 2);
        assert_eq!(wl.len(), 6);
        let tags: Vec<_> = wl.kernels.iter().map(|k| k.kind.tag()).collect();
        assert_eq!(tags, ["spmm", "gemm", "gemm", "spmm", "gemm", "gemm"]);
    }

    #[test]
    fn self_loops_added_to_nnz() {
        let ds = Dataset::ogbn_arxiv();
        let wl = gcn_workload(&ds, 1, 128);
        match wl.kernels[0].kind {
            KernelKind::SpMM { nnz, .. } => assert_eq!(nnz, ds.edges + ds.vertices),
            _ => panic!(),
        }
    }

    #[test]
    fn gin_has_higher_dense_ratio_than_gcn() {
        // §VI-C2: GIN invokes more GEMMs → higher dense/sparse FLOP ratio.
        let ds = Dataset::ogbn_products();
        let ratio = |wl: &Workload| {
            let dense: f64 = wl
                .kernels
                .iter()
                .filter(|k| k.kind.tag() == "gemm")
                .map(|k| k.kind.flops())
                .sum();
            let sparse: f64 = wl
                .kernels
                .iter()
                .filter(|k| k.kind.tag() == "spmm")
                .map(|k| k.kind.flops())
                .sum();
            dense / sparse
        };
        let gcn = gcn_workload(&ds, 2, 128);
        let gin = gin_workload(&ds, 2, 128, 2);
        assert!(ratio(&gin) > ratio(&gcn));
    }

    #[test]
    fn e2e_workload_has_artifacts() {
        let wl = e2e_gcn_workload();
        assert!(wl.kernels.iter().all(|k| k.artifact.is_some()));
    }
}
