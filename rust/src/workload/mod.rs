//! Workload IR and builders: kernels, datasets, and the two case-study
//! workload families of §IV (GNNs and sliding-window transformers).

pub mod datasets;
pub mod gnn;
pub mod kernel;
pub mod transformer;

pub use datasets::{BlockEllGraph, Dataset};
pub use kernel::{KernelDesc, KernelKind, Workload, F32_BYTES};
