//! Kernel descriptors — the workload IR the scheduler consumes.
//!
//! A workload (§II "Target Workload") is a linear chain of compute kernels,
//! each characterized by its input dimensions, sparsity, and the size of
//! the intermediate tensor it hands to its successor. These data
//! characteristics are exactly what makes DYPE *data-aware*: they feed the
//! performance-model features of §V (GFLOP, arithmetic intensity, nnz, …).


/// Bytes per FP32 element — both device types run FP32 (§VI-A).
pub const F32_BYTES: f64 = 4.0;

/// The compute-kernel taxonomy of the two case-study workloads (§IV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelKind {
    /// Sparse × dense matmul `Y[m,n] = A[m,k] · X[k,n]`, `nnz` non-zeros in A.
    SpMM { m: u64, k: u64, n: u64, nnz: u64 },
    /// Dense matmul `C[m,n] = A[m,k] · B[k,n]`.
    Gemm { m: u64, k: u64, n: u64 },
    /// Sliding-window attention (Eq 6): fused SDDMM + softmax + SpMM over a
    /// band of total width `window`; `heads × dim` = model dimension.
    WindowAttn { seq: u64, window: u64, heads: u64, dim: u64 },
}

impl KernelKind {
    /// Floating-point operations of one invocation (the paper's GFLOP
    /// feature is `self.flops() * 1e-9`).
    pub fn flops(&self) -> f64 {
        match *self {
            // Paper §V: GFLOP = (2·nnz·N − M·N)·10⁻⁹ — each output element
            // costs one multiply-add per contributing nnz, minus the first add.
            KernelKind::SpMM { m, n, nnz, .. } => {
                (2.0 * nnz as f64 * n as f64 - (m * n) as f64).max(0.0)
            }
            KernelKind::Gemm { m, k, n } => 2.0 * (m * k * n) as f64,
            // Banded QKᵀ + S'·V: each query attends to `window` keys;
            // 2 matmuls of (seq × window × dim) per head + softmax (~5 ops/score).
            KernelKind::WindowAttn { seq, window, heads, dim } => {
                let band = (seq * window.min(seq)) as f64;
                heads as f64 * (4.0 * band * dim as f64 + 5.0 * band)
            }
        }
    }

    /// Bytes moved to/from device memory per invocation (ideal caching).
    pub fn bytes(&self) -> f64 {
        match *self {
            // CSR-ish traffic: 8B per nnz (value + index, amortized row
            // pointers) + dense operand in + result out.
            KernelKind::SpMM { m, k, n, nnz } => {
                8.0 * nnz as f64 + F32_BYTES * ((k * n) as f64 + (m * n) as f64)
            }
            KernelKind::Gemm { m, k, n } => {
                F32_BYTES * ((m * k) as f64 + (k * n) as f64 + (m * n) as f64)
            }
            KernelKind::WindowAttn { seq, window, heads, dim } => {
                let d_model = (heads * dim) as f64;
                // Q, K, V in + Z out + banded score traffic.
                F32_BYTES
                    * (4.0 * seq as f64 * d_model
                        + 2.0 * (seq * window.min(seq)) as f64 * heads as f64)
            }
        }
    }

    /// Arithmetic intensity `arm` (§V): FLOPs per byte — the non-linear
    /// feature that lets a linear regression capture sparse behaviour.
    pub fn arithmetic_intensity(&self) -> f64 {
        let b = self.bytes();
        if b > 0.0 {
            self.flops() / b
        } else {
            0.0
        }
    }

    /// Density of the operand (1.0 for dense kernels).
    pub fn density(&self) -> f64 {
        match *self {
            KernelKind::SpMM { m, k, nnz, .. } => nnz as f64 / (m as f64 * k as f64),
            KernelKind::Gemm { .. } => 1.0,
            KernelKind::WindowAttn { seq, window, .. } => {
                (window.min(seq)) as f64 / seq as f64
            }
        }
    }

    /// Size in bytes of the kernel's output tensor (the inter-stage
    /// transfer payload if a pipeline boundary is placed after it).
    pub fn output_bytes(&self) -> f64 {
        match *self {
            KernelKind::SpMM { m, n, .. } => F32_BYTES * (m * n) as f64,
            KernelKind::Gemm { m, n, .. } => F32_BYTES * (m * n) as f64,
            KernelKind::WindowAttn { seq, heads, dim, .. } => {
                F32_BYTES * (seq * heads * dim) as f64
            }
        }
    }

    /// Size in bytes of the *dynamic* input tensor (what must be shipped to
    /// the stage; static data — graph structure, weights — is pre-loaded,
    /// §II-B data-partition strategy).
    pub fn dynamic_input_bytes(&self) -> f64 {
        match *self {
            KernelKind::SpMM { k, n, .. } => F32_BYTES * (k * n) as f64,
            KernelKind::Gemm { m, k, .. } => F32_BYTES * (m * k) as f64,
            KernelKind::WindowAttn { seq, heads, dim, .. } => {
                F32_BYTES * (seq * heads * dim) as f64
            }
        }
    }

    /// Short type tag (used by FleetRec*-style type pinning and reports).
    pub fn tag(&self) -> &'static str {
        match self {
            KernelKind::SpMM { .. } => "spmm",
            KernelKind::Gemm { .. } => "gemm",
            KernelKind::WindowAttn { .. } => "winattn",
        }
    }

    /// Every tag [`KernelKind::tag`] can return — the single vocabulary
    /// consumers re-interning persisted tags (schedule-cache
    /// `load_from`) match against. Keep in lockstep with `tag` when
    /// adding a kernel family (`tag_vocabulary_is_exhaustive` guards the
    /// pairing).
    pub const ALL_TAGS: [&'static str; 3] = ["spmm", "gemm", "winattn"];
}

/// One kernel instance in a workload chain.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDesc {
    /// Position in the workload (0-based).
    pub id: usize,
    /// Human-readable name, e.g. `SpMM1`, `GeMM2`.
    pub name: String,
    pub kind: KernelKind,
    /// Which artifact executes this kernel in the real-execution pipeline
    /// (`None` for simulation-only workloads whose shapes have no lowered
    /// artifact).
    pub artifact: Option<String>,
}

/// A workload: a named linear chain of kernels (the paper's `wl`).
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub kernels: Vec<KernelDesc>,
}

impl Workload {
    pub fn new(name: impl Into<String>, kinds: Vec<(String, KernelKind)>) -> Self {
        let kernels = kinds
            .into_iter()
            .enumerate()
            .map(|(id, (name, kind))| KernelDesc { id, name, kind, artifact: None })
            .collect();
        Workload { name: name.into(), kernels }
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }

    /// Total FLOPs of one inference.
    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.kind.flops()).sum()
    }

    /// Payload entering the stage that starts at kernel `i`: the output of
    /// kernel `i-1`, or the workload's external input for `i == 0`.
    pub fn transfer_bytes_into(&self, i: usize) -> f64 {
        if i == 0 {
            self.kernels[0].kind.dynamic_input_bytes()
        } else {
            self.kernels[i - 1].kind.output_bytes()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_vocabulary_is_exhaustive() {
        // One witness per variant: every tag() value must appear in
        // ALL_TAGS (and vice versa), so persisted caches written with a
        // new kernel family cannot silently become unloadable.
        let witnesses = [
            KernelKind::SpMM { m: 1, k: 1, n: 1, nnz: 1 },
            KernelKind::Gemm { m: 1, k: 1, n: 1 },
            KernelKind::WindowAttn { seq: 1, window: 1, heads: 1, dim: 1 },
        ];
        assert_eq!(witnesses.len(), KernelKind::ALL_TAGS.len());
        for w in &witnesses {
            assert!(KernelKind::ALL_TAGS.contains(&w.tag()), "missing tag {}", w.tag());
        }
    }

    #[test]
    fn spmm_flops_match_paper_formula() {
        let k = KernelKind::SpMM { m: 1000, k: 1000, n: 128, nnz: 50_000 };
        // GFLOP = (2·nnz·N − M·N)·1e-9
        let expect = 2.0 * 50_000.0 * 128.0 - 1000.0 * 128.0;
        assert_eq!(k.flops(), expect);
    }

    #[test]
    fn gemm_flops() {
        let k = KernelKind::Gemm { m: 10, k: 20, n: 30 };
        assert_eq!(k.flops(), 2.0 * 6000.0);
    }

    #[test]
    fn density_bounds() {
        let sp = KernelKind::SpMM { m: 100, k: 100, n: 8, nnz: 100 };
        assert!((sp.density() - 0.01).abs() < 1e-12);
        assert_eq!(KernelKind::Gemm { m: 1, k: 1, n: 1 }.density(), 1.0);
        let wa = KernelKind::WindowAttn { seq: 1024, window: 512, heads: 8, dim: 64 };
        assert!((wa.density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn window_capped_by_seq() {
        let full = KernelKind::WindowAttn { seq: 512, window: 4096, heads: 8, dim: 64 };
        let exact = KernelKind::WindowAttn { seq: 512, window: 512, heads: 8, dim: 64 };
        assert_eq!(full.flops(), exact.flops());
        assert_eq!(full.density(), 1.0);
    }

    #[test]
    fn arithmetic_intensity_positive_and_sparser_is_lower() {
        let dense = KernelKind::SpMM { m: 1000, k: 1000, n: 128, nnz: 500_000 };
        let sparse = KernelKind::SpMM { m: 1000, k: 1000, n: 128, nnz: 5_000 };
        assert!(dense.arithmetic_intensity() > sparse.arithmetic_intensity());
        assert!(sparse.arithmetic_intensity() > 0.0);
    }

    #[test]
    fn transfer_bytes_chain() {
        let wl = Workload::new(
            "t",
            vec![
                ("a".into(), KernelKind::Gemm { m: 10, k: 4, n: 8 }),
                ("b".into(), KernelKind::Gemm { m: 10, k: 8, n: 2 }),
            ],
        );
        assert_eq!(wl.transfer_bytes_into(0), 4.0 * 40.0); // external input m×k
        assert_eq!(wl.transfer_bytes_into(1), 4.0 * 80.0); // a's output m×n
    }
}
