//! Sliding-window transformer workload builder (§IV-B).
//!
//! The paper adopts the BigBird setting (d_model = 512, 8 heads) with a
//! 32-layer model (Mistral-7B-like layer count), window `w ∈ [512, 4096]`
//! and `seq_len ∈ [1024, 16384]`, `w ≤ seq_len`. One layer contributes:
//!
//! 1. fused QKV projection GEMM  (seq × d × 3d)
//! 2. sliding-window attention   (Eq 6: SDDMM + softmax + SpMM band)
//! 3. output projection GEMM     (seq × d × d)
//! 4. FFN GEMM 1                 (seq × d × 4d)
//! 5. FFN GEMM 2                 (seq × 4d × d)

use super::kernel::{KernelDesc, KernelKind, Workload};

/// BigBird attention dimensionality (§IV-B).
pub const D_MODEL: u64 = 512;
/// BigBird head count (§IV-B).
pub const HEADS: u64 = 8;
/// Mistral-7B-aligned layer count (§IV-B).
pub const PAPER_LAYERS: usize = 32;
/// FFN expansion factor (standard 4×).
pub const FFN_MULT: u64 = 4;

/// Build a sliding-window transformer inference workload.
pub fn transformer_workload(seq_len: u64, window: u64, layers: usize) -> Workload {
    assert!(window <= seq_len, "invalid combination: w={window} > seq_len={seq_len}");
    let d = D_MODEL;
    let mut kernels = Vec::new();
    for l in 1..=layers {
        kernels.push(KernelDesc {
            id: kernels.len(),
            name: format!("QKV{l}"),
            kind: KernelKind::Gemm { m: seq_len, k: d, n: 3 * d },
            artifact: None,
        });
        kernels.push(KernelDesc {
            id: kernels.len(),
            name: format!("WinAttn{l}"),
            kind: KernelKind::WindowAttn { seq: seq_len, window, heads: HEADS, dim: d / HEADS },
            artifact: None,
        });
        kernels.push(KernelDesc {
            id: kernels.len(),
            name: format!("Proj{l}"),
            kind: KernelKind::Gemm { m: seq_len, k: d, n: d },
            artifact: None,
        });
        kernels.push(KernelDesc {
            id: kernels.len(),
            name: format!("FFN{l}a"),
            kind: KernelKind::Gemm { m: seq_len, k: d, n: FFN_MULT * d },
            artifact: None,
        });
        kernels.push(KernelDesc {
            id: kernels.len(),
            name: format!("FFN{l}b"),
            kind: KernelKind::Gemm { m: seq_len, k: FFN_MULT * d, n: d },
            artifact: None,
        });
    }
    Workload { name: format!("Transf-s{seq_len}-w{window}"), kernels }
}

/// The paper's 32-layer evaluation model.
pub fn paper_transformer(seq_len: u64, window: u64) -> Workload {
    transformer_workload(seq_len, window, PAPER_LAYERS)
}

/// The (seq_len, window) evaluation grid of §IV-B: seq ∈ {1024 … 16384},
/// w ∈ {512 … 4096}, powers of two, `w ≤ seq`.
pub fn paper_sweep() -> Vec<(u64, u64)> {
    let seqs = [1024u64, 2048, 4096, 8192, 16384];
    let wins = [512u64, 1024, 2048, 4096];
    let mut grid = Vec::new();
    for &s in &seqs {
        for &w in &wins {
            if w <= s {
                grid.push((s, w));
            }
        }
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_structure() {
        let wl = transformer_workload(2048, 512, 2);
        assert_eq!(wl.len(), 10);
        let tags: Vec<_> = wl.kernels.iter().map(|k| k.kind.tag()).collect();
        assert_eq!(
            tags,
            ["gemm", "winattn", "gemm", "gemm", "gemm", "gemm", "winattn", "gemm", "gemm", "gemm"]
        );
    }

    #[test]
    fn paper_model_is_160_kernels() {
        assert_eq!(paper_transformer(1024, 512).len(), 160);
    }

    #[test]
    #[should_panic(expected = "invalid combination")]
    fn rejects_window_larger_than_seq() {
        transformer_workload(512, 1024, 1);
    }

    #[test]
    fn sweep_respects_w_le_seq() {
        let grid = paper_sweep();
        assert!(grid.iter().all(|&(s, w)| w <= s));
        assert_eq!(grid.len(), 17); // 5*4 minus (1024,2048),(1024,4096),(2048,4096)
    }

    #[test]
    fn attention_fraction_grows_with_seq() {
        // The band FLOPs are linear in seq (w fixed) but so are the GEMMs —
        // attention *density* falls with seq, shrinking its share of work on
        // a dense device. Sanity-check the density trend the paper leans on.
        let short = transformer_workload(1024, 512, 1);
        let long = transformer_workload(16384, 512, 1);
        let d = |wl: &Workload| {
            wl.kernels
                .iter()
                .find(|k| k.kind.tag() == "winattn")
                .unwrap()
                .kind
                .density()
        };
        assert!(d(&long) < d(&short));
    }
}
