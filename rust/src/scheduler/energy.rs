//! `f_eng` — pipeline energy model (§II-A energy optimization).
//!
//! Per-device power states come from Table II / system configuration:
//! execution (kernel-dependent on the FPGA: the SpMM and win-attn
//! bitstreams draw differently), data transfer, and idle (static). Energy
//! per inference for a pipeline with period `T`:
//!
//! ```text
//! E = Σ_stages n · [ Σ_k P_dyn(kernel, dev)·t_k  +  P_xfer·(t_in + t_out)
//!                    + P_static·T ]
//! ```
//!
//! Idleness is captured by charging static power over the full period:
//! a stage busy for `t < T` idles for the remainder.

use crate::devices::{DeviceType, FpgaConfig, GpuConfig};
use crate::workload::KernelKind;

/// Power lookup derived from the system's device configs.
#[derive(Debug, Clone)]
pub struct PowerTable {
    pub gpu: GpuConfig,
    pub fpga: FpgaConfig,
}

impl PowerTable {
    pub fn new(gpu: GpuConfig, fpga: FpgaConfig) -> Self {
        PowerTable { gpu, fpga }
    }

    /// Dynamic power while executing `kind` on `dev` (W).
    pub fn dynamic_power(&self, kind: &KernelKind, dev: DeviceType) -> f64 {
        match dev {
            DeviceType::Gpu => self.gpu.dynamic_power,
            DeviceType::Fpga => match kind {
                KernelKind::WindowAttn { .. } => self.fpga.attn_dynamic_power,
                // SpMM bitstream powers both sparse and (overlay) dense ops.
                _ => self.fpga.spmm_dynamic_power,
            },
        }
    }

    /// Power while driving PCIe transfers (W).
    pub fn transfer_power(&self, dev: DeviceType) -> f64 {
        match dev {
            DeviceType::Gpu => self.gpu.transfer_power,
            DeviceType::Fpga => self.fpga.transfer_power,
        }
    }

    /// Static/idle power (W).
    pub fn static_power(&self, dev: DeviceType) -> f64 {
        match dev {
            DeviceType::Gpu => self.gpu.static_power,
            DeviceType::Fpga => self.fpga.static_power,
        }
    }

    /// Worst-case peak draw of `dev` (W): static plus its hungriest
    /// dynamic state.
    pub fn peak_power(&self, dev: DeviceType) -> f64 {
        match dev {
            DeviceType::Gpu => self.gpu.static_power + self.gpu.dynamic_power,
            DeviceType::Fpga => {
                self.fpga.static_power
                    + self.fpga.spmm_dynamic_power.max(self.fpga.attn_dynamic_power)
            }
        }
    }

    /// Worst-case draw of a device pool (W): every device executing its
    /// hungriest kernel simultaneously. This is the `f_eng` figure an
    /// [`crate::engine::EnergyBudget`] power cap is naturally expressed
    /// against (e.g. "cap the pool at 40% of peak").
    pub fn pool_power_cap(&self, n_fpga: usize, n_gpu: usize) -> f64 {
        n_fpga as f64 * self.peak_power(DeviceType::Fpga)
            + n_gpu as f64 * self.peak_power(DeviceType::Gpu)
    }
}

/// Activity energy of one stage (everything except the static-power term):
/// `n · (Σ_k P_dyn·t_k + P_xfer·(t_in + t_out))`. The caller adds
/// `static_weight · period` where `static_weight = Σ n·P_static`.
pub fn stage_activity_energy(
    power: &PowerTable,
    dev: DeviceType,
    n: usize,
    kernel_times: &[(KernelKind, f64)],
    comm_in: f64,
    comm_out: f64,
) -> f64 {
    let exec: f64 = kernel_times.iter().map(|(kind, t)| power.dynamic_power(kind, dev) * t).sum();
    n as f64 * (exec + power.transfer_power(dev) * (comm_in + comm_out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> PowerTable {
        PowerTable::new(GpuConfig::default(), FpgaConfig::default())
    }

    #[test]
    fn fpga_power_depends_on_bitstream() {
        let p = table();
        let spmm = KernelKind::SpMM { m: 10, k: 10, n: 10, nnz: 10 };
        let attn = KernelKind::WindowAttn { seq: 1024, window: 512, heads: 8, dim: 64 };
        assert_eq!(p.dynamic_power(&spmm, DeviceType::Fpga), 55.0);
        assert_eq!(p.dynamic_power(&attn, DeviceType::Fpga), 50.2);
        assert_eq!(p.dynamic_power(&spmm, DeviceType::Gpu), 300.0);
    }

    #[test]
    fn activity_energy_scales_with_devices_and_time() {
        let p = table();
        let k = KernelKind::Gemm { m: 10, k: 10, n: 10 };
        let e1 = stage_activity_energy(&p, DeviceType::Gpu, 1, &[(k, 1e-3)], 0.0, 0.0);
        let e2 = stage_activity_energy(&p, DeviceType::Gpu, 2, &[(k, 1e-3)], 0.0, 0.0);
        assert!((e1 - 0.3).abs() < 1e-12); // 300 W × 1 ms
        assert!((e2 - 2.0 * e1).abs() < 1e-12);
    }

    #[test]
    fn transfer_energy_counted() {
        let p = table();
        let e = stage_activity_energy(&p, DeviceType::Fpga, 1, &[], 1e-3, 2e-3);
        assert!((e - 30.0 * 3e-3).abs() < 1e-12);
    }

    #[test]
    fn pool_power_cap_sums_peak_draws() {
        let p = table();
        // GPU: 300 dyn + 45 static; FPGA: max(55, 50.2) dyn + 19.5 static.
        assert!((p.peak_power(DeviceType::Gpu) - 345.0).abs() < 1e-12);
        assert!((p.peak_power(DeviceType::Fpga) - 74.5).abs() < 1e-12);
        assert!((p.pool_power_cap(3, 2) - (3.0 * 74.5 + 2.0 * 345.0)).abs() < 1e-12);
        assert_eq!(p.pool_power_cap(0, 0), 0.0);
    }
}
