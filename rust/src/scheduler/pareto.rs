//! Pareto-front extraction over the DP design space (Fig 9 DSE plots).
//!
//! Every complete-workload DP state — both tables, every device budget —
//! is a design point (throughput, energy/inference, device count). DYPE
//! exposes the points that are Pareto-optimal in (max throughput,
//! min energy, min devices), which is what the paper's Fig 9 scatters.


use super::dp::DpTables;

/// One Pareto-optimal design point.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    /// Paper-notation schedule mnemonic (e.g. `3F2G`).
    pub mnemonic: String,
    pub throughput: f64,
    pub energy_per_inf: f64,
    pub n_fpga: usize,
    pub n_gpu: usize,
}

impl ParetoPoint {
    pub fn devices(&self) -> usize {
        self.n_fpga + self.n_gpu
    }

    /// True if `self` dominates `other`: no worse on all three axes and
    /// strictly better on at least one.
    fn dominates(&self, other: &ParetoPoint) -> bool {
        let ge_all = self.throughput >= other.throughput
            && self.energy_per_inf <= other.energy_per_inf
            && self.devices() <= other.devices();
        let gt_any = self.throughput > other.throughput
            || self.energy_per_inf < other.energy_per_inf
            || self.devices() < other.devices();
        ge_all && gt_any
    }
}

/// Extract the Pareto front from filled DP tables, sorted by descending
/// throughput.
pub fn pareto_front(tables: &DpTables) -> Vec<ParetoPoint> {
    let mut points: Vec<ParetoPoint> = tables
        .final_states()
        .iter()
        .map(|fs| {
            let sched = tables.reconstruct(fs);
            ParetoPoint {
                mnemonic: sched.mnemonic(),
                throughput: 1.0 / fs.period,
                energy_per_inf: fs.energy_per_inf,
                n_fpga: fs.n_fpga,
                n_gpu: fs.n_gpu,
            }
        })
        .collect();

    // Deduplicate identical schedules arising from both tables.
    points.sort_by(|a, b| {
        (&a.mnemonic, a.throughput)
            .partial_cmp(&(&b.mnemonic, b.throughput))
            .unwrap()
    });
    points.dedup_by(|a, b| {
        a.mnemonic == b.mnemonic
            && (a.throughput - b.throughput).abs() < 1e-12 * b.throughput.abs().max(1e-12)
            && (a.energy_per_inf - b.energy_per_inf).abs()
                < 1e-12 * b.energy_per_inf.abs().max(1e-12)
    });

    let front: Vec<ParetoPoint> = points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .cloned()
        .collect();

    let mut front = front;
    front.sort_by(|a, b| b.throughput.partial_cmp(&a.throughput).unwrap());
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Objective, SystemSpec};
    use crate::devices::{GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::scheduler::dp::DpScheduler;
    use crate::workload::{gnn, Dataset};

    fn front_for(ds: &Dataset) -> Vec<ParetoPoint> {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &g };
        let sched = DpScheduler::new(&s, &oracle);
        let wl = gnn::gcn_workload(ds, 2, 128);
        pareto_front(&sched.tables(&wl))
    }

    #[test]
    fn front_is_nonempty_and_mutually_nondominated() {
        let front = front_for(&Dataset::ogbn_arxiv());
        assert!(!front.is_empty());
        for (i, p) in front.iter().enumerate() {
            for (j, q) in front.iter().enumerate() {
                if i != j {
                    assert!(!p.dominates(q), "{} dominates {}", p.mnemonic, q.mnemonic);
                }
            }
        }
    }

    #[test]
    fn front_contains_the_perf_optimum() {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &g };
        let sched = DpScheduler::new(&s, &oracle);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let tables = sched.tables(&wl);
        let best = tables.select(Objective::Performance).unwrap();
        let front = pareto_front(&tables);
        let best_thp = 1.0 / best.period;
        assert!(
            front.iter().any(|p| (p.throughput - best_thp).abs() < 1e-9 * best_thp),
            "perf-optimal point missing from front"
        );
    }

    #[test]
    fn front_sorted_by_throughput() {
        let front = front_for(&Dataset::synthetic2());
        for w in front.windows(2) {
            assert!(w[0].throughput >= w[1].throughput);
        }
    }
}
