//! Evaluation baselines (§VI-A):
//!
//! * **GPU-only / FPGA-only** — the homogeneous systems: DP restricted to
//!   one device type (inventory zeroed for the other).
//! * **theoretical-additive** — sums the two homogeneous throughputs and
//!   averages their energy efficiencies: the "uniformly distributed
//!   resources" strawman.
//! * **static** — the manually-tuned fixed schedule: DP-tuned once on a
//!   reference configuration (ogbn-arxiv / PCIe 4.0 for GNNs; the
//!   mid-grid point for transformers), then frozen — both structure and
//!   device counts — and re-applied everywhere.
//! * **FleetRec\*** — the paper's FleetRec emulation: device *types* are
//!   pinned per kernel pattern (sparse → FPGA, dense → GPU, the manual
//!   partitioning the intro describes), but DYPE still tunes grouping and
//!   device counts per input.

use std::collections::HashMap;

use crate::config::{Objective, SystemSpec};
use crate::devices::DeviceType;
use crate::perfmodel::PerfEstimator;
use crate::workload::Workload;

use super::dp::DpScheduler;
use super::evaluate::evaluate_plan;
use super::pipeline_def::{Schedule, StagePlan};

/// DP on a GPU-only installation of the same system.
pub fn gpu_only<E: PerfEstimator>(
    sys: &SystemSpec,
    est: &E,
    wl: &Workload,
    obj: Objective,
) -> Schedule {
    let s = SystemSpec { n_fpga: 0, ..sys.clone() };
    DpScheduler::new(&s, est).schedule(wl, obj)
}

/// DP on an FPGA-only installation of the same system.
pub fn fpga_only<E: PerfEstimator>(
    sys: &SystemSpec,
    est: &E,
    wl: &Workload,
    obj: Objective,
) -> Schedule {
    let s = SystemSpec { n_gpu: 0, ..sys.clone() };
    DpScheduler::new(&s, est).schedule(wl, obj)
}

/// theoretical-additive (§VI-A): summed throughput, averaged efficiency.
/// Returns `(throughput, energy_efficiency)`.
pub fn theoretical_additive<E: PerfEstimator>(
    sys: &SystemSpec,
    est: &E,
    wl: &Workload,
    obj: Objective,
) -> (f64, f64) {
    let g = gpu_only(sys, est, wl, obj);
    let f = fpga_only(sys, est, wl, obj);
    (
        g.throughput() + f.throughput(),
        0.5 * (g.energy_efficiency() + f.energy_efficiency()),
    )
}

/// The paper's manual kernel-pattern → device-type partitioning.
pub fn natural_type_pin() -> HashMap<String, DeviceType> {
    HashMap::from([
        ("spmm".to_string(), DeviceType::Fpga),
        ("winattn".to_string(), DeviceType::Fpga),
        ("gemm".to_string(), DeviceType::Gpu),
    ])
}

/// FleetRec*: DYPE constrained to the fixed type selection, re-optimized
/// (grouping + counts) per input.
///
/// Returns `None` when the pinning is infeasible — e.g. a deep
/// transformer whose kernel types alternate faster than the device budget
/// allows stages. The paper hits the same wall: for transformers
/// "the FleetRec approach effectively becomes indistinguishable from the
/// static method" (§VI-C1, and Table IV merges the two rows); callers
/// should fall back to the static plan in that case.
pub fn fleetrec<E: PerfEstimator>(
    sys: &SystemSpec,
    est: &E,
    wl: &Workload,
    obj: Objective,
) -> Option<Schedule> {
    DpScheduler::new(sys, est)
        .with_type_pin(natural_type_pin())
        .try_schedule(wl, obj)
}

/// Tune the static plan on `reference_wl` (the deployment-time manual
/// profiling run) and freeze it — structure, device types AND counts.
///
/// The paper's static baseline is the *manual partitioning* the intro
/// describes: kernels of a pattern go to "their" accelerator (the
/// FleetRec pin), with a fixed allocation tuned once on the reference
/// configuration. This puts the three policies in the paper's strictness
/// order: static (fixed types + counts) ⊂ FleetRec* (fixed types, tuned
/// counts) ⊂ DYPE (everything dynamic). Where the pinning is infeasible
/// (deep transformers), the tuner falls back to unpinned DP — matching
/// the paper's "static/FleetRec*" merged treatment for transformers.
pub fn tune_static_plan<E: PerfEstimator>(
    sys: &SystemSpec,
    est: &E,
    reference_wl: &Workload,
    obj: Objective,
) -> Vec<StagePlan> {
    let pinned = DpScheduler::new(sys, est)
        .with_type_pin(natural_type_pin())
        .try_schedule(reference_wl, obj);
    match pinned {
        Some(s) => s.plan(),
        None => DpScheduler::new(sys, est).schedule(reference_wl, obj).plan(),
    }
}

/// Apply a frozen static plan to a (same-shape) workload under `est`.
///
/// Panics if the plan does not cover `wl` — static plans only transfer
/// between workloads of the same model family (same kernel count).
pub fn apply_static_plan<E: PerfEstimator>(
    sys: &SystemSpec,
    est: &E,
    wl: &Workload,
    plan: &[StagePlan],
) -> Schedule {
    let power = super::energy::PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    evaluate_plan(wl, plan, est, &sys.comm_model(), &power)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::workload::{gnn, transformer, Dataset};

    fn setup() -> (SystemSpec, GroundTruth) {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        (s, g)
    }

    #[test]
    fn homogeneous_baselines_use_one_type() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let go = gpu_only(&s, &oracle, &wl, Objective::Performance);
        let fo = fpga_only(&s, &oracle, &wl, Objective::Performance);
        assert_eq!(go.fpgas_used(), 0);
        assert_eq!(fo.gpus_used(), 0);
        assert!(go.validate(wl.len(), 0, s.n_gpu).is_ok());
        assert!(fo.validate(wl.len(), s.n_fpga, 0).is_ok());
    }

    #[test]
    fn dype_beats_or_matches_both_homogeneous_baselines() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        for ds in Dataset::table1() {
            let wl = gnn::gcn_workload(&ds, 2, 128);
            let dype = DpScheduler::new(&s, &oracle)
                .schedule(&wl, Objective::Performance)
                .throughput();
            let go = gpu_only(&s, &oracle, &wl, Objective::Performance).throughput();
            let fo = fpga_only(&s, &oracle, &wl, Objective::Performance).throughput();
            // The heterogeneous design space contains both homogeneous ones.
            assert!(dype >= go * (1.0 - 1e-9), "{}: DYPE {dype} < GPU-only {go}", ds.code);
            assert!(dype >= fo * (1.0 - 1e-9), "{}: DYPE {dype} < FPGA-only {fo}", ds.code);
        }
    }

    #[test]
    fn dype_beats_or_matches_fleetrec_which_beats_nothing_weaker() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let wl = gnn::gin_workload(&Dataset::ogbn_products(), 2, 128, 2);
        let dype = DpScheduler::new(&s, &oracle).schedule(&wl, Objective::Performance).throughput();
        let fr = fleetrec(&s, &oracle, &wl, Objective::Performance).unwrap().throughput();
        assert!(dype >= fr * (1.0 - 1e-9), "constrained space cannot win: {dype} vs {fr}");
    }

    #[test]
    fn static_plan_transfers_across_datasets() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let reference = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let plan = tune_static_plan(&s, &oracle, &reference, Objective::Performance);
        for ds in Dataset::table1() {
            let wl = gnn::gcn_workload(&ds, 2, 128);
            let sched = apply_static_plan(&s, &oracle, &wl, &plan);
            assert!(sched.validate(wl.len(), s.n_fpga, s.n_gpu).is_ok(), "{}", ds.code);
            // Static can never beat DYPE re-tuned on the same input.
            let dype = DpScheduler::new(&s, &oracle)
                .schedule(&wl, Objective::Performance)
                .throughput();
            assert!(dype >= sched.throughput() * (1.0 - 1e-9), "{}", ds.code);
        }
    }

    #[test]
    fn theoretical_additive_sums_throughputs() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let wl = transformer::transformer_workload(2048, 512, 4);
        let (thp, eff) = theoretical_additive(&s, &oracle, &wl, Objective::Performance);
        let go = gpu_only(&s, &oracle, &wl, Objective::Performance);
        let fo = fpga_only(&s, &oracle, &wl, Objective::Performance);
        assert!((thp - (go.throughput() + fo.throughput())).abs() < 1e-9 * thp);
        assert!(eff > 0.0);
    }

    #[test]
    fn fleetrec_pins_winattn_to_fpga_when_feasible() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        // One layer: G-stage, F-stage, G-stage fits in 3F+2G.
        let wl = transformer::transformer_workload(4096, 512, 1);
        let sched = fleetrec(&s, &oracle, &wl, Objective::Performance)
            .expect("1-layer pinning is feasible");
        for st in &sched.stages {
            for k in st.first..=st.last {
                if wl.kernels[k].kind.tag() == "winattn" {
                    assert_eq!(st.dev, DeviceType::Fpga);
                }
            }
        }
    }

    #[test]
    fn fleetrec_infeasible_on_deep_transformer() {
        // 32 layers alternate kernel types 64+ times: pinning demands far
        // more stages than 5 devices allow (§VI-C1's observation).
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let wl = transformer::paper_transformer(1024, 512);
        assert!(fleetrec(&s, &oracle, &wl, Objective::Performance).is_none());
    }
}
