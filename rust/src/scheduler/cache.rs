//! Schedule cache — memoized Algorithm-1 results keyed by quantized
//! workload characteristics (DESIGN.md §Perf).
//!
//! Rescheduling sits on the serving path: every time the coordinator sees
//! drifted input characteristics it runs the full DP, which is the
//! dominant latency of a reschedule (milliseconds for deep workloads —
//! see `benches/scheduler_cache.rs`). But drift *recurs*: rush-hour
//! traffic looks like yesterday's rush hour, a sliding-window service
//! cycles through the same few sequence-length regimes. The cache
//! exploits that by memoizing the *structure* of past DP decisions —
//! the [`StagePlan`] vector — keyed by
//! [`crate::perfmodel::features::kernel_bucket`]'s quantized
//! sparsity/shape buckets, the objective, and a fingerprint of the
//! [`SystemSpec`].
//!
//! On a hit the caller re-times the cached plan under the current
//! estimator ([`crate::scheduler::evaluate_plan`], O(stages·kernels))
//! instead of re-running the DP (O(|wl|²·F·G·(F+G))). Timings therefore
//! always reflect the *actual* observed characteristics; only the
//! grouping/allocation decision is reused. Because the key contains every
//! kernel's family tag in order, a cached plan is always structurally
//! valid for the workload that hits it.
//!
//! Capacity is bounded with LRU eviction, and keys embed the system
//! fingerprint, so changing the device inventory (or handing a stream a
//! different partition of it) can never resurrect a stale plan.
//!
//! Fingerprint scoping cuts both ways: a lease *migration* re-scopes a
//! stream's keys, so every regime it already learned would go cold.
//! [`ScheduleCache::prewarm`] closes that gap at migration time by
//! re-keying the old partition's plans under the prospective partition's
//! fingerprint — re-fitting each plan's device allocations to the new
//! inventory ([`fit_plan`]) but never re-running Algorithm 1; the first
//! post-migration admission of a known regime then hits and is re-timed
//! via [`crate::scheduler::evaluate_plan`] like any other hit.
//!
//! The cache also persists: [`ScheduleCache::save_to`] /
//! [`ScheduleCache::load_from`] serialize the entries (and their recency
//! order) through `util/json`, so a restarted server warm-starts past
//! the cold DP storm instead of re-solving every regime it already knew.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::config::{Objective, SystemSpec};
use crate::devices::DeviceType;
use crate::perfmodel::{kernel_bucket, KernelBucket};
use crate::util::json::Json;
use crate::workload::{KernelKind, Workload};

use super::pipeline_def::StagePlan;

/// A schedule-cache key: system fingerprint × objective × the quantized
/// per-kernel characteristic buckets, in chain order.
///
/// `Default` produces an empty key (no kernels) that matches nothing the
/// cache would ever store — it exists so hot-path callers can hold a
/// reusable key and refill it in place with [`CacheKey::assign`].
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct CacheKey {
    sys_fp: u64,
    obj_fp: u64,
    kernels: Vec<KernelBucket>,
}

impl CacheKey {
    /// Build the key for scheduling `wl` under `objective` on the system
    /// identified by `sys_fp` (see [`system_fingerprint`]).
    pub fn new(sys_fp: u64, wl: &Workload, objective: Objective) -> CacheKey {
        let mut key = CacheKey::default();
        key.assign(sys_fp, wl, objective);
        key
    }

    /// Refill `self` in place as [`CacheKey::new`] would build it,
    /// reusing the kernel-bucket vector's capacity.
    pub(crate) fn assign(&mut self, sys_fp: u64, wl: &Workload, objective: Objective) {
        self.sys_fp = sys_fp;
        self.obj_fp = objective_fingerprint(objective);
        self.kernels.clear();
        self.kernels.extend(wl.kernels.iter().map(|k| kernel_bucket(&k.kind)));
    }
}

/// FNV-1a over a byte stream — the in-tree stand-in for a hashing crate.
fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Fingerprint of everything about a [`SystemSpec`] that can change a
/// schedule: inventory, interconnect generation, and every device
/// parameter. Two specs with equal fingerprints produce identical DP
/// inputs, so cached plans transfer between them.
pub fn system_fingerprint(sys: &SystemSpec) -> u64 {
    let mut bytes: Vec<u8> = Vec::with_capacity(200);
    bytes.extend((sys.n_fpga as u64).to_le_bytes());
    bytes.extend((sys.n_gpu as u64).to_le_bytes());
    bytes.push(match sys.interconnect {
        crate::devices::Interconnect::Pcie4 => 0,
        crate::devices::Interconnect::Pcie5 => 1,
        crate::devices::Interconnect::Cxl3 => 2,
    });
    let g = &sys.gpu;
    for v in [
        g.peak_flops,
        g.mem_bw,
        g.launch_overhead,
        g.dynamic_power,
        g.static_power,
        g.transfer_power,
        g.pcie_bw,
    ] {
        bytes.extend(v.to_bits().to_le_bytes());
    }
    let f = &sys.fpga;
    for v in [
        f.spmm_freq,
        f.spmm_macs,
        f.attn_freq,
        f.attn_t_pipeline,
        f.attn_t_init,
        f.gemm_peak_flops,
        f.mem_bw,
        f.launch_overhead,
        f.spmm_dynamic_power,
        f.attn_dynamic_power,
        f.static_power,
        f.transfer_power,
        f.pcie_bw,
    ] {
        bytes.extend(v.to_bits().to_le_bytes());
    }
    fnv1a(bytes)
}

/// Fingerprint of an [`Objective`], including its numeric parameters.
pub fn objective_fingerprint(obj: Objective) -> u64 {
    let (disc, param) = match obj {
        Objective::Performance => (0u8, 0u64),
        Objective::Energy => (1, 0),
        Objective::Balanced { min_throughput_frac } => (2, min_throughput_frac.to_bits()),
        Objective::QoS { min_throughput } => (3, min_throughput.to_bits()),
    };
    fnv1a(std::iter::once(disc).chain(param.to_le_bytes()))
}

/// Running hit/miss/eviction counters, cheap to copy into reports.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries dropped by explicit invalidation ([`ScheduleCache::clear`]).
    pub invalidations: u64,
    /// Plans re-keyed onto a prospective partition by
    /// [`ScheduleCache::prewarm`] (counting entries already warm there).
    pub prewarm_hits: u64,
    /// Plans a prewarm could *not* carry over (the old plan cannot be
    /// re-fitted to the new inventory); the regime goes cold and its
    /// first post-migration admission re-runs the DP.
    pub prewarm_misses: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction over all lookups so far (0.0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// Counter-wise difference vs an earlier snapshot (per-stream
    /// attribution in the multi-stream server).
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            invalidations: self.invalidations - earlier.invalidations,
            prewarm_hits: self.prewarm_hits - earlier.prewarm_hits,
            prewarm_misses: self.prewarm_misses - earlier.prewarm_misses,
        }
    }

    /// Counter-wise sum with `delta`. The serving engine attributes
    /// shared-cache traffic per stream by accumulating per-dispatch
    /// [`CacheStats::since`] diffs through this.
    pub fn accumulate(&mut self, delta: &CacheStats) {
        self.hits += delta.hits;
        self.misses += delta.misses;
        self.evictions += delta.evictions;
        self.invalidations += delta.invalidations;
        self.prewarm_hits += delta.prewarm_hits;
        self.prewarm_misses += delta.prewarm_misses;
    }
}

impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{} hits ({:.1}%), {} evictions, {}/{} prewarmed",
            self.hits,
            self.lookups(),
            self.hit_rate() * 100.0,
            self.evictions,
            self.prewarm_hits,
            self.prewarm_hits + self.prewarm_misses
        )
    }
}

/// Outcome of one [`ScheduleCache::prewarm`] call: how many of the old
/// partition's plans carried over to the prospective fingerprint.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrewarmReport {
    /// Plans now warm under the new fingerprint (re-fitted, or already
    /// present there).
    pub hits: u64,
    /// Plans that could not be re-fitted to the new inventory.
    pub misses: u64,
}

/// Re-fit a cached plan to a new device inventory without re-running the
/// DP: keep the kernel grouping and device *types*, shrink per-stage
/// device counts (largest stage first, ties to the earlier stage) until
/// the plan's totals fit. `None` when no re-fit exists — the plan has
/// more stages of one type than the new partition has devices of it
/// (stages of a pipeline occupy distinct devices). Shrink-only; growing
/// into surplus inventory is the separate, objective-dependent
/// [`widen_plan`].
pub fn fit_plan(plan: &[StagePlan], n_fpga: usize, n_gpu: usize) -> Option<Vec<StagePlan>> {
    let mut fitted = plan.to_vec();
    for (dev, avail) in [(DeviceType::Fpga, n_fpga), (DeviceType::Gpu, n_gpu)] {
        let stages = fitted.iter().filter(|s| s.dev == dev).count();
        if stages > avail {
            return None;
        }
        let mut used: usize = fitted.iter().filter(|s| s.dev == dev).map(|s| s.n).sum();
        while used > avail {
            // `used > avail >= stages` guarantees a stage with n >= 2.
            let widest = fitted
                .iter_mut()
                .filter(|s| s.dev == dev && s.n >= 2)
                .max_by(|a, b| a.n.cmp(&b.n).then(b.first.cmp(&a.first)))
                .expect("used > stages implies a shrinkable stage");
            widest.n -= 1;
            used -= 1;
        }
    }
    Some(fitted)
}

/// Grow a (fitting) plan into surplus inventory: distribute each device
/// type's unused devices to that type's narrowest stages first (ties to
/// the earlier stage). Without this, a plan carried onto a *larger*
/// partition by [`ScheduleCache::prewarm`] would pin its old, narrower
/// allocation forever — every later admission hits the cached entry, so
/// the DP never runs again for that regime and the new hardware sits
/// idle. Widening keeps the grouping decision but claims the inventory;
/// timings stay honest because every hit is re-timed by
/// [`crate::scheduler::evaluate_plan`]. A device type the plan does not
/// use gains no stages (the grouping is never restructured here).
/// `prewarm` skips widening for `Objective::Energy` plans — their narrow
/// allocation is the point (static power scales with device count), not
/// an artifact of the old partition.
pub fn widen_plan(plan: &mut [StagePlan], n_fpga: usize, n_gpu: usize) {
    for (dev, avail) in [(DeviceType::Fpga, n_fpga), (DeviceType::Gpu, n_gpu)] {
        if plan.iter().all(|s| s.dev != dev) {
            continue; // no stage of this type to widen
        }
        let mut used: usize = plan.iter().filter(|s| s.dev == dev).map(|s| s.n).sum();
        while used < avail {
            let narrowest = plan
                .iter_mut()
                .filter(|s| s.dev == dev)
                .min_by(|a, b| a.n.cmp(&b.n).then(a.first.cmp(&b.first)))
                .expect("a stage of this type exists");
            narrowest.n += 1;
            used += 1;
        }
    }
}

/// The memoization store: quantized key → frozen [`StagePlan`] vector,
/// LRU-bounded. See the module docs for the retiming contract.
#[derive(Debug)]
pub struct ScheduleCache {
    capacity: usize,
    entries: HashMap<CacheKey, Vec<StagePlan>>,
    /// Recency order, most recent at the back. Touched on hit and insert.
    lru: VecDeque<CacheKey>,
    stats: CacheStats,
}

/// Thread-shared handle used by coordinators serving concurrent streams.
pub type SharedScheduleCache = Arc<Mutex<ScheduleCache>>;

impl ScheduleCache {
    /// A cache holding at most `capacity` distinct quantized schedules.
    pub fn new(capacity: usize) -> ScheduleCache {
        assert!(capacity >= 1, "zero-capacity cache");
        ScheduleCache {
            capacity,
            entries: HashMap::new(),
            lru: VecDeque::new(),
            stats: CacheStats::default(),
        }
    }

    /// A shareable cache for multi-stream serving.
    pub fn shared(capacity: usize) -> SharedScheduleCache {
        Arc::new(Mutex::new(ScheduleCache::new(capacity)))
    }

    /// Look up the plan for `key`, counting a hit or miss.
    pub fn lookup(&mut self, key: &CacheKey) -> Option<Vec<StagePlan>> {
        let mut out = Vec::new();
        self.lookup_into(key, &mut out).then_some(out)
    }

    /// [`ScheduleCache::lookup`] into caller-owned storage: on a hit,
    /// `out` is cleared and refilled with the cached plan and `true` is
    /// returned; on a miss `out` is left untouched. Stats and recency
    /// update exactly as `lookup` does. The engine's dispatch path uses
    /// this so steady-state cache hits copy into a reusable buffer
    /// instead of cloning a fresh `Vec` per admission.
    pub fn lookup_into(&mut self, key: &CacheKey, out: &mut Vec<StagePlan>) -> bool {
        match self.entries.get(key) {
            Some(plan) => {
                out.clear();
                out.extend_from_slice(plan);
            }
            None => {
                self.stats.misses += 1;
                return false;
            }
        }
        self.stats.hits += 1;
        self.touch(key);
        true
    }

    /// Memoize a freshly-computed plan, evicting the least-recently-used
    /// entry when full.
    pub fn insert(&mut self, key: CacheKey, plan: Vec<StagePlan>) {
        if self.entries.insert(key.clone(), plan).is_none() {
            self.lru.push_back(key);
        } else {
            self.touch(&key);
        }
        while self.entries.len() > self.capacity {
            if let Some(old) = self.lru.pop_front() {
                self.entries.remove(&old);
                self.stats.evictions += 1;
            }
        }
    }

    fn touch(&mut self, key: &CacheKey) {
        if let Some(pos) = self.lru.iter().position(|k| k == key) {
            let k = self.lru.remove(pos).unwrap();
            self.lru.push_back(k);
        }
    }

    /// Re-key every plan cached under `old_fp` onto `new_fp` — the
    /// prospective partition of a lease migration, with `n_fpga`/`n_gpu`
    /// devices — so a migrated stream's first admissions of known regimes
    /// are hits, not cold misses. Plans are re-fitted to the new
    /// inventory ([`fit_plan`]) and widened into any surplus
    /// ([`widen_plan`]; skipped for `Objective::Energy`, whose narrow
    /// allocations are deliberate). A plan that cannot fit — or that the
    /// cache's own capacity evicts before the batch completes — counts as
    /// a prewarm miss and its regime goes cold (one DP re-run at next
    /// sight); `hits` only ever reports plans actually resident under
    /// `new_fp` when the call returns. Entries are *copied*, not moved:
    /// the old partition's keys stay valid for whichever stream inherits
    /// that partition shape. Timings are never computed here — a
    /// prewarmed hit re-times through [`crate::scheduler::evaluate_plan`]
    /// like any other hit.
    pub fn prewarm(
        &mut self,
        old_fp: u64,
        new_fp: u64,
        n_fpga: usize,
        n_gpu: usize,
    ) -> PrewarmReport {
        let mut report = PrewarmReport::default();
        if old_fp == new_fp {
            return report;
        }
        // Collect in LRU order so re-keyed entries inherit the source
        // recency order (oldest first, like a persisted-cache load).
        let candidates: Vec<(CacheKey, Vec<StagePlan>)> = self
            .lru
            .iter()
            .filter(|k| k.sys_fp == old_fp)
            .map(|k| (k.clone(), self.entries[k].clone()))
            .collect();
        let energy_fp = objective_fingerprint(Objective::Energy);
        let mut rekeyed: Vec<CacheKey> = Vec::with_capacity(candidates.len());
        for (key, plan) in candidates {
            let obj_fp = key.obj_fp;
            let new_key = CacheKey { sys_fp: new_fp, ..key };
            if self.entries.contains_key(&new_key) {
                // Already warm under the new partition: refresh its
                // recency so this batch's own inserts evict colder
                // entries first, not the plans we are vouching for.
                self.touch(&new_key);
                rekeyed.push(new_key);
                continue;
            }
            match fit_plan(&plan, n_fpga, n_gpu) {
                Some(mut fitted) => {
                    // Claim surplus inventory on a grown partition —
                    // except for Energy-objective plans, whose narrow
                    // allocation is deliberate (see `widen_plan`).
                    if obj_fp != energy_fp {
                        widen_plan(&mut fitted, n_fpga, n_gpu);
                    }
                    self.insert(new_key.clone(), fitted);
                    rekeyed.push(new_key);
                }
                None => report.misses += 1,
            }
        }
        // Count as warm only what is actually resident after the whole
        // batch: on a small cache, later inserts can evict earlier
        // re-keyed (or already-warm) entries, and claiming those as hits
        // would overstate the post-migration warmth.
        report.hits = rekeyed.iter().filter(|k| self.entries.contains_key(*k)).count() as u64;
        report.misses += rekeyed.len() as u64 - report.hits;
        self.stats.prewarm_hits += report.hits;
        self.stats.prewarm_misses += report.misses;
        report
    }

    /// How many cached plans match `key`'s workload shape and objective
    /// under *any* system fingerprint — the cache-affinity signal the
    /// fleet router ([`crate::fleet`]) scores shard placements with. The
    /// system half of the key is deliberately ignored: a stream's plans
    /// are keyed under whatever partition slice its lane last held, which
    /// the router cannot predict before admission; what it can know is
    /// whether this shard has *ever* solved this quantized regime under
    /// this objective. Read-only: no stats are touched and no recency is
    /// refreshed (a placement probe is not serving traffic).
    pub fn affinity(&self, key: &CacheKey) -> usize {
        self.entries
            .keys()
            .filter(|k| k.obj_fp == key.obj_fp && k.kernels == key.kernels)
            .count()
    }

    /// Whether a plan is resident under exactly `key`. Read-only: no
    /// hit/miss is counted and no recency is refreshed — this is for
    /// offline seeding passes (fleet registry prewarm) that must probe
    /// residency without polluting the serving-path statistics.
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.entries.contains_key(key)
    }

    /// Copy every entry cached under `sys_fp` into `dst`, preserving this
    /// cache's recency order (oldest first, like a persisted-cache load),
    /// and return how many entries were offered. The cross-cache leg of a
    /// fleet stream migration: the source shard's plans for the departing
    /// stream's old partition are carried into the destination shard's
    /// cache, then re-keyed onto the stream's prospective partition there
    /// via [`ScheduleCache::prewarm`] — `self` is never mutated (the
    /// source shard keeps serving its remaining streams from an
    /// untouched cache). `dst`'s capacity applies as on any insert, so
    /// the count is an upper bound on what stays resident; the follow-up
    /// `prewarm` reports actual residency.
    pub fn copy_fingerprint_into(&self, dst: &mut ScheduleCache, sys_fp: u64) -> usize {
        let mut copied = 0;
        for key in &self.lru {
            if key.sys_fp == sys_fp && !dst.entries.contains_key(key) {
                dst.insert(key.clone(), self.entries[key].clone());
                copied += 1;
            }
        }
        copied
    }

    /// Drop every entry (e.g. after a device-parameter recalibration whose
    /// fingerprint the caller does not thread through keys).
    pub fn clear(&mut self) {
        self.stats.invalidations += self.entries.len() as u64;
        self.entries.clear();
        self.lru.clear();
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Persist every entry to `path` as JSON, least-recently-used first,
    /// so [`ScheduleCache::load_from`] rebuilds both the entries *and*
    /// the eviction order. Counters are not persisted — a restarted
    /// server starts its statistics fresh; what it skips is the
    /// cold-start DP storm, because every previously-seen quantized
    /// regime re-hits its memoized plan.
    pub fn save_to(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut out = String::with_capacity(64 + self.entries.len() * 256);
        out.push_str("{\"version\":1,\"entries\":[");
        for (n, key) in self.lru.iter().enumerate() {
            if n > 0 {
                out.push(',');
            }
            let plan = self.entries.get(key).expect("lru tracks entries");
            out.push_str(&format!(
                "{{\"sys\":\"{:016x}\",\"obj\":\"{:016x}\",\"kernels\":[",
                key.sys_fp, key.obj_fp
            ));
            for (i, kb) in key.kernels.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"tag\":\"{}\",\"dims\":[{},{},{},{}],\"density\":{}}}",
                    kb.tag, kb.dims[0], kb.dims[1], kb.dims[2], kb.dims[3], kb.density
                ));
            }
            out.push_str("],\"plan\":[");
            for (i, sp) in plan.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"first\":{},\"last\":{},\"dev\":\"{}\",\"n\":{}}}",
                    sp.first,
                    sp.last,
                    sp.dev.letter(),
                    sp.n
                ));
            }
            out.push_str("]}");
        }
        out.push_str("]}");
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Rebuild a cache from a [`ScheduleCache::save_to`] file. Entries
    /// are re-inserted in saved order (LRU first), so recency carries
    /// over; if `capacity` is smaller than the saved entry count, the
    /// least-recent overflow is evicted exactly as live inserts would.
    /// Strict: any malformed entry fails the whole load (a corrupt warm
    /// file should be noticed, not half-used).
    pub fn load_from(path: impl AsRef<Path>, capacity: usize) -> anyhow::Result<ScheduleCache> {
        let text = std::fs::read_to_string(path)?;
        let doc = crate::util::json::parse(&text)?;
        let version = doc
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("cache file missing version"))?;
        anyhow::ensure!(version == 1, "unsupported cache-file version {version}");
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("cache file missing entries array"))?;
        let mut cache = ScheduleCache::new(capacity);
        for (n, e) in entries.iter().enumerate() {
            let (key, plan) =
                parse_entry(e).map_err(|msg| anyhow::anyhow!("cache entry {n}: {msg}"))?;
            cache.insert(key, plan);
        }
        // Warmup bookkeeping is not serving traffic.
        cache.stats = CacheStats::default();
        Ok(cache)
    }
}

/// Parse one persisted cache entry. Returns a human-readable reason on
/// any shape violation; the caller wraps it with the entry index.
fn parse_entry(e: &Json) -> Result<(CacheKey, Vec<StagePlan>), String> {
    let sys_fp = fingerprint_field(e, "sys")?;
    let obj_fp = fingerprint_field(e, "obj")?;

    let kernels_json = e.get("kernels").and_then(Json::as_arr).ok_or("missing kernels")?;
    let mut kernels = Vec::with_capacity(kernels_json.len());
    for k in kernels_json {
        let tag_str = k.get("tag").and_then(Json::as_str).ok_or("missing kernel tag")?;
        let tag = static_tag(tag_str).ok_or_else(|| format!("unknown kernel tag {tag_str:?}"))?;
        let dims_json = k.get("dims").and_then(Json::as_arr).ok_or("missing dims")?;
        if dims_json.len() != 4 {
            return Err(format!("dims must have 4 elements, got {}", dims_json.len()));
        }
        let mut dims = [0u32; 4];
        for (i, d) in dims_json.iter().enumerate() {
            dims[i] = d.as_u64().ok_or("bad dim")? as u32;
        }
        let density = k.get("density").and_then(Json::as_f64).ok_or("missing density")? as i32;
        kernels.push(KernelBucket { tag, dims, density });
    }

    let plan_json = e.get("plan").and_then(Json::as_arr).ok_or("missing plan")?;
    if plan_json.is_empty() {
        return Err("empty plan".into());
    }
    let mut plan = Vec::with_capacity(plan_json.len());
    for sp in plan_json {
        let first = sp.get("first").and_then(Json::as_u64).ok_or("bad first")? as usize;
        let last = sp.get("last").and_then(Json::as_u64).ok_or("bad last")? as usize;
        let n = sp.get("n").and_then(Json::as_u64).ok_or("bad n")? as usize;
        let dev = match sp.get("dev").and_then(Json::as_str) {
            Some("G") => DeviceType::Gpu,
            Some("F") => DeviceType::Fpga,
            other => return Err(format!("bad device letter {other:?}")),
        };
        if n == 0 || last < first {
            return Err(format!("malformed stage plan {first}..{last} × {n}"));
        }
        plan.push(StagePlan { first, last, dev, n });
    }
    // Structural sanity mirrors `Schedule::validate`: contiguous coverage
    // from kernel 0 (total kernel count is only known at hit time).
    if plan[0].first != 0 {
        return Err("plan must start at kernel 0".into());
    }
    for w in plan.windows(2) {
        if w[1].first != w[0].last + 1 {
            return Err(format!("gap/overlap between stages {}..{}", w[0].last, w[1].first));
        }
    }
    Ok((CacheKey { sys_fp, obj_fp, kernels }, plan))
}

fn fingerprint_field(e: &Json, name: &str) -> Result<u64, String> {
    let s = e.get(name).and_then(Json::as_str).ok_or_else(|| format!("missing {name}"))?;
    u64::from_str_radix(s, 16).map_err(|_| format!("bad {name} fingerprint {s:?}"))
}

/// Re-intern a persisted kernel-family tag as the `'static` string the
/// live [`KernelBucket`]s carry, so loaded keys hash/compare identically.
/// The vocabulary is [`KernelKind::ALL_TAGS`] — adding a kernel family
/// there keeps persisted caches loadable automatically.
fn static_tag(s: &str) -> Option<&'static str> {
    KernelKind::ALL_TAGS.into_iter().find(|t| *t == s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{DeviceType, Interconnect};
    use crate::workload::{gnn, Dataset};

    fn plan() -> Vec<StagePlan> {
        vec![StagePlan { first: 0, last: 3, dev: DeviceType::Gpu, n: 1 }]
    }

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4)
    }

    #[test]
    fn hit_within_bucket_miss_across_boundary() {
        let s = sys();
        let fp = system_fingerprint(&s);
        let mut cache = ScheduleCache::new(8);

        let base =
            gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, 2_000_000, 200, 0.2), 2, 128);
        let drift =
            gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, 2_040_000, 200, 0.2), 2, 128);
        let rush =
            gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, 150_000_000, 200, 0.2), 2, 128);

        let k_base = CacheKey::new(fp, &base, Objective::Performance);
        assert!(cache.lookup(&k_base).is_none());
        cache.insert(k_base, plan());

        // ~2% drift quantizes to the same key → hit.
        let k_drift = CacheKey::new(fp, &drift, Objective::Performance);
        assert!(cache.lookup(&k_drift).is_some());

        // 75× drift crosses bucket boundaries → miss.
        let k_rush = CacheKey::new(fp, &rush, Objective::Performance);
        assert!(cache.lookup(&k_rush).is_none());

        // Same characteristics, different objective → miss.
        let k_energy = CacheKey::new(fp, &drift, Objective::Energy);
        assert!(cache.lookup(&k_energy).is_none());

        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (1, 3));
        assert!((st.hit_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn system_change_invalidates_by_fingerprint() {
        let a = sys();
        let mut b = sys();
        b.n_gpu = 1; // shrink the inventory
        let mut c = sys();
        c.gpu.peak_flops *= 2.0; // same inventory, different silicon

        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let mut cache = ScheduleCache::new(8);
        cache.insert(CacheKey::new(system_fingerprint(&a), &wl, Objective::Performance), plan());

        for other in [&b, &c] {
            let k = CacheKey::new(system_fingerprint(other), &wl, Objective::Performance);
            assert!(cache.lookup(&k).is_none(), "changed SystemSpec must miss");
        }
        let k_same = CacheKey::new(system_fingerprint(&a), &wl, Objective::Performance);
        assert!(cache.lookup(&k_same).is_some());
    }

    #[test]
    fn lru_eviction_keeps_recent_entries() {
        let s = sys();
        let fp = system_fingerprint(&s);
        let mut cache = ScheduleCache::new(2);
        let wls: Vec<_> = [1u64, 9, 70]
            .iter()
            .map(|m| {
                gnn::gcn_workload(
                    &Dataset::new("T", "t", 1_000_000, m * 1_000_000, 200, 0.2),
                    2,
                    128,
                )
            })
            .collect();
        let keys: Vec<_> =
            wls.iter().map(|w| CacheKey::new(fp, w, Objective::Performance)).collect();
        cache.insert(keys[0].clone(), plan());
        cache.insert(keys[1].clone(), plan());
        assert!(cache.lookup(&keys[0]).is_some()); // refresh 0 → 1 is LRU
        cache.insert(keys[2].clone(), plan());
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup(&keys[1]).is_none(), "LRU entry evicted");
        assert!(cache.lookup(&keys[0]).is_some());
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn clear_counts_invalidations() {
        let s = sys();
        let fp = system_fingerprint(&s);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let mut cache = ScheduleCache::new(4);
        cache.insert(CacheKey::new(fp, &wl, Objective::Performance), plan());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats().invalidations, 1);
    }

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dype_cache_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn persistence_round_trips_entries_and_recency() {
        let s = sys();
        let fp = system_fingerprint(&s);
        let mut cache = ScheduleCache::new(8);
        let wls: Vec<_> = [2u64, 20, 150]
            .iter()
            .map(|m| {
                gnn::gcn_workload(
                    &Dataset::new("T", "t", 1_000_000, m * 1_000_000, 200, 0.2),
                    2,
                    128,
                )
            })
            .collect();
        let keys: Vec<_> =
            wls.iter().map(|w| CacheKey::new(fp, w, Objective::Performance)).collect();
        for k in &keys {
            cache.insert(k.clone(), plan());
        }
        cache.lookup(&keys[0]); // refresh 0 → LRU order is 1, 2, 0

        let path = temp_path("roundtrip");
        cache.save_to(&path).unwrap();
        let mut loaded = ScheduleCache::load_from(&path, 2).unwrap();
        std::fs::remove_file(&path).ok();

        // Capacity 2 < 3 saved entries: the least-recent entry (key 1)
        // was evicted during load, recency carried over.
        assert_eq!(loaded.len(), 2);
        assert!(loaded.lookup(&keys[1]).is_none(), "LRU entry evicted on load");
        assert_eq!(loaded.lookup(&keys[2]).unwrap(), plan());
        assert_eq!(loaded.lookup(&keys[0]).unwrap(), plan());
    }

    #[test]
    fn loaded_cache_counts_stats_fresh() {
        let s = sys();
        let fp = system_fingerprint(&s);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let key = CacheKey::new(fp, &wl, Objective::Performance);
        let mut cache = ScheduleCache::new(4);
        cache.lookup(&key); // a miss, just to dirty the counters
        cache.insert(key.clone(), plan());

        let path = temp_path("stats");
        cache.save_to(&path).unwrap();
        let mut loaded = ScheduleCache::load_from(&path, 4).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(loaded.stats(), CacheStats::default(), "warmup is not traffic");
        assert!(loaded.lookup(&key).is_some(), "warm entry hits immediately");
        assert_eq!(loaded.stats().hits, 1);
        assert_eq!(loaded.stats().misses, 0, "no cold-start DP for a known regime");
    }

    #[test]
    fn load_rejects_malformed_files() {
        let path = temp_path("garbage");
        for bad in [
            "not json at all",
            "{\"entries\":[]}",                                      // missing version
            "{\"version\":2,\"entries\":[]}",                        // future version
            "{\"version\":1,\"entries\":[{\"sys\":\"zz\"}]}",        // bad fingerprint
            // Unknown kernel family must not be half-imported.
            "{\"version\":1,\"entries\":[{\"sys\":\"00\",\"obj\":\"00\",\
             \"kernels\":[{\"tag\":\"conv\",\"dims\":[1,1,1,1],\"density\":0}],\
             \"plan\":[{\"first\":0,\"last\":0,\"dev\":\"G\",\"n\":1}]}]}",
            // Plan with a gap.
            "{\"version\":1,\"entries\":[{\"sys\":\"00\",\"obj\":\"00\",\
             \"kernels\":[{\"tag\":\"gemm\",\"dims\":[1,1,1,0],\"density\":0}],\
             \"plan\":[{\"first\":0,\"last\":0,\"dev\":\"G\",\"n\":1},\
                       {\"first\":2,\"last\":3,\"dev\":\"F\",\"n\":1}]}]}",
        ] {
            std::fs::write(&path, bad).unwrap();
            assert!(ScheduleCache::load_from(&path, 8).is_err(), "accepted: {bad}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn accumulate_sums_counters() {
        let mut a = CacheStats { hits: 1, misses: 2, ..CacheStats::default() };
        a.accumulate(&CacheStats {
            hits: 3,
            misses: 1,
            evictions: 2,
            invalidations: 1,
            prewarm_hits: 4,
            prewarm_misses: 1,
        });
        assert_eq!(
            a,
            CacheStats {
                hits: 4,
                misses: 3,
                evictions: 2,
                invalidations: 1,
                prewarm_hits: 4,
                prewarm_misses: 1,
            }
        );
    }

    #[test]
    fn fit_plan_shrinks_to_inventory_largest_stage_first() {
        let plan = vec![
            StagePlan { first: 0, last: 0, dev: DeviceType::Fpga, n: 3 },
            StagePlan { first: 1, last: 2, dev: DeviceType::Gpu, n: 2 },
            StagePlan { first: 3, last: 3, dev: DeviceType::Fpga, n: 1 },
        ];
        // Plenty of room: the plan transfers unchanged.
        assert_eq!(fit_plan(&plan, 4, 2).unwrap(), plan);
        // 2 FPGAs for two FPGA stages: the 3-wide stage shrinks to 1.
        let shrunk = fit_plan(&plan, 2, 1).unwrap();
        assert_eq!(shrunk[0].n, 1, "widest FPGA stage shrinks first");
        assert_eq!(shrunk[1].n, 1);
        assert_eq!(shrunk[2].n, 1);
        // Grouping and device types are preserved exactly.
        for (a, b) in shrunk.iter().zip(&plan) {
            assert_eq!((a.first, a.last, a.dev), (b.first, b.last, b.dev));
        }
        // One FPGA cannot host two pipelined FPGA stages: no re-fit.
        assert!(fit_plan(&plan, 1, 2).is_none());
        assert!(fit_plan(&plan, 2, 0).is_none(), "a GPU stage needs a GPU");
    }

    #[test]
    fn prewarm_rekeys_plans_onto_the_new_partition() {
        let old = SystemSpec { n_fpga: 2, n_gpu: 1, ..sys() };
        let new = SystemSpec { n_fpga: 1, n_gpu: 1, ..sys() };
        let (old_fp, new_fp) = (system_fingerprint(&old), system_fingerprint(&new));
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let key = CacheKey::new(old_fp, &wl, Objective::Performance);
        let wide = vec![
            StagePlan { first: 0, last: 1, dev: DeviceType::Fpga, n: 2 },
            StagePlan { first: 2, last: 3, dev: DeviceType::Gpu, n: 1 },
        ];
        let mut cache = ScheduleCache::new(8);
        cache.insert(key.clone(), wide);

        let r = cache.prewarm(old_fp, new_fp, new.n_fpga, new.n_gpu);
        assert_eq!(r, PrewarmReport { hits: 1, misses: 0 });

        // The prospective key hits, with the plan re-fitted to 1F1G…
        let new_key = CacheKey::new(new_fp, &wl, Objective::Performance);
        let fitted = cache.lookup(&new_key).expect("prewarmed entry");
        assert_eq!(fitted[0].n, 1, "FPGA stage re-fitted to the new inventory");
        // …and the old key is copied, not moved.
        assert!(cache.lookup(&key).is_some(), "source entries survive a prewarm");
        let st = cache.stats();
        assert_eq!((st.prewarm_hits, st.prewarm_misses), (1, 0));

        // Prewarming again finds the target already warm: still a hit,
        // no churn.
        let again = cache.prewarm(old_fp, new_fp, new.n_fpga, new.n_gpu);
        assert_eq!(again, PrewarmReport { hits: 1, misses: 0 });
        // A same-fingerprint prewarm is a no-op.
        assert_eq!(cache.prewarm(old_fp, old_fp, 2, 1), PrewarmReport::default());
    }

    #[test]
    fn widen_plan_claims_surplus_narrowest_stage_first() {
        let mut plan = vec![
            StagePlan { first: 0, last: 0, dev: DeviceType::Fpga, n: 2 },
            StagePlan { first: 1, last: 2, dev: DeviceType::Gpu, n: 1 },
            StagePlan { first: 3, last: 3, dev: DeviceType::Fpga, n: 1 },
        ];
        widen_plan(&mut plan, 5, 2);
        // 2 surplus FPGAs: the narrower stage (n=1) catches up first,
        // then the earlier of the now-equal stages takes the last one.
        assert_eq!(plan[0].n, 3);
        assert_eq!(plan[2].n, 2);
        assert_eq!(plan[1].n, 2, "the sole GPU stage takes the whole surplus");
        // No surplus → no change; a type with no stage gains none.
        let mut gpu_only = vec![StagePlan { first: 0, last: 3, dev: DeviceType::Gpu, n: 1 }];
        widen_plan(&mut gpu_only, 3, 1);
        assert_eq!(gpu_only[0].n, 1, "cannot invent FPGA stages");
    }

    #[test]
    fn prewarm_widens_onto_a_grown_partition_except_for_energy_plans() {
        let small = SystemSpec { n_fpga: 1, n_gpu: 1, ..sys() };
        let grown = sys(); // 3F + 2G
        let (small_fp, grown_fp) = (system_fingerprint(&small), system_fingerprint(&grown));
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let narrow = vec![
            StagePlan { first: 0, last: 1, dev: DeviceType::Fpga, n: 1 },
            StagePlan { first: 2, last: 3, dev: DeviceType::Gpu, n: 1 },
        ];
        let mut cache = ScheduleCache::new(8);
        cache.insert(CacheKey::new(small_fp, &wl, Objective::Performance), narrow.clone());
        cache.insert(CacheKey::new(small_fp, &wl, Objective::Energy), narrow.clone());

        let r = cache.prewarm(small_fp, grown_fp, grown.n_fpga, grown.n_gpu);
        assert_eq!(r, PrewarmReport { hits: 2, misses: 0 });

        // The performance plan claims the whole grown inventory…
        let perf = cache
            .lookup(&CacheKey::new(grown_fp, &wl, Objective::Performance))
            .expect("prewarmed");
        assert_eq!((perf[0].n, perf[1].n), (3, 2), "surplus must not strand: {perf:?}");
        // …the energy plan keeps its deliberate narrow allocation.
        let eng =
            cache.lookup(&CacheKey::new(grown_fp, &wl, Objective::Energy)).expect("prewarmed");
        assert_eq!(eng, narrow, "energy plans are never widened");
    }

    #[test]
    fn prewarm_only_counts_entries_that_survive_capacity() {
        // Tight cache: 3 slots, an already-warm target at the LRU front
        // plus two old-fp regimes. Prewarming must (a) refresh the
        // already-warm target so the batch's own insert evicts a source
        // entry instead of the plan it is vouching for, and (b) report
        // only actually-resident plans as hits.
        let old = SystemSpec { n_fpga: 2, n_gpu: 1, ..sys() };
        let new = SystemSpec { n_fpga: 1, n_gpu: 1, ..sys() };
        let (old_fp, new_fp) = (system_fingerprint(&old), system_fingerprint(&new));
        let r1 = gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, 2_000_000, 200, 0.2), 2, 128);
        let r2 =
            gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, 150_000_000, 200, 0.2), 2, 128);
        let mut cache = ScheduleCache::new(3);
        // Oldest first: the already-warm target, then the two sources.
        cache.insert(CacheKey::new(new_fp, &r1, Objective::Performance), plan());
        cache.insert(CacheKey::new(old_fp, &r1, Objective::Performance), plan());
        cache.insert(CacheKey::new(old_fp, &r2, Objective::Performance), plan());

        let r = cache.prewarm(old_fp, new_fp, new.n_fpga, new.n_gpu);
        assert_eq!(r, PrewarmReport { hits: 2, misses: 0 }, "both regimes end up warm");
        for wl in [&r1, &r2] {
            assert!(
                cache.lookup(&CacheKey::new(new_fp, wl, Objective::Performance)).is_some(),
                "every reported hit must actually be resident"
            );
        }
    }

    #[test]
    fn prewarm_counts_unfittable_plans_as_misses() {
        let old = sys(); // 3F + 2G
        let new = SystemSpec { n_fpga: 1, n_gpu: 0, ..sys() };
        let (old_fp, new_fp) = (system_fingerprint(&old), system_fingerprint(&new));
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let mut cache = ScheduleCache::new(8);
        // A GPU stage cannot re-fit onto a 1F+0G partition.
        cache.insert(
            CacheKey::new(old_fp, &wl, Objective::Performance),
            vec![StagePlan { first: 0, last: 3, dev: DeviceType::Gpu, n: 1 }],
        );
        let r = cache.prewarm(old_fp, new_fp, new.n_fpga, new.n_gpu);
        assert_eq!(r, PrewarmReport { hits: 0, misses: 1 });
        assert!(cache.lookup(&CacheKey::new(new_fp, &wl, Objective::Performance)).is_none());
        assert_eq!(cache.stats().prewarm_misses, 1);
    }

    #[test]
    fn affinity_matches_shape_and_objective_across_fingerprints() {
        let a = sys();
        let b = SystemSpec { n_fpga: 1, n_gpu: 1, ..sys() };
        let (fp_a, fp_b) = (system_fingerprint(&a), system_fingerprint(&b));
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let other = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
        let mut cache = ScheduleCache::new(8);
        cache.insert(CacheKey::new(fp_a, &wl, Objective::Performance), plan());
        cache.insert(CacheKey::new(fp_b, &wl, Objective::Performance), plan());
        cache.insert(CacheKey::new(fp_a, &other, Objective::Performance), plan());

        // Both fingerprints of the same regime count; any probe
        // fingerprint sees them.
        let probe = CacheKey::new(system_fingerprint(&b), &wl, Objective::Performance);
        assert_eq!(cache.affinity(&probe), 2, "system half of the key is ignored");
        // A different objective is a different plan family: no affinity.
        let cold = CacheKey::new(fp_a, &wl, Objective::Energy);
        assert_eq!(cache.affinity(&cold), 0);
        // Probing is not traffic: counters and recency untouched.
        assert_eq!(cache.stats().lookups(), 0);
    }

    #[test]
    fn copy_fingerprint_into_carries_entries_for_a_cross_cache_prewarm() {
        let old = SystemSpec { n_fpga: 2, n_gpu: 1, ..sys() };
        let new = SystemSpec { n_fpga: 1, n_gpu: 1, ..sys() };
        let (old_fp, new_fp) = (system_fingerprint(&old), system_fingerprint(&new));
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let key = CacheKey::new(old_fp, &wl, Objective::Performance);
        let mut src = ScheduleCache::new(8);
        src.insert(key.clone(), plan());
        // An unrelated fingerprint must not travel.
        src.insert(CacheKey::new(7, &wl, Objective::Performance), plan());

        let mut dst = ScheduleCache::new(8);
        assert_eq!(src.copy_fingerprint_into(&mut dst, old_fp), 1);
        assert_eq!(dst.len(), 1, "only the requested fingerprint crosses");
        // The migration leg: re-key inside the destination cache.
        let r = dst.prewarm(old_fp, new_fp, new.n_fpga, new.n_gpu);
        assert_eq!(r, PrewarmReport { hits: 1, misses: 0 });
        assert!(dst.lookup(&CacheKey::new(new_fp, &wl, Objective::Performance)).is_some());
        // The source cache was never mutated.
        assert_eq!(src.len(), 2);
        assert_eq!(src.stats().prewarm_hits, 0);
        // Copying again is idempotent: already-present keys are skipped.
        assert_eq!(src.copy_fingerprint_into(&mut dst, old_fp), 0);
    }

    #[test]
    fn objective_fingerprints_distinguish_parameters() {
        assert_ne!(
            objective_fingerprint(Objective::Balanced { min_throughput_frac: 0.7 }),
            objective_fingerprint(Objective::Balanced { min_throughput_frac: 0.9 }),
        );
        assert_ne!(
            objective_fingerprint(Objective::Performance),
            objective_fingerprint(Objective::Energy),
        );
    }
}
