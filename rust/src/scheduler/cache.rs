//! Schedule cache — memoized Algorithm-1 results keyed by quantized
//! workload characteristics (DESIGN.md §Perf).
//!
//! Rescheduling sits on the serving path: every time the coordinator sees
//! drifted input characteristics it runs the full DP, which is the
//! dominant latency of a reschedule (milliseconds for deep workloads —
//! see `benches/scheduler_cache.rs`). But drift *recurs*: rush-hour
//! traffic looks like yesterday's rush hour, a sliding-window service
//! cycles through the same few sequence-length regimes. The cache
//! exploits that by memoizing the *structure* of past DP decisions —
//! the [`StagePlan`] vector — keyed by
//! [`crate::perfmodel::features::kernel_bucket`]'s quantized
//! sparsity/shape buckets, the objective, and a fingerprint of the
//! [`SystemSpec`].
//!
//! On a hit the caller re-times the cached plan under the current
//! estimator ([`crate::scheduler::evaluate_plan`], O(stages·kernels))
//! instead of re-running the DP (O(|wl|²·F·G·(F+G))). Timings therefore
//! always reflect the *actual* observed characteristics; only the
//! grouping/allocation decision is reused. Because the key contains every
//! kernel's family tag in order, a cached plan is always structurally
//! valid for the workload that hits it.
//!
//! Capacity is bounded with LRU eviction, and keys embed the system
//! fingerprint, so changing the device inventory (or handing a stream a
//! different partition of it) can never resurrect a stale plan.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::config::{Objective, SystemSpec};
use crate::perfmodel::{kernel_bucket, KernelBucket};
use crate::workload::Workload;

use super::pipeline_def::StagePlan;

/// A schedule-cache key: system fingerprint × objective × the quantized
/// per-kernel characteristic buckets, in chain order.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    sys_fp: u64,
    obj_fp: u64,
    kernels: Vec<KernelBucket>,
}

impl CacheKey {
    /// Build the key for scheduling `wl` under `objective` on the system
    /// identified by `sys_fp` (see [`system_fingerprint`]).
    pub fn new(sys_fp: u64, wl: &Workload, objective: Objective) -> CacheKey {
        CacheKey {
            sys_fp,
            obj_fp: objective_fingerprint(objective),
            kernels: wl.kernels.iter().map(|k| kernel_bucket(&k.kind)).collect(),
        }
    }
}

/// FNV-1a over a byte stream — the in-tree stand-in for a hashing crate.
fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Fingerprint of everything about a [`SystemSpec`] that can change a
/// schedule: inventory, interconnect generation, and every device
/// parameter. Two specs with equal fingerprints produce identical DP
/// inputs, so cached plans transfer between them.
pub fn system_fingerprint(sys: &SystemSpec) -> u64 {
    let mut bytes: Vec<u8> = Vec::with_capacity(200);
    bytes.extend((sys.n_fpga as u64).to_le_bytes());
    bytes.extend((sys.n_gpu as u64).to_le_bytes());
    bytes.push(match sys.interconnect {
        crate::devices::Interconnect::Pcie4 => 0,
        crate::devices::Interconnect::Pcie5 => 1,
        crate::devices::Interconnect::Cxl3 => 2,
    });
    let g = &sys.gpu;
    for v in [
        g.peak_flops,
        g.mem_bw,
        g.launch_overhead,
        g.dynamic_power,
        g.static_power,
        g.transfer_power,
        g.pcie_bw,
    ] {
        bytes.extend(v.to_bits().to_le_bytes());
    }
    let f = &sys.fpga;
    for v in [
        f.spmm_freq,
        f.spmm_macs,
        f.attn_freq,
        f.attn_t_pipeline,
        f.attn_t_init,
        f.gemm_peak_flops,
        f.mem_bw,
        f.launch_overhead,
        f.spmm_dynamic_power,
        f.attn_dynamic_power,
        f.static_power,
        f.transfer_power,
        f.pcie_bw,
    ] {
        bytes.extend(v.to_bits().to_le_bytes());
    }
    fnv1a(bytes)
}

/// Fingerprint of an [`Objective`], including its numeric parameters.
pub fn objective_fingerprint(obj: Objective) -> u64 {
    let (disc, param) = match obj {
        Objective::Performance => (0u8, 0u64),
        Objective::Energy => (1, 0),
        Objective::Balanced { min_throughput_frac } => (2, min_throughput_frac.to_bits()),
        Objective::QoS { min_throughput } => (3, min_throughput.to_bits()),
    };
    fnv1a(std::iter::once(disc).chain(param.to_le_bytes()))
}

/// Running hit/miss/eviction counters, cheap to copy into reports.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries dropped by explicit invalidation ([`ScheduleCache::clear`]).
    pub invalidations: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction over all lookups so far (0.0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// Counter-wise difference vs an earlier snapshot (per-stream
    /// attribution in the multi-stream server).
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            invalidations: self.invalidations - earlier.invalidations,
        }
    }
}

impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{} hits ({:.1}%), {} evictions",
            self.hits,
            self.lookups(),
            self.hit_rate() * 100.0,
            self.evictions
        )
    }
}

/// The memoization store: quantized key → frozen [`StagePlan`] vector,
/// LRU-bounded. See the module docs for the retiming contract.
#[derive(Debug)]
pub struct ScheduleCache {
    capacity: usize,
    entries: HashMap<CacheKey, Vec<StagePlan>>,
    /// Recency order, most recent at the back. Touched on hit and insert.
    lru: VecDeque<CacheKey>,
    stats: CacheStats,
}

/// Thread-shared handle used by coordinators serving concurrent streams.
pub type SharedScheduleCache = Arc<Mutex<ScheduleCache>>;

impl ScheduleCache {
    /// A cache holding at most `capacity` distinct quantized schedules.
    pub fn new(capacity: usize) -> ScheduleCache {
        assert!(capacity >= 1, "zero-capacity cache");
        ScheduleCache {
            capacity,
            entries: HashMap::new(),
            lru: VecDeque::new(),
            stats: CacheStats::default(),
        }
    }

    /// A shareable cache for multi-stream serving.
    pub fn shared(capacity: usize) -> SharedScheduleCache {
        Arc::new(Mutex::new(ScheduleCache::new(capacity)))
    }

    /// Look up the plan for `key`, counting a hit or miss.
    pub fn lookup(&mut self, key: &CacheKey) -> Option<Vec<StagePlan>> {
        let hit = self.entries.get(key).cloned();
        match hit {
            Some(plan) => {
                self.stats.hits += 1;
                self.touch(key);
                Some(plan)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Memoize a freshly-computed plan, evicting the least-recently-used
    /// entry when full.
    pub fn insert(&mut self, key: CacheKey, plan: Vec<StagePlan>) {
        if self.entries.insert(key.clone(), plan).is_none() {
            self.lru.push_back(key);
        } else {
            self.touch(&key);
        }
        while self.entries.len() > self.capacity {
            if let Some(old) = self.lru.pop_front() {
                self.entries.remove(&old);
                self.stats.evictions += 1;
            }
        }
    }

    fn touch(&mut self, key: &CacheKey) {
        if let Some(pos) = self.lru.iter().position(|k| k == key) {
            let k = self.lru.remove(pos).unwrap();
            self.lru.push_back(k);
        }
    }

    /// Drop every entry (e.g. after a device-parameter recalibration whose
    /// fingerprint the caller does not thread through keys).
    pub fn clear(&mut self) {
        self.stats.invalidations += self.entries.len() as u64;
        self.entries.clear();
        self.lru.clear();
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{DeviceType, Interconnect};
    use crate::workload::{gnn, Dataset};

    fn plan() -> Vec<StagePlan> {
        vec![StagePlan { first: 0, last: 3, dev: DeviceType::Gpu, n: 1 }]
    }

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4)
    }

    #[test]
    fn hit_within_bucket_miss_across_boundary() {
        let s = sys();
        let fp = system_fingerprint(&s);
        let mut cache = ScheduleCache::new(8);

        let base = gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, 2_000_000, 200, 0.2), 2, 128);
        let drift = gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, 2_040_000, 200, 0.2), 2, 128);
        let rush = gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, 150_000_000, 200, 0.2), 2, 128);

        let k_base = CacheKey::new(fp, &base, Objective::Performance);
        assert!(cache.lookup(&k_base).is_none());
        cache.insert(k_base, plan());

        // ~2% drift quantizes to the same key → hit.
        let k_drift = CacheKey::new(fp, &drift, Objective::Performance);
        assert!(cache.lookup(&k_drift).is_some());

        // 75× drift crosses bucket boundaries → miss.
        let k_rush = CacheKey::new(fp, &rush, Objective::Performance);
        assert!(cache.lookup(&k_rush).is_none());

        // Same characteristics, different objective → miss.
        let k_energy = CacheKey::new(fp, &drift, Objective::Energy);
        assert!(cache.lookup(&k_energy).is_none());

        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (1, 3));
        assert!((st.hit_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn system_change_invalidates_by_fingerprint() {
        let a = sys();
        let mut b = sys();
        b.n_gpu = 1; // shrink the inventory
        let mut c = sys();
        c.gpu.peak_flops *= 2.0; // same inventory, different silicon

        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let mut cache = ScheduleCache::new(8);
        cache.insert(CacheKey::new(system_fingerprint(&a), &wl, Objective::Performance), plan());

        for other in [&b, &c] {
            let k = CacheKey::new(system_fingerprint(other), &wl, Objective::Performance);
            assert!(cache.lookup(&k).is_none(), "changed SystemSpec must miss");
        }
        let k_same = CacheKey::new(system_fingerprint(&a), &wl, Objective::Performance);
        assert!(cache.lookup(&k_same).is_some());
    }

    #[test]
    fn lru_eviction_keeps_recent_entries() {
        let s = sys();
        let fp = system_fingerprint(&s);
        let mut cache = ScheduleCache::new(2);
        let wls: Vec<_> = [1u64, 9, 70]
            .iter()
            .map(|m| {
                gnn::gcn_workload(
                    &Dataset::new("T", "t", 1_000_000, m * 1_000_000, 200, 0.2),
                    2,
                    128,
                )
            })
            .collect();
        let keys: Vec<_> =
            wls.iter().map(|w| CacheKey::new(fp, w, Objective::Performance)).collect();
        cache.insert(keys[0].clone(), plan());
        cache.insert(keys[1].clone(), plan());
        assert!(cache.lookup(&keys[0]).is_some()); // refresh 0 → 1 is LRU
        cache.insert(keys[2].clone(), plan());
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup(&keys[1]).is_none(), "LRU entry evicted");
        assert!(cache.lookup(&keys[0]).is_some());
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn clear_counts_invalidations() {
        let s = sys();
        let fp = system_fingerprint(&s);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let mut cache = ScheduleCache::new(4);
        cache.insert(CacheKey::new(fp, &wl, Objective::Performance), plan());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats().invalidations, 1);
    }

    #[test]
    fn objective_fingerprints_distinguish_parameters() {
        assert_ne!(
            objective_fingerprint(Objective::Balanced { min_throughput_frac: 0.7 }),
            objective_fingerprint(Objective::Balanced { min_throughput_frac: 0.9 }),
        );
        assert_ne!(
            objective_fingerprint(Objective::Performance),
            objective_fingerprint(Objective::Energy),
        );
    }
}
