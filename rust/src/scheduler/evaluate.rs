//! Re-time a fixed pipeline structure under any `f_perf` source.
//!
//! Used by (a) the static / FleetRec* baselines, which freeze a structure
//! and apply it to new inputs, and (b) the pipeline simulator, which
//! re-measures DYPE's schedules under ground truth (the paper's
//! "applying the schedule on our hardware build").

use crate::devices::{CommModel, Endpoint};
use crate::perfmodel::PerfEstimator;
use crate::workload::{KernelKind, Workload};

use super::energy::{stage_activity_energy, PowerTable};
use super::pipeline_def::{Schedule, Stage, StagePlan};

/// Scratch buffers for [`evaluate_plan_into`]: the per-stage kind and
/// kernel-time vectors, which hold their capacity across calls so
/// steady-state re-timing allocates nothing.
#[derive(Debug, Default)]
pub struct EvalScratch {
    kinds: Vec<KernelKind>,
    kernel_times: Vec<(KernelKind, f64)>,
}

/// Build a fully-timed [`Schedule`] for `plan` over `wl`, with execution
/// times from `est` and transfers from `comm`.
pub fn evaluate_plan<E: PerfEstimator>(
    wl: &Workload,
    plan: &[StagePlan],
    est: &E,
    comm: &CommModel,
    power: &PowerTable,
) -> Schedule {
    let mut scratch = EvalScratch::default();
    let mut out = Schedule::default();
    evaluate_plan_into(wl, plan, est, comm, power, &mut scratch, &mut out);
    out
}

/// [`evaluate_plan`] into caller-owned storage: `out` is cleared and
/// refilled in place (its stage vector and workload-name string keep
/// their capacity) and the per-stage buffers live in `scratch`, so a
/// caller that re-times schedules repeatedly — the serving engine's
/// dispatch path does, once per admitted batch — allocates nothing at
/// steady state.
pub fn evaluate_plan_into<E: PerfEstimator>(
    wl: &Workload,
    plan: &[StagePlan],
    est: &E,
    comm: &CommModel,
    power: &PowerTable,
    scratch: &mut EvalScratch,
    out: &mut Schedule,
) {
    assert!(!plan.is_empty(), "empty plan");
    assert_eq!(plan[0].first, 0, "plan must start at kernel 0");
    assert_eq!(plan.last().unwrap().last + 1, wl.len(), "plan must cover the workload");

    out.workload.clear();
    out.workload.push_str(&wl.name);
    out.stages.clear();
    for (idx, p) in plan.iter().enumerate() {
        scratch.kinds.clear();
        scratch.kinds.extend(wl.kernels[p.first..=p.last].iter().map(|k| k.kind));
        let exec = est.stage_time(&scratch.kinds, p.dev, p.n);
        let bytes = wl.transfer_bytes_into(p.first);
        let src = if idx == 0 {
            Endpoint::Host
        } else {
            let prev = &plan[idx - 1];
            Endpoint::Devices(prev.dev, prev.n)
        };
        let t_comm = comm.transfer_time(bytes, src, Endpoint::Devices(p.dev, p.n));
        if idx > 0 {
            out.stages[idx - 1].comm_out_time = t_comm;
        }
        out.stages.push(Stage {
            first: p.first,
            last: p.last,
            dev: p.dev,
            n: p.n,
            exec_time: exec,
            comm_in_time: t_comm,
            comm_out_time: 0.0,
        });
    }

    out.period = out.stages.iter().map(Stage::total_time).fold(0.0f64, f64::max);

    // Energy account (see `energy.rs`).
    let mut activity = 0.0;
    let mut static_weight = 0.0;
    for s in &out.stages {
        scratch.kernel_times.clear();
        scratch.kernel_times.extend(
            wl.kernels[s.first..=s.last]
                .iter()
                .map(|k| (k.kind, est.stage_time(std::slice::from_ref(&k.kind), s.dev, s.n))),
        );
        activity += stage_activity_energy(
            power,
            s.dev,
            s.n,
            &scratch.kernel_times,
            s.comm_in_time,
            s.comm_out_time,
        );
        static_weight += s.n as f64 * power.static_power(s.dev);
    }
    out.energy_per_inf = activity + static_weight * out.period;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Objective, SystemSpec};
    use crate::devices::{DeviceType, GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::scheduler::dp::DpScheduler;
    use crate::workload::{gnn, Dataset};

    #[test]
    fn evaluating_a_dp_schedules_own_plan_reproduces_it() {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &g };
        let sched = DpScheduler::new(&s, &oracle);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let dp_out = sched.schedule(&wl, Objective::Performance);
        let re = evaluate_plan(&wl, &dp_out.plan(), &oracle, &sched.comm, &sched.power);
        assert!((re.period - dp_out.period).abs() < 1e-9 * dp_out.period);
        assert!((re.energy_per_inf - dp_out.energy_per_inf).abs() < 1e-6 * dp_out.energy_per_inf);
        assert_eq!(re.mnemonic(), dp_out.mnemonic());
    }

    #[test]
    fn plan_applied_to_different_dataset_retimes() {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &g };
        let power = crate::scheduler::energy::PowerTable::new(s.gpu.clone(), s.fpga.clone());
        let plan = vec![
            StagePlan { first: 0, last: 0, dev: DeviceType::Fpga, n: 3 },
            StagePlan { first: 1, last: 3, dev: DeviceType::Gpu, n: 2 },
        ];
        let wl_a = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let wl_b = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
        let a = evaluate_plan(&wl_a, &plan, &oracle, &s.comm_model(), &power);
        let b = evaluate_plan(&wl_b, &plan, &oracle, &s.comm_model(), &power);
        assert!(b.period > a.period, "S1 is far heavier than OA");
    }

    #[test]
    #[should_panic(expected = "cover the workload")]
    fn rejects_partial_plans() {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &g };
        let power = crate::scheduler::energy::PowerTable::new(s.gpu.clone(), s.fpga.clone());
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let plan = vec![StagePlan { first: 0, last: 1, dev: DeviceType::Gpu, n: 1 }];
        evaluate_plan(&wl, &plan, &oracle, &s.comm_model(), &power);
    }
}
