//! DYPE's scheduling core (§II): Algorithm 1's DP over pipeline
//! groupings × device allocations, the energy model `f_eng`, baselines,
//! Pareto analysis, and the exhaustive optimality reference.

pub mod baselines;
pub mod cache;
pub mod dp;
pub mod energy;
pub mod evaluate;
pub mod oracle;
pub mod pareto;
pub mod pipeline_def;

pub use cache::{
    system_fingerprint, CacheKey, CacheStats, PrewarmReport, ScheduleCache, SharedScheduleCache,
};
pub use dp::{DpScheduler, DpTables, FinalState, TableKind};
pub use energy::PowerTable;
pub use evaluate::{evaluate_plan, evaluate_plan_into, EvalScratch};
pub use oracle::ExhaustiveScheduler;
pub use pareto::{pareto_front, ParetoPoint};
pub use pipeline_def::{Schedule, Stage, StagePlan};
