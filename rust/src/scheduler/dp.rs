//! Algorithm 1 — DYPE's dynamic-programming scheduler.
//!
//! `dp[i][f][g]` is the best pipeline for the first `i` kernels using
//! *exactly* `f` FPGAs and `g` GPUs. Two tables are filled in one pass:
//! `dp_perf` minimizes the pipeline period (bottleneck stage time) and
//! `dp_eng` minimizes energy per inference. Transitions consider every
//! grouping of the trailing `j` kernels into a new stage executed by
//! `n_f` FPGAs or `n_g` GPUs (the paper's two strategies: multi-device
//! stages and multi-kernel stages).
//!
//! When a new stage is appended, the previous schedule's *last* stage
//! gains the outgoing transfer cost (`t_comm^src`, line 21) — entries
//! therefore store their bottleneck *excluding* the last stage's outgoing
//! cost, and the extension re-maximizes with it included (lines 22–23).
//!
//! Entries hold parent pointers instead of stage vectors; full schedules
//! are reconstructed only for the selected final states (see §Perf in
//! DESIGN.md).

use std::collections::HashMap;

use crate::config::{Objective, SystemSpec};
use crate::devices::{CommModel, DeviceType, Endpoint};
use crate::perfmodel::PerfEstimator;
use crate::workload::Workload;

use super::energy::PowerTable;
use super::pipeline_def::{Schedule, Stage};

/// Relative tolerance for "equal" objective values (tie-breaking).
const REL_EPS: f64 = 1e-9;

#[derive(Debug, Clone)]
struct Entry {
    /// Max stage total-time so far, with the last stage carrying no
    /// outgoing-transfer cost yet.
    bottleneck: f64,
    /// Σ stage activity energies (exec + transfer power terms), including
    /// every already-applied outgoing-transfer update.
    activity: f64,
    /// Σ over stages of `n · P_static` — multiplied by the final period to
    /// close the energy account.
    static_weight: f64,
    /// Cached objective energy: `activity + static_weight · bottleneck`.
    energy: f64,
    /// The last stage (comm_out still 0).
    last: Stage,
    /// Predecessor state `(i, f, g)`; `None` for the empty pipeline.
    parent: Option<(usize, usize, usize)>,
    /// Outgoing-transfer time added to the parent's last stage when this
    /// entry extended it (needed for reconstruction).
    prev_comm_out: f64,
}

/// Hot-path precomputation for one `tables()` run (see
/// `DpScheduler::precompute`).
struct Precomp {
    /// `[dev_idx·(max_dev+1)+count]` → prefix sums of per-kernel time.
    time_pref: Vec<Vec<f64>>,
    /// Same layout → prefix sums of per-kernel `P_dyn·t`.
    energy_pref: Vec<Vec<f64>>,
    /// Per device: `bad_before[j]` = 1 + last kernel index `< j` that the
    /// type pin forbids on this device (0 when none so far).
    bad_before: [Vec<usize>; 2],
    max_dev: usize,
}

impl Precomp {
    #[inline]
    fn dev_idx(dev: DeviceType) -> usize {
        match dev {
            DeviceType::Fpga => 0,
            DeviceType::Gpu => 1,
        }
    }

    #[inline]
    fn slot(&self, dev: DeviceType, n: usize) -> usize {
        Self::dev_idx(dev) * (self.max_dev + 1) + n
    }

    /// `f_perf` of kernels `[first, last]` on `n × dev` (exact prefix
    /// difference of the injected estimator's per-kernel times).
    #[inline]
    fn group_time(&self, dev: DeviceType, n: usize, first: usize, last: usize) -> f64 {
        let tp = &self.time_pref[self.slot(dev, n)];
        tp[last + 1] - tp[first]
    }

    /// Σ `P_dyn(kernel)·t_kernel` over the group (per single logical run;
    /// multiply by device count for stage energy).
    #[inline]
    fn group_exec_energy(&self, dev: DeviceType, n: usize, first: usize, last: usize) -> f64 {
        let ep = &self.energy_pref[self.slot(dev, n)];
        ep[last + 1] - ep[first]
    }

    #[inline]
    fn allowed(&self, dev: DeviceType, first: usize, last: usize) -> bool {
        self.bad_before[Self::dev_idx(dev)][last + 1] <= first
    }
}

/// Which DP table a final state was taken from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableKind {
    Perf,
    Eng,
}

/// The filled DP tables plus everything needed to reconstruct schedules
/// and enumerate the design space (Pareto analysis, mode selection).
pub struct DpTables {
    perf: Vec<Option<Entry>>,
    eng: Vec<Option<Entry>>,
    n_kernels: usize,
    n_fpga: usize,
    n_gpu: usize,
    workload: String,
}

/// A candidate final configuration: the complete-workload state for a
/// specific device budget, drawn from one of the two tables.
#[derive(Debug, Clone)]
pub struct FinalState {
    pub table: TableKind,
    pub n_fpga: usize,
    pub n_gpu: usize,
    pub period: f64,
    pub energy_per_inf: f64,
}

impl DpTables {
    #[inline]
    fn idx(&self, i: usize, f: usize, g: usize) -> usize {
        (i * (self.n_fpga + 1) + f) * (self.n_gpu + 1) + g
    }

    fn entry(&self, table: TableKind, i: usize, f: usize, g: usize) -> &Option<Entry> {
        let idx = self.idx(i, f, g);
        match table {
            TableKind::Perf => &self.perf[idx],
            TableKind::Eng => &self.eng[idx],
        }
    }

    /// All complete-workload states (both tables, every device budget).
    pub fn final_states(&self) -> Vec<FinalState> {
        let mut out = Vec::new();
        for table in [TableKind::Perf, TableKind::Eng] {
            for f in 0..=self.n_fpga {
                for g in 0..=self.n_gpu {
                    if let Some(e) = self.entry(table, self.n_kernels, f, g) {
                        out.push(FinalState {
                            table,
                            n_fpga: f,
                            n_gpu: g,
                            period: e.bottleneck,
                            energy_per_inf: e.energy,
                        });
                    }
                }
            }
        }
        out
    }

    /// Reconstruct the schedule for a final state.
    pub fn reconstruct(&self, fs: &FinalState) -> Schedule {
        let mut stages: Vec<Stage> = Vec::new();
        let mut cursor = Some((self.n_kernels, fs.n_fpga, fs.n_gpu));
        let mut pending_comm_out = 0.0;
        while let Some((i, f, g)) = cursor {
            if i == 0 {
                break;
            }
            let e = self.entry(fs.table, i, f, g).as_ref().expect("broken parent chain");
            let mut st = e.last.clone();
            st.comm_out_time = pending_comm_out;
            stages.push(st);
            pending_comm_out = e.prev_comm_out;
            cursor = e.parent;
        }
        stages.reverse();
        Schedule {
            workload: self.workload.clone(),
            stages,
            period: fs.period,
            energy_per_inf: fs.energy_per_inf,
        }
    }

    /// Min-energy state whose throughput clears `floor` (helper for the
    /// balanced and QoS modes).
    fn min_energy_above(&self, states: Vec<FinalState>, floor: f64) -> Option<FinalState> {
        states
            .into_iter()
            .filter(|s| 1.0 / s.period >= floor * (1.0 - REL_EPS))
            .min_by(|a, b| {
                (a.energy_per_inf, a.period)
                    .partial_cmp(&(b.energy_per_inf, b.period))
                    .unwrap()
            })
    }

    /// Highest achievable throughput across the whole design space.
    pub fn max_throughput(&self) -> f64 {
        self.final_states()
            .iter()
            .map(|s| 1.0 / s.period)
            .fold(0.0, f64::max)
    }

    /// Select the best final state for an objective (§VI-A modes):
    /// * Performance — min period (from either table);
    /// * Energy — min energy per inference;
    /// * Balanced — min energy subject to throughput ≥ frac · max.
    pub fn select(&self, objective: Objective) -> Option<FinalState> {
        let states = self.final_states();
        match objective {
            Objective::Performance => states.into_iter().min_by(|a, b| {
                (a.period, a.energy_per_inf)
                    .partial_cmp(&(b.period, b.energy_per_inf))
                    .unwrap()
            }),
            Objective::Energy => states.into_iter().min_by(|a, b| {
                (a.energy_per_inf, a.period)
                    .partial_cmp(&(b.energy_per_inf, b.period))
                    .unwrap()
            }),
            Objective::Balanced { min_throughput_frac } => {
                let max_thp = self.max_throughput();
                self.min_energy_above(states, max_thp * min_throughput_frac)
            }
            Objective::QoS { min_throughput } => {
                // Best effort: if the floor is unreachable, serve the
                // fastest schedule instead of failing the request path.
                let max_thp = self.max_throughput();
                let floor = min_throughput.min(max_thp);
                self.min_energy_above(states, floor)
            }
        }
    }
}

/// The DYPE scheduler (Algorithm 1) over an injected `f_perf` estimator —
/// either the trained §V models or the ground-truth oracle.
pub struct DpScheduler<'a, E: PerfEstimator> {
    pub est: &'a E,
    pub comm: CommModel,
    pub power: PowerTable,
    pub n_fpga: usize,
    pub n_gpu: usize,
    /// FleetRec*-style constraint: kernel tag → pinned device type
    /// (§VI-A: "applying design constraints to limit the fixed types of
    /// devices on specific kernels").
    pub type_pin: Option<HashMap<String, DeviceType>>,
}

impl<'a, E: PerfEstimator> DpScheduler<'a, E> {
    pub fn new(sys: &SystemSpec, est: &'a E) -> Self {
        DpScheduler {
            est,
            comm: sys.comm_model(),
            power: PowerTable::new(sys.gpu.clone(), sys.fpga.clone()),
            n_fpga: sys.n_fpga,
            n_gpu: sys.n_gpu,
            type_pin: None,
        }
    }

    /// Restrict each kernel tag to a fixed device type (FleetRec* mode).
    pub fn with_type_pin(mut self, pin: HashMap<String, DeviceType>) -> Self {
        self.type_pin = Some(pin);
        self
    }

    /// Precompute per-(device, count) prefix sums of kernel time and
    /// dynamic-power·time, plus pin-allowance prefixes (§Perf: turns the
    /// O(|wl|) per-transition group evaluation into O(1), taking the whole
    /// DP from O(|wl|³·F·G·(F+G)) to O(|wl|²·F·G·(F+G))). Exactness: both
    /// `ModelRegistry::stage_time` and `GroundTruth::group_time` are sums
    /// of per-kernel terms, so prefix differences reproduce them to
    /// rounding.
    fn precompute(&self, wl: &Workload) -> Precomp {
        let n = wl.len();
        let max_dev = self.n_fpga.max(self.n_gpu);
        let mut time_pref = vec![vec![]; 2 * (max_dev + 1)];
        let mut energy_pref = vec![vec![]; 2 * (max_dev + 1)];
        for (di, dev) in DeviceType::ALL.iter().enumerate() {
            let dev_max = match dev {
                DeviceType::Fpga => self.n_fpga,
                DeviceType::Gpu => self.n_gpu,
            };
            for cnt in 1..=dev_max {
                let mut tp = Vec::with_capacity(n + 1);
                let mut ep = Vec::with_capacity(n + 1);
                tp.push(0.0);
                ep.push(0.0);
                for k in &wl.kernels {
                    let t = self.est.stage_time(std::slice::from_ref(&k.kind), *dev, cnt);
                    tp.push(tp.last().unwrap() + t);
                    ep.push(ep.last().unwrap() + t * self.power.dynamic_power(&k.kind, *dev));
                }
                time_pref[di * (max_dev + 1) + cnt] = tp;
                energy_pref[di * (max_dev + 1) + cnt] = ep;
            }
        }
        // bad_before[di][j] = 1 + largest kernel index < j disallowed on
        // dev (0 when none): group [first, last] allowed iff
        // bad_before[last+1] <= first.
        let mut bad_before = [vec![0usize; n + 1], vec![0usize; n + 1]];
        for (di, dev) in DeviceType::ALL.iter().enumerate() {
            for j in 1..=n {
                let allowed = match &self.type_pin {
                    None => true,
                    Some(pin) => pin
                        .get(wl.kernels[j - 1].kind.tag())
                        .map_or(true, |&d| d == *dev),
                };
                bad_before[di][j] = if allowed { bad_before[di][j - 1] } else { j };
            }
        }
        Precomp { time_pref, energy_pref, bad_before, max_dev }
    }

    /// Fill both DP tables for `wl` (Algorithm 1 lines 1–41).
    pub fn tables(&self, wl: &Workload) -> DpTables {
        let n = wl.len();
        assert!(n > 0, "empty workload");
        let (nf, ng) = (self.n_fpga, self.n_gpu);
        let size = (n + 1) * (nf + 1) * (ng + 1);
        let mut tables = DpTables {
            perf: vec![None; size],
            eng: vec![None; size],
            n_kernels: n,
            n_fpga: nf,
            n_gpu: ng,
            workload: wl.name.clone(),
        };
        let origin = Entry {
            bottleneck: 0.0,
            activity: 0.0,
            static_weight: 0.0,
            energy: 0.0,
            last: Stage {
                first: 0,
                last: 0,
                dev: DeviceType::Gpu,
                n: 0,
                exec_time: 0.0,
                comm_in_time: 0.0,
                comm_out_time: 0.0,
            },
            parent: None,
            prev_comm_out: 0.0,
        };
        let o = tables.idx(0, 0, 0);
        tables.perf[o] = Some(origin.clone());
        tables.eng[o] = Some(origin);

        let pre = self.precompute(wl);
        for i in 1..=n {
            for f in 0..=nf {
                for g in 0..=ng {
                    self.relax_state(wl, &pre, &mut tables, i, f, g);
                }
            }
        }
        tables
    }

    /// Compute the best entries for state (i, f, g) in both tables.
    fn relax_state(
        &self,
        wl: &Workload,
        pre: &Precomp,
        tables: &mut DpTables,
        i: usize,
        f: usize,
        g: usize,
    ) {
        for j in 1..=i {
            let (first, last) = (i - j, i - 1);
            // New stage on FPGAs.
            if pre.allowed(DeviceType::Fpga, first, last) {
                for n_f in 1..=f {
                    self.try_extend(wl, pre, tables, i, f, g, j, DeviceType::Fpga, n_f, f - n_f, g);
                }
            }
            // New stage on GPUs.
            if pre.allowed(DeviceType::Gpu, first, last) {
                for n_g in 1..=g {
                    self.try_extend(wl, pre, tables, i, f, g, j, DeviceType::Gpu, n_g, f, g - n_g);
                }
            }
        }
    }

    /// Lines 10–33: extend `dp[i-j][pf][pg]` with a new stage of kernels
    /// `[i-j, i-1]` on `n × dev`, updating both tables.
    #[allow(clippy::too_many_arguments)]
    fn try_extend(
        &self,
        wl: &Workload,
        pre: &Precomp,
        tables: &mut DpTables,
        i: usize,
        f: usize,
        g: usize,
        j: usize,
        dev: DeviceType,
        n: usize,
        pf: usize,
        pg: usize,
    ) {
        let (first, last) = (i - j, i - 1);
        // f_perf of the new stage's kernel group (line 19, first term).
        let exec = pre.group_time(dev, n, first, last);
        // Bitstream-dependent execution energy of the group.
        let exec_energy = pre.group_exec_energy(dev, n, first, last);
        let bytes = wl.transfer_bytes_into(first);
        let static_w = n as f64 * self.power.static_power(dev);

        let target = tables.idx(i, f, g);
        let parent_idx = tables.idx(i - j, pf, pg);

        for table in [TableKind::Perf, TableKind::Eng] {
            let parent = match table {
                TableKind::Perf => tables.perf[parent_idx].as_ref(),
                TableKind::Eng => tables.eng[parent_idx].as_ref(),
            };
            let Some(parent) = parent else { continue };

            // Lines 11–17: incoming transfer from the previous schedule's
            // last stage (or host ingress for the first stage).
            let src = if first == 0 {
                Endpoint::Host
            } else {
                Endpoint::Devices(parent.last.dev, parent.last.n)
            };
            let t_comm = self.comm.transfer_time(bytes, src, Endpoint::Devices(dev, n));
            // Line 21: the source side is occupied for the same transfer
            // (none when the source is the host DMA engine).
            let t_comm_src = if first == 0 { 0.0 } else { t_comm };

            let new_stage = Stage {
                first,
                last,
                dev,
                n,
                exec_time: exec,
                comm_in_time: t_comm,
                comm_out_time: 0.0,
            };
            // Lines 22–23: new pipeline bottleneck.
            let prev_last_total = parent.last.total_time() + t_comm_src;
            let bottleneck = parent.bottleneck.max(prev_last_total).max(new_stage.total_time());

            // Energy account (f_eng, lines 29–30).
            let prev_xfer_energy = if first == 0 {
                0.0
            } else {
                parent.last.n as f64
                    * self.power.transfer_power(parent.last.dev)
                    * t_comm_src
            };
            let activity = parent.activity
                + prev_xfer_energy
                + n as f64 * (exec_energy + self.power.transfer_power(dev) * t_comm);
            let static_weight = parent.static_weight + static_w;
            let energy = activity + static_weight * bottleneck;

            let cand = Entry {
                bottleneck,
                activity,
                static_weight,
                energy,
                last: new_stage,
                parent: Some((i - j, pf, pg)),
                prev_comm_out: t_comm_src,
            };

            let slot = match table {
                TableKind::Perf => &mut tables.perf[target],
                TableKind::Eng => &mut tables.eng[target],
            };
            let better = match slot.as_ref() {
                None => true,
                Some(cur) => match table {
                    // Line 25: strictly better period wins; ties prefer
                    // lower energy.
                    TableKind::Perf => {
                        cand.bottleneck < cur.bottleneck * (1.0 - REL_EPS)
                            || (cand.bottleneck <= cur.bottleneck * (1.0 + REL_EPS)
                                && cand.energy < cur.energy)
                    }
                    // Line 31.
                    TableKind::Eng => {
                        cand.energy < cur.energy * (1.0 - REL_EPS)
                            || (cand.energy <= cur.energy * (1.0 + REL_EPS)
                                && cand.bottleneck < cur.bottleneck)
                    }
                },
            };
            if better {
                *slot = Some(cand);
            }
        }
    }

    /// Schedule `wl` under `objective`, or `None` when no feasible
    /// pipeline exists (empty inventory, or type pins that demand more
    /// alternating stages than the device budget allows).
    pub fn try_schedule(&self, wl: &Workload, objective: Objective) -> Option<Schedule> {
        let tables = self.tables(wl);
        let fs = tables.select(objective)?;
        Some(tables.reconstruct(&fs))
    }

    /// Schedule `wl` under `objective` (tables + selection + rebuild).
    pub fn schedule(&self, wl: &Workload, objective: Objective) -> Schedule {
        self.try_schedule(wl, objective)
            .expect("no feasible schedule: is the device inventory empty?")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::workload::{gnn, Dataset};

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4)
    }

    fn gt(s: &SystemSpec) -> GroundTruth {
        GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model())
    }

    #[test]
    fn schedules_are_valid_for_all_objectives() {
        let s = sys();
        let g = gt(&s);
        let oracle = OracleModels { gt: &g };
        let sched = DpScheduler::new(&s, &oracle);
        for ds in Dataset::table1() {
            let wl = gnn::gcn_workload(&ds, 2, 128);
            for obj in [Objective::Performance, Objective::Energy, Objective::balanced()] {
                let out = sched.schedule(&wl, obj);
                out.validate(wl.len(), s.n_fpga, s.n_gpu)
                    .unwrap_or_else(|e| panic!("{} {}: {e}", ds.code, obj.name()));
            }
        }
    }

    #[test]
    fn perf_mode_beats_or_matches_energy_mode_throughput() {
        let s = sys();
        let g = gt(&s);
        let oracle = OracleModels { gt: &g };
        let sched = DpScheduler::new(&s, &oracle);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let p = sched.schedule(&wl, Objective::Performance);
        let e = sched.schedule(&wl, Objective::Energy);
        assert!(p.throughput() >= e.throughput() * (1.0 - 1e-9));
        assert!(e.energy_per_inf <= p.energy_per_inf * (1.0 + 1e-9));
    }

    #[test]
    fn balanced_mode_respects_throughput_floor() {
        let s = sys();
        let g = gt(&s);
        let oracle = OracleModels { gt: &g };
        let sched = DpScheduler::new(&s, &oracle);
        for ds in Dataset::table1() {
            let wl = gnn::gin_workload(&ds, 2, 128, 2);
            let tables = sched.tables(&wl);
            let max_thp = tables.max_throughput();
            let b = tables.select(Objective::balanced()).unwrap();
            assert!(
                1.0 / b.period >= 0.7 * max_thp * (1.0 - 1e-6),
                "{}: balanced throughput below floor",
                ds.code
            );
        }
    }

    #[test]
    fn more_devices_never_hurt_throughput() {
        // The DP scans all budgets; a bigger inventory can only widen the
        // design space.
        let small = SystemSpec { n_fpga: 1, n_gpu: 1, ..sys() };
        let big = sys();
        let g_small = gt(&small);
        let g_big = gt(&big);
        let wl = gnn::gcn_workload(&Dataset::ogbn_products(), 2, 128);
        let thp_small = DpScheduler::new(&small, &OracleModels { gt: &g_small })
            .schedule(&wl, Objective::Performance)
            .throughput();
        let thp_big = DpScheduler::new(&big, &OracleModels { gt: &g_big })
            .schedule(&wl, Objective::Performance)
            .throughput();
        assert!(thp_big >= thp_small * (1.0 - 1e-9));
    }

    #[test]
    fn type_pin_is_respected() {
        let s = sys();
        let g = gt(&s);
        let oracle = OracleModels { gt: &g };
        let mut pin = HashMap::new();
        pin.insert("spmm".to_string(), DeviceType::Fpga);
        pin.insert("gemm".to_string(), DeviceType::Gpu);
        let sched = DpScheduler::new(&s, &oracle).with_type_pin(pin);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let out = sched.schedule(&wl, Objective::Performance);
        for st in &out.stages {
            for k in st.first..=st.last {
                let tag = wl.kernels[k].kind.tag();
                match tag {
                    "spmm" => assert_eq!(st.dev, DeviceType::Fpga),
                    "gemm" => assert_eq!(st.dev, DeviceType::Gpu),
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn gpu_only_system_uses_only_gpus() {
        let s = SystemSpec { n_fpga: 0, ..sys() };
        let g = gt(&s);
        let oracle = OracleModels { gt: &g };
        let wl = gnn::gcn_workload(&Dataset::synthetic2(), 2, 128);
        let out = DpScheduler::new(&s, &oracle).schedule(&wl, Objective::Performance);
        assert!(out.stages.iter().all(|st| st.dev == DeviceType::Gpu));
        assert_eq!(out.fpgas_used(), 0);
    }

    #[test]
    fn single_kernel_workload_single_stage() {
        let s = sys();
        let g = gt(&s);
        let oracle = OracleModels { gt: &g };
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 1, 128);
        let mut only_spmm = wl.clone();
        only_spmm.kernels.truncate(1);
        let out = DpScheduler::new(&s, &oracle).schedule(&only_spmm, Objective::Performance);
        assert_eq!(out.stages.len(), 1);
        assert!(out.validate(1, s.n_fpga, s.n_gpu).is_ok());
    }

    #[test]
    fn period_is_bottleneck_and_energy_consistent() {
        let s = sys();
        let g = gt(&s);
        let oracle = OracleModels { gt: &g };
        let wl = gnn::gin_workload(&Dataset::synthetic3(), 2, 128, 2);
        let out = DpScheduler::new(&s, &oracle).schedule(&wl, Objective::Performance);
        let bottleneck = out.stages.iter().map(Stage::total_time).fold(0.0f64, f64::max);
        assert!((out.period - bottleneck).abs() < 1e-12 * bottleneck.max(1e-12));
        assert!(out.energy_per_inf > 0.0);
    }

    #[test]
    #[should_panic(expected = "no feasible schedule")]
    fn empty_inventory_panics() {
        let s = SystemSpec { n_fpga: 0, n_gpu: 0, ..sys() };
        let g = gt(&s);
        let oracle = OracleModels { gt: &g };
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        DpScheduler::new(&s, &oracle).schedule(&wl, Objective::Performance);
    }
}
