//! Pipeline & schedule representation: the object Algorithm 1 builds.


use crate::devices::DeviceType;

/// One pipeline stage: a contiguous kernel group executed by `n` devices
/// of one type. Stage time = incoming transfer + execution + outgoing
/// transfer; the serialization of transfers with compute *is* the paper's
/// Fig-4 conflict-avoidance schedule (transfers never overlap compute or
/// each other on the stage's PCIe ports).
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    /// First kernel id (inclusive).
    pub first: usize,
    /// Last kernel id (inclusive).
    pub last: usize,
    pub dev: DeviceType,
    pub n: usize,
    /// `f_perf` of the kernel group on `n × dev` (s).
    pub exec_time: f64,
    /// Incoming data-transfer time (s) — `t_comm^dst` in Algorithm 1.
    pub comm_in_time: f64,
    /// Outgoing data-transfer time (s) — `t_comm^src`; 0 for the final stage.
    pub comm_out_time: f64,
}

impl Stage {
    /// The stage's occupancy per inference — its contribution to the
    /// pipeline period.
    pub fn total_time(&self) -> f64 {
        self.comm_in_time + self.exec_time + self.comm_out_time
    }

    pub fn kernel_count(&self) -> usize {
        self.last - self.first + 1
    }
}

/// The structural part of a stage — kernel range + device allocation,
/// without timing. Freezing a [`Schedule`] into plans and re-timing them
/// elsewhere is how static baselines and ground-truth re-measurement work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StagePlan {
    pub first: usize,
    pub last: usize,
    pub dev: DeviceType,
    pub n: usize,
}

/// A complete schedule for a workload on a system.
///
/// Derives `Default` (an empty, zero-period schedule) so re-timing
/// sinks like [`super::evaluate::evaluate_plan_into`] can be
/// constructed once and refilled in place — an empty `Schedule` is
/// never a *valid* schedule (see [`Schedule::validate`]), just a
/// buffer awaiting its first fill.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    pub workload: String,
    pub stages: Vec<Stage>,
    /// Pipeline period = bottleneck stage time (s). Steady-state
    /// throughput is `1 / period`.
    pub period: f64,
    /// Energy per inference (J) under the estimator that built this
    /// schedule (re-measure with the pipeline simulator for ground truth).
    pub energy_per_inf: f64,
}

impl Schedule {
    /// Steady-state throughput (inferences/s).
    pub fn throughput(&self) -> f64 {
        if self.period > 0.0 {
            1.0 / self.period
        } else {
            f64::INFINITY
        }
    }

    /// Energy efficiency (inferences/J) — the paper's `eng` metric.
    pub fn energy_efficiency(&self) -> f64 {
        if self.energy_per_inf > 0.0 {
            1.0 / self.energy_per_inf
        } else {
            f64::INFINITY
        }
    }

    /// End-to-end latency of one inference (sum of stage times).
    pub fn latency(&self) -> f64 {
        self.stages.iter().map(Stage::total_time).sum()
    }

    pub fn fpgas_used(&self) -> usize {
        self.stages.iter().filter(|s| s.dev == DeviceType::Fpga).map(|s| s.n).sum()
    }

    pub fn gpus_used(&self) -> usize {
        self.stages.iter().filter(|s| s.dev == DeviceType::Gpu).map(|s| s.n).sum()
    }

    /// Freeze the structure (drop timings) for re-evaluation elsewhere.
    pub fn plan(&self) -> Vec<StagePlan> {
        let mut out = Vec::with_capacity(self.stages.len());
        self.plan_into(&mut out);
        out
    }

    /// [`Schedule::plan`] into caller-owned storage (`out` is cleared
    /// first), reusing its capacity — the serving hot path freezes the
    /// installed structure once per batch through this.
    pub fn plan_into(&self, out: &mut Vec<StagePlan>) {
        out.clear();
        for s in &self.stages {
            out.push(StagePlan { first: s.first, last: s.last, dev: s.dev, n: s.n });
        }
    }

    /// The paper's schedule notation: `3F2G` = 3 FPGAs then 2 GPUs;
    /// `2F1G1F1G` = four stages alternating.
    pub fn mnemonic(&self) -> String {
        self.stages.iter().map(|s| format!("{}{}", s.n, s.dev.letter())).collect()
    }

    /// Structural validity: contiguous full kernel coverage, device counts
    /// within the installed inventory, positive stage times.
    pub fn validate(&self, n_kernels: usize, n_fpga: usize, n_gpu: usize) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("empty schedule".into());
        }
        if self.stages[0].first != 0 {
            return Err("first stage must start at kernel 0".into());
        }
        if self.stages.last().unwrap().last + 1 != n_kernels {
            return Err("last stage must end at the final kernel".into());
        }
        for w in self.stages.windows(2) {
            if w[1].first != w[0].last + 1 {
                return Err(format!(
                    "gap/overlap between stages at kernels {}..{}",
                    w[0].last, w[1].first
                ));
            }
        }
        if self.fpgas_used() > n_fpga {
            return Err(format!("uses {} FPGAs > {n_fpga} installed", self.fpgas_used()));
        }
        if self.gpus_used() > n_gpu {
            return Err(format!("uses {} GPUs > {n_gpu} installed", self.gpus_used()));
        }
        for s in &self.stages {
            if s.n == 0 {
                return Err("stage with zero devices".into());
            }
            if !(s.exec_time.is_finite() && s.exec_time > 0.0) {
                return Err(format!("non-positive exec time {:?}", s));
            }
        }
        let bottleneck = self.stages.iter().map(Stage::total_time).fold(0.0f64, f64::max);
        if (bottleneck - self.period).abs() > 1e-9 * bottleneck.max(1e-12) {
            return Err(format!("period {} != bottleneck stage {}", self.period, bottleneck));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(first: usize, last: usize, dev: DeviceType, n: usize, t: f64) -> Stage {
        Stage { first, last, dev, n, exec_time: t, comm_in_time: 0.0, comm_out_time: 0.0 }
    }

    fn sched(stages: Vec<Stage>) -> Schedule {
        let period = stages.iter().map(Stage::total_time).fold(0.0f64, f64::max);
        Schedule { workload: "t".into(), stages, period, energy_per_inf: 1.0 }
    }

    #[test]
    fn mnemonic_matches_paper_notation() {
        let s = sched(vec![
            stage(0, 0, DeviceType::Fpga, 3, 1e-3),
            stage(1, 3, DeviceType::Gpu, 2, 2e-3),
        ]);
        assert_eq!(s.mnemonic(), "3F2G");
        assert!(s.validate(4, 3, 2).is_ok());
    }

    #[test]
    fn four_stage_mnemonic() {
        let s = sched(vec![
            stage(0, 0, DeviceType::Fpga, 2, 1e-3),
            stage(1, 1, DeviceType::Gpu, 1, 1e-3),
            stage(2, 2, DeviceType::Fpga, 1, 1e-3),
            stage(3, 3, DeviceType::Gpu, 1, 1e-3),
        ]);
        assert_eq!(s.mnemonic(), "2F1G1F1G");
    }

    #[test]
    fn validate_catches_gaps_and_overuse() {
        let gap = sched(vec![
            stage(0, 0, DeviceType::Gpu, 1, 1e-3),
            stage(2, 3, DeviceType::Gpu, 1, 1e-3),
        ]);
        assert!(gap.validate(4, 3, 2).is_err());

        let overuse = sched(vec![stage(0, 3, DeviceType::Gpu, 5, 1e-3)]);
        assert!(overuse.validate(4, 3, 2).is_err());
    }

    #[test]
    fn throughput_is_inverse_period() {
        let s = sched(vec![stage(0, 1, DeviceType::Gpu, 1, 4e-3)]);
        assert!((s.throughput() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn latency_sums_stages_period_takes_max() {
        let s = sched(vec![
            stage(0, 0, DeviceType::Fpga, 1, 3e-3),
            stage(1, 1, DeviceType::Gpu, 1, 5e-3),
        ]);
        assert!((s.latency() - 8e-3).abs() < 1e-12);
        assert!((s.period - 5e-3).abs() < 1e-12);
    }
}
