//! Exhaustive schedule enumeration — the optimality reference for small
//! instances.
//!
//! Enumerates every (contiguous grouping × device allocation) pipeline and
//! evaluates each with the same `f_perf`/`f_comm`/`f_eng` machinery the DP
//! uses. Exponential in kernel count; intended for |wl| ≤ ~8 (the GNN
//! workloads) in tests and the Table III optimality audit.

use crate::config::{Objective, SystemSpec};
use crate::devices::DeviceType;
use crate::perfmodel::PerfEstimator;
use crate::workload::Workload;

use super::energy::PowerTable;
use super::evaluate::evaluate_plan;
use super::pipeline_def::{Schedule, StagePlan};

/// Enumerate all complete pipelines for `wl` on `sys` and return the best
/// under `objective` (plus the whole candidate set for audits).
pub struct ExhaustiveScheduler<'a, E: PerfEstimator> {
    pub sys: &'a SystemSpec,
    pub est: &'a E,
}

impl<'a, E: PerfEstimator> ExhaustiveScheduler<'a, E> {
    pub fn new(sys: &'a SystemSpec, est: &'a E) -> Self {
        ExhaustiveScheduler { sys, est }
    }

    /// All valid plans (every split of the chain × every allocation of
    /// remaining devices, one type per stage).
    pub fn enumerate_plans(&self, wl: &Workload) -> Vec<Vec<StagePlan>> {
        let mut out = Vec::new();
        let mut cur = Vec::new();
        self.recurse(wl, 0, self.sys.n_fpga, self.sys.n_gpu, &mut cur, &mut out);
        out
    }

    fn recurse(
        &self,
        wl: &Workload,
        next: usize,
        f_left: usize,
        g_left: usize,
        cur: &mut Vec<StagePlan>,
        out: &mut Vec<Vec<StagePlan>>,
    ) {
        if next == wl.len() {
            out.push(cur.clone());
            return;
        }
        for last in next..wl.len() {
            for n_f in 1..=f_left {
                cur.push(StagePlan { first: next, last, dev: DeviceType::Fpga, n: n_f });
                self.recurse(wl, last + 1, f_left - n_f, g_left, cur, out);
                cur.pop();
            }
            for n_g in 1..=g_left {
                cur.push(StagePlan { first: next, last, dev: DeviceType::Gpu, n: n_g });
                self.recurse(wl, last + 1, f_left, g_left - n_g, cur, out);
                cur.pop();
            }
        }
    }

    /// Evaluate every plan and return the best schedule for `objective`.
    pub fn best(&self, wl: &Workload, objective: Objective) -> Option<Schedule> {
        let power = PowerTable::new(self.sys.gpu.clone(), self.sys.fpga.clone());
        let comm = self.sys.comm_model();
        let mut schedules: Vec<Schedule> = self
            .enumerate_plans(wl)
            .iter()
            .map(|p| evaluate_plan(wl, p, self.est, &comm, &power))
            .collect();
        if schedules.is_empty() {
            return None;
        }
        match objective {
            Objective::Performance => schedules.into_iter().min_by(|a, b| {
                (a.period, a.energy_per_inf)
                    .partial_cmp(&(b.period, b.energy_per_inf))
                    .unwrap()
            }),
            Objective::Energy => schedules.into_iter().min_by(|a, b| {
                (a.energy_per_inf, a.period)
                    .partial_cmp(&(b.energy_per_inf, b.period))
                    .unwrap()
            }),
            Objective::Balanced { .. } | Objective::QoS { .. } => {
                let max_thp = schedules.iter().map(Schedule::throughput).fold(0.0, f64::max);
                let floor = match objective {
                    Objective::Balanced { min_throughput_frac } => max_thp * min_throughput_frac,
                    Objective::QoS { min_throughput } => min_throughput.min(max_thp),
                    _ => unreachable!(),
                };
                schedules.retain(|s| s.throughput() >= floor * (1.0 - 1e-9));
                schedules.into_iter().min_by(|a, b| {
                    (a.energy_per_inf, a.period)
                        .partial_cmp(&(b.energy_per_inf, b.period))
                        .unwrap()
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::scheduler::dp::DpScheduler;
    use crate::workload::{gnn, Dataset};

    fn setup() -> (SystemSpec, GroundTruth) {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        (s, g)
    }

    #[test]
    fn enumeration_count_small_case() {
        // 1 kernel, 3F+2G: plans = {1F,2F,3F,1G,2G} = 5.
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 1, 128);
        wl.kernels.truncate(1);
        let ex = ExhaustiveScheduler::new(&s, &oracle);
        assert_eq!(ex.enumerate_plans(&wl).len(), 5);
    }

    /// The DP explores the same space as exhaustive enumeration; its
    /// greedy per-state substitution can in principle lose a little, but
    /// on the paper's GNN workloads it must land within a few percent of
    /// the true optimum (and usually exactly on it).
    #[test]
    fn dp_matches_exhaustive_on_gnn_workloads() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        for ds in Dataset::table1() {
            for wl in [gnn::gcn_workload(&ds, 2, 128), gnn::gin_workload(&ds, 2, 128, 2)] {
                let dp = DpScheduler::new(&s, &oracle).schedule(&wl, Objective::Performance);
                let ex = ExhaustiveScheduler::new(&s, &oracle)
                    .best(&wl, Objective::Performance)
                    .unwrap();
                assert!(
                    dp.period <= ex.period * 1.02,
                    "{}: DP {} ({}) vs exhaustive {} ({})",
                    wl.name,
                    dp.period,
                    dp.mnemonic(),
                    ex.period,
                    ex.mnemonic()
                );
            }
        }
    }

    #[test]
    fn dp_matches_exhaustive_on_energy_objective() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        for ds in [Dataset::ogbn_arxiv(), Dataset::synthetic2(), Dataset::synthetic4()] {
            let wl = gnn::gcn_workload(&ds, 2, 128);
            let dp = DpScheduler::new(&s, &oracle).schedule(&wl, Objective::Energy);
            let ex = ExhaustiveScheduler::new(&s, &oracle).best(&wl, Objective::Energy).unwrap();
            assert!(
                dp.energy_per_inf <= ex.energy_per_inf * 1.02,
                "{}: DP {} vs exhaustive {}",
                ds.code,
                dp.energy_per_inf,
                ex.energy_per_inf
            );
        }
    }
}
