//! `dype` — CLI for the DYPE heterogeneous-scheduling framework.
//!
//! Subcommands:
//! * `schedule`  — run Algorithm 1 for a workload/system/objective, print
//!   the chosen pipeline (mnemonic, stages, throughput, energy).
//! * `pareto`    — dump the Pareto front of the design space.
//! * `calibrate` — train the §V performance models and print fit quality.
//! * `sweep`     — DYPE vs baselines across the paper's GNN workloads.
//! * `scenario-sweep` — the serving scenario zoo crossed with every
//!   serving policy (or one manifest from disk), Pareto-annotated;
//!   `--trace` re-runs the first scenario's winner with a timeline
//!   recorder and writes a Perfetto `trace_events` JSON.
//! * `fleet`     — serve one scenario manifest across a sharded engine
//!   fleet (SLO-aware routing, cache-affinity placement, cross-shard
//!   migration); `--out` writes the shard-namespaced Perfetto trace,
//!   `--cache-dir` persists per-shard schedule caches across runs.
//! * `lint`      — static feasibility and consistency analysis over
//!   scenario manifests, without running a single simulated event:
//!   deadline floors from the performance model, budget starvation, pool
//!   timelines under scripted cuts, SLO consistency, fleet shape; `--json`
//!   for machine-readable diagnostics, nonzero exit on error-severity
//!   findings. `scenario-sweep` and `fleet` run the same checks before
//!   building an engine.
//! * `trace-validate` — strict-parse a trace file and run the exporter's
//!   structural validator over it.
//! * `bench-report` — render the tracked perf baseline
//!   (`BENCH_serving.json`) and, given a fresh medians capture, the
//!   per-bench deltas the CI bench gate reasons about.
//! * `serve`     — end-to-end real execution: stream inferences through a
//!   scheduled pipeline running AOT artifacts via PJRT.
//!
//! File-handling flags are uniform across subcommands: `--manifest` for
//! scenario inputs, `--trace` for trace files, `--out` for written
//! outputs. Every subcommand answers `--help` with its own usage.
//!
//! (Argument parsing is hand-rolled: the offline build has no clap.)

use anyhow::{bail, Result};

use dype::config::{Interconnect, Objective, SystemSpec};
use dype::coordinator::Coordinator;
use dype::devices::GroundTruth;
use dype::metrics::{fmt_ratio, Table};
use dype::perfmodel::{calibrate, OracleModels};
use dype::pipeline::PipelineSim;
use dype::scheduler::{baselines, pareto_front, DpScheduler, PowerTable};
use dype::util::Rng;
use dype::workload::{gnn, transformer, Dataset, Workload};

const USAGE: &str = "\
dype — data-aware dynamic execution on heterogeneous systems

USAGE:
  dype schedule  [--workload W] [--interconnect I] [--objective O]
                 [--fpgas N] [--gpus N] [--oracle]
  dype pareto    [--workload W] [--interconnect I]
  dype calibrate [--interconnect I]
  dype sweep     [--interconnect I] [--objective O]
  dype scenario-sweep [--manifest FILE.json] [--out TRACE.json]
  dype fleet     [--manifest FILE.json] [--shards N] [--out TRACE.json]
                 [--cache-dir DIR]
  dype lint      [--manifest FILE.json | --all] [--json]
  dype trace-validate [--trace] FILE.json
  dype bench-report   [--baseline FILE.json] [--fresh FILE.json]
  dype serve     [--inferences N] [--artifact-dir DIR]

  W: gcn-<DS> | gin-<DS> (DS in S1..S4, OA, OP) | transf-<seq>-<win>
  I: pcie4 | pcie5 | cxl3          O: perf | balanced | energy

  `dype <subcommand> --help` prints that subcommand's own usage.
";

/// Per-subcommand usage blurbs (`dype <subcommand> --help`).
fn sub_usage(cmd: &str) -> Option<&'static str> {
    Some(match cmd {
        "schedule" => {
            "dype schedule — run Algorithm 1 for one workload, print the pipeline\n\n\
             USAGE:\n  dype schedule [--workload W] [--interconnect I] [--objective O]\n\
             \x20               [--fpgas N] [--gpus N] [--oracle]\n\n\
             \x20 --workload W      gcn-<DS> | gin-<DS> (DS in S1..S4, OA, OP) |\n\
             \x20                   transf-<seq>-<win>        [default: gcn-OA]\n\
             \x20 --interconnect I  pcie4 | pcie5 | cxl3      [default: pcie4]\n\
             \x20 --objective O     perf | balanced | energy  [default: perf]\n\
             \x20 --fpgas/--gpus N  installed device counts   [default: 3F 2G]\n\
             \x20 --oracle          use ground-truth models, not calibrated fits\n"
        }
        "pareto" => {
            "dype pareto — dump the Pareto front of the design space\n\n\
             USAGE:\n  dype pareto [--workload W] [--interconnect I]\n"
        }
        "calibrate" => {
            "dype calibrate — train the performance models, print fit quality\n\n\
             USAGE:\n  dype calibrate [--interconnect I]\n"
        }
        "sweep" => {
            "dype sweep — DYPE vs baselines across the paper's GNN workloads\n\n\
             USAGE:\n  dype sweep [--interconnect I] [--objective O]\n"
        }
        "scenario-sweep" => {
            "dype scenario-sweep — serving zoo x policy grid, Pareto-annotated\n\n\
             USAGE:\n  dype scenario-sweep [--manifest FILE.json] [--out TRACE.json]\n\n\
             \x20 --manifest FILE  run one manifest from disk instead of the zoo\n\
             \x20 --out TRACE      re-run the first scenario's winner with a\n\
             \x20                  recorder, write the Perfetto trace here\n\
             \x20                  (--trace is a back-compat alias)\n"
        }
        "fleet" => {
            "dype fleet — serve a manifest across a sharded engine fleet\n\n\
             USAGE:\n  dype fleet [--manifest FILE.json] [--shards N] [--out TRACE.json]\n\
             \x20           [--cache-dir DIR]\n\n\
             \x20 --manifest FILE  scenario manifest to serve [default: the\n\
             \x20                  built-in fleet-balanced zoo scenario]\n\
             \x20 --shards N       engine shards over disjoint pool slices [default: 4]\n\
             \x20 --out TRACE      write the shard-namespaced Perfetto trace here\n\
             \x20 --cache-dir DIR  load per-shard schedule caches before the run\n\
             \x20                  and persist them after it\n"
        }
        "lint" => {
            "dype lint — static feasibility & consistency analysis of manifests\n\n\
             USAGE:\n  dype lint [--manifest FILE.json | --all] [--json]\n\n\
             \x20 --manifest FILE  lint one manifest from disk\n\
             \x20 --all            lint the whole built-in scenario zoo\n\
             \x20                  (the default when no --manifest is given)\n\
             \x20 --json           machine-readable output: one JSON report\n\
             \x20                  per manifest with the typed diagnostics\n\n\
             Every check runs on the manifest alone — no simulated events.\n\
             Exit is nonzero iff any error-severity diagnostic fires;\n\
             warnings alone keep exit 0. Codes and the differential\n\
             validation policy are documented in DESIGN.md §Static\n\
             Analysis.\n"
        }
        "trace-validate" => {
            "dype trace-validate — strict-parse + structurally validate a trace\n\n\
             USAGE:\n  dype trace-validate [--trace] FILE.json\n\n\
             Exits nonzero on any parse or validation error.\n"
        }
        "bench-report" => {
            "dype bench-report — tracked perf baseline, with optional deltas\n\n\
             USAGE:\n  dype bench-report [--baseline FILE.json] [--fresh FILE.json]\n\n\
             \x20 --baseline FILE  tracked medians  [default: BENCH_serving.json]\n\
             \x20 --fresh FILE     fresh medians (the CI artifact, or a raw\n\
             \x20                  DYPE_BENCH_JSON JSONL capture); adds the\n\
             \x20                  per-bench delta column the CI gate checks\n"
        }
        "serve" => {
            "dype serve — stream real inferences through a scheduled pipeline\n\n\
             USAGE:\n  dype serve [--inferences N] [--artifact-dir DIR]\n"
        }
        _ => return None,
    })
}

/// Tiny argument scanner: `--key value` pairs plus boolean flags.
struct Args {
    kv: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut kv = std::collections::HashMap::new();
        let mut flags = std::collections::HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    kv.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument '{a}'\n\n{USAGE}");
            }
        }
        Ok(Args { kv, flags })
    }

    fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.kv.get(key).map(String::as_str).unwrap_or(default)
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.contains(key)
    }
}

fn parse_workload(name: &str) -> Result<Workload> {
    let ds_by_code = |code: &str| -> Result<Dataset> {
        Dataset::table1()
            .into_iter()
            .find(|d| d.code.eq_ignore_ascii_case(code))
            .ok_or_else(|| anyhow::anyhow!("unknown dataset '{code}' (S1..S4, OA, OP)"))
    };
    let lower = name.to_lowercase();
    if let Some(code) = lower.strip_prefix("gcn-") {
        return Ok(gnn::gcn_workload(&ds_by_code(code)?, 2, 128));
    }
    if let Some(code) = lower.strip_prefix("gin-") {
        return Ok(gnn::gin_workload(&ds_by_code(code)?, 2, 128, 2));
    }
    if let Some(rest) = lower.strip_prefix("transf-") {
        let mut it = rest.split('-');
        let seq: u64 = it.next().unwrap_or("").parse()?;
        let win: u64 = it.next().unwrap_or("").parse()?;
        return Ok(transformer::paper_transformer(seq, win));
    }
    bail!("unknown workload '{name}' (gcn-OA, gin-S3, transf-4096-512, ...)")
}

fn dataset_skew(wl_name: &str) -> f64 {
    Dataset::table1()
        .into_iter()
        .find(|d| wl_name.ends_with(&d.code))
        .map(|d| d.degree_skew)
        .unwrap_or(0.0)
}

fn print_schedule(wl: &Workload, sched: &dype::scheduler::Schedule) {
    println!("workload : {}", wl.name);
    println!("schedule : {}", sched.mnemonic());
    println!(
        "period   : {:.3} ms  (throughput {:.1} inf/s)",
        sched.period * 1e3,
        sched.throughput()
    );
    println!(
        "energy   : {:.3} J/inf  (efficiency {:.2} inf/J)",
        sched.energy_per_inf,
        sched.energy_efficiency()
    );
    let mut t =
        Table::new(&["stage", "kernels", "devices", "exec(ms)", "comm_in(ms)", "comm_out(ms)"]);
    for (i, s) in sched.stages.iter().enumerate() {
        let kernels: Vec<&str> =
            wl.kernels[s.first..=s.last].iter().map(|k| k.name.as_str()).collect();
        let label = if kernels.len() > 4 {
            format!("{}..{} ({})", kernels[0], kernels[kernels.len() - 1], kernels.len())
        } else {
            kernels.join("+")
        };
        t.row(vec![
            format!("{i}"),
            label,
            format!("{}{}", s.n, s.dev.letter()),
            format!("{:.3}", s.exec_time * 1e3),
            format!("{:.3}", s.comm_in_time * 1e3),
            format!("{:.3}", s.comm_out_time * 1e3),
        ]);
    }
    print!("{}", t.render());
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    if argv[1..].iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", sub_usage(cmd).unwrap_or(USAGE));
        return Ok(());
    }
    if cmd == "trace-validate" {
        // `--trace FILE` is the unified spelling; a bare positional path
        // is kept for back-compat with the original CLI.
        let path = match argv.get(1) {
            Some(p) if !p.starts_with("--") => p.clone(),
            _ => match Args::parse(&argv[1..])?.kv.get("trace") {
                Some(p) => p.clone(),
                None => bail!("trace-validate needs a file (positional or --trace)\n\n{USAGE}"),
            },
        };
        return trace_validate(&path);
    }
    let args = Args::parse(&argv[1..])?;
    let ic = Interconnect::parse(args.get("interconnect", "pcie4"))?;
    match cmd.as_str() {
        "schedule" => {
            let wl = parse_workload(args.get("workload", "gcn-OA"))?;
            let obj = Objective::parse(args.get("objective", "perf"))?;
            let mut sys = SystemSpec::paper_testbed(ic);
            sys.n_fpga = args.get_usize("fpgas", 3)?;
            sys.n_gpu = args.get_usize("gpus", 2)?;
            let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model())
                .with_degree_skew(dataset_skew(&wl.name));
            let sched = if args.flag("oracle") {
                let est = OracleModels { gt: &gt };
                DpScheduler::new(&sys, &est).schedule(&wl, obj)
            } else {
                let reg = calibrate::calibrated_registry(&sys);
                DpScheduler::new(&sys, &reg).schedule(&wl, obj)
            };
            print_schedule(&wl, &sched);
        }
        "pareto" => {
            let wl = parse_workload(args.get("workload", "gcn-S1"))?;
            let sys = SystemSpec::paper_testbed(ic);
            let reg = calibrate::calibrated_registry(&sys);
            let tables = DpScheduler::new(&sys, &reg).tables(&wl);
            let front = pareto_front(&tables);
            let mut t = Table::new(&["schedule", "thp(inf/s)", "J/inf", "devices"]);
            for p in front {
                t.row(vec![
                    p.mnemonic.clone(),
                    format!("{:.2}", p.throughput),
                    format!("{:.3}", p.energy_per_inf),
                    format!("{}F{}G", p.n_fpga, p.n_gpu),
                ]);
            }
            print!("{}", t.render());
        }
        "calibrate" => {
            let sys = SystemSpec::paper_testbed(ic);
            let reg = calibrate::calibrated_registry(&sys);
            let mut t = Table::new(&["kernel", "device", "rmse(s)", "R2"]);
            for (tag, dev, rmse, r2) in reg.fit_report() {
                t.row(vec![tag, dev.to_string(), format!("{rmse:.3e}"), format!("{r2:.4}")]);
            }
            print!("{}", t.render());
        }
        "sweep" => {
            let obj = Objective::parse(args.get("objective", "perf"))?;
            sweep(ic, obj)?;
        }
        "scenario-sweep" => {
            // `--out` is the unified output flag; `--trace` stays as a
            // back-compat alias from when the trace was the only output.
            let out = args.kv.get("out").or_else(|| args.kv.get("trace"));
            scenario_sweep(args.kv.get("manifest").map(String::as_str), out.map(String::as_str))?;
        }
        "fleet" => {
            fleet(
                args.kv.get("manifest").map(String::as_str),
                args.get_usize("shards", 4)?,
                args.kv.get("out").map(String::as_str),
                args.kv.get("cache-dir").map(String::as_str),
            )?;
        }
        "lint" => {
            lint(args.kv.get("manifest").map(String::as_str), args.flag("json"))?;
        }
        "bench-report" => {
            bench_report(
                args.get("baseline", "BENCH_serving.json"),
                args.kv.get("fresh").map(String::as_str),
            )?;
        }
        "serve" => {
            serve(args.get_usize("inferences", 16)?, args.get("artifact-dir", "artifacts"))?;
        }
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => bail!("unknown subcommand '{other}'\n\n{USAGE}"),
    }
    Ok(())
}

/// DYPE vs baselines over the paper's 12 GNN workloads, measured on the
/// ground-truth pipeline simulator.
fn sweep(ic: Interconnect, obj: Objective) -> Result<()> {
    let sys = SystemSpec::paper_testbed(ic);
    let reg = calibrate::calibrated_registry(&sys);
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let comm = sys.comm_model();
    let mut t = Table::new(&[
        "workload", "DYPE", "static", "FleetRec*", "GPU-only", "FPGA-only", "DYPE/static",
    ]);
    for ds in Dataset::table1() {
        for wl in gnn::paper_gnn_workloads(&ds) {
            let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model())
                .with_degree_skew(ds.degree_skew);
            let sim = PipelineSim::new(&power, &comm);
            let oracle = OracleModels { gt: &gt };
            let measure = |sched: &dype::scheduler::Schedule| {
                let retimed =
                    dype::scheduler::evaluate_plan(&wl, &sched.plan(), &oracle, &comm, &power);
                sim.run(&wl, &retimed, 100).throughput
            };
            let dype = DpScheduler::new(&sys, &reg).schedule(&wl, obj);
            let reference = if wl.name.starts_with("GCN") {
                gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128)
            } else {
                gnn::gin_workload(&Dataset::ogbn_arxiv(), 2, 128, 2)
            };
            let static_plan = baselines::tune_static_plan(&sys, &reg, &reference, obj);
            let stat = baselines::apply_static_plan(&sys, &reg, &wl, &static_plan);
            let fr = baselines::fleetrec(&sys, &reg, &wl, obj);
            let go = baselines::gpu_only(&sys, &reg, &wl, obj);
            let fo = baselines::fpga_only(&sys, &reg, &wl, obj);
            let (d, s_, g_, f_) = (measure(&dype), measure(&stat), measure(&go), measure(&fo));
            let fr_thp = fr.as_ref().map(&measure).unwrap_or(s_);
            t.row(vec![
                wl.name.clone(),
                format!("{d:.2}"),
                format!("{s_:.2}"),
                format!("{fr_thp:.2}"),
                format!("{g_:.2}"),
                format!("{f_:.2}"),
                fmt_ratio(d / s_),
            ]);
        }
    }
    print!("{}", t.render());
    Ok(())
}

/// `dype lint` — static feasibility and consistency analysis over one
/// manifest or the whole zoo, without running a single simulated event.
/// Prints every diagnostic (a JSON array of per-manifest reports with
/// `--json`) and exits nonzero iff any error-severity finding fired, so
/// CI can gate on errors while humans still see the advisories.
fn lint(manifest: Option<&str>, json: bool) -> Result<()> {
    use dype::analysis::lint_manifest;
    use dype::util::json::Json;
    let manifests = match manifest {
        Some(path) => vec![dype::scenario::ScenarioManifest::load(path)?],
        None => dype::scenario::catalog::all(),
    };
    let reports: Vec<_> = manifests.iter().map(lint_manifest).collect();
    let errors: usize = reports.iter().map(|r| r.errors()).sum();
    let warnings: usize = reports.iter().map(|r| r.warnings()).sum();
    if json {
        println!("{}", Json::Arr(reports.iter().map(|r| r.to_json()).collect()));
    } else {
        for r in &reports {
            print!("{}", r.render());
        }
        println!("lint: {} manifest(s), {errors} error(s), {warnings} warning(s)", reports.len());
    }
    if errors > 0 {
        bail!("lint: {errors} error-severity diagnostic(s) — see output above");
    }
    Ok(())
}

/// The scenario zoo crossed with every serving policy — or a single
/// manifest loaded from disk — rendered as the Pareto-annotated grid.
/// With `trace`, the first scenario is re-run under its score-winning
/// policy with a timeline recorder attached, and the Perfetto export is
/// written to the given path.
///
/// Every manifest is statically linted first: error-severity findings
/// refuse the run before any engine is built; warnings are printed and
/// the sweep proceeds.
fn scenario_sweep(manifest: Option<&str>, trace: Option<&str>) -> Result<()> {
    use dype::scenario::sweep::{run_grid_parallel, Policy};
    use dype::util::pool::default_threads;
    let manifests = match manifest {
        Some(path) => vec![dype::scenario::ScenarioManifest::load(path)?],
        None => dype::scenario::catalog::all(),
    };
    for m in &manifests {
        let report = dype::analysis::lint_manifest(m);
        if !report.is_clean() {
            bail!("manifest '{}' fails lint; refusing to sweep:\n{}", m.name, report.render());
        }
        for d in &report.diagnostics {
            println!("lint: {}", d.render());
        }
        // The grid includes the frozen-lease Static policy — surface the
        // config-dependent advisories for it too.
        for d in dype::analysis::lint_engine_config(m, &Policy::Static.engine_config()) {
            println!("lint[static]: {}", d.render());
        }
    }
    let report = run_grid_parallel(&manifests, &Policy::ALL, default_threads())?;
    print!("{}", report.render());
    if let Some(out) = trace {
        let m = &manifests[0];
        let policy = report.winner(&m.name).map(|c| c.policy).unwrap_or(Policy::AdaptiveDrain);
        write_winner_trace(m, policy, out)?;
    }
    Ok(())
}

/// Re-run one manifest under one policy with a timeline recorder and
/// write the validated Perfetto `trace_events` document to `out`.
fn write_winner_trace(
    m: &dype::scenario::ScenarioManifest,
    policy: dype::scenario::sweep::Policy,
    out: &str,
) -> Result<()> {
    use dype::telemetry::{export, Recorder};
    let built = m.build()?;
    let rec = Recorder::timeline();
    let mut cfg = built.apply(policy.engine_config());
    cfg.recorder = Some(rec.clone());
    dype::experiments::run_multi_stream_with(&built.system, &built.streams, cfg);
    let names: Vec<String> = built.streams.iter().map(|s| s.name.clone()).collect();
    let records = rec.drain();
    let doc = export::perfetto(&records, &names);
    export::validate(&doc).map_err(|e| anyhow::anyhow!("exporter produced invalid trace: {e}"))?;
    std::fs::write(out, format!("{doc}\n"))?;
    println!(
        "trace: {} records from '{}' under {} -> {out}",
        records.len(),
        m.name,
        policy.name()
    );
    Ok(())
}

/// Serve one scenario manifest across a sharded engine fleet: route at
/// admission, run every shard in parallel, migrate off degraded shards,
/// and render the per-shard report. With `out`, the shard-namespaced
/// Perfetto trace is validated and written; with `cache_dir`, per-shard
/// schedule caches load before the run and persist after it.
fn fleet(
    manifest: Option<&str>,
    shards: usize,
    out: Option<&str>,
    cache_dir: Option<&str>,
) -> Result<()> {
    use dype::engine::EngineConfig;
    use dype::fleet::{FleetConfig, ServingFleet};
    use dype::telemetry::export;
    let m = match manifest {
        Some(path) => dype::scenario::ScenarioManifest::load(path)?,
        None => dype::scenario::catalog::fleet_balanced(),
    };
    // Static gate, phase 1: manifest feasibility. Runs before `build()`
    // because lint diagnoses (DY011) exactly the degenerate manifests
    // that would panic inside the builders.
    let lint = dype::analysis::lint_manifest(&m);
    if !lint.is_clean() {
        bail!("manifest '{}' fails lint; refusing to serve:\n{}", m.name, lint.render());
    }
    let built = m.build()?;
    let sys = built.system.clone();
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let cfg = FleetConfig {
        shards,
        engine: built.apply(EngineConfig::default()),
        telemetry: out.is_some(),
        registry_prewarm: true,
        ..FleetConfig::default()
    };
    // Phase 2: fleet shape vs this exact config — refuse shard layouts
    // `ServingFleet::new` would assert on; print advisories and run.
    let shape = dype::analysis::lint_fleet(&m, &cfg);
    let shape_errors: Vec<_> =
        shape.iter().filter(|d| d.severity == dype::analysis::Severity::Error).collect();
    if !shape_errors.is_empty() {
        let rendered: Vec<String> = shape_errors.iter().map(|d| d.render()).collect();
        bail!("fleet shape for '{}' fails lint:\n  {}", m.name, rendered.join("\n  "));
    }
    for d in lint.diagnostics.iter().chain(&shape) {
        println!("lint: {}", d.render());
    }
    let mut fleet = ServingFleet::new(sys, &est, cfg);
    if let Some(dir) = cache_dir {
        let loaded = fleet.load_caches(dir)?;
        println!("caches: loaded {loaded} shard file(s) from {dir}");
    }
    let report = fleet.serve(&built.streams);
    print!("{}", report.render());
    if let Some(dir) = cache_dir {
        fleet.save_caches(dir)?;
        println!("caches: persisted {shards} shard file(s) to {dir}");
    }
    if let Some(out) = out {
        let doc = export::perfetto_fleet(&report.timelines());
        export::validate(&doc)
            .map_err(|e| anyhow::anyhow!("exporter produced invalid trace: {e}"))?;
        std::fs::write(out, format!("{doc}\n"))?;
        println!("trace: '{}' across {shards} shards -> {out}", m.name);
    }
    Ok(())
}

/// Strict-parse a Perfetto trace file and run the exporter's structural
/// validator over it; non-zero exit on any violation.
fn trace_validate(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read '{path}': {e}"))?;
    let doc = dype::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("'{path}' is not strict JSON: {e}"))?;
    dype::telemetry::export::validate(&doc)
        .map_err(|e| anyhow::anyhow!("'{path}' is not a valid trace: {e}"))?;
    let events = doc.get("traceEvents").and_then(|v| v.as_arr()).map_or(0, |a| a.len());
    println!("{path}: valid Perfetto trace ({events} events)");
    Ok(())
}

/// Render the tracked perf baseline and, given a fresh medians file, the
/// per-bench deltas the CI bench-smoke gate reasons about.
fn bench_report(baseline: &str, fresh: Option<&str>) -> Result<()> {
    use dype::util::bench::fmt_time;
    let base = read_medians(baseline)?;
    let fresh_rows = fresh.map(read_medians).transpose()?;
    match fresh_rows {
        None => {
            let mut t = Table::new(&["bench", "median"]);
            for (name, ns) in &base {
                t.row(vec![name.clone(), fmt_time(ns * 1e-9)]);
            }
            print!("{}", t.render());
        }
        Some(rows) => {
            let mut t = Table::new(&["bench", "baseline", "fresh", "delta"]);
            for (name, ns) in &rows {
                t.row(match base.iter().find(|(b, _)| b == name) {
                    Some((_, b)) => vec![
                        name.clone(),
                        fmt_time(b * 1e-9),
                        fmt_time(ns * 1e-9),
                        format!("{:+.1}%", (ns / b - 1.0) * 100.0),
                    ],
                    None => vec![name.clone(), "-".into(), fmt_time(ns * 1e-9), "new".into()],
                });
            }
            print!("{}", t.render());
        }
    }
    Ok(())
}

/// Parse a bench-medians file: either the tracked JSON array
/// (`[{"bench": ..., "median_ns": ...}, ...]`) or the raw JSONL capture
/// a bench run appends via `DYPE_BENCH_JSON` (one object per line).
fn read_medians(path: &str) -> Result<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read '{path}': {e}"))?;
    let row = |v: &dype::util::json::Json| -> Result<(String, f64)> {
        let name = v.get("bench").and_then(|n| n.as_str());
        let ns = v.get("median_ns").and_then(|n| n.as_f64());
        match (name, ns) {
            (Some(n), Some(m)) => Ok((n.to_string(), m)),
            _ => bail!("'{path}': every row needs \"bench\" and \"median_ns\""),
        }
    };
    let mut out = Vec::new();
    if let Ok(doc) = dype::util::json::parse(&text) {
        let arr = doc.as_arr().ok_or_else(|| anyhow::anyhow!("'{path}': expected a JSON array"))?;
        for v in arr {
            out.push(row(v)?);
        }
        return Ok(out);
    }
    // Not a single JSON document — try one object per non-empty line.
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let v = dype::util::json::parse(line)
            .map_err(|e| anyhow::anyhow!("'{path}' is neither JSON nor JSONL: {e}"))?;
        out.push(row(&v)?);
    }
    Ok(out)
}

/// End-to-end real execution of the demo GCN through a scheduled pipeline.
fn serve(inferences: usize, artifact_dir: &str) -> Result<()> {
    use dype::pipeline::{run_pipeline, ArgSource, KernelBinding, StageSpec};
    use dype::runtime::HostTensor;
    use dype::workload::BlockEllGraph;

    let sys = SystemSpec::paper_testbed(Interconnect::Pcie4);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let mut coord = Coordinator::new(sys.clone(), &est, Objective::Performance);
    let wl = gnn::e2e_gcn_workload();
    let sched = coord.process_batch(&wl).clone();
    println!("schedule: {}", sched.mnemonic());

    // Static data (§II-B pre-loading): graph blocks + per-layer weights.
    let g = BlockEllGraph::generate(8, 4, 128, 128, 42);
    let mut rng = Rng::seed_from_u64(7);
    let theta: Vec<f32> = (0..128 * 128).map(|_| rng.gen_range_f32(-0.05, 0.05)).collect();
    let blocks = HostTensor::f32(g.blocks.clone(), &[8, 4, 128, 128]);
    let indices = HostTensor::i32(g.indices.clone(), &[8, 4]);
    let theta_t = HostTensor::f32(theta, &[128, 128]);

    let spmm = KernelBinding {
        artifact: "spmm".into(),
        args: vec![ArgSource::Static(blocks), ArgSource::Static(indices), ArgSource::Dynamic],
    };
    let gemm = KernelBinding {
        artifact: "gemm".into(),
        args: vec![ArgSource::Dynamic, ArgSource::Static(theta_t)],
    };

    // Map the schedule's stages onto kernel bindings.
    let per_kernel = [spmm.clone(), gemm.clone(), spmm, gemm];
    let stages: Vec<StageSpec> = sched
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| StageSpec {
            name: format!("stage{i}-{}{}", s.n, s.dev.letter()),
            kernels: per_kernel[s.first..=s.last].to_vec(),
        })
        .collect();

    let inputs: Vec<HostTensor> = (0..inferences)
        .map(|i| {
            let mut r = Rng::seed_from_u64(100 + i as u64);
            let x: Vec<f32> = (0..1024 * 128).map(|_| r.gen_range_f32(-1.0, 1.0)).collect();
            HostTensor::f32(x, &[1024, 128])
        })
        .collect();

    let report = run_pipeline(artifact_dir.into(), stages, inputs)?;
    println!(
        "real execution: {} inferences in {:.2}s ({:.2} inf/s on this host)",
        inferences, report.wall_time, report.throughput
    );
    for (i, b) in report.stage_busy.iter().enumerate() {
        println!("  stage {i} busy {b:.2}s");
    }
    println!(
        "simulated testbed: {:.1} inf/s, {:.3} J/inf",
        sched.throughput(),
        sched.energy_per_inf
    );
    Ok(())
}
