//! Ground-truth GPU timing model (MI210 stand-in).
//!
//! Substitution note (DESIGN.md): the paper measures rocSPARSE / rocBLAS /
//! PyTorch kernels on real MI210s. Here the "hardware" is an analytical
//! roofline with empirically-shaped efficiency curves:
//!
//! * dense GEMM — high MXU-style utilization that degrades for small
//!   matrices (launch + tile quantization);
//! * sparse SpMM — compute efficiency collapses with density^½ (cache-line
//!   under-utilization on scattered rows), the effect the paper's Eq (7)
//!   features (nnz, GFLOP, arithmetic intensity) are designed to track;
//! * sliding-window attention — executed as *dense* attention (§V: GPU
//!   implementations only reduce memory, not time), so cost is quadratic
//!   in sequence length. This is the crossover driver in Fig 8.
//!
//! These curves are *richer* than the §V linear estimators: the estimators
//! are trained against this model through the calibration harness exactly
//! as the paper trains against measurements, preserving the
//! estimator-vs-oracle gap that Table III quantifies.

use super::types::GpuConfig;
use crate::workload::KernelKind;

/// Deterministic GPU kernel-time model. All returns are seconds.
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub cfg: GpuConfig,
}

impl GpuModel {
    pub fn new(cfg: GpuConfig) -> Self {
        GpuModel { cfg }
    }

    /// Dense-GEMM compute efficiency as a function of the problem volume.
    /// Large GNN-scale GEMMs reach ~85% of peak; small ones are launch- and
    /// tile-bound.
    fn gemm_efficiency(&self, m: u64, k: u64, n: u64) -> f64 {
        let vol = (m as f64) * (k as f64) * (n as f64);
        // Half-saturation at 1.3e8 MACs (~512³): matches the observation
        // that MI210 sgemm hits peak only beyond ~1k-sized squares.
        0.85 * vol / (vol + 1.3e8)
    }

    /// Sparse compute efficiency: fraction of peak FLOPs rocSPARSE-like
    /// CSR SpMM sustains at a given operand density. Calibrated against
    /// the paper's §I anchor (3×U280 ≈ 1×MI210 at ogbn-arxiv-level
    /// sparsity) and the Table V regime boundaries (GPU wins S1 outright;
    /// FPGAs take over at OP/S4 sparsity). Real CSR kernels sit in the
    /// low single-digit percent of peak on graphs this sparse.
    fn spmm_efficiency(&self, density: f64) -> f64 {
        (1.3 * density.sqrt()).clamp(5e-4, 0.4)
    }

    /// Execution time of `kind` on ONE GPU.
    pub fn kernel_time(&self, kind: &KernelKind) -> f64 {
        let c = &self.cfg;
        match *kind {
            KernelKind::Gemm { m, k, n } => {
                let flops = kind.flops();
                let compute = flops / (c.peak_flops * self.gemm_efficiency(m, k, n));
                let mem = kind.bytes() / c.mem_bw;
                compute.max(mem) + c.launch_overhead
            }
            KernelKind::SpMM { .. } => {
                let eff = self.spmm_efficiency(kind.density());
                let compute = kind.flops() / (c.peak_flops * eff);
                // Irregular gathers achieve ~60% of streaming bandwidth.
                let mem = kind.bytes() / (c.mem_bw * 0.6);
                compute.max(mem) + c.launch_overhead
            }
            KernelKind::WindowAttn { seq, heads, dim, .. } => {
                // §V: dense computation — the band mask saves no time.
                // Attention is NOT one clean GEMM: QKᵀ, masked softmax and
                // S'V are separate memory-bound kernels with transposes in
                // between, so sustained efficiency is roughly half of a
                // same-volume sgemm and several launches are paid.
                let d_model = (heads * dim) as f64;
                let s = seq as f64;
                // QKᵀ and S'V over the FULL seq×seq score matrix.
                let flops = 4.0 * s * s * d_model + 5.0 * s * s * heads as f64;
                let eff = 0.5 * self.gemm_efficiency(seq, heads * dim, seq);
                let compute = flops / (c.peak_flops * eff);
                // Score-matrix traffic dominates memory: written by QKᵀ,
                // read+written by softmax, read by S'V.
                let mem = (heads as f64 * s * s * 4.0 * 4.0
                    + 4.0 * s * d_model * 4.0)
                    / c.mem_bw;
                compute.max(mem) + 4.0 * c.launch_overhead
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::KernelKind;

    fn model() -> GpuModel {
        GpuModel::new(GpuConfig::default())
    }

    #[test]
    fn big_gemm_near_roofline() {
        let m = model();
        let k = KernelKind::Gemm { m: 170_000, k: 128, n: 128 };
        let t = m.kernel_time(&k);
        let ideal = k.flops() / m.cfg.peak_flops;
        assert!(t > ideal, "cannot beat peak");
        assert!(t < 4.0 * ideal, "large GEMM should be reasonably efficient: {t} vs {ideal}");
    }

    #[test]
    fn sparser_spmm_is_less_efficient() {
        let m = model();
        // Same FLOPs, different density: the sparser one must be slower
        // per-FLOP (that is the paper's core GPU-vs-FPGA premise).
        let dense = KernelKind::SpMM { m: 10_000, k: 10_000, n: 128, nnz: 1_000_000 };
        let sparse = KernelKind::SpMM { m: 100_000, k: 100_000, n: 128, nnz: 1_000_000 };
        let per_flop_d = m.kernel_time(&dense) / dense.flops();
        let per_flop_s = m.kernel_time(&sparse) / sparse.flops();
        assert!(per_flop_s > per_flop_d);
    }

    #[test]
    fn window_attention_is_quadratic_in_seq() {
        let m = model();
        let t1 =
            m.kernel_time(&KernelKind::WindowAttn { seq: 2048, window: 512, heads: 8, dim: 64 });
        let t2 =
            m.kernel_time(&KernelKind::WindowAttn { seq: 8192, window: 512, heads: 8, dim: 64 });
        // 4× seq ⇒ ~16× time (dense execution ignores the window).
        assert!(t2 / t1 > 8.0, "expected quadratic growth, got {}", t2 / t1);
    }

    #[test]
    fn window_size_does_not_change_gpu_time() {
        let m = model();
        let a =
            m.kernel_time(&KernelKind::WindowAttn { seq: 4096, window: 512, heads: 8, dim: 64 });
        let b =
            m.kernel_time(&KernelKind::WindowAttn { seq: 4096, window: 4096, heads: 8, dim: 64 });
        assert_eq!(a, b, "GPU runs dense attention regardless of window");
    }

    #[test]
    fn times_are_positive_and_finite() {
        let m = model();
        for k in [
            KernelKind::Gemm { m: 64, k: 64, n: 64 },
            KernelKind::SpMM { m: 100, k: 100, n: 8, nnz: 10 },
            KernelKind::WindowAttn { seq: 1024, window: 512, heads: 8, dim: 64 },
        ] {
            let t = m.kernel_time(&k);
            assert!(t.is_finite() && t > 0.0);
        }
    }
}
