//! The "run it on the hardware" harness.
//!
//! In the paper, kernel ground truth comes from executing on the MI210 /
//! U280 testbed. Here it comes from the device models plus a deterministic
//! per-configuration measurement perturbation. Everything downstream
//! treats this struct as the hardware:
//!
//! * the calibration harness (`perfmodel::calibrate`) benchmarks synthetic
//!   kernels against it and fits the §V linear estimators;
//! * the pipeline simulator measures schedules against it;
//! * Table III compares "schedule from estimates" vs "schedule from
//!   ground truth" exactly as the paper does.
//!
//! The perturbation is a hash-seeded ±σ factor per (kernel, device type,
//! device count): deterministic (bit-identical reruns) yet opaque to the
//! linear estimators, preserving the estimator-error phenomenology that
//! drives the paper's sub-optimality analysis.

use std::hash::{Hash, Hasher};

use super::fpga::FpgaModel;
use super::gpu::GpuModel;
use super::interconnect::CommModel;
use super::types::{DeviceType, FpgaConfig, GpuConfig};
use crate::workload::KernelKind;

/// Parallel-efficiency loss per extra device within a stage (operator
/// parallelism splits rows/tokens across devices; skew + sync cost ~5%).
const MULTI_DEV_ALPHA: f64 = 0.05;

#[derive(Debug, Clone)]
pub struct GroundTruth {
    pub gpu: GpuModel,
    pub fpga: FpgaModel,
    pub comm: CommModel,
    /// Relative measurement-noise amplitude (default 3%).
    pub noise_sigma: f64,
}

impl GroundTruth {
    pub fn new(gpu: GpuConfig, fpga: FpgaConfig, comm: CommModel) -> Self {
        GroundTruth {
            gpu: GpuModel::new(gpu),
            fpga: FpgaModel::new(fpga),
            comm,
            noise_sigma: 0.03,
        }
    }

    /// Set the degree skew of the currently loaded graph (per-dataset).
    pub fn with_degree_skew(mut self, skew: f64) -> Self {
        self.fpga.degree_skew = skew;
        self
    }

    /// Noise-free single-device time (the device models' analytic value).
    pub fn ideal_kernel_time(&self, kind: &KernelKind, dev: DeviceType) -> f64 {
        match dev {
            DeviceType::Gpu => self.gpu.kernel_time(kind),
            DeviceType::Fpga => self.fpga.kernel_time(kind),
        }
    }

    /// Deterministic perturbation factor in `[1-σ, 1+σ]` for a
    /// measurement configuration. Hashes the kind's raw fields directly —
    /// this sits on the DP hot path (§Perf: the original `format!`-based
    /// hash dominated the 160-kernel transformer DP).
    fn noise(&self, kind: &KernelKind, dev: DeviceType, n: usize) -> f64 {
        if self.noise_sigma == 0.0 {
            return 1.0;
        }
        let mut h = std::collections::hash_map::DefaultHasher::new();
        match *kind {
            KernelKind::SpMM { m, k, n: nn, nnz } => (0u8, m, k, nn, nnz).hash(&mut h),
            KernelKind::Gemm { m, k, n: nn } => (1u8, m, k, nn, 0u64).hash(&mut h),
            KernelKind::WindowAttn { seq, window, heads, dim } => {
                (2u8, seq, window, heads, dim).hash(&mut h)
            }
        }
        dev.letter().hash(&mut h);
        n.hash(&mut h);
        let u = h.finish() as f64 / u64::MAX as f64; // [0, 1]
        1.0 + self.noise_sigma * (2.0 * u - 1.0)
    }

    /// "Measured" execution time of `kind` on `n` devices of type `dev`
    /// acting as one pipeline stage (operator parallelism within the
    /// stage). Includes the gather/scatter cost §II-B folds into f_perf.
    pub fn kernel_time(&self, kind: &KernelKind, dev: DeviceType, n: usize) -> f64 {
        assert!(n >= 1, "stage needs at least one device");
        let single = self.ideal_kernel_time(kind, dev);
        let eff = 1.0 + MULTI_DEV_ALPHA * (n as f64 - 1.0);
        let mut t = single / n as f64 * eff;
        if n > 1 {
            // Partial results live on different devices: a fraction of the
            // output crosses PCIe to assemble the stage output.
            let sg_bytes = kind.output_bytes() * (n as f64 - 1.0) / n as f64 * 0.5;
            t += sg_bytes / self.comm.aggregate_bw(dev, n);
        }
        t * self.noise(kind, dev, n)
    }

    /// "Measured" time for a *group* of kernels executed sequentially by
    /// the same stage devices (Algorithm 1's grouping strategy).
    pub fn group_time(&self, kinds: &[KernelKind], dev: DeviceType, n: usize) -> f64 {
        kinds.iter().map(|k| self.kernel_time(k, dev, n)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::interconnect::Interconnect;

    fn gt() -> GroundTruth {
        GroundTruth::new(
            GpuConfig::default(),
            FpgaConfig::default(),
            CommModel::new(Interconnect::Pcie4),
        )
    }

    fn spmm() -> KernelKind {
        KernelKind::SpMM { m: 170_000, k: 170_000, n: 128, nnz: 1_270_000 }
    }

    #[test]
    fn deterministic() {
        let a = gt().kernel_time(&spmm(), DeviceType::Fpga, 2);
        let b = gt().kernel_time(&spmm(), DeviceType::Fpga, 2);
        assert_eq!(a, b);
    }

    #[test]
    fn noise_is_bounded() {
        let g = gt();
        let ideal = g.ideal_kernel_time(&spmm(), DeviceType::Gpu);
        let measured = g.kernel_time(&spmm(), DeviceType::Gpu, 1);
        let ratio = measured / ideal;
        assert!((1.0 - g.noise_sigma..=1.0 + g.noise_sigma).contains(&ratio));
    }

    #[test]
    fn more_devices_is_faster_but_sublinear() {
        let g = gt();
        let t1 = g.kernel_time(&spmm(), DeviceType::Fpga, 1);
        let t2 = g.kernel_time(&spmm(), DeviceType::Fpga, 2);
        let t3 = g.kernel_time(&spmm(), DeviceType::Fpga, 3);
        assert!(t2 < t1 && t3 < t2, "scaling should help");
        assert!(t3 > t1 / 3.0 * 0.95, "but not superlinearly");
    }

    #[test]
    fn group_time_is_sum_of_members() {
        let g = gt();
        let a = KernelKind::Gemm { m: 1000, k: 128, n: 128 };
        let b = spmm();
        let grouped = g.group_time(&[a, b], DeviceType::Gpu, 2);
        let split = g.kernel_time(&a, DeviceType::Gpu, 2) + g.kernel_time(&b, DeviceType::Gpu, 2);
        assert!((grouped - split).abs() < 1e-12);
    }

    #[test]
    fn zero_sigma_recovers_ideal() {
        let mut g = gt();
        g.noise_sigma = 0.0;
        let k = KernelKind::Gemm { m: 512, k: 512, n: 512 };
        assert_eq!(g.kernel_time(&k, DeviceType::Gpu, 1), g.ideal_kernel_time(&k, DeviceType::Gpu));
    }
}
