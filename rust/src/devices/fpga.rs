//! Ground-truth FPGA timing model (Alveo U280 stand-in).
//!
//! The paper's FPGA kernels have *analytically predictable* latency (§V) —
//! we use the paper's own formulas as the backbone of the ground truth:
//!
//! * SpMM: customized Sextans [30] — `t = C·(nnz + 13·M)·N / (MACs·F)`
//!   (§V, C a calibration constant); we add a row-skew load-imbalance
//!   factor, the real-world effect that makes even FPGA timing slightly
//!   input-dependent and gives the §V estimator something to miss.
//! * Sliding-window attention: SWAT [6] — Eq (9):
//!   `t = C·(seq·t_pipeline + t_init)·(w/1024)/F`.
//! * Dense GEMM: the HLS overlay of [31] at ~0.55 TFLOPS FP32, so
//!   FPGA-only baselines can execute the dense kernels too (they must —
//!   the paper runs FPGA-only end to end).

use super::types::FpgaConfig;
use crate::workload::KernelKind;

/// Deterministic FPGA kernel-time model. All returns are seconds.
#[derive(Debug, Clone)]
pub struct FpgaModel {
    pub cfg: FpgaConfig,
    /// Row-degree skew of the graph currently loaded (0 = uniform). Set
    /// from `Dataset::degree_skew` by the ground-truth harness.
    pub degree_skew: f64,
}

impl FpgaModel {
    pub fn new(cfg: FpgaConfig) -> Self {
        FpgaModel { cfg, degree_skew: 0.0 }
    }

    pub fn with_skew(cfg: FpgaConfig, degree_skew: f64) -> Self {
        FpgaModel { cfg, degree_skew }
    }

    /// Execution time of `kind` on ONE FPGA.
    pub fn kernel_time(&self, kind: &KernelKind) -> f64 {
        let c = &self.cfg;
        match *kind {
            KernelKind::SpMM { m, n, nnz, .. } => {
                // Sextans streaming model: one MAC-array pass over
                // (nnz + 13·M) elements per dense column, N columns.
                let cycles = (nnz as f64 + 13.0 * m as f64) * n as f64 / c.spmm_macs;
                // Load imbalance: skewed row degrees stall the PE array.
                let imbalance = 1.0 + 0.18 * self.degree_skew;
                cycles * imbalance / c.spmm_freq + c.launch_overhead
            }
            KernelKind::WindowAttn { seq, window, .. } => {
                // SWAT Eq (9) verbatim (C folded to 1.0 in ground truth;
                // estimators fit their own C).
                let cyc = seq as f64 * c.attn_t_pipeline + c.attn_t_init;
                cyc * (window as f64 / 1024.0) / c.attn_freq + c.launch_overhead
            }
            KernelKind::Gemm { .. } => {
                let compute = kind.flops() / c.gemm_peak_flops;
                let mem = kind.bytes() / c.mem_bw;
                compute.max(mem) + c.launch_overhead
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Dataset, KernelKind};

    fn model() -> FpgaModel {
        FpgaModel::new(FpgaConfig::default())
    }

    #[test]
    fn sextans_formula_matches_hand_calc() {
        let m = model();
        let k = KernelKind::SpMM { m: 1000, k: 1000, n: 64, nnz: 10_000 };
        let expect = (10_000.0 + 13.0 * 1000.0) * 64.0 / 640.0 / 215e6 + m.cfg.launch_overhead;
        assert!((m.kernel_time(&k) - expect).abs() < 1e-12);
    }

    #[test]
    fn swat_formula_matches_hand_calc() {
        let m = model();
        let k = KernelKind::WindowAttn { seq: 4096, window: 512, heads: 8, dim: 64 };
        let expect = (4096.0 * 201.0 + 904.0) * 0.5 / 421e6 + m.cfg.launch_overhead;
        assert!((m.kernel_time(&k) - expect).abs() < 1e-12);
    }

    #[test]
    fn fpga_attention_linear_in_seq_and_window() {
        let m = model();
        let base =
            m.kernel_time(&KernelKind::WindowAttn { seq: 2048, window: 512, heads: 8, dim: 64 });
        let seq2 =
            m.kernel_time(&KernelKind::WindowAttn { seq: 4096, window: 512, heads: 8, dim: 64 });
        let win2 =
            m.kernel_time(&KernelKind::WindowAttn { seq: 2048, window: 1024, heads: 8, dim: 64 });
        assert!((seq2 / base - 2.0).abs() < 0.05);
        assert!((win2 / base - 2.0).abs() < 0.05);
    }

    #[test]
    fn skew_slows_spmm() {
        let k = KernelKind::SpMM { m: 100_000, k: 100_000, n: 128, nnz: 1_000_000 };
        let uniform = model().kernel_time(&k);
        let skewed = FpgaModel::with_skew(FpgaConfig::default(), 1.0).kernel_time(&k);
        assert!(skewed > uniform);
    }

    /// §I headline: three U280s ≈ one MI210 on high-sparsity SpMM with
    /// ~1.5-1.8× better energy efficiency. This test pins the calibration
    /// of the two ground-truth models to that claim.
    #[test]
    fn three_fpga_vs_one_gpu_on_high_sparsity_spmm() {
        use crate::devices::gpu::GpuModel;
        use crate::devices::types::GpuConfig;
        let ds = Dataset::ogbn_arxiv(); // 99.996% sparse
        let k = KernelKind::SpMM {
            m: ds.vertices,
            k: ds.vertices,
            n: 128,
            nnz: ds.edges + ds.vertices,
        };
        let t_gpu = GpuModel::new(GpuConfig::default()).kernel_time(&k);
        let t_fpga = model().kernel_time(&k);
        // 3 FPGAs split rows ⇒ ~t_fpga/3: "comparable" = within 2×.
        let three_f = t_fpga / 3.0;
        let ratio = three_f / t_gpu;
        assert!(
            (0.5..2.0).contains(&ratio),
            "3×FPGA should be comparable to 1×GPU, got ratio {ratio}"
        );
        // Energy: 3 FPGAs at 55 W vs 1 GPU at 300 W.
        let e_fpga = 3.0 * 55.0 * three_f;
        let e_gpu = 300.0 * t_gpu;
        let eff_gain = e_gpu / e_fpga;
        assert!(eff_gain > 1.2, "FPGA energy-efficiency advantage missing: {eff_gain}");
    }

    /// Low-sparsity graphs flip the preference to the GPU (Table V: GCN-S1
    /// perf-opt schedules are pure-GPU).
    #[test]
    fn gpu_wins_low_sparsity_spmm() {
        use crate::devices::gpu::GpuModel;
        use crate::devices::types::GpuConfig;
        let ds = Dataset::synthetic1(); // 99.77% sparse = "dense" here
        let k = KernelKind::SpMM {
            m: ds.vertices,
            k: ds.vertices,
            n: ds.feature_len,
            nnz: ds.edges,
        };
        let t_gpu = GpuModel::new(GpuConfig::default()).kernel_time(&k);
        let t_fpga = model().kernel_time(&k);
        assert!(t_fpga / 3.0 > 1.5 * t_gpu, "even 3 FPGAs should lose on S1");
    }
}
