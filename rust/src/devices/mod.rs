//! The heterogeneous-testbed substrate: device timing/power models, the
//! interconnect simulator, and the ground-truth "measurement" harness.
//!
//! This module is the stand-in for the paper's §III hardware build (2×
//! MI210 + 3× U280 + PCIe 4.0 P2P); see DESIGN.md's substitution table.

pub mod fpga;
pub mod gpu;
pub mod ground_truth;
pub mod interconnect;
pub mod types;

pub use fpga::FpgaModel;
pub use gpu::GpuModel;
pub use ground_truth::GroundTruth;
pub use interconnect::{CommModel, Endpoint, Interconnect};
pub use types::{DeviceType, FpgaConfig, GpuConfig};
