//! Device-type taxonomy and hardware parameter blocks (Table II).


/// The two accelerator classes of the prototype (§III-A). The scheduling
/// algorithm is device-type-generic; the prototype — and this reproduction
/// — instantiate GPUs and FPGAs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceType {
    Gpu,
    Fpga,
}

impl DeviceType {
    /// Mnemonic letter used in the paper's schedule notation (3F2G, …).
    pub fn letter(&self) -> char {
        match self {
            DeviceType::Gpu => 'G',
            DeviceType::Fpga => 'F',
        }
    }

    pub const ALL: [DeviceType; 2] = [DeviceType::Fpga, DeviceType::Gpu];
}

impl std::fmt::Display for DeviceType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeviceType::Gpu => write!(f, "GPU"),
            DeviceType::Fpga => write!(f, "FPGA"),
        }
    }
}

/// AMD Instinct MI210 parameters (Table II + public specs).
#[derive(Debug, Clone)]
pub struct GpuConfig {
    /// FP32 peak throughput (FLOP/s). MI210: 22.6 TFLOPS.
    pub peak_flops: f64,
    /// HBM2e bandwidth (B/s). MI210: 1.6 TB/s.
    pub mem_bw: f64,
    /// Kernel-launch / runtime overhead per kernel invocation (s).
    pub launch_overhead: f64,
    /// Dynamic power while executing (W) — Table II: 300 W.
    pub dynamic_power: f64,
    /// Static/idle power (W) — Table II: 45 W.
    pub static_power: f64,
    /// Power while driving PCIe transfers (W).
    pub transfer_power: f64,
    /// PCIe 4.0 x16 physical bandwidth per device (B/s) — §III-A: 31.52 GB/s.
    pub pcie_bw: f64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig {
            peak_flops: 22.6e12,
            mem_bw: 1.6e12,
            launch_overhead: 8e-6,
            dynamic_power: 300.0,
            static_power: 45.0,
            transfer_power: 90.0,
            pcie_bw: 31.52e9,
        }
    }
}

/// AMD Alveo U280 parameters with the paper's two bitstreams:
/// customized Sextans SpMM (§V) and SWAT sliding-window attention (§V).
#[derive(Debug, Clone)]
pub struct FpgaConfig {
    /// Sextans clock (Hz) — §V: 215 MHz.
    pub spmm_freq: f64,
    /// Sextans MAC units — §V: 640 (after removing α/βC, §VI-A).
    pub spmm_macs: f64,
    /// SWAT clock (Hz) — §V: 421 MHz.
    pub attn_freq: f64,
    /// SWAT pipeline fill cycles per token — Eq 9: t_pipeline = 201.
    pub attn_t_pipeline: f64,
    /// SWAT init cycles — Eq 9: t_init = 904.
    pub attn_t_init: f64,
    /// Dense GEMM peak on the FPGA overlay ([31]): ~0.55 TFLOPS FP32.
    pub gemm_peak_flops: f64,
    /// HBM2 bandwidth (B/s). U280: 460 GB/s.
    pub mem_bw: f64,
    /// Reconfiguration / invocation overhead per kernel (s).
    pub launch_overhead: f64,
    /// Dynamic power for the SpMM bitstream (W) — Table II: 55 W.
    pub spmm_dynamic_power: f64,
    /// Dynamic power for the win-attn bitstream (W) — Table II: 50.2 W.
    pub attn_dynamic_power: f64,
    /// Static/idle power (W) — Table II: 19.5 W.
    pub static_power: f64,
    /// Power while driving PCIe transfers (W).
    pub transfer_power: f64,
    /// PCIe 4.0 x8 physical bandwidth per device (B/s) — §III-A: 15.76 GB/s.
    pub pcie_bw: f64,
}

impl Default for FpgaConfig {
    fn default() -> Self {
        FpgaConfig {
            spmm_freq: 215e6,
            spmm_macs: 640.0,
            attn_freq: 421e6,
            attn_t_pipeline: 201.0,
            attn_t_init: 904.0,
            gemm_peak_flops: 0.55e12,
            mem_bw: 460e9,
            launch_overhead: 20e-6,
            spmm_dynamic_power: 55.0,
            attn_dynamic_power: 50.2,
            static_power: 19.5,
            transfer_power: 30.0,
            pcie_bw: 15.76e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn letters_match_paper_mnemonics() {
        assert_eq!(DeviceType::Fpga.letter(), 'F');
        assert_eq!(DeviceType::Gpu.letter(), 'G');
    }

    #[test]
    fn table2_power_values() {
        let g = GpuConfig::default();
        let f = FpgaConfig::default();
        assert_eq!(g.dynamic_power, 300.0);
        assert_eq!(g.static_power, 45.0);
        assert_eq!(f.spmm_dynamic_power, 55.0);
        assert_eq!(f.attn_dynamic_power, 50.2);
        assert_eq!(f.static_power, 19.5);
    }
}
