//! Interconnect & data-transfer simulator (§III-B, Fig 4, Fig 6).
//!
//! Models the testbed's transfer paths:
//!
//! * **P2P** (FPGA↔GPU direct, §III-B): one DMA over the bottleneck link —
//!   the FPGA's x8 port, the GPU's x16 port, or the CPU-CPU fabric —
//!   plus a small doorbell/setup overhead.
//! * **Host-staged**: two sequential copies (src→host, host→dst) plus the
//!   CPU-involvement overhead (buffer pinning, runtime sync) that Fig 6
//!   shows dominating small transfers.
//!
//! Aggregate bandwidth scales with the number of devices on each side
//! (§III-B: "the overall bandwidth is determined by the combined
//! bandwidths of the involved GPUs and FPGAs").
//!
//! The generational projection of §VI-A (PCIe 5.0, CXL 3.0) scales only
//! the transfer path, exactly as the paper projects only transfer times.


use super::types::DeviceType;

/// Interconnect generation (§VI-A evaluation axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Interconnect {
    Pcie4,
    Pcie5,
    Cxl3,
}

impl Interconnect {
    /// Link-bandwidth multiplier relative to the PCIe 4.0 testbed.
    pub fn bw_multiplier(&self) -> f64 {
        match self {
            Interconnect::Pcie4 => 1.0,
            Interconnect::Pcie5 => 2.0,  // 32 GT/s vs 16 GT/s per lane
            Interconnect::Cxl3 => 4.0,   // 64 GT/s PAM4 + flit efficiency
        }
    }

    /// Fixed-overhead multiplier (protocol latency improves with CXL).
    pub fn overhead_multiplier(&self) -> f64 {
        match self {
            Interconnect::Pcie4 => 1.0,
            Interconnect::Pcie5 => 0.8,
            Interconnect::Cxl3 => 0.4,
        }
    }

    pub const ALL: [Interconnect; 3] =
        [Interconnect::Pcie4, Interconnect::Pcie5, Interconnect::Cxl3];
}

impl std::fmt::Display for Interconnect {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Interconnect::Pcie4 => write!(f, "PCIe4.0"),
            Interconnect::Pcie5 => write!(f, "PCIe5.0"),
            Interconnect::Cxl3 => write!(f, "CXL3.0"),
        }
    }
}

/// An endpoint of a transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Endpoint {
    /// Host DRAM (workload ingress/egress).
    Host,
    /// `n` devices of a type acting in aggregate (a pipeline stage).
    Devices(DeviceType, usize),
}

/// Transfer-time model over the testbed topology (Fig 5a).
#[derive(Debug, Clone)]
pub struct CommModel {
    pub gen: Interconnect,
    /// Per-GPU PCIe bandwidth at gen=PCIe4 (B/s). §III-A: 31.52 GB/s (x16).
    pub gpu_link_bw: f64,
    /// Per-FPGA PCIe bandwidth at gen=PCIe4 (B/s). §III-A: 15.76 GB/s (x8).
    pub fpga_link_bw: f64,
    /// CPU↔CPU fabric bandwidth (B/s). §III-A: 128 GB/s.
    pub cpu_fabric_bw: f64,
    /// P2P doorbell/setup overhead (s) at PCIe4.
    pub p2p_overhead: f64,
    /// Host-staging overhead (s) at PCIe4 — CPU sync + pinned-buffer cost.
    pub staged_overhead: f64,
    /// Whether FPGA-GPU P2P is enabled (the paper's §III-B contribution;
    /// disable to reproduce the Fig 6 "traditional" baseline).
    pub p2p_enabled: bool,
}

impl CommModel {
    pub fn new(gen: Interconnect) -> Self {
        CommModel {
            gen,
            gpu_link_bw: 31.52e9,
            fpga_link_bw: 15.76e9,
            cpu_fabric_bw: 128e9,
            p2p_overhead: 10e-6,
            staged_overhead: 60e-6,
            p2p_enabled: true,
        }
    }

    fn link_bw(&self, ty: DeviceType) -> f64 {
        let base = match ty {
            DeviceType::Gpu => self.gpu_link_bw,
            DeviceType::Fpga => self.fpga_link_bw,
        };
        base * self.gen.bw_multiplier()
    }

    /// Aggregate PCIe bandwidth of `n` devices of `ty` (§III-B).
    pub fn aggregate_bw(&self, ty: DeviceType, n: usize) -> f64 {
        self.link_bw(ty) * n.max(1) as f64
    }

    fn oh_p2p(&self) -> f64 {
        self.p2p_overhead * self.gen.overhead_multiplier()
    }

    fn oh_staged(&self) -> f64 {
        self.staged_overhead * self.gen.overhead_multiplier()
    }

    /// One direct DMA hop of `bytes` over the path `src → dst`.
    fn p2p_time(&self, bytes: f64, src_bw: f64, dst_bw: f64) -> f64 {
        let bw = src_bw.min(dst_bw).min(self.cpu_fabric_bw * self.gen.bw_multiplier());
        bytes / bw + self.oh_p2p()
    }

    /// Two store-and-forward copies through host DRAM.
    fn staged_time(&self, bytes: f64, src_bw: f64, dst_bw: f64) -> f64 {
        bytes / src_bw + bytes / dst_bw + self.oh_staged()
    }

    /// Transfer `bytes` from `src` to `dst`. This is the physical-path
    /// model that `scheduler::comm::f_comm` builds stage costs from.
    pub fn transfer_time(&self, bytes: f64, src: Endpoint, dst: Endpoint) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        match (src, dst) {
            (Endpoint::Host, Endpoint::Devices(ty, n))
            | (Endpoint::Devices(ty, n), Endpoint::Host) => {
                bytes / self.aggregate_bw(ty, n) + self.oh_p2p()
            }
            // Note: consecutive pipeline stages always occupy *distinct*
            // physical devices (the DP consumes the device budget), so
            // every cross-stage transfer pays a real PCIe cost — including
            // GPU→GPU pairs.
            (Endpoint::Devices(st, sn), Endpoint::Devices(dt, dn)) => {
                let src_bw = self.aggregate_bw(st, sn);
                let dst_bw = self.aggregate_bw(dt, dn);
                if self.p2p_enabled {
                    self.p2p_time(bytes, src_bw, dst_bw)
                } else {
                    self.staged_time(bytes, src_bw, dst_bw)
                }
            }
            (Endpoint::Host, Endpoint::Host) => 0.0,
        }
    }

    /// Fig 6 experiment: speedup of P2P over host-staged for a single
    /// GPU→FPGA transfer of `bytes`.
    pub fn p2p_speedup(&self, bytes: f64) -> f64 {
        let src_bw = self.link_bw(DeviceType::Gpu);
        let dst_bw = self.link_bw(DeviceType::Fpga);
        self.staged_time(bytes, src_bw, dst_bw) / self.p2p_time(bytes, src_bw, dst_bw)
    }

    /// Fig 4 conflict rule: a CPU-FPGA transfer overlapping an FPGA-GPU
    /// P2P transfer on the same root complex must be temporally separated;
    /// the schedule inserts a delay of one CPU-FPGA communication cycle.
    /// Returns that guard delay for a payload of `bytes`.
    pub fn conflict_guard_delay(&self, bytes: f64) -> f64 {
        bytes / self.link_bw(DeviceType::Fpga) + self.oh_staged()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_speedup_large_at_small_sizes_and_near_2x_at_1mb() {
        let c = CommModel::new(Interconnect::Pcie4);
        let small = c.p2p_speedup(1024.0);
        let mid = c.p2p_speedup(1e6);
        let large = c.p2p_speedup(64e6);
        assert!(small > 3.0, "CPU overhead should dominate 1KB: {small}");
        assert!((1.6..2.6).contains(&mid), "~2x at 1MB (Fig 6): {mid}");
        assert!(large < mid, "speedup declines toward the bw-ratio asymptote");
        assert!(large > 1.4, "P2P always wins: {large}");
    }

    #[test]
    fn speedup_is_monotonically_decreasing() {
        let c = CommModel::new(Interconnect::Pcie4);
        let sizes = [1e3, 1e4, 1e5, 1e6, 1e7, 6.4e7];
        let sp: Vec<f64> = sizes.iter().map(|&s| c.p2p_speedup(s)).collect();
        for w in sp.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn aggregate_bandwidth_scales_with_device_count() {
        let c = CommModel::new(Interconnect::Pcie4);
        let one = c.transfer_time(
            1e8,
            Endpoint::Devices(DeviceType::Gpu, 1),
            Endpoint::Devices(DeviceType::Fpga, 1),
        );
        let many = c.transfer_time(
            1e8,
            Endpoint::Devices(DeviceType::Gpu, 2),
            Endpoint::Devices(DeviceType::Fpga, 3),
        );
        assert!(many < one);
    }

    #[test]
    fn gpu_to_gpu_transfer_is_not_free() {
        // Distinct stages = distinct physical devices: same-type transfers
        // still cross PCIe.
        let c = CommModel::new(Interconnect::Pcie4);
        let t = c.transfer_time(
            1e9,
            Endpoint::Devices(DeviceType::Gpu, 1),
            Endpoint::Devices(DeviceType::Gpu, 1),
        );
        assert!(t > 1e9 / 31.52e9 * 0.99);
    }

    #[test]
    fn faster_generations_are_faster() {
        let bytes = 1e7;
        let t = |g| {
            CommModel::new(g).transfer_time(
                bytes,
                Endpoint::Devices(DeviceType::Fpga, 3),
                Endpoint::Devices(DeviceType::Gpu, 2),
            )
        };
        assert!(t(Interconnect::Pcie5) < t(Interconnect::Pcie4));
        assert!(t(Interconnect::Cxl3) < t(Interconnect::Pcie5));
    }

    #[test]
    fn disabling_p2p_reproduces_staged_path() {
        let mut c = CommModel::new(Interconnect::Pcie4);
        let p2p = c.transfer_time(
            1e6,
            Endpoint::Devices(DeviceType::Gpu, 1),
            Endpoint::Devices(DeviceType::Fpga, 1),
        );
        c.p2p_enabled = false;
        let staged = c.transfer_time(
            1e6,
            Endpoint::Devices(DeviceType::Gpu, 1),
            Endpoint::Devices(DeviceType::Fpga, 1),
        );
        assert!(staged > p2p);
    }

    #[test]
    fn zero_bytes_is_free() {
        let c = CommModel::new(Interconnect::Cxl3);
        assert_eq!(
            c.transfer_time(
                0.0,
                Endpoint::Host,
                Endpoint::Devices(DeviceType::Fpga, 1)
            ),
            0.0
        );
    }
}
