//! Multi-stream serving: several concurrent request streams — each with
//! its own workload family, arrival process, and drifting input
//! characteristics — share one heterogeneous device pool (DESIGN.md
//! §Serving).
//!
//! The paper's serving story is a single stream of continuous inferences;
//! a deployment at the ROADMAP's "millions of users" scale multiplexes
//! *many*. Since PR 2 the heavy lifting lives in the
//! [`crate::engine`] subsystem: [`MultiStreamServer::serve`] is a thin
//! front-end over [`crate::engine::ServingEngine`], which
//!
//! 1. **leases** the [`SystemSpec`] inventory to the active streams
//!    demand-proportionally ([`crate::engine::lease`]) — exclusive
//!    partitions when devices suffice, weighted-round-robin time slices
//!    when streams outnumber devices (no request is ever rejected);
//! 2. drains every stream's FIFO admission queue through **one global
//!    event heap** ([`crate::engine::events`]), each stream's
//!    [`Coordinator`] applying the reschedule-hysteresis policy to its
//!    own drift;
//! 3. memoizes every coordinator into one shared
//!    [`crate::scheduler::ScheduleCache`] — keys embed each partition's
//!    fingerprint, so streams never collide but recurring drift turns
//!    reschedules into cache hits;
//! 4. **re-partitions online by default**
//!    ([`crate::engine::repartition`]) when observed demand drifts away
//!    from the leases in force; every migration *prewarms* the schedule
//!    cache for the prospective partition
//!    ([`crate::scheduler::ScheduleCache::prewarm`]), so a migrated
//!    stream's known regimes stay hits — freeze the leases with
//!    [`crate::engine::EngineConfigBuilder::static_leases`] via
//!    [`MultiStreamServer::with_engine_config`] to reproduce the
//!    historical static numbers;
//! 5. optionally serves **multi-objective**: a per-window joule budget
//!    ([`crate::engine::budget`]) defers below-priority admissions when
//!    the `f_eng` account runs dry, per-stream p99 targets
//!    ([`crate::engine::slo`]) feed back into the lease weights, hard
//!    per-request deadlines shed infeasible requests at admission
//!    (never deferring them past their bound), and a per-stream
//!    migration-mode override ties mid-slot preemption to stream
//!    criticality — all opt-in, all inert for default [`StreamSlo`]s
//!    and `None` budgets.
//!
//! This module keeps the stream vocabulary ([`StreamSpec`]) and the
//! report types ([`StreamReport`], [`MultiStreamReport`]), plus the
//! strict spatial partitioner [`partition_system`] for callers that want
//! exclusive device ownership or nothing.

use crate::config::{Objective, SystemSpec};
use crate::engine::{lease, EngineConfig, EngineMetrics, OverSubscribed, ServingEngine, StreamSlo};
use crate::perfmodel::PerfEstimator;
use crate::scheduler::{
    system_fingerprint, CacheKey, CacheStats, DpScheduler, ScheduleCache, SharedScheduleCache,
};

use super::server::{Request, ServeReport};

/// One request stream: a named trace with its own design objective and
/// service-level objective.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    pub name: String,
    pub objective: Objective,
    /// Arrival-ordered requests (see [`super::server::generate_trace`]).
    pub trace: Vec<Request>,
    /// Latency target + QoS priority ([`StreamSlo`]). Defaults to
    /// best-effort at unit priority, which leaves every engine decision
    /// exactly as demand-proportional serving made it.
    pub slo: StreamSlo,
}

impl StreamSpec {
    pub fn new(name: impl Into<String>, objective: Objective, trace: Vec<Request>) -> StreamSpec {
        assert!(!trace.is_empty(), "empty stream trace");
        StreamSpec { name: name.into(), objective, trace, slo: StreamSlo::default() }
    }

    /// Attach a service-level objective (p99 target and/or priority).
    pub fn with_slo(mut self, slo: StreamSlo) -> StreamSpec {
        self.slo = slo;
        self
    }

    /// The trace's arrival span, floored at one second for degenerate
    /// traces (a single request, or an instantaneous burst): dividing by
    /// a near-zero span would report an astronomically inflated rate and
    /// invert the demand-proportional partitioning.
    fn span(&self) -> f64 {
        (self.trace.last().unwrap().arrival - self.trace[0].arrival).max(1.0)
    }

    /// Offered request rate (req/s) over the trace's arrival span.
    pub fn offered_rate(&self) -> f64 {
        self.trace.len() as f64 / self.span()
    }

    /// Offered compute load (FLOP/s) — the demand signal the lease
    /// assignment apportions by (and the demand tracker's initial
    /// estimate when online re-partitioning is enabled).
    pub fn demand(&self) -> f64 {
        let flops: f64 = self.trace.iter().map(|r| r.workload.total_flops()).sum();
        flops / self.span()
    }
}

/// Split a device pool across `demands.len()` active streams,
/// demand-proportionally per device type, guaranteeing every stream at
/// least one device (progress ⇒ no starvation). Errs when there are more
/// streams than devices — spatial multiplexing cannot serve that; the
/// serving engine answers the same situation with time-sliced leases
/// ([`crate::engine::lease::assign`]), which is what
/// [`MultiStreamServer::serve`] uses.
pub fn partition_system(
    sys: &SystemSpec,
    demands: &[f64],
) -> Result<Vec<SystemSpec>, OverSubscribed> {
    let k = demands.len();
    assert!(k >= 1, "no streams");
    let devices = sys.n_fpga + sys.n_gpu;
    if devices < k {
        return Err(OverSubscribed { streams: k, devices });
    }
    Ok(lease::split_pool(sys, demands))
}

/// One stream's outcome: its device lease and its serving statistics.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub name: String,
    /// Devices leased by the engine, `"2F1G"` style; time-sliced leases
    /// carry their share, e.g. `"1F1G@33%"`.
    pub partition: String,
    pub report: ServeReport,
}

/// The multi-stream run's combined outcome.
#[derive(Debug, Clone)]
pub struct MultiStreamReport {
    pub streams: Vec<StreamReport>,
    /// Combined schedule-cache counters across every stream.
    pub cache: CacheStats,
    /// Wall-clock of the concurrent run on the engine's global clock:
    /// the slowest stream's makespan.
    pub makespan: f64,
    pub total_completed: usize,
    /// Completed inferences per second of concurrent wall-clock.
    pub aggregate_throughput: f64,
    /// Jain fairness index over per-stream service ratios
    /// (achieved/offered rate): 1.0 = perfectly even, → 1/n as one
    /// stream monopolizes the pool.
    pub fairness: f64,
    /// Summed modeled energy across every stream (J) — with
    /// [`MultiStreamReport::throughput_per_joule`], one point on the
    /// serving throughput-vs-joules frontier.
    pub total_energy: f64,
    /// Completed inferences per modeled joule (the Pareto ordinate the
    /// energy-budget sweeps plot).
    pub throughput_per_joule: f64,
    /// Event/lease/migration/budget counters from the serving engine.
    pub engine: EngineMetrics,
}

/// Serving front-end for several concurrent streams over one device pool.
/// A thin wrapper over [`ServingEngine`] that owns the pool, the
/// estimator, the shared schedule cache, and the engine configuration
/// across successive `serve` calls.
pub struct MultiStreamServer<'a, E: PerfEstimator> {
    sys: SystemSpec,
    est: &'a E,
    cache: SharedScheduleCache,
    cfg: EngineConfig,
    prewarm: bool,
}

impl<'a, E: PerfEstimator> MultiStreamServer<'a, E> {
    /// A server over `sys` with a default 64-entry shared schedule cache.
    pub fn new(sys: SystemSpec, est: &'a E) -> Self {
        Self::with_cache(sys, est, ScheduleCache::shared(64))
    }

    /// A server sharing an externally-owned cache (e.g. to persist hit
    /// statistics across successive `serve` calls, or one prewarmed via
    /// [`ScheduleCache::load_from`]).
    pub fn with_cache(sys: SystemSpec, est: &'a E, cache: SharedScheduleCache) -> Self {
        MultiStreamServer { sys, est, cache, cfg: EngineConfig::default(), prewarm: false }
    }

    /// Seed the schedule cache from the streams' workload registry
    /// before the clock starts: `serve` runs
    /// [`MultiStreamServer::registry_prewarm`] first, so under static
    /// leases the first serving window takes zero cold misses — the
    /// single-engine twin of `FleetConfig::registry_prewarm`.
    pub fn with_registry_prewarm(mut self) -> Self {
        self.prewarm = true;
        self
    }

    /// Override the engine configuration — e.g.
    /// `EngineConfig::builder().static_leases().build()` to freeze the
    /// initial leases (serving runs adaptive with cache prewarming by
    /// default).
    pub fn with_engine_config(mut self, cfg: EngineConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Handle to the shared cache (e.g. for reporting after a run).
    pub fn cache(&self) -> SharedScheduleCache {
        self.cache.clone()
    }

    /// Lease the pool by stream demand, then serve every stream's trace
    /// to completion through the global event loop.
    pub fn serve(&mut self, streams: &[StreamSpec]) -> MultiStreamReport {
        if self.prewarm {
            self.registry_prewarm(streams);
        }
        ServingEngine::new(self.sys.clone(), self.est)
            .with_cache(self.cache.clone())
            .with_config(self.cfg.clone())
            .serve(streams)
    }

    /// Seed the cache for `streams` at spin-up: mirror the engine's
    /// initial lease apportionment (SLO-weighted demand,
    /// [`crate::engine::lease`] over the whole pool), then run the DP
    /// once per distinct (lane partition, regime, objective) key the
    /// streams will look up on first admission and insert the plans —
    /// exactly what each lane's coordinator would compute on its first
    /// cold miss, done before the clock starts. `Balanced`-objective
    /// lanes bypass the cache and are skipped. Returns the number of
    /// plans seeded.
    pub fn registry_prewarm(&self, streams: &[StreamSpec]) -> usize {
        if streams.is_empty() {
            return 0;
        }
        let weighted: Vec<f64> =
            streams.iter().map(|s| s.demand() * self.cfg.slo.weight(&s.slo, None)).collect();
        let assignment = lease::assign(&self.sys, &weighted);
        let mut cache = self.cache.lock().unwrap();
        let mut seeded = 0;
        for (i, s) in streams.iter().enumerate() {
            if matches!(s.objective, Objective::Balanced { .. }) {
                continue;
            }
            let (part, _) = assignment.lease_of(i);
            let fp = system_fingerprint(part);
            for r in &s.trace {
                let key = CacheKey::new(fp, &r.workload, s.objective);
                if cache.contains(&key) {
                    continue;
                }
                let sched = DpScheduler::new(part, self.est).schedule(&r.workload, s.objective);
                cache.insert(key, sched.plan());
                seeded += 1;
            }
        }
        seeded
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::workload::{gnn, transformer, Dataset, Workload};

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4) // 3F + 2G
    }

    fn gcn(edges: u64) -> Workload {
        gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, edges, 200, 0.2), 2, 128)
    }

    #[test]
    fn partition_conserves_inventory_and_guarantees_progress() {
        let s = sys();
        for demands in [
            vec![1.0, 1.0],
            vec![10.0, 1.0],
            vec![1.0, 0.0],
            vec![5.0, 3.0, 1.0],
            vec![1.0, 1.0, 1.0, 1.0, 1.0],
        ] {
            let parts = partition_system(&s, &demands).expect("enough devices");
            assert_eq!(parts.len(), demands.len());
            assert_eq!(parts.iter().map(|p| p.n_fpga).sum::<usize>(), s.n_fpga);
            assert_eq!(parts.iter().map(|p| p.n_gpu).sum::<usize>(), s.n_gpu);
            for p in &parts {
                assert!(p.n_fpga + p.n_gpu >= 1, "a stream got no devices: {demands:?}");
            }
        }
    }

    #[test]
    fn heavier_demand_gets_more_devices() {
        let parts = partition_system(&sys(), &[9.0, 1.0]).unwrap();
        assert!(parts[0].n_fpga + parts[0].n_gpu > parts[1].n_fpga + parts[1].n_gpu);
    }

    #[test]
    fn oversubscription_is_an_error_not_a_panic() {
        let err = partition_system(&sys(), &[1.0; 6]).unwrap_err();
        assert_eq!(err, OverSubscribed { streams: 6, devices: 5 });
        assert!(err.to_string().contains("time-sliced leases"));
    }

    #[test]
    fn eight_streams_on_three_devices_all_make_progress() {
        // The old `partition_system` panicked here; the engine's
        // time-sliced leases serve it by construction.
        let s = SystemSpec::reduced_testbed(Interconnect::Pcie4); // 2F + 1G
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let streams: Vec<StreamSpec> = (0..8u64)
            .map(|i| {
                let trace = super::super::server::generate_trace(
                    &[(gcn(2_000_000), 5)],
                    8.0,
                    50 + i,
                );
                StreamSpec::new(format!("stream-{i}"), Objective::Performance, trace)
            })
            .collect();
        let mut server = MultiStreamServer::new(s, &est);
        let r = server.serve(&streams);
        assert_eq!(r.total_completed, 40, "no stream may starve");
        assert!(r.fairness > 0.0, "fairness {}", r.fairness);
        for sr in &r.streams {
            assert_eq!(sr.report.completed, 5, "{} starved", sr.name);
            assert!(sr.report.p50_latency <= sr.report.p99_latency);
        }
    }

    #[test]
    fn two_streams_serve_to_completion_without_starvation() {
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let gcn_trace = super::super::server::generate_trace(
            &[(gcn(2_000_000), 12), (gcn(150_000_000), 12), (gcn(2_000_000), 12)],
            15.0,
            11,
        );
        let tf_trace = super::super::server::generate_trace(
            &[
                (transformer::transformer_workload(2048, 512, 4), 10),
                (transformer::transformer_workload(8192, 512, 4), 10),
                (transformer::transformer_workload(2048, 512, 4), 10),
            ],
            10.0,
            13,
        );
        let streams = vec![
            StreamSpec::new("gcn-traffic", Objective::Performance, gcn_trace),
            StreamSpec::new("transformer", Objective::Performance, tf_trace),
        ];
        let mut server = MultiStreamServer::new(s, &est);
        let r = server.serve(&streams);

        assert_eq!(r.total_completed, 66, "every request of every stream completes");
        for sr in &r.streams {
            assert!(sr.report.p50_latency <= sr.report.p99_latency);
            assert!(sr.report.p99_latency.is_finite());
        }
        // Recurring drift (phase 3 revisits phase 1's bucket) + intra-phase
        // repeats ⇒ the shared cache absorbs most reschedule decisions —
        // under the *adaptive default*, because migrations prewarm the
        // prospective partition's keys. Only a regime's first sighting
        // (≤ 8 of them) or the fallout of an unfittable prewarm (at most
        // two DP re-runs each across migration chains) may run the DP.
        assert!(r.cache.hit_rate() > 0.5, "hit rate {}", r.cache.hit_rate());
        assert!(
            r.cache.misses <= 8 + 2 * r.engine.prewarm_misses,
            "misses {} vs {} prewarm misses",
            r.cache.misses,
            r.engine.prewarm_misses
        );
        assert!(r.fairness > 0.5, "fairness {}", r.fairness);
        assert!(r.makespan > 0.0 && r.aggregate_throughput > 0.0);
        // Every request pops an arrival plus (except each stream's final
        // slot, still in the heap when the run drains) a completion.
        assert!(r.engine.events_processed >= 2 * 66 - 2, "events {}", r.engine.events_processed);
    }

    #[test]
    fn identical_twin_streams_share_cached_schedules() {
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let trace = super::super::server::generate_trace(&[(gcn(2_000_000), 10)], 10.0, 7);
        let streams = vec![
            StreamSpec::new("a", Objective::Performance, trace.clone()),
            StreamSpec::new("b", Objective::Performance, trace),
        ];
        let mut server = MultiStreamServer::new(s, &est);
        let r = server.serve(&streams);
        // Equal demand ⇒ twin partitions differ (3F2G split unevenly), but
        // each stream still only misses on its own first request bucket.
        assert!(r.cache.misses <= 2, "misses {}", r.cache.misses);
        assert_eq!(r.total_completed, 20);
    }
}
