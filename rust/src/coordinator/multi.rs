//! Multi-stream serving: several concurrent request streams — each with
//! its own workload family, arrival process, and drifting input
//! characteristics — share one heterogeneous device pool (DESIGN.md
//! §Serving).
//!
//! The paper's serving story is a single stream of continuous inferences;
//! a deployment at the ROADMAP's "millions of users" scale multiplexes
//! *many*. This module adds the three pieces that requires:
//!
//! 1. **Device partitioning** — [`partition_system`] splits the
//!    [`SystemSpec`] inventory across the active streams in proportion to
//!    their offered FLOP rate (largest-remainder apportionment per device
//!    type, with a fix-up guaranteeing every stream at least one device —
//!    the spatial-multiplexing analogue of fair-share scheduling, and the
//!    reason no stream can starve: each owns hardware that makes
//!    progress).
//! 2. **Per-stream admission queues** — each stream runs the FIFO
//!    admission/batching loop of [`super::server::serve_trace`] against
//!    its own partition, with its own [`Coordinator`] applying the
//!    reschedule-hysteresis policy to its own drift.
//! 3. **A shared schedule cache** — all per-stream coordinators memoize
//!    into one [`crate::scheduler::ScheduleCache`]; keys embed each
//!    partition's fingerprint, so streams never collide but recurring
//!    drift within a stream (and identical twin streams on identical
//!    partitions) turn reschedules into cache hits. The combined hit
//!    rate is reported in [`MultiStreamReport`].
//!
//! Because partitions are disjoint, streams do not contend for devices
//! and the simulation can serve them one at a time without changing any
//! result; wall-clock quantities in the report treat the streams as
//! concurrent (makespan = max over streams, throughput aggregated).

use crate::config::{Objective, SystemSpec};
use crate::devices::GroundTruth;
use crate::perfmodel::PerfEstimator;
use crate::scheduler::{CacheStats, ScheduleCache, SharedScheduleCache};

use super::server::{serve_trace, Request, ServeReport};
use super::Coordinator;

/// One request stream: a named trace with its own design objective.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    pub name: String,
    pub objective: Objective,
    /// Arrival-ordered requests (see [`super::server::generate_trace`]).
    pub trace: Vec<Request>,
}

impl StreamSpec {
    pub fn new(name: impl Into<String>, objective: Objective, trace: Vec<Request>) -> StreamSpec {
        assert!(!trace.is_empty(), "empty stream trace");
        StreamSpec { name: name.into(), objective, trace }
    }

    /// The trace's arrival span, floored at one second for degenerate
    /// traces (a single request, or an instantaneous burst): dividing by
    /// a near-zero span would report an astronomically inflated rate and
    /// invert the demand-proportional partitioning.
    fn span(&self) -> f64 {
        (self.trace.last().unwrap().arrival - self.trace[0].arrival).max(1.0)
    }

    /// Offered request rate (req/s) over the trace's arrival span.
    pub fn offered_rate(&self) -> f64 {
        self.trace.len() as f64 / self.span()
    }

    /// Offered compute load (FLOP/s) — the demand signal the device
    /// partitioner apportions by.
    pub fn demand(&self) -> f64 {
        let flops: f64 = self.trace.iter().map(|r| r.workload.total_flops()).sum();
        flops / self.span()
    }
}

/// Largest-remainder apportionment of `total` identical devices over
/// normalized `weights` (Σ = 1). Conserves `total` exactly.
fn apportion(total: usize, weights: &[f64]) -> Vec<usize> {
    let quotas: Vec<f64> = weights.iter().map(|w| w * total as f64).collect();
    let mut alloc: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let mut remainder = total - alloc.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    for &i in &order {
        if remainder == 0 {
            break;
        }
        alloc[i] += 1;
        remainder -= 1;
    }
    alloc
}

/// Split a device pool across `demands.len()` active streams,
/// demand-proportionally per device type, guaranteeing every stream at
/// least one device (progress ⇒ no starvation). Panics when there are
/// more streams than devices — spatial multiplexing cannot serve that;
/// time-slicing a partition is an open ROADMAP item.
pub fn partition_system(sys: &SystemSpec, demands: &[f64]) -> Vec<SystemSpec> {
    let k = demands.len();
    assert!(k >= 1, "no streams");
    assert!(
        sys.n_fpga + sys.n_gpu >= k,
        "more streams ({k}) than devices ({})",
        sys.n_fpga + sys.n_gpu
    );
    let total: f64 = demands.iter().sum();
    let weights: Vec<f64> = if total > 0.0 {
        demands.iter().map(|d| d / total).collect()
    } else {
        vec![1.0 / k as f64; k]
    };
    let mut fpgas = apportion(sys.n_fpga, &weights);
    let mut gpus = apportion(sys.n_gpu, &weights);

    // Fix-up: a low-demand stream can be apportioned zero devices; donate
    // one from the richest stream (preserving the donor's progress).
    loop {
        let Some(poor) = (0..k).find(|&i| fpgas[i] + gpus[i] == 0) else { break };
        let rich = (0..k)
            .max_by_key(|&i| fpgas[i] + gpus[i])
            .expect("non-empty");
        assert!(fpgas[rich] + gpus[rich] > 1, "inventory ≥ streams ⇒ a donor exists");
        if fpgas[rich] >= gpus[rich] {
            fpgas[rich] -= 1;
            fpgas[poor] += 1;
        } else {
            gpus[rich] -= 1;
            gpus[poor] += 1;
        }
    }

    (0..k)
        .map(|i| SystemSpec { n_fpga: fpgas[i], n_gpu: gpus[i], ..sys.clone() })
        .collect()
}

/// One stream's outcome: its device share and its serving statistics.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub name: String,
    /// Devices granted by the partitioner, `"2F1G"` style.
    pub partition: String,
    pub report: ServeReport,
}

/// The multi-stream run's combined outcome.
#[derive(Debug, Clone)]
pub struct MultiStreamReport {
    pub streams: Vec<StreamReport>,
    /// Combined schedule-cache counters across every stream.
    pub cache: CacheStats,
    /// Wall-clock of the concurrent run: the slowest stream's makespan.
    pub makespan: f64,
    pub total_completed: usize,
    /// Completed inferences per second of concurrent wall-clock.
    pub aggregate_throughput: f64,
    /// Jain fairness index over per-stream service ratios
    /// (achieved/offered rate): 1.0 = perfectly even, → 1/n as one
    /// stream monopolizes the pool.
    pub fairness: f64,
}

/// Serving front-end for several concurrent streams over one device pool.
pub struct MultiStreamServer<'a, E: PerfEstimator> {
    sys: SystemSpec,
    est: &'a E,
    cache: SharedScheduleCache,
}

impl<'a, E: PerfEstimator> MultiStreamServer<'a, E> {
    /// A server over `sys` with a default 64-entry shared schedule cache.
    pub fn new(sys: SystemSpec, est: &'a E) -> Self {
        Self::with_cache(sys, est, ScheduleCache::shared(64))
    }

    /// A server sharing an externally-owned cache (e.g. to persist hit
    /// statistics across successive `serve` calls).
    pub fn with_cache(sys: SystemSpec, est: &'a E, cache: SharedScheduleCache) -> Self {
        MultiStreamServer { sys, est, cache }
    }

    /// Handle to the shared cache (e.g. for reporting after a run).
    pub fn cache(&self) -> SharedScheduleCache {
        self.cache.clone()
    }

    /// Partition the pool by stream demand, then serve every stream's
    /// trace to completion on its partition.
    pub fn serve(&mut self, streams: &[StreamSpec]) -> MultiStreamReport {
        assert!(!streams.is_empty(), "no streams");
        let cache_before = self.cache.lock().unwrap().stats();
        let demands: Vec<f64> = streams.iter().map(StreamSpec::demand).collect();
        let parts = partition_system(&self.sys, &demands);

        let mut out: Vec<StreamReport> = Vec::with_capacity(streams.len());
        for (spec, part) in streams.iter().zip(&parts) {
            let gt = GroundTruth::new(part.gpu.clone(), part.fpga.clone(), part.comm_model());
            let mut coord = Coordinator::new(part.clone(), self.est, spec.objective)
                .with_cache(self.cache.clone());
            let report = serve_trace(&mut coord, part, &gt, &spec.trace);
            out.push(StreamReport {
                name: spec.name.clone(),
                partition: format!("{}F{}G", part.n_fpga, part.n_gpu),
                report,
            });
        }

        let makespan = out.iter().map(|s| s.report.makespan).fold(0.0, f64::max);
        let total_completed: usize = out.iter().map(|s| s.report.completed).sum();
        let ratios: Vec<f64> = out
            .iter()
            .zip(streams)
            .map(|(s, spec)| s.report.throughput / spec.offered_rate().max(1e-9))
            .collect();
        let fairness = jain_index(&ratios);
        let cache = self.cache.lock().unwrap().stats().since(&cache_before);
        MultiStreamReport {
            streams: out,
            cache,
            makespan,
            total_completed,
            aggregate_throughput: total_completed as f64 / makespan.max(1e-12),
            fairness,
        }
    }
}

/// Jain's fairness index `(Σx)² / (n·Σx²)` over non-negative rates.
fn jain_index(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 0.0;
    }
    sum * sum / (n * sq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Interconnect;
    use crate::perfmodel::OracleModels;
    use crate::workload::{gnn, transformer, Dataset, Workload};

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4) // 3F + 2G
    }

    fn gcn(edges: u64) -> Workload {
        gnn::gcn_workload(&Dataset::new("T", "t", 1_000_000, edges, 200, 0.2), 2, 128)
    }

    #[test]
    fn partition_conserves_inventory_and_guarantees_progress() {
        let s = sys();
        for demands in [
            vec![1.0, 1.0],
            vec![10.0, 1.0],
            vec![1.0, 0.0],
            vec![5.0, 3.0, 1.0],
            vec![1.0, 1.0, 1.0, 1.0, 1.0],
        ] {
            let parts = partition_system(&s, &demands);
            assert_eq!(parts.len(), demands.len());
            assert_eq!(parts.iter().map(|p| p.n_fpga).sum::<usize>(), s.n_fpga);
            assert_eq!(parts.iter().map(|p| p.n_gpu).sum::<usize>(), s.n_gpu);
            for p in &parts {
                assert!(p.n_fpga + p.n_gpu >= 1, "a stream got no devices: {demands:?}");
            }
        }
    }

    #[test]
    fn heavier_demand_gets_more_devices() {
        let parts = partition_system(&sys(), &[9.0, 1.0]);
        assert!(parts[0].n_fpga + parts[0].n_gpu > parts[1].n_fpga + parts[1].n_gpu);
    }

    #[test]
    #[should_panic(expected = "more streams")]
    fn rejects_more_streams_than_devices() {
        partition_system(&sys(), &[1.0; 6]);
    }

    #[test]
    fn apportion_is_exact() {
        assert_eq!(apportion(5, &[0.5, 0.5]).iter().sum::<usize>(), 5);
        assert_eq!(apportion(3, &[0.9, 0.05, 0.05]).iter().sum::<usize>(), 3);
        assert_eq!(apportion(0, &[1.0]), vec![0]);
    }

    #[test]
    fn two_streams_serve_to_completion_without_starvation() {
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let gcn_trace = super::super::server::generate_trace(
            &[(gcn(2_000_000), 12), (gcn(150_000_000), 12), (gcn(2_000_000), 12)],
            15.0,
            11,
        );
        let tf_trace = super::super::server::generate_trace(
            &[
                (transformer::transformer_workload(2048, 512, 4), 10),
                (transformer::transformer_workload(8192, 512, 4), 10),
                (transformer::transformer_workload(2048, 512, 4), 10),
            ],
            10.0,
            13,
        );
        let streams = vec![
            StreamSpec::new("gcn-traffic", Objective::Performance, gcn_trace),
            StreamSpec::new("transformer", Objective::Performance, tf_trace),
        ];
        let mut server = MultiStreamServer::new(s, &est);
        let r = server.serve(&streams);

        assert_eq!(r.total_completed, 66, "every request of every stream completes");
        for sr in &r.streams {
            assert!(sr.report.p50_latency <= sr.report.p99_latency);
            assert!(sr.report.p99_latency.is_finite());
        }
        // Recurring drift (phase 3 revisits phase 1's bucket) + intra-phase
        // repeats ⇒ the shared cache absorbs most reschedule decisions.
        assert!(r.cache.hit_rate() > 0.5, "hit rate {}", r.cache.hit_rate());
        assert!(r.fairness > 0.5, "fairness {}", r.fairness);
        assert!(r.makespan > 0.0 && r.aggregate_throughput > 0.0);
    }

    #[test]
    fn identical_twin_streams_share_cached_schedules() {
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let est = OracleModels { gt: &gt };
        let trace = super::super::server::generate_trace(&[(gcn(2_000_000), 10)], 10.0, 7);
        let streams = vec![
            StreamSpec::new("a", Objective::Performance, trace.clone()),
            StreamSpec::new("b", Objective::Performance, trace),
        ];
        let mut server = MultiStreamServer::new(s, &est);
        let r = server.serve(&streams);
        // Equal demand ⇒ twin partitions differ (3F2G split unevenly), but
        // each stream still only misses on its own first request bucket.
        assert!(r.cache.misses <= 2, "misses {}", r.cache.misses);
        assert_eq!(r.total_completed, 20);
    }
}
