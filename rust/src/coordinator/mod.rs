//! The serving coordinator — DYPE's *dynamic* layer.
//!
//! §II: "The scheduler can dynamically adapt to new scenarios, as in GNN
//! applications like traffic forecasting" — input characteristics
//! (sparsity, sequence length, window) drift at runtime, and the
//! coordinator re-runs Algorithm 1 when the current schedule has become
//! sufficiently suboptimal for the observed inputs (Fig 2's motivating
//! re-optimization).
//!
//! The coordinator owns: the objective, the trained estimators, the
//! current schedule, and a reschedule policy (hysteresis threshold so tiny
//! drifts don't thrash the pipeline — remapping devices costs a drain +
//! reload in a real deployment).
//!
//! Optionally it consults a [`crate::scheduler::ScheduleCache`]: when attached (see
//! [`Coordinator::with_cache`]), recurring drift — input characteristics
//! quantizing to a previously-scheduled bucket — re-times the memoized
//! plan instead of re-running Algorithm 1, turning the reschedule
//! decision from a DP run into an O(stages) evaluation. The cache can be
//! shared by several coordinators ([`multi`]'s per-stream coordinators do
//! exactly that).

pub mod multi;
pub mod server;

pub use multi::{partition_system, MultiStreamReport, MultiStreamServer, StreamReport, StreamSpec};
pub use server::{generate_trace, serve_trace, Completion, Request, ServeReport, Server};

use crate::config::{Objective, SystemSpec};
use crate::devices::CommModel;
use crate::perfmodel::PerfEstimator;
use crate::scheduler::{
    cache::CacheKey, evaluate_plan_into, system_fingerprint, CacheStats, DpScheduler, EvalScratch,
    PowerTable, PrewarmReport, Schedule, SharedScheduleCache, StagePlan,
};
use crate::workload::Workload;

/// One rescheduling decision, for observability and the examples' logs.
#[derive(Debug, Clone)]
pub struct RescheduleEvent {
    pub batch: usize,
    pub workload: String,
    pub old_mnemonic: String,
    pub new_mnemonic: String,
    /// Estimated throughput gain that justified the swap.
    pub estimated_gain: f64,
}

/// Streaming-serving coordinator with input-aware rescheduling.
///
/// The per-batch path ([`Coordinator::process_batch`] /
/// `process_batch_into`) is allocation-free at steady state: the cache
/// key, candidate/re-timed schedules, plan buffers, and evaluation
/// scratch all live on the coordinator and are refilled in place.
/// Allocations happen only on the cold paths — a DP run, a structure
/// swap's log entry, or a capacity grow of one of the scratch buffers.
pub struct Coordinator<'a, E: PerfEstimator> {
    sys: SystemSpec,
    est: &'a E,
    objective: Objective,
    /// Minimum relative period improvement before swapping schedules.
    pub reschedule_threshold: f64,
    current: Option<Schedule>,
    batches_seen: usize,
    events: Vec<RescheduleEvent>,
    /// Optional schedule memoization (possibly shared across streams).
    cache: Option<SharedScheduleCache>,
    /// Fingerprint of `sys`, precomputed for cache keys.
    sys_fp: u64,
    /// Power/comm models for re-timing, rebuilt on [`Coordinator::retarget`].
    power: PowerTable,
    comm: CommModel,
    /// Reusable cache key (refilled per lookup, never reallocated).
    key: CacheKey,
    /// Candidate schedule under construction; swapped into `current` and
    /// recycled from the displaced schedule's allocation.
    cand: Schedule,
    /// Re-timing sink for the hysteresis comparison.
    retimed: Schedule,
    /// Cache-hit plan buffer.
    lookup_buf: Vec<StagePlan>,
    /// Plan buffer backing the by-reference [`Coordinator::process_batch`].
    wrap_buf: Vec<StagePlan>,
    scratch: EvalScratch,
}

impl<'a, E: PerfEstimator> Coordinator<'a, E> {
    pub fn new(sys: SystemSpec, est: &'a E, objective: Objective) -> Self {
        let sys_fp = system_fingerprint(&sys);
        let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
        let comm = sys.comm_model();
        Coordinator {
            sys,
            est,
            objective,
            reschedule_threshold: 0.05,
            current: None,
            batches_seen: 0,
            events: Vec::new(),
            cache: None,
            sys_fp,
            power,
            comm,
            key: CacheKey::default(),
            cand: Schedule::default(),
            retimed: Schedule::default(),
            lookup_buf: Vec::new(),
            wrap_buf: Vec::new(),
            scratch: EvalScratch::default(),
        }
    }

    /// Attach a schedule cache: repeat drift into a previously-seen
    /// quantized characteristic bucket reuses the memoized plan
    /// (re-timed for the observed inputs) instead of re-running the DP.
    pub fn with_cache(mut self, cache: SharedScheduleCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Cache counters, when a cache is attached. Shared caches report the
    /// combined counters of every coordinator using them.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.lock().unwrap().stats())
    }

    /// Move this coordinator onto a different device inventory — the
    /// serving engine calls this when a lease migration hands the stream
    /// a new partition. The current schedule is dropped (it may allocate
    /// devices the new partition does not have), so the next
    /// [`Coordinator::process_batch`] schedules afresh — *without*
    /// logging a reschedule event, because the migration drain is charged
    /// separately by the engine. Reschedule history, hysteresis setting,
    /// and the attached cache are preserved; cache keys re-scope through
    /// the new system fingerprint, and every regime memoized under the
    /// old fingerprint is **prewarmed** onto the new one
    /// ([`crate::scheduler::ScheduleCache::prewarm`]) so the first
    /// post-migration admission of a known regime re-times a carried-over
    /// plan instead of re-running Algorithm 1. Returns the prewarm
    /// outcome (zero without a cache, or for the cache-bypassing
    /// `Balanced` objective).
    pub fn retarget(&mut self, sys: SystemSpec) -> PrewarmReport {
        let old_fp = self.sys_fp;
        self.sys_fp = system_fingerprint(&sys);
        self.sys = sys;
        self.power = PowerTable::new(self.sys.gpu.clone(), self.sys.fpga.clone());
        self.comm = self.sys.comm_model();
        self.current = None;
        let cacheable = !matches!(self.objective, Objective::Balanced { .. });
        match self.cache.as_ref().filter(|_| cacheable) {
            Some(cache) => cache.lock().unwrap().prewarm(
                old_fp,
                self.sys_fp,
                self.sys.n_fpga,
                self.sys.n_gpu,
            ),
            None => PrewarmReport::default(),
        }
    }

    /// Produce the best-known schedule for `wl`: a cache hit re-times the
    /// memoized plan under the current estimator; a miss runs Algorithm 1
    /// and memoizes its structure.
    ///
    /// Constrained objectives need care: a plan that satisfied its
    /// constraint when memoized can violate it after intra-bucket drift.
    /// A `QoS` hit whose re-timed throughput no longer clears the absolute
    /// floor is demoted to a miss (the DP re-runs and the entry is
    /// refreshed). `Balanced`'s floor is *relative* to the
    /// max-over-design-space throughput, which only the DP tables know —
    /// it cannot be re-validated from a single re-timed plan, so Balanced
    /// coordinators bypass the cache entirely.
    fn candidate_into(&mut self, wl: &Workload) {
        let cacheable = !matches!(self.objective, Objective::Balanced { .. });
        let Some(cache) = self.cache.as_ref().filter(|_| cacheable) else {
            self.cand = DpScheduler::new(&self.sys, self.est).schedule(wl, self.objective);
            return;
        };
        self.key.assign(self.sys_fp, wl, self.objective);
        let hit = cache.lock().unwrap().lookup_into(&self.key, &mut self.lookup_buf);
        if hit {
            evaluate_plan_into(
                wl,
                &self.lookup_buf,
                self.est,
                &self.comm,
                &self.power,
                &mut self.scratch,
                &mut self.cand,
            );
            let still_valid = match self.objective {
                Objective::QoS { min_throughput } => {
                    self.cand.throughput() >= min_throughput * (1.0 - 1e-9)
                }
                _ => true,
            };
            if still_valid {
                return;
            }
        }
        let sched = DpScheduler::new(&self.sys, self.est).schedule(wl, self.objective);
        cache.lock().unwrap().insert(self.key.clone(), sched.plan());
        self.cand = sched;
    }

    /// Observe the characteristics of the next input batch and return the
    /// schedule to run it with, rescheduling if the estimated gain exceeds
    /// the hysteresis threshold.
    pub fn process_batch(&mut self, wl: &Workload) -> &Schedule {
        let mut buf = std::mem::take(&mut self.wrap_buf);
        self.process_batch_into(wl, &mut buf);
        self.wrap_buf = buf;
        self.current.as_ref().expect("process_batch_into installs a schedule")
    }

    /// [`Coordinator::process_batch`] into caller-owned storage: `plan_out`
    /// ends holding the installed schedule's frozen plan, and the return
    /// value says whether the structure changed this batch (first
    /// schedule, shape change, or a hysteresis-approved swap) — callers
    /// re-measuring timings can skip the work when it is `false` and
    /// nothing else changed. Allocation-free at steady state.
    pub(crate) fn process_batch_into(
        &mut self,
        wl: &Workload,
        plan_out: &mut Vec<StagePlan>,
    ) -> bool {
        self.batches_seen += 1;
        self.candidate_into(wl);

        let swap = match &self.current {
            None => true,
            Some(cur) => {
                let same_shape = cur.stages.last().map(|s| s.last + 1) == Some(wl.len());
                if !same_shape {
                    true
                } else {
                    // When the candidate keeps the current structure, the
                    // re-timed current *is* the candidate: gain is exactly
                    // 0 and a non-negative threshold can never approve the
                    // swap, so skip the re-timing entirely. (A zero or
                    // negative threshold keeps the explicit comparison —
                    // such a caller wants every tie broken toward the
                    // candidate.)
                    let same_structure = cur.stages.len() == self.cand.stages.len()
                        && cur.stages.iter().zip(&self.cand.stages).all(|(a, b)| {
                            (a.first, a.last, a.dev, a.n) == (b.first, b.last, b.dev, b.n)
                        });
                    if same_structure && self.reschedule_threshold > 0.0 {
                        false
                    } else {
                        // Re-time the current structure under the new input
                        // characteristics; swap only for a real improvement.
                        cur.plan_into(plan_out);
                        evaluate_plan_into(
                            wl,
                            plan_out,
                            self.est,
                            &self.comm,
                            &self.power,
                            &mut self.scratch,
                            &mut self.retimed,
                        );
                        let gain = self.retimed.period / self.cand.period - 1.0;
                        if gain > self.reschedule_threshold {
                            self.events.push(RescheduleEvent {
                                batch: self.batches_seen,
                                workload: wl.name.clone(),
                                old_mnemonic: self.retimed.mnemonic(),
                                new_mnemonic: self.cand.mnemonic(),
                                estimated_gain: gain,
                            });
                            true
                        } else {
                            false
                        }
                    }
                }
            }
        };
        if swap {
            // Install the candidate; the displaced schedule's allocation
            // becomes the next candidate's scratch.
            let prev = self.current.take();
            self.current = Some(std::mem::take(&mut self.cand));
            if let Some(old) = prev {
                self.cand = old;
            }
        }
        self.current.as_ref().expect("swap installs on first batch").plan_into(plan_out);
        swap
    }

    pub fn current_schedule(&self) -> Option<&Schedule> {
        self.current.as_ref()
    }

    pub fn reschedule_events(&self) -> &[RescheduleEvent] {
        &self.events
    }

    pub fn batches_seen(&self) -> usize {
        self.batches_seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::workload::{gnn, Dataset};

    fn setup() -> (SystemSpec, GroundTruth) {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        (s, g)
    }

    #[test]
    fn first_batch_always_schedules() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s, &oracle, Objective::Performance);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let sched = c.process_batch(&wl);
        assert!(!sched.stages.is_empty());
        assert!(c.reschedule_events().is_empty(), "first schedule is not a reschedule");
    }

    #[test]
    fn stable_inputs_do_not_thrash() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s, &oracle, Objective::Performance);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        for _ in 0..10 {
            c.process_batch(&wl);
        }
        assert!(c.reschedule_events().is_empty());
    }

    #[test]
    fn sparsity_shift_triggers_reschedule_when_profitable() {
        // Fig 2's scenario: the same model, drastically different input
        // sparsity ⇒ different optimal schedule.
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s, &oracle, Objective::Performance);
        let dense_wl = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
        let sparse_wl = gnn::gcn_workload(&Dataset::synthetic4(), 2, 128);
        let first = c.process_batch(&dense_wl).mnemonic();
        let second = c.process_batch(&sparse_wl).mnemonic();
        // If DYPE picked different schedules, an event must be logged.
        if first != second {
            assert!(!c.reschedule_events().is_empty());
            assert!(c.reschedule_events()[0].estimated_gain > 0.05);
        }
    }

    #[test]
    fn cached_coordinator_hits_on_recurring_drift() {
        use crate::scheduler::ScheduleCache;
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let cache = ScheduleCache::shared(16);
        let mut c = Coordinator::new(s, &oracle, Objective::Performance).with_cache(cache);
        let dense = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
        let sparse = gnn::gcn_workload(&Dataset::synthetic4(), 2, 128);
        // Two regimes, revisited repeatedly: only the first visit of each
        // regime runs the DP.
        for _ in 0..4 {
            c.process_batch(&dense);
            c.process_batch(&sparse);
        }
        let st = c.cache_stats().unwrap();
        assert_eq!(st.misses, 2, "one DP per distinct regime");
        assert_eq!(st.hits, 6);
        assert!(st.hit_rate() > 0.5);
    }

    #[test]
    fn cached_and_uncached_coordinators_agree_on_first_schedule() {
        use crate::scheduler::ScheduleCache;
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let mut plain = Coordinator::new(s.clone(), &oracle, Objective::Performance);
        let mut cached = Coordinator::new(s, &oracle, Objective::Performance)
            .with_cache(ScheduleCache::shared(4));
        assert_eq!(plain.process_batch(&wl).mnemonic(), cached.process_batch(&wl).mnemonic());
        // Re-processing the same batch is a hit and yields the same plan.
        assert_eq!(plain.process_batch(&wl).mnemonic(), cached.process_batch(&wl).mnemonic());
        assert_eq!(cached.cache_stats().unwrap().hits, 1);
    }

    #[test]
    fn retarget_reschedules_fresh_without_logging_an_event() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s.clone(), &oracle, Objective::Performance);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        c.process_batch(&wl);
        assert!(c.current_schedule().is_some());

        let shrunk = SystemSpec { n_fpga: 1, n_gpu: 1, ..s };
        c.retarget(shrunk.clone());
        assert!(c.current_schedule().is_none(), "migration drops the stale schedule");
        let sched = c.process_batch(&wl).clone();
        assert!(
            sched.validate(wl.len(), shrunk.n_fpga, shrunk.n_gpu).is_ok(),
            "fresh schedule must fit the new inventory"
        );
        assert!(c.reschedule_events().is_empty(), "migration is not a reschedule event");
    }

    #[test]
    fn retarget_prewarms_known_regimes_onto_the_new_inventory() {
        use crate::scheduler::ScheduleCache;
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s.clone(), &oracle, Objective::Performance)
            .with_cache(ScheduleCache::shared(16));
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        c.process_batch(&wl); // miss: DP + memoize under the old fingerprint
        assert_eq!(c.cache_stats().unwrap().misses, 1);

        // Growing the inventory guarantees the old plan re-fits.
        let grown = SystemSpec { n_fpga: s.n_fpga + 1, n_gpu: s.n_gpu + 1, ..s };
        let prewarm = c.retarget(grown);
        assert_eq!(prewarm.hits, 1, "the known regime must carry over");
        assert_eq!(prewarm.misses, 0);

        // First post-migration admission of the known regime: a hit, not
        // a cold DP re-run.
        let misses_before = c.cache_stats().unwrap().misses;
        c.process_batch(&wl);
        let st = c.cache_stats().unwrap();
        assert_eq!(st.misses, misses_before, "prewarmed regime must not go cold");
        assert_eq!(st.prewarm_hits, 1);
    }

    #[test]
    fn threshold_suppresses_marginal_swaps() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s, &oracle, Objective::Performance);
        c.reschedule_threshold = f64::INFINITY; // never swap after the first
        let a = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
        let b = gnn::gcn_workload(&Dataset::synthetic4(), 2, 128);
        let first = c.process_batch(&a).mnemonic();
        let second = c.process_batch(&b).mnemonic();
        assert_eq!(first, second);
        assert!(c.reschedule_events().is_empty());
    }
}
