//! The serving coordinator — DYPE's *dynamic* layer.
//!
//! §II: "The scheduler can dynamically adapt to new scenarios, as in GNN
//! applications like traffic forecasting" — input characteristics
//! (sparsity, sequence length, window) drift at runtime, and the
//! coordinator re-runs Algorithm 1 when the current schedule has become
//! sufficiently suboptimal for the observed inputs (Fig 2's motivating
//! re-optimization).
//!
//! The coordinator owns: the objective, the trained estimators, the
//! current schedule, and a reschedule policy (hysteresis threshold so tiny
//! drifts don't thrash the pipeline — remapping devices costs a drain +
//! reload in a real deployment).

pub mod server;

use crate::config::{Objective, SystemSpec};
use crate::perfmodel::PerfEstimator;
use crate::scheduler::{evaluate_plan, DpScheduler, PowerTable, Schedule};
use crate::workload::Workload;

/// One rescheduling decision, for observability and the examples' logs.
#[derive(Debug, Clone)]
pub struct RescheduleEvent {
    pub batch: usize,
    pub workload: String,
    pub old_mnemonic: String,
    pub new_mnemonic: String,
    /// Estimated throughput gain that justified the swap.
    pub estimated_gain: f64,
}

/// Streaming-serving coordinator with input-aware rescheduling.
pub struct Coordinator<'a, E: PerfEstimator> {
    sys: SystemSpec,
    est: &'a E,
    objective: Objective,
    /// Minimum relative period improvement before swapping schedules.
    pub reschedule_threshold: f64,
    current: Option<Schedule>,
    batches_seen: usize,
    events: Vec<RescheduleEvent>,
}

impl<'a, E: PerfEstimator> Coordinator<'a, E> {
    pub fn new(sys: SystemSpec, est: &'a E, objective: Objective) -> Self {
        Coordinator {
            sys,
            est,
            objective,
            reschedule_threshold: 0.05,
            current: None,
            batches_seen: 0,
            events: Vec::new(),
        }
    }

    /// Observe the characteristics of the next input batch and return the
    /// schedule to run it with, rescheduling if the estimated gain exceeds
    /// the hysteresis threshold.
    pub fn process_batch(&mut self, wl: &Workload) -> &Schedule {
        self.batches_seen += 1;
        let candidate = DpScheduler::new(&self.sys, self.est).schedule(wl, self.objective);

        let swap = match &self.current {
            None => true,
            Some(cur) => {
                // Re-time the current structure under the new input
                // characteristics; swap only for a real improvement.
                let power = PowerTable::new(self.sys.gpu.clone(), self.sys.fpga.clone());
                let same_shape = cur.stages.last().map(|s| s.last + 1) == Some(wl.len());
                if !same_shape {
                    true
                } else {
                    let retimed =
                        evaluate_plan(wl, &cur.plan(), self.est, &self.sys.comm_model(), &power);
                    let gain = retimed.period / candidate.period - 1.0;
                    if gain > self.reschedule_threshold {
                        self.events.push(RescheduleEvent {
                            batch: self.batches_seen,
                            workload: wl.name.clone(),
                            old_mnemonic: retimed.mnemonic(),
                            new_mnemonic: candidate.mnemonic(),
                            estimated_gain: gain,
                        });
                        true
                    } else {
                        false
                    }
                }
            }
        };
        if swap {
            self.current = Some(candidate);
        }
        self.current.as_ref().unwrap()
    }

    pub fn current_schedule(&self) -> Option<&Schedule> {
        self.current.as_ref()
    }

    pub fn reschedule_events(&self) -> &[RescheduleEvent] {
        &self.events
    }

    pub fn batches_seen(&self) -> usize {
        self.batches_seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{GroundTruth, Interconnect};
    use crate::perfmodel::OracleModels;
    use crate::workload::{gnn, Dataset};

    fn setup() -> (SystemSpec, GroundTruth) {
        let s = SystemSpec::paper_testbed(Interconnect::Pcie4);
        let g = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        (s, g)
    }

    #[test]
    fn first_batch_always_schedules() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s, &oracle, Objective::Performance);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        let sched = c.process_batch(&wl);
        assert!(!sched.stages.is_empty());
        assert!(c.reschedule_events().is_empty(), "first schedule is not a reschedule");
    }

    #[test]
    fn stable_inputs_do_not_thrash() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s, &oracle, Objective::Performance);
        let wl = gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128);
        for _ in 0..10 {
            c.process_batch(&wl);
        }
        assert!(c.reschedule_events().is_empty());
    }

    #[test]
    fn sparsity_shift_triggers_reschedule_when_profitable() {
        // Fig 2's scenario: the same model, drastically different input
        // sparsity ⇒ different optimal schedule.
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s, &oracle, Objective::Performance);
        let dense_wl = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
        let sparse_wl = gnn::gcn_workload(&Dataset::synthetic4(), 2, 128);
        let first = c.process_batch(&dense_wl).mnemonic();
        let second = c.process_batch(&sparse_wl).mnemonic();
        // If DYPE picked different schedules, an event must be logged.
        if first != second {
            assert!(!c.reschedule_events().is_empty());
            assert!(c.reschedule_events()[0].estimated_gain > 0.05);
        }
    }

    #[test]
    fn threshold_suppresses_marginal_swaps() {
        let (s, g) = setup();
        let oracle = OracleModels { gt: &g };
        let mut c = Coordinator::new(s, &oracle, Objective::Performance);
        c.reschedule_threshold = f64::INFINITY; // never swap after the first
        let a = gnn::gcn_workload(&Dataset::synthetic1(), 2, 128);
        let b = gnn::gcn_workload(&Dataset::synthetic4(), 2, 128);
        let first = c.process_batch(&a).mnemonic();
        let second = c.process_batch(&b).mnemonic();
        assert_eq!(first, second);
        assert!(c.reschedule_events().is_empty());
    }
}
