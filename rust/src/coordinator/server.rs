//! Streaming inference server: the request-level layer above the
//! coordinator (the router/batcher shape of serving systems).
//!
//! The paper evaluates "streaming of continuous inferences, which is
//! common in machine learning workloads" (§VII) — this module models that
//! serving loop end to end: requests arrive (deterministic Poisson-like
//! process), a batcher admits them into the pipeline, the simulated
//! pipeline completes them with the current schedule's period, and the
//! coordinator reschedules whenever the observed input characteristics
//! drift. Latency percentiles, queue depths, and reschedule downtime are
//! tracked — the metrics a deployment actually watches.
//!
//! Execution is delegated to the global event-heap engine
//! ([`crate::engine`]): [`serve_trace`] is the engine's single-stream
//! special case (one lane, exclusive full-share lease), so single- and
//! multi-stream serving share one event loop.

use crate::config::{Objective, SystemSpec};
use crate::devices::GroundTruth;
use crate::perfmodel::PerfEstimator;
use crate::scheduler::CacheStats;
use crate::util::Rng;
use crate::workload::Workload;

use super::Coordinator;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    /// Arrival time (s).
    pub arrival: f64,
    /// The workload characteristics this request carries (the data-aware
    /// part: sparsity/shape can differ per request batch).
    pub workload: Workload,
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub arrival: f64,
    pub start: f64,
    pub finish: f64,
}

impl Completion {
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// Serving statistics over a run (one stream's view in multi-stream
/// serving — see [`super::MultiStreamReport`]).
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Per-request completion records, in service order (the raw data
    /// behind the percentiles; also what the engine-equivalence property
    /// tests compare).
    pub completions: Vec<Completion>,
    pub completed: usize,
    pub makespan: f64,
    pub throughput: f64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p90_latency: f64,
    pub p99_latency: f64,
    pub max_queue_depth: usize,
    pub reschedules: usize,
    /// Total pipeline drain time paid for reschedules (s).
    pub reschedule_downtime: f64,
    /// Total modeled energy of this stream's batches (J) — the per-stream
    /// `f_eng` account, what the engine's budget windows were charged.
    pub energy: f64,
    /// Fraction of completions meeting the stream's p99 SLO target
    /// ([`crate::metrics::attainment`]); 1.0 when no target is set.
    pub slo_attainment: f64,
    /// Fraction of the stream's *admission population* (completions plus
    /// shed requests) that finished inside its deadline
    /// ([`crate::metrics::deadline_attainment`]); 1.0 when no deadline
    /// is set. Reported alongside `slo_attainment`: the p99 number
    /// grades the served tail, this one also charges every shed.
    pub deadline_attainment: f64,
    /// Requests the engine's deadline feasibility check shed at
    /// admission (they were never dispatched and never budget-deferred;
    /// 0 for streams without a [`crate::engine::StreamSlo::deadline`]).
    pub shed: usize,
    /// Admissions the engine's energy budget denied this stream (one per
    /// denial decision; 0 without a budget).
    pub deferrals: usize,
    /// In-flight slots of this stream cancelled mid-term by lease
    /// migrations (per-stream view of
    /// [`crate::engine::EngineMetrics::slot_preemptions`], deciding by
    /// the stream's own [`crate::engine::StreamSlo::migration`] override
    /// when set, the policy mode otherwise).
    pub slot_preemptions: usize,
    /// The lane's live incremental p99 estimate as the run ended — the
    /// [`crate::metrics::P2Quantile`] value the SLO controller actually
    /// fed back into lease weight, exported so the controller's input is
    /// inspectable post-run. `None` before any completion. Converges on
    /// `p99_latency` (the exact post-hoc percentile) as observations
    /// grow; the two are identical through the estimator's exact phase
    /// (≤ 5 completions).
    pub p99_estimate: Option<f64>,
    /// Completions the p99 estimator observed — the sample size behind
    /// `p99_estimate` (preempted slots never complete, so this equals
    /// `completed` on the engine path).
    pub p99_observations: usize,
    /// Schedule-cache counters attributable to this run (all-zero when the
    /// serving coordinator has no cache attached).
    pub cache: CacheStats,
}

/// Cost of swapping schedules: the pipeline drains and the new mapping's
/// static data is (re)loaded. Modeled as a fixed drain + weight-reload.
/// Public because the engine charges it inside its dispatch path and the
/// equivalence tests reproduce the legacy accounting against it.
pub const RESCHEDULE_DRAIN_COST: f64 = 50e-3;

/// The streaming server: admission queue + coordinator + simulated
/// pipeline execution.
pub struct Server<'a, E: PerfEstimator> {
    coordinator: Coordinator<'a, E>,
    sys: SystemSpec,
    gt: GroundTruth,
}

impl<'a, E: PerfEstimator> Server<'a, E> {
    pub fn new(sys: SystemSpec, est: &'a E, objective: Objective) -> Self {
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
        Server { coordinator: Coordinator::new(sys.clone(), est, objective), sys, gt }
    }

    /// Attach a schedule cache to the serving coordinator (see
    /// [`Coordinator::with_cache`]); the resulting [`ServeReport`] then
    /// carries the run's hit/miss counters.
    pub fn with_cache(mut self, cache: crate::scheduler::SharedScheduleCache) -> Self {
        self.coordinator = self.coordinator.with_cache(cache);
        self
    }

    /// Serve a pre-generated request trace to completion (see
    /// [`serve_trace`] for the service model).
    pub fn serve(&mut self, trace: &[Request]) -> ServeReport {
        serve_trace(&mut self.coordinator, &self.sys, &self.gt, trace)
    }
}

/// The serving loop shared by [`Server`] (one stream) and
/// [`super::MultiStreamServer`] (one lane per stream): since PR 2 this is
/// the *single-stream special case* of the engine's event loop
/// ([`crate::engine`]) — one lane holding an exclusive full-share lease
/// on `sys` (a sole tenant has nothing to re-partition, so this path
/// runs the static-lease config), and there is exactly one event loop in
/// the codebase.
///
/// Service model (unchanged from the legacy synchronous loop, and
/// verified equivalent by the property tests in `rust/tests/engine.rs`):
/// requests are admitted FIFO; the pipeline completes one inference per
/// period (steady-state); characteristic drift between consecutive
/// requests triggers coordinator rescheduling, paying
/// [`RESCHEDULE_DRAIN_COST`]. Latency percentiles are computed with
/// [`crate::metrics::LatencySummary`], and the report carries the
/// schedule-cache counters incurred by this trace alone.
pub fn serve_trace<E: PerfEstimator>(
    coordinator: &mut Coordinator<'_, E>,
    sys: &SystemSpec,
    gt: &GroundTruth,
    trace: &[Request],
) -> ServeReport {
    crate::engine::run_single(coordinator, sys, gt, trace)
}

/// Deterministic Poisson-ish request trace: exponential inter-arrivals at
/// `rate` req/s, workload characteristics drawn from `phases` (each phase
/// contributes a contiguous run of requests).
pub fn generate_trace(
    phases: &[(Workload, usize)],
    rate: f64,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    for (wl, count) in phases {
        for _ in 0..*count {
            // Exponential inter-arrival via inverse CDF.
            t += -(1.0 - rng.gen_f64()).ln() / rate;
            out.push(Request { id: out.len(), arrival: t, workload: wl.clone() });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Interconnect;
    use crate::workload::{gnn, Dataset};

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4)
    }

    fn wl(edges: u64) -> Workload {
        let ds = Dataset::new("T", "t", 1_000_000, edges, 200, 0.2);
        gnn::gcn_workload(&ds, 2, 128)
    }

    #[test]
    fn trace_is_sorted_and_sized() {
        let trace = generate_trace(&[(wl(2_000_000), 10), (wl(50_000_000), 5)], 100.0, 1);
        assert_eq!(trace.len(), 15);
        for w in trace.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn serves_all_requests_with_sane_latencies() {
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &gt };
        let mut server = Server::new(s, &oracle, Objective::Performance);
        let trace = generate_trace(&[(wl(2_000_000), 30)], 10.0, 2);
        let report = server.serve(&trace);
        assert_eq!(report.completed, 30);
        assert!(report.p50_latency <= report.p99_latency);
        assert!(report.mean_latency > 0.0);
        assert!(report.energy > 0.0);
        assert_eq!(report.reschedules, 0, "stable characteristics must not thrash");
    }

    #[test]
    fn drift_triggers_bounded_reschedules() {
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &gt };
        let mut server = Server::new(s, &oracle, Objective::Performance);
        let trace = generate_trace(
            &[(wl(2_000_000), 10), (wl(150_000_000), 10), (wl(2_000_000), 10)],
            20.0,
            3,
        );
        let report = server.serve(&trace);
        assert_eq!(report.completed, 30);
        assert!(report.reschedules >= 1, "the drift should trigger a reschedule");
        assert!(report.reschedules <= 4, "hysteresis must bound thrash: {}", report.reschedules);
        assert!(report.reschedule_downtime < report.makespan * 0.5);
    }

    #[test]
    fn cached_server_hits_on_recurring_drift() {
        use crate::scheduler::ScheduleCache;
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &gt };
        let mut server =
            Server::new(s, &oracle, Objective::Performance).with_cache(ScheduleCache::shared(8));
        // Day-cycle drift repeated twice: the second cycle re-hits the
        // first cycle's buckets, and within a phase every request hits.
        let day: Vec<(Workload, usize)> =
            [2u64, 150, 8, 2, 150, 8].iter().map(|m| (wl(m * 1_000_000), 5)).collect();
        let report = server.serve(&generate_trace(&day, 20.0, 5));
        assert_eq!(report.completed, 30);
        assert!(report.cache.hit_rate() > 0.5, "hit rate {}", report.cache.hit_rate());
        assert!(report.cache.misses <= 3, "one DP per distinct regime");
    }

    #[test]
    fn overload_grows_queue_underload_does_not() {
        let s = sys();
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let oracle = OracleModels { gt: &gt };
        // Service rate for this workload is ~24 inf/s (see examples).
        let slow = {
            let mut server = Server::new(s.clone(), &oracle, Objective::Performance);
            server.serve(&generate_trace(&[(wl(2_000_000), 40)], 2.0, 4))
        };
        let fast = {
            let mut server = Server::new(s, &oracle, Objective::Performance);
            server.serve(&generate_trace(&[(wl(2_000_000), 40)], 500.0, 4))
        };
        assert!(fast.max_queue_depth > slow.max_queue_depth);
        assert!(fast.p99_latency > slow.p99_latency);
    }
}
