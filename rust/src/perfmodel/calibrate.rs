//! Two-step calibration (§V): generate synthetic input profiles spanning
//! the workload characteristic space, "benchmark" them on the hardware
//! (the ground-truth harness), and fit the linear estimators.
//!
//! The estimators never see the device models' internals — only
//! (features, measured time) pairs, exactly like the paper's methodology.

use super::features::features;
use super::linreg::LinReg;
use super::ModelRegistry;
use crate::config::SystemSpec;
use crate::devices::{DeviceType, GroundTruth};
use crate::workload::KernelKind;

/// Samples per (kernel family × device) fit set.
const SAMPLES: usize = 160;
const RIDGE: f64 = 1e-8;

use crate::util::Rng;

/// Synthetic SpMM profiles spanning Table I's characteristic ranges
/// (vertices 100K–4M, densities 1e-7–5e-3, feature widths 16–600).
fn spmm_profiles(rng: &mut Rng) -> Vec<KernelKind> {
    (0..SAMPLES)
        .map(|_| {
            let m = rng.log_uniform(1e5, 4e6) as u64;
            let density = rng.log_uniform(1e-7, 5e-3);
            let nnz = ((m as f64 * m as f64 * density) as u64).max(m);
            let n = rng.log_uniform(16.0, 600.0) as u64;
            KernelKind::SpMM { m, k: m, n, nnz }
        })
        .collect()
}

/// Synthetic GEMM profiles: GNN feature GEMMs (tall-skinny) and
/// transformer projections.
fn gemm_profiles(rng: &mut Rng) -> Vec<KernelKind> {
    (0..SAMPLES)
        .map(|_| {
            let m = rng.log_uniform(1e3, 4e6) as u64;
            let k = rng.log_uniform(16.0, 2048.0) as u64;
            let n = rng.log_uniform(16.0, 2048.0) as u64;
            KernelKind::Gemm { m, k, n }
        })
        .collect()
}

/// Synthetic sliding-window profiles over the §IV-B grid.
fn winattn_profiles(rng: &mut Rng) -> Vec<KernelKind> {
    (0..SAMPLES)
        .map(|_| {
            let seq = rng.log_uniform(1024.0, 16384.0) as u64;
            let window = (rng.log_uniform(512.0, 4096.0) as u64).min(seq);
            KernelKind::WindowAttn { seq, window, heads: 8, dim: 64 }
        })
        .collect()
}

/// Fit one estimator: benchmark `profiles` on `dev` and regress.
fn fit_family(
    gt: &GroundTruth,
    sys: &SystemSpec,
    profiles: &[KernelKind],
    dev: DeviceType,
) -> LinReg {
    let xs: Vec<Vec<f64>> = profiles.iter().map(|k| features(k, dev, &sys.fpga)).collect();
    let ys: Vec<f64> = profiles.iter().map(|k| gt.kernel_time(k, dev, 1)).collect();
    LinReg::fit_relative(&xs, &ys, RIDGE).expect("calibration fit failed")
}

/// Run the full §V calibration for a system: returns the trained
/// [`ModelRegistry`] backing `f_perf`.
pub fn calibrated_registry(sys: &SystemSpec) -> ModelRegistry {
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    calibrated_registry_against(sys, &gt)
}

/// Calibrate against an explicit ground truth (tests inject noise-free or
/// skewed variants).
pub fn calibrated_registry_against(sys: &SystemSpec, gt: &GroundTruth) -> ModelRegistry {
    let mut rng = Rng::seed_from_u64(0xD17E);
    let spmm = spmm_profiles(&mut rng);
    let gemm = gemm_profiles(&mut rng);
    let wattn = winattn_profiles(&mut rng);

    let mut reg = ModelRegistry::new(sys.fpga.clone(), sys.comm_model());
    for dev in DeviceType::ALL {
        reg.insert("spmm", dev, fit_family(gt, sys, &spmm, dev));
        reg.insert("gemm", dev, fit_family(gt, sys, &gemm, dev));
        reg.insert("winattn", dev, fit_family(gt, sys, &wattn, dev));
    }
    reg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Interconnect;

    fn sys() -> SystemSpec {
        SystemSpec::paper_testbed(Interconnect::Pcie4)
    }

    #[test]
    fn calibration_produces_six_models() {
        let reg = calibrated_registry(&sys());
        assert_eq!(reg.len(), 6);
    }

    #[test]
    fn fpga_models_fit_tightly() {
        // FPGA timing is analytically predictable (§V): the regression of
        // the architectural formula must be near-perfect.
        let reg = calibrated_registry(&sys());
        for (tag, dev, _rmse, r2) in reg.fit_report() {
            if dev == DeviceType::Fpga && (tag == "spmm" || tag == "winattn") {
                assert!(r2 > 0.98, "{tag}/FPGA fit poor: r2={r2}");
            }
        }
    }

    #[test]
    fn estimates_are_within_2x_of_ground_truth_on_real_workloads() {
        let s = sys();
        let reg = calibrated_registry(&s);
        let gt = GroundTruth::new(s.gpu.clone(), s.fpga.clone(), s.comm_model());
        let cases = [
            KernelKind::SpMM { m: 170_000, k: 170_000, n: 128, nnz: 1_270_000 },
            KernelKind::SpMM { m: 2_400_000, k: 2_400_000, n: 100, nnz: 63_400_000 },
            KernelKind::Gemm { m: 170_000, k: 128, n: 128 },
            KernelKind::WindowAttn { seq: 4096, window: 512, heads: 8, dim: 64 },
        ];
        for k in &cases {
            for dev in DeviceType::ALL {
                let est = reg.single_device_time(k, dev);
                let truth = gt.kernel_time(k, dev, 1);
                let ratio = est / truth;
                assert!(
                    (0.3..3.0).contains(&ratio),
                    "{k:?} on {dev}: est {est:.3e} vs truth {truth:.3e} (x{ratio:.2})"
                );
            }
        }
    }

    #[test]
    fn estimator_prefers_fpga_only_at_high_sparsity() {
        // The data-aware decision the whole paper hinges on must survive
        // the estimation error.
        let s = sys();
        let reg = calibrated_registry(&s);
        let sparse = KernelKind::SpMM { m: 2_000_000, k: 2_000_000, n: 64, nnz: 4_000_000 };
        let denser = KernelKind::SpMM { m: 230_000, k: 230_000, n: 600, nnz: 120_000_000 };
        let pref = |k: &KernelKind| {
            reg.single_device_time(k, DeviceType::Fpga) / reg.single_device_time(k, DeviceType::Gpu)
        };
        assert!(pref(&sparse) < pref(&denser), "FPGA preference should grow with sparsity");
    }
}
