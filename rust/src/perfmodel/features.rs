//! Feature builders for the §V kernel performance models.
//!
//! One feature vector per (kernel family × device type), matching the
//! paper's equations:
//!
//! * Eq (7)  SpMM-GPU:     `[N, nnz, GFLOP, arm, 1]`
//! * Sextans SpMM-FPGA:    `[(nnz + 13M)·N / (MACs·F), 1]`
//! * Eq (8)  GEMM-GPU:     `[K, N, MN, MK, KN, MKN, 1]`
//! * GEMM-FPGA ([31]):     `[GFLOP, GB, 1]`
//! * Eq (9)  win-attn-FPGA:`[(seq·t_pipe + t_init)·(w/1024)/F, 1]`
//! * win-attn-GPU (dense): `[seq²·d_model·1e-9, seq²·1e-9, seq·d_model·1e-9, 1]`
//!
//! Features are pre-scaled to O(1)–O(10³) magnitudes so the normal-
//! equation solve stays well-conditioned.

use crate::devices::{DeviceType, FpgaConfig};
use crate::workload::KernelKind;

/// Stable model key: one regression per (kernel family, device type).
pub fn model_key(kind: &KernelKind, dev: DeviceType) -> (&'static str, DeviceType) {
    (kind.tag(), dev)
}

/// Build the feature vector for `kind` on `dev`.
pub fn features(kind: &KernelKind, dev: DeviceType, fpga: &FpgaConfig) -> Vec<f64> {
    match (kind, dev) {
        (KernelKind::SpMM { n, nnz, .. }, DeviceType::Gpu) => {
            // Eq (7): t = C1·N + C2·nnz + C3·GFLOP + C4·arm (+ b), extended
            // per §V's "more detailed models for complex kernels" clause
            // with a density-aware compute term (GFLOP/√density — sparse
            // rows under-utilize cache lines superlinearly) and the raw
            // memory-traffic volume.
            let gflop = kind.flops() * 1e-9;
            let arm = kind.arithmetic_intensity();
            vec![
                *n as f64 * 1e-3,
                *nnz as f64 * 1e-9,
                gflop,
                arm,
                gflop / kind.density().sqrt() * 1e-3,
                kind.bytes() * 1e-9,
                1.0,
            ]
        }
        (KernelKind::SpMM { m, n, nnz, .. }, DeviceType::Fpga) => {
            // §V: the architectural formula as the main regressor, scaling
            // factor C and intercept fitted.
            let cycles =
                (*nnz as f64 + 13.0 * *m as f64) * *n as f64 / fpga.spmm_macs;
            vec![cycles / fpga.spmm_freq, 1.0]
        }
        (KernelKind::Gemm { m, k, n }, DeviceType::Gpu) => {
            // Eq (8): t = C1·K + C2·N + C3·MN + C4·MK + C5·KN + C6·MKN + b.
            let (m, k, n) = (*m as f64, *k as f64, *n as f64);
            vec![
                k * 1e-3,
                n * 1e-3,
                m * n * 1e-9,
                m * k * 1e-9,
                k * n * 1e-9,
                m * k * n * 1e-12,
                1.0,
            ]
        }
        (KernelKind::Gemm { .. }, DeviceType::Fpga) => {
            vec![kind.flops() * 1e-9, kind.bytes() * 1e-9, 1.0]
        }
        (KernelKind::WindowAttn { seq, window, .. }, DeviceType::Fpga) => {
            // Eq (9): t = C·(seq·t_pipeline + t_init)·(w/1024)/F (+ b).
            let cyc = *seq as f64 * fpga.attn_t_pipeline + fpga.attn_t_init;
            vec![cyc * (*window as f64 / 1024.0) / fpga.attn_freq, 1.0]
        }
        (KernelKind::WindowAttn { seq, heads, dim, .. }, DeviceType::Gpu) => {
            // §V: dense-computation model — quadratic-in-seq terms.
            let s = *seq as f64;
            let d_model = (*heads * *dim) as f64;
            vec![s * s * d_model * 1e-9, s * s * 1e-9, s * d_model * 1e-9, 1.0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FPGA: fn() -> FpgaConfig = FpgaConfig::default;

    #[test]
    fn spmm_gpu_has_eq7_features() {
        let k = KernelKind::SpMM { m: 1000, k: 1000, n: 128, nnz: 50_000 };
        let f = features(&k, DeviceType::Gpu, &FPGA());
        assert_eq!(f.len(), 7);
        assert!((f[0] - 0.128).abs() < 1e-12); // N·1e-3
        assert!((f[1] - 5e-5).abs() < 1e-12); // nnz·1e-9
    }

    #[test]
    fn gemm_gpu_has_eq8_features() {
        let k = KernelKind::Gemm { m: 100, k: 200, n: 300 };
        let f = features(&k, DeviceType::Gpu, &FPGA());
        assert_eq!(f.len(), 7);
        assert!((f[5] - 100.0 * 200.0 * 300.0 * 1e-12).abs() < 1e-18); // MKN
    }

    #[test]
    fn window_gpu_features_ignore_window() {
        // §V: GPU runs dense attention — the window must not appear.
        let a = KernelKind::WindowAttn { seq: 4096, window: 512, heads: 8, dim: 64 };
        let b = KernelKind::WindowAttn { seq: 4096, window: 2048, heads: 8, dim: 64 };
        assert_eq!(
            features(&a, DeviceType::Gpu, &FPGA()),
            features(&b, DeviceType::Gpu, &FPGA())
        );
    }

    #[test]
    fn fpga_features_embed_architectural_formulas() {
        let k = KernelKind::WindowAttn { seq: 4096, window: 1024, heads: 8, dim: 64 };
        let f = features(&k, DeviceType::Fpga, &FPGA());
        let expect = (4096.0 * 201.0 + 904.0) / 421e6;
        assert!((f[0] - expect).abs() < 1e-12);
    }

    #[test]
    fn all_combinations_produce_finite_features() {
        let kinds = [
            KernelKind::SpMM { m: 3_500_000, k: 3_500_000, n: 20, nnz: 5_000_000 },
            KernelKind::Gemm { m: 16384, k: 512, n: 2048 },
            KernelKind::WindowAttn { seq: 16384, window: 4096, heads: 8, dim: 64 },
        ];
        for k in &kinds {
            for d in DeviceType::ALL {
                for v in features(k, d, &FPGA()) {
                    assert!(v.is_finite());
                }
            }
        }
    }
}
