//! Feature builders for the §V kernel performance models.
//!
//! One feature vector per (kernel family × device type), matching the
//! paper's equations:
//!
//! * Eq (7)  SpMM-GPU:     `[N, nnz, GFLOP, arm, 1]`
//! * Sextans SpMM-FPGA:    `[(nnz + 13M)·N / (MACs·F), 1]`
//! * Eq (8)  GEMM-GPU:     `[K, N, MN, MK, KN, MKN, 1]`
//! * GEMM-FPGA ([31]):     `[GFLOP, GB, 1]`
//! * Eq (9)  win-attn-FPGA:`[(seq·t_pipe + t_init)·(w/1024)/F, 1]`
//! * win-attn-GPU (dense): `[seq²·d_model·1e-9, seq²·1e-9, seq·d_model·1e-9, 1]`
//!
//! Features are pre-scaled to O(1)–O(10³) magnitudes so the normal-
//! equation solve stays well-conditioned.

use crate::devices::{DeviceType, FpgaConfig};
use crate::workload::KernelKind;

/// Stable model key: one regression per (kernel family, device type).
pub fn model_key(kind: &KernelKind, dev: DeviceType) -> (&'static str, DeviceType) {
    (kind.tag(), dev)
}

/// Octave (log₂) bucket of a dimension — the shape quantizer behind the
/// schedule cache. Two values land in the same bucket iff they are within
/// a factor of two of the same power of two, which is far finer than the
/// granularity at which Algorithm 1 changes its mind about a schedule.
pub fn shape_bucket(x: u64) -> u32 {
    // floor(log2(max(x, 1))): 0→0, 1→0, 2..3→1, 4..7→2, …
    63 - x.max(1).leading_zeros()
}

/// Quarter-decade bucket of a density/sparsity value in (0, 1].
pub fn density_bucket(d: f64) -> i32 {
    if !(d > 0.0) {
        return i32::MIN;
    }
    // floor(4·log10(d)): quarter-decade resolution — S1 (2.3e-3) and S2
    // (2.8e-4) land ~4 buckets apart; a ±30% drift stays in one bucket.
    (4.0 * d.log10()).floor() as i32
}

/// The quantized data-characteristic signature of one kernel — the unit
/// the [`crate::scheduler::ScheduleCache`] keys on. Everything that feeds
/// the §V feature builders above is represented, but coarsened: exact
/// shapes map to octave buckets and densities to quarter-decades, so
/// recurring drift (e.g. rush-hour traffic revisiting yesterday's edge
/// count ±20%) re-hits the cached schedule instead of re-running the DP.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelBucket {
    /// Kernel family (`spmm`/`gemm`/`winattn`).
    pub tag: &'static str,
    /// Octave buckets of the family's shape dimensions.
    pub dims: [u32; 4],
    /// Quarter-decade bucket of the operand density (sparsity signature).
    pub density: i32,
}

/// Quantize a kernel's data characteristics into its cache bucket.
pub fn kernel_bucket(kind: &KernelKind) -> KernelBucket {
    let dims = match *kind {
        KernelKind::SpMM { m, k, n, nnz } => {
            [shape_bucket(m), shape_bucket(k), shape_bucket(n), shape_bucket(nnz)]
        }
        KernelKind::Gemm { m, k, n } => [shape_bucket(m), shape_bucket(k), shape_bucket(n), 0],
        KernelKind::WindowAttn { seq, window, heads, dim } => {
            [shape_bucket(seq), shape_bucket(window), shape_bucket(heads), shape_bucket(dim)]
        }
    };
    KernelBucket { tag: kind.tag(), dims, density: density_bucket(kind.density()) }
}

/// Build the feature vector for `kind` on `dev`.
pub fn features(kind: &KernelKind, dev: DeviceType, fpga: &FpgaConfig) -> Vec<f64> {
    match (kind, dev) {
        (KernelKind::SpMM { n, nnz, .. }, DeviceType::Gpu) => {
            // Eq (7): t = C1·N + C2·nnz + C3·GFLOP + C4·arm (+ b), extended
            // per §V's "more detailed models for complex kernels" clause
            // with a density-aware compute term (GFLOP/√density — sparse
            // rows under-utilize cache lines superlinearly) and the raw
            // memory-traffic volume.
            let gflop = kind.flops() * 1e-9;
            let arm = kind.arithmetic_intensity();
            vec![
                *n as f64 * 1e-3,
                *nnz as f64 * 1e-9,
                gflop,
                arm,
                gflop / kind.density().sqrt() * 1e-3,
                kind.bytes() * 1e-9,
                1.0,
            ]
        }
        (KernelKind::SpMM { m, n, nnz, .. }, DeviceType::Fpga) => {
            // §V: the architectural formula as the main regressor, scaling
            // factor C and intercept fitted.
            let cycles = (*nnz as f64 + 13.0 * *m as f64) * *n as f64 / fpga.spmm_macs;
            vec![cycles / fpga.spmm_freq, 1.0]
        }
        (KernelKind::Gemm { m, k, n }, DeviceType::Gpu) => {
            // Eq (8): t = C1·K + C2·N + C3·MN + C4·MK + C5·KN + C6·MKN + b.
            let (m, k, n) = (*m as f64, *k as f64, *n as f64);
            vec![
                k * 1e-3,
                n * 1e-3,
                m * n * 1e-9,
                m * k * 1e-9,
                k * n * 1e-9,
                m * k * n * 1e-12,
                1.0,
            ]
        }
        (KernelKind::Gemm { .. }, DeviceType::Fpga) => {
            vec![kind.flops() * 1e-9, kind.bytes() * 1e-9, 1.0]
        }
        (KernelKind::WindowAttn { seq, window, .. }, DeviceType::Fpga) => {
            // Eq (9): t = C·(seq·t_pipeline + t_init)·(w/1024)/F (+ b).
            let cyc = *seq as f64 * fpga.attn_t_pipeline + fpga.attn_t_init;
            vec![cyc * (*window as f64 / 1024.0) / fpga.attn_freq, 1.0]
        }
        (KernelKind::WindowAttn { seq, heads, dim, .. }, DeviceType::Gpu) => {
            // §V: dense-computation model — quadratic-in-seq terms.
            let s = *seq as f64;
            let d_model = (*heads * *dim) as f64;
            vec![s * s * d_model * 1e-9, s * s * 1e-9, s * d_model * 1e-9, 1.0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FPGA: fn() -> FpgaConfig = FpgaConfig::default;

    #[test]
    fn spmm_gpu_has_eq7_features() {
        let k = KernelKind::SpMM { m: 1000, k: 1000, n: 128, nnz: 50_000 };
        let f = features(&k, DeviceType::Gpu, &FPGA());
        assert_eq!(f.len(), 7);
        assert!((f[0] - 0.128).abs() < 1e-12); // N·1e-3
        assert!((f[1] - 5e-5).abs() < 1e-12); // nnz·1e-9
    }

    #[test]
    fn gemm_gpu_has_eq8_features() {
        let k = KernelKind::Gemm { m: 100, k: 200, n: 300 };
        let f = features(&k, DeviceType::Gpu, &FPGA());
        assert_eq!(f.len(), 7);
        assert!((f[5] - 100.0 * 200.0 * 300.0 * 1e-12).abs() < 1e-18); // MKN
    }

    #[test]
    fn window_gpu_features_ignore_window() {
        // §V: GPU runs dense attention — the window must not appear.
        let a = KernelKind::WindowAttn { seq: 4096, window: 512, heads: 8, dim: 64 };
        let b = KernelKind::WindowAttn { seq: 4096, window: 2048, heads: 8, dim: 64 };
        assert_eq!(features(&a, DeviceType::Gpu, &FPGA()), features(&b, DeviceType::Gpu, &FPGA()));
    }

    #[test]
    fn fpga_features_embed_architectural_formulas() {
        let k = KernelKind::WindowAttn { seq: 4096, window: 1024, heads: 8, dim: 64 };
        let f = features(&k, DeviceType::Fpga, &FPGA());
        let expect = (4096.0 * 201.0 + 904.0) / 421e6;
        assert!((f[0] - expect).abs() < 1e-12);
    }

    #[test]
    fn shape_buckets_are_octaves() {
        assert_eq!(shape_bucket(0), 0);
        assert_eq!(shape_bucket(1), 0);
        assert_eq!(shape_bucket(2), 1);
        assert_eq!(shape_bucket(3), 1);
        assert_eq!(shape_bucket(4), 2);
        assert_eq!(shape_bucket(1 << 20), 20);
        assert_eq!(shape_bucket((1 << 21) - 1), 20);
    }

    #[test]
    fn density_buckets_quarter_decades() {
        assert_eq!(density_bucket(1.0), 0);
        // ±30% drift around a density stays within one bucket step.
        assert!((density_bucket(1e-3) - density_bucket(1.3e-3)).abs() <= 1);
        // An order of magnitude moves 4 buckets.
        assert_eq!(density_bucket(1e-3) - density_bucket(1e-2), -4);
        assert_eq!(density_bucket(0.0), i32::MIN);
    }

    #[test]
    fn kernel_buckets_separate_families_and_scales() {
        let a = KernelKind::SpMM { m: 1_000_000, k: 1_000_000, n: 128, nnz: 2_000_000 };
        let drifted = KernelKind::SpMM { m: 1_000_000, k: 1_000_000, n: 128, nnz: 2_050_000 };
        let rush = KernelKind::SpMM { m: 1_000_000, k: 1_000_000, n: 128, nnz: 150_000_000 };
        assert_eq!(kernel_bucket(&a), kernel_bucket(&drifted), "small drift: same bucket");
        assert_ne!(kernel_bucket(&a), kernel_bucket(&rush), "75x drift: new bucket");
        let g = KernelKind::Gemm { m: 1_000_000, k: 128, n: 128 };
        assert_ne!(kernel_bucket(&a).tag, kernel_bucket(&g).tag);
    }

    #[test]
    fn all_combinations_produce_finite_features() {
        let kinds = [
            KernelKind::SpMM { m: 3_500_000, k: 3_500_000, n: 20, nnz: 5_000_000 },
            KernelKind::Gemm { m: 16384, k: 512, n: 2048 },
            KernelKind::WindowAttn { seq: 16384, window: 4096, heads: 8, dim: 64 },
        ];
        for k in &kinds {
            for d in DeviceType::ALL {
                for v in features(k, d, &FPGA()) {
                    assert!(v.is_finite());
                }
            }
        }
    }
}
