//! Ordinary least squares / ridge regression via normal equations.
//!
//! The paper's performance models (§V) are linear regressions over
//! hand-designed features, trained on synthetic benchmark profiles. The
//! feature counts are tiny (≤ 7), so a dense normal-equation solve with
//! Cholesky factorization is exact and allocation-cheap.

use anyhow::{ensure, Result};

/// A fitted linear model `y ≈ w · x` (the intercept, when used, is an
/// explicit all-ones feature appended by the feature builder).
#[derive(Debug, Clone)]
pub struct LinReg {
    pub weights: Vec<f64>,
    /// Training diagnostics: root-mean-square error and R² on the fit set.
    pub rmse: f64,
    pub r2: f64,
}

impl LinReg {
    /// Fit with ridge damping `lambda` (relative to the mean diagonal of
    /// XᵀX, so the scale is feature-invariant).
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], lambda: f64) -> Result<LinReg> {
        ensure!(!xs.is_empty(), "empty training set");
        ensure!(xs.len() == ys.len(), "X/y length mismatch");
        let d = xs[0].len();
        ensure!(d > 0, "no features");
        ensure!(xs.iter().all(|x| x.len() == d), "ragged feature rows");
        ensure!(xs.len() >= d, "need at least as many samples as features");

        // Normal equations: (XᵀX + λI) w = Xᵀy.
        let mut xtx = vec![0.0; d * d];
        let mut xty = vec![0.0; d];
        for (x, &y) in xs.iter().zip(ys) {
            for i in 0..d {
                xty[i] += x[i] * y;
                for j in 0..d {
                    xtx[i * d + j] += x[i] * x[j];
                }
            }
        }
        let mean_diag: f64 = (0..d).map(|i| xtx[i * d + i]).sum::<f64>() / d as f64;
        let damp = lambda * mean_diag.max(1e-300);
        for i in 0..d {
            xtx[i * d + i] += damp;
        }

        let weights = cholesky_solve(&xtx, &xty, d)?;

        // Diagnostics.
        let n = ys.len() as f64;
        let mean_y: f64 = ys.iter().sum::<f64>() / n;
        let mut sse = 0.0;
        let mut sst = 0.0;
        for (x, &y) in xs.iter().zip(ys) {
            let pred: f64 = x.iter().zip(&weights).map(|(a, b)| a * b).sum();
            sse += (pred - y) * (pred - y);
            sst += (y - mean_y) * (y - mean_y);
        }
        let rmse = (sse / n).sqrt();
        let r2 = if sst > 0.0 { 1.0 - sse / sst } else { 1.0 };
        Ok(LinReg { weights, rmse, r2 })
    }

    /// Fit minimizing *relative* residuals `Σ((ŷ−y)/y)²` — weighted least
    /// squares with weights `1/y²`. Kernel times span 5+ orders of
    /// magnitude across the §IV characteristic space; plain OLS would let
    /// the multi-second samples dominate and leave microsecond kernels
    /// with huge relative error (which is what drives scheduling
    /// decisions). Implemented by scaling each row and target by `1/y`.
    pub fn fit_relative(xs: &[Vec<f64>], ys: &[f64], lambda: f64) -> Result<LinReg> {
        ensure!(ys.iter().all(|&y| y > 0.0), "relative fit needs positive targets");
        let xs_scaled: Vec<Vec<f64>> = xs
            .iter()
            .zip(ys)
            .map(|(x, &y)| x.iter().map(|v| v / y).collect())
            .collect();
        let ones = vec![1.0; ys.len()];
        let mut m = LinReg::fit(&xs_scaled, &ones, lambda)?;
        // Recompute diagnostics in the original (absolute) space.
        let n = ys.len() as f64;
        let mean_y: f64 = ys.iter().sum::<f64>() / n;
        let mut sse = 0.0;
        let mut sst = 0.0;
        for (x, &y) in xs.iter().zip(ys) {
            let pred = m.predict(x);
            sse += (pred - y) * (pred - y);
            sst += (y - mean_y) * (y - mean_y);
        }
        m.rmse = (sse / n).sqrt();
        m.r2 = if sst > 0.0 { 1.0 - sse / sst } else { 1.0 };
        Ok(m)
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.weights.len());
        x.iter().zip(&self.weights).map(|(a, b)| a * b).sum()
    }
}

/// Solve `A w = b` for symmetric positive-definite `A` (row-major, d×d).
fn cholesky_solve(a: &[f64], b: &[f64], d: usize) -> Result<Vec<f64>> {
    // Factor A = L Lᵀ.
    let mut l = vec![0.0; d * d];
    for i in 0..d {
        for j in 0..=i {
            let mut s = a[i * d + j];
            for k in 0..j {
                s -= l[i * d + k] * l[j * d + k];
            }
            if i == j {
                ensure!(s > 0.0, "matrix not positive definite (pivot {i}: {s})");
                l[i * d + i] = s.sqrt();
            } else {
                l[i * d + j] = s / l[j * d + j];
            }
        }
    }
    // Forward solve L z = b.
    let mut z = vec![0.0; d];
    for i in 0..d {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * d + k] * z[k];
        }
        z[i] = s / l[i * d + i];
    }
    // Back solve Lᵀ w = z.
    let mut w = vec![0.0; d];
    for i in (0..d).rev() {
        let mut s = z[i];
        for k in i + 1..d {
            s -= l[k * d + i] * w[k];
        }
        w[i] = s / l[i * d + i];
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_linear_relation() {
        // y = 3 x0 - 2 x1 + 0.5
        let xs: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64, (i * i % 17) as f64, 1.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x[0] - 2.0 * x[1] + 0.5).collect();
        let m = LinReg::fit(&xs, &ys, 1e-12).unwrap();
        assert!((m.weights[0] - 3.0).abs() < 1e-6);
        assert!((m.weights[1] + 2.0).abs() < 1e-6);
        assert!((m.weights[2] - 0.5).abs() < 1e-6);
        assert!(m.r2 > 0.999999);
    }

    #[test]
    fn handles_noisy_data_with_good_r2() {
        let xs: Vec<Vec<f64>> = (0..200).map(|i| vec![(i % 37) as f64, 1.0]).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 2.0 * x[0] + 1.0 + 0.01 * ((i * 7919 % 13) as f64 - 6.0))
            .collect();
        let m = LinReg::fit(&xs, &ys, 1e-9).unwrap();
        assert!((m.weights[0] - 2.0).abs() < 0.01);
        assert!(m.r2 > 0.99);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(LinReg::fit(&[], &[], 0.0).is_err());
        assert!(LinReg::fit(&[vec![1.0]], &[1.0, 2.0], 0.0).is_err());
        // Fewer samples than features.
        assert!(LinReg::fit(&[vec![1.0, 2.0, 3.0]], &[1.0], 0.0).is_err());
    }

    #[test]
    fn relative_fit_balances_magnitudes() {
        // y spans 1e-5 .. 1e1 with y = 2*x; absolute OLS with an extra
        // noise feature would sacrifice the small samples — relative fit
        // must keep relative error small everywhere.
        let xs: Vec<Vec<f64>> = (0..60)
            .map(|i| {
                let x = 10f64.powf(-5.0 + 6.0 * (i as f64) / 59.0);
                vec![x, 1.0]
            })
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x[0] + 1e-7).collect();
        let m = LinReg::fit_relative(&xs, &ys, 1e-10).unwrap();
        for (x, &y) in xs.iter().zip(&ys) {
            let rel = (m.predict(x) - y).abs() / y;
            assert!(rel < 0.05, "rel err {rel} at y={y}");
        }
    }

    #[test]
    fn relative_fit_rejects_nonpositive_targets() {
        assert!(LinReg::fit_relative(&[vec![1.0]], &[0.0], 0.0).is_err());
    }

    #[test]
    fn ridge_stabilizes_collinear_features() {
        // x1 == x0 exactly: pure OLS normal equations are singular; ridge
        // must still produce a usable predictor.
        let xs: Vec<Vec<f64>> = (1..40).map(|i| vec![i as f64, i as f64]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 4.0 * x[0]).collect();
        let m = LinReg::fit(&xs, &ys, 1e-6).unwrap();
        let pred = m.predict(&[10.0, 10.0]);
        assert!((pred - 40.0).abs() < 0.1, "pred={pred}");
    }
}
