//! Kernel performance estimation (§V): feature extraction, linear
//! regression, the per-(kernel, device) model registry, and the two-step
//! calibration harness (synthetic profiles → benchmark → fit).

pub mod calibrate;
pub mod features;
pub mod linreg;

use std::collections::HashMap;

use crate::devices::{CommModel, DeviceType, FpgaConfig};
use crate::workload::KernelKind;
use linreg::LinReg;

pub use features::{kernel_bucket, KernelBucket};

/// Parallel-efficiency loss per extra device — the scheduler-side mirror
/// of `devices::ground_truth::MULTI_DEV_ALPHA` (the framework profiles the
/// scaling law once at install time; per-kernel noise remains unknown).
const MULTI_DEV_ALPHA: f64 = 0.05;

/// The trained §V estimator set: one [`LinReg`] per (kernel family,
/// device type). This is `f_perf` in Algorithm 1.
#[derive(Debug, Clone)]
pub struct ModelRegistry {
    models: HashMap<(&'static str, DeviceType), LinReg>,
    fpga_cfg: FpgaConfig,
    comm: CommModel,
}

impl ModelRegistry {
    pub fn new(fpga_cfg: FpgaConfig, comm: CommModel) -> Self {
        ModelRegistry { models: HashMap::new(), fpga_cfg, comm }
    }

    pub fn insert(&mut self, tag: &'static str, dev: DeviceType, model: LinReg) {
        self.models.insert((tag, dev), model);
    }

    pub fn get(&self, tag: &str, dev: DeviceType) -> Option<&LinReg> {
        // Keys are 'static strs; match by value.
        self.models.iter().find(|((t, d), _)| *t == tag && *d == dev).map(|(_, m)| m)
    }

    /// Estimated single-device execution time (seconds, clamped ≥ 1 µs —
    /// a linear model can go negative at the domain edge; physical time
    /// cannot).
    pub fn single_device_time(&self, kind: &KernelKind, dev: DeviceType) -> f64 {
        let model = self
            .get(kind.tag(), dev)
            .unwrap_or_else(|| panic!("no model for ({}, {dev})", kind.tag()));
        let x = features::features(kind, dev, &self.fpga_cfg);
        model.predict(&x).max(1e-6)
    }

    /// `f_perf`: estimated time for `kinds` executed sequentially by a
    /// stage of `n` devices of type `dev` (mirrors
    /// [`crate::devices::GroundTruth::group_time`]'s scaling law).
    pub fn stage_time(&self, kinds: &[KernelKind], dev: DeviceType, n: usize) -> f64 {
        assert!(n >= 1);
        let eff = 1.0 + MULTI_DEV_ALPHA * (n as f64 - 1.0);
        kinds
            .iter()
            .map(|k| {
                let mut t = self.single_device_time(k, dev) / n as f64 * eff;
                if n > 1 {
                    let sg = k.output_bytes() * (n as f64 - 1.0) / n as f64 * 0.5;
                    t += sg / self.comm.aggregate_bw(dev, n);
                }
                t
            })
            .sum()
    }

    /// Number of fitted models (diagnostics).
    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Fit-quality summary: (tag, device, rmse, r2) per model.
    pub fn fit_report(&self) -> Vec<(String, DeviceType, f64, f64)> {
        let mut rows: Vec<_> = self
            .models
            .iter()
            .map(|((t, d), m)| (t.to_string(), *d, m.rmse, m.r2))
            .collect();
        rows.sort_by(|a, b| (a.0.clone(), a.1.letter()).cmp(&(b.0.clone(), b.1.letter())));
        rows
    }
}

/// An *oracle* registry — `f_perf` backed directly by ground truth
/// (used by Table III to isolate estimator error from scheduler error).
#[derive(Debug, Clone)]
pub struct OracleModels<'a> {
    pub gt: &'a crate::devices::GroundTruth,
}

/// A common trait so the scheduler accepts either the trained estimators
/// or the ground-truth oracle as `f_perf`.
pub trait PerfEstimator {
    /// Estimated execution time of a kernel group on `n` devices of `dev`.
    fn stage_time(&self, kinds: &[KernelKind], dev: DeviceType, n: usize) -> f64;
}

impl PerfEstimator for ModelRegistry {
    fn stage_time(&self, kinds: &[KernelKind], dev: DeviceType, n: usize) -> f64 {
        ModelRegistry::stage_time(self, kinds, dev, n)
    }
}

impl PerfEstimator for OracleModels<'_> {
    fn stage_time(&self, kinds: &[KernelKind], dev: DeviceType, n: usize) -> f64 {
        self.gt.group_time(kinds, dev, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Interconnect;

    #[test]
    fn registry_panics_without_model() {
        let reg = ModelRegistry::new(FpgaConfig::default(), CommModel::new(Interconnect::Pcie4));
        let k = KernelKind::Gemm { m: 10, k: 10, n: 10 };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            reg.single_device_time(&k, DeviceType::Gpu)
        }));
        assert!(res.is_err());
    }

    #[test]
    fn stage_time_scales_down_with_devices() {
        let mut reg =
            ModelRegistry::new(FpgaConfig::default(), CommModel::new(Interconnect::Pcie4));
        // Trivial constant model: t = 1 ms regardless of features.
        reg.insert(
            "gemm",
            DeviceType::Gpu,
            LinReg {
                weights: vec![0.0; 6].into_iter().chain([1e-3]).collect(),
                rmse: 0.0,
                r2: 1.0,
            },
        );
        let k = KernelKind::Gemm { m: 128, k: 128, n: 128 };
        let t1 = reg.stage_time(&[k], DeviceType::Gpu, 1);
        let t2 = reg.stage_time(&[k], DeviceType::Gpu, 2);
        assert!(t2 < t1);
    }
}
