//! Reporting helpers: aligned console tables, ratio statistics, latency
//! percentiles for the serving reports, and the geometric/arithmetic
//! means the paper's Table IV aggregates with.

/// Arithmetic mean (the paper averages improvement ratios arithmetically).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (reported alongside for robustness).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// `p`-th quantile (`0.0..=1.0`) of an ascending-sorted slice, by the
/// nearest-rank method the serving reports use (`p=0.5` → median).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    assert!((0.0..=1.0).contains(&p), "quantile {p} outside [0, 1]");
    sorted[((sorted.len() as f64 - 1.0) * p).round() as usize]
}

/// Latency distribution summary — the per-stream numbers a serving
/// deployment watches (p50/p90/p99 plus mean and max), in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl LatencySummary {
    /// Summarize a sample of latencies (any order; consumed for sorting).
    pub fn from_unsorted(mut xs: Vec<f64>) -> LatencySummary {
        assert!(!xs.is_empty(), "empty latency sample");
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySummary {
            p50: percentile(&xs, 0.50),
            p90: percentile(&xs, 0.90),
            p99: percentile(&xs, 0.99),
            mean: xs.iter().sum::<f64>() / xs.len() as f64,
            max: *xs.last().unwrap(),
        }
    }
}

/// Incremental quantile estimator — the P² algorithm (Jain & Chlamtac,
/// CACM 1985): five markers track the target quantile, its neighbours,
/// and the extremes, adjusted by a piecewise-parabolic fit on every
/// observation. O(1) time and memory per observation, no sample history
/// — which is what lets the serving engine observe a long-running
/// stream's p99 at every lease re-validation without re-sorting its
/// whole completion record (the [`crate::engine::slo`] controller's
/// measurement side). Exact (nearest-rank) until the five P² markers
/// are fully seeded — i.e. through the fifth observation; the marker
/// heights only start tracking the target quantile from the sixth
/// observation on, and the middle marker of a freshly seeded estimator
/// is the sample *median*, which for p = 0.99 would briefly report the
/// median as the tail.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights q₀..q₄ (q₂ estimates the target quantile).
    q: [f64; 5],
    /// Marker positions (1-based observation ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: usize,
    /// The first five observations, kept for the exact small-sample path.
    init: [f64; 5],
}

impl P2Quantile {
    /// An estimator for the `p`-th quantile (`0.0..=1.0`), e.g. `0.99`.
    pub fn new(p: f64) -> P2Quantile {
        assert!((0.0..=1.0).contains(&p), "quantile {p} outside [0, 1]");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            init: [0.0; 5],
        }
    }

    /// Fold one observation into the estimate.
    pub fn observe(&mut self, x: f64) {
        assert!(x.is_finite(), "non-finite observation {x}");
        if self.count < 5 {
            self.init[self.count] = x;
            self.count += 1;
            if self.count == 5 {
                let mut s = self.init;
                s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                self.q = s;
            }
            return;
        }
        self.count += 1;
        // Locate the cell, stretching the extreme markers if needed.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            (0..4).rfind(|&i| self.q[i] <= x).unwrap_or(0)
        };
        for ni in self.n.iter_mut().skip(k + 1) {
            *ni += 1.0;
        }
        for (npi, dni) in self.np.iter_mut().zip(self.dn) {
            *npi += dni;
        }
        // Nudge the three interior markers toward their desired ranks.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let parabolic = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < parabolic && parabolic < self.q[i + 1] {
                    parabolic
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i]
            + d / (n[i + 1] - n[i - 1])
                * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// The current estimate: `None` before any observation, exact
    /// nearest-rank while the markers are still seeding (count ≤ 5 — at
    /// exactly five the markers hold the sorted sample but have not been
    /// adjusted yet, so `q[2]` would be the *median*, not the target
    /// quantile), the P² marker from the sixth observation on.
    pub fn value(&self) -> Option<f64> {
        match self.count {
            0 => None,
            c if c <= 5 => {
                let mut s = self.init[..c].to_vec();
                s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                Some(percentile(&s, self.p))
            }
            _ => Some(self.q[2]),
        }
    }

    /// Observations folded in so far.
    pub fn count(&self) -> usize {
        self.count
    }
}

/// Format a fraction as a percentage (`0.732` → `73.2%`).
pub fn fmt_percent(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// SLO attainment: the fraction of `latencies` at or under `target`.
/// The per-stream number the serving reports carry next to the
/// percentiles (1.0 = every request met the target).
pub fn attainment(latencies: &[f64], target: f64) -> f64 {
    if latencies.is_empty() {
        return 1.0;
    }
    latencies.iter().filter(|&&l| l <= target).count() as f64 / latencies.len() as f64
}

/// Deadline attainment over a stream's whole admission population:
/// completions at or under `deadline`, divided by completions *plus*
/// `shed` requests — a request the engine shed at admission missed its
/// deadline by definition, so unlike [`attainment`] the denominator
/// counts it. 1.0 for an empty population.
pub fn deadline_attainment(latencies: &[f64], deadline: f64, shed: usize) -> f64 {
    let n = latencies.len() + shed;
    if n == 0 {
        return 1.0;
    }
    latencies.iter().filter(|&&l| l <= deadline).count() as f64 / n as f64
}

/// Simple fixed-width console table writer for the bench harnesses.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for i in 0..ncol {
                s.push_str(&format!("{:<w$}  ", cells[i], w = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format a ratio the way the paper's tables do (`1.53x`).
pub fn fmt_ratio(x: f64) -> String {
    format!("{x:.2}x")
}

/// Jain's fairness index `(Σx)² / (n·Σx²)` over non-negative rates:
/// 1.0 = perfectly even, → 1/n as one participant monopolizes. Used by
/// the serving engine over per-stream achieved/offered service ratios.
pub fn jain_index(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 0.0;
    }
    sum * sum / (n * sq)
}

/// Indices of the Pareto-non-dominated rows of `points`, every
/// dimension maximized (negate a dimension to minimize it). A point is
/// dominated when some other point is at least as good everywhere and
/// strictly better somewhere; exact duplicates dominate nothing, so
/// both survive. O(n²·d) — sized for report grids, not DP tables (the
/// scheduler keeps its own specialized
/// [`crate::scheduler::pareto_front`]).
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<usize> {
    if let Some(first) = points.first() {
        for p in points {
            assert_eq!(p.len(), first.len(), "ragged pareto points");
        }
    }
    let dominates = |a: &[f64], b: &[f64]| {
        a.iter().zip(b).all(|(x, y)| x >= y) && a.iter().zip(b).any(|(x, y)| x > y)
    };
    (0..points.len())
        .filter(|&i| !points.iter().enumerate().any(|(j, p)| j != i && dominates(p, &points[i])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means() {
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "thp"]);
        t.row(vec!["GCN-OA".into(), "1.53x".into()]);
        let out = t.render();
        assert!(out.contains("GCN-OA"));
        assert!(out.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }

    #[test]
    fn ratio_format_matches_paper_style() {
        assert_eq!(fmt_ratio(1.534), "1.53x");
        assert_eq!(fmt_percent(0.7321), "73.2%");
    }

    #[test]
    fn attainment_is_a_fraction_of_met_latencies() {
        assert_eq!(attainment(&[], 0.1), 1.0, "vacuous attainment");
        assert_eq!(attainment(&[0.05, 0.1, 0.2, 0.4], 0.1), 0.5);
        assert_eq!(attainment(&[0.05, 0.06], 0.1), 1.0);
        assert_eq!(attainment(&[0.5, 0.6], 0.1), 0.0);
    }

    #[test]
    fn jain_index_bounds() {
        assert!((jain_index(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let skew = jain_index(&[1.0, 0.0, 0.0]);
        assert!((skew - 1.0 / 3.0).abs() < 1e-12, "monopolist → 1/n, got {skew}");
        assert_eq!(jain_index(&[0.0, 0.0]), 0.0, "degenerate sample");
    }

    #[test]
    fn pareto_front_keeps_exactly_the_non_dominated() {
        // b dominates a; c trades off against b; d duplicates c.
        let pts = vec![
            vec![1.0, 1.0], // a: dominated by b
            vec![2.0, 2.0], // b
            vec![3.0, 0.5], // c: better x, worse y
            vec![3.0, 0.5], // d: exact duplicate of c
        ];
        assert_eq!(pareto_front(&pts), vec![1, 2, 3]);
        assert_eq!(pareto_front(&[]), Vec::<usize>::new());
        assert_eq!(pareto_front(&[vec![1.0]]), vec![0]);
    }

    #[test]
    fn p2_is_exact_below_five_observations() {
        let mut est = P2Quantile::new(0.99);
        assert_eq!(est.value(), None, "no observations, no estimate");
        for (i, x) in [3.0, 1.0, 2.0].iter().enumerate() {
            est.observe(*x);
            assert_eq!(est.count(), i + 1);
        }
        let mut sorted = vec![3.0, 1.0, 2.0];
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(est.value(), Some(percentile(&sorted, 0.99)));
    }

    #[test]
    fn p2_cold_start_is_exact_at_every_seed_count() {
        // The cold-start regression: for a tail quantile every estimate
        // during marker seeding must be the exact nearest-rank
        // percentile of the samples seen so far — at 1, 2, 3, 4 AND 5
        // observations. At exactly five the markers are seeded but
        // unadjusted, so the naive `q[2]` readout would report the
        // *median* of the first five (here 3.0) as the p99.
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        for p in [0.5, 0.9, 0.99] {
            let mut est = P2Quantile::new(p);
            for (i, &x) in xs.iter().enumerate() {
                est.observe(x);
                let mut sorted = xs[..=i].to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let exact = percentile(&sorted, p);
                assert_eq!(
                    est.value(),
                    Some(exact),
                    "p={p}: estimate after {} samples must be exact",
                    i + 1
                );
            }
        }
        // In particular the 5-sample p99 is the max, not the median.
        let mut est = P2Quantile::new(0.99);
        for &x in &xs {
            est.observe(x);
        }
        assert_eq!(est.value(), Some(5.0), "seeded-but-unadjusted markers must not leak q[2]");
    }

    #[test]
    fn p2_single_sample_estimates_that_sample() {
        let mut est = P2Quantile::new(0.99);
        est.observe(0.042);
        assert_eq!(est.value(), Some(0.042));
    }

    #[test]
    fn deadline_attainment_counts_shed_requests_as_misses() {
        assert_eq!(deadline_attainment(&[], 0.1, 0), 1.0, "vacuous population");
        assert_eq!(deadline_attainment(&[0.05, 0.2], 0.1, 0), 0.5, "no sheds: plain attainment");
        assert_eq!(deadline_attainment(&[0.05, 0.05], 0.1, 2), 0.5, "sheds dilute the numerator");
        assert_eq!(deadline_attainment(&[], 0.1, 3), 0.0, "all shed, nothing attained");
    }

    #[test]
    fn p2_tracks_the_exact_percentile_on_seeded_traces() {
        // The engine's use case: p99 of latency-like samples. Compare the
        // incremental estimate against the exact nearest-rank percentile
        // over seeded pseudo-random traces of three shapes.
        for (seed, shape) in [(11u64, "uniform"), (12, "exponential"), (13, "bimodal")] {
            let mut rng = crate::util::Rng::seed_from_u64(seed);
            let xs: Vec<f64> = (0..2000)
                .map(|_| {
                    let u = rng.gen_f64();
                    match shape {
                        "uniform" => u,
                        "exponential" => -(1.0 - u).ln(),
                        _ => {
                            if u < 0.9 {
                                u * 0.1 // fast mode
                            } else {
                                1.0 + (u - 0.9) * 5.0 // slow tail
                            }
                        }
                    }
                })
                .collect();
            let mut est = P2Quantile::new(0.99);
            for &x in &xs {
                est.observe(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let exact = percentile(&sorted, 0.99);
            let p2 = est.value().unwrap();
            assert!(
                (p2 - exact).abs() <= 0.15 * exact.abs().max(0.05),
                "{shape}: P² {p2} vs exact {exact}"
            );
            assert!(p2 >= sorted[0] && p2 <= *sorted.last().unwrap(), "estimate within range");
        }
    }

    #[test]
    fn p2_median_converges_on_a_ramp() {
        // Deterministic sanity at a different quantile: the median of
        // 1..=999 is 500, and P² should land very close.
        let mut est = P2Quantile::new(0.5);
        for i in 1..=999 {
            est.observe(i as f64);
        }
        let m = est.value().unwrap();
        assert!((m - 500.0).abs() < 5.0, "median estimate {m}");
    }

    #[test]
    #[should_panic(expected = "non-finite observation")]
    fn p2_rejects_non_finite_samples() {
        P2Quantile::new(0.99).observe(f64::NAN);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.5), 51.0); // round(99·0.5)=50 → xs[50]
        let s = LatencySummary::from_unsorted(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }
}
