//! Reporting helpers: aligned console tables, ratio statistics, and the
//! geometric/arithmetic means the paper's Table IV aggregates with.

/// Arithmetic mean (the paper averages improvement ratios arithmetically).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (reported alongside for robustness).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Simple fixed-width console table writer for the bench harnesses.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for i in 0..ncol {
                s.push_str(&format!("{:<w$}  ", cells[i], w = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format a ratio the way the paper's tables do (`1.53x`).
pub fn fmt_ratio(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means() {
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "thp"]);
        t.row(vec!["GCN-OA".into(), "1.53x".into()]);
        let out = t.render();
        assert!(out.contains("GCN-OA"));
        assert!(out.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }

    #[test]
    fn ratio_format_matches_paper_style() {
        assert_eq!(fmt_ratio(1.534), "1.53x");
    }
}
