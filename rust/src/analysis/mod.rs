//! Static feasibility & consistency analysis — `dype lint`.
//!
//! DYPE's premise is that schedule quality is decidable from input-data
//! characteristics *before* execution; this module brings that analysis
//! to t = 0. [`lint_manifest`] proves or refutes feasibility of a
//! [`ScenarioManifest`] without simulating a single event: it replays
//! the engine's own t = 0 lease math ([`crate::engine::lease::assign`]
//! over SLO-weighted demands) and derives each stream's **zero-load
//! batch floor** — the DP plan for each phase's workload, re-timed under
//! the oracle estimator exactly as the engine's dispatch path would, so
//! the admission feasibility inequality `elapsed + batch > deadline` can
//! be evaluated symbolically at zero load. On top of that it walks the
//! scripted perturbation timeline against the device pool and the trace
//! horizon, and prices the energy budget against the cheapest per-window
//! demand of each priority class. [`lint_engine_config`] and
//! [`lint_fleet`] add config-dependent checks (frozen leases, shard
//! shapes, prewarm coverage).
//!
//! Every finding is a typed [`Diagnostic`] with a stable `DYxxx` code
//! and the manifest key path it anchors to — the same dotted
//! `streams[2].slo.deadline` paths the strict JSON codec reports — so a
//! lint finding and a parse error point at a manifest the same way.
//!
//! Severity contract: an **error** means the simulator is known to
//! refuse, panic, or unconditionally shed (every error code has a
//! differential test in `rust/tests/lint.rs` where the simulator
//! confirms the predicted failure mode); a **warning** means the
//! scenario runs but a stated intent cannot be met. `dype
//! scenario-sweep` and `dype fleet` refuse error-severity manifests
//! before building an engine; warnings annotate the run. The full code
//! table lives in DESIGN.md §Static Analysis.

use std::collections::BTreeMap;
use std::fmt;

use crate::config::Objective;
use crate::devices::GroundTruth;
use crate::engine::{lease, EngineConfig, MigrationMode, PerturbationKind, SloController};
use crate::fleet::FleetConfig;
use crate::perfmodel::OracleModels;
use crate::scenario::{Arrival, ScenarioManifest, WorkloadCfg};
use crate::scheduler::{evaluate_plan, DpScheduler, PowerTable};
use crate::util::json::Json;

/// Diagnostic severity. `Error` means the simulator is known to refuse,
/// panic, or unconditionally shed; `Warning` means the run proceeds but
/// a stated intent cannot be met. `Ord` puts `Error` above `Warning` so
/// reports sort errors first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// One static-analysis finding: a stable code (`DY001`..), a severity,
/// the manifest key path it anchors to (same dotted spelling as the
/// strict codec's parse errors), a human-readable claim, and the
/// numeric evidence backing it.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub code: &'static str,
    pub severity: Severity,
    pub key_path: String,
    pub message: String,
    pub evidence: String,
}

impl Diagnostic {
    fn error(
        code: &'static str,
        key_path: impl Into<String>,
        message: impl Into<String>,
        evidence: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            code,
            severity: Severity::Error,
            key_path: key_path.into(),
            message: message.into(),
            evidence: evidence.into(),
        }
    }

    fn warning(
        code: &'static str,
        key_path: impl Into<String>,
        message: impl Into<String>,
        evidence: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            severity: Severity::Warning,
            ..Diagnostic::error(code, key_path, message, evidence)
        }
    }

    /// `severity[code] key_path: message (evidence)` — one line per
    /// finding, grep-stable.
    pub fn render(&self) -> String {
        let Diagnostic { code, severity, key_path, message, evidence } = self;
        format!("{severity}[{code}] {key_path}: {message} ({evidence})")
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("code".to_string(), Json::Str(self.code.to_string()));
        m.insert("severity".to_string(), Json::Str(self.severity.to_string()));
        m.insert("key_path".to_string(), Json::Str(self.key_path.clone()));
        m.insert("message".to_string(), Json::Str(self.message.clone()));
        m.insert("evidence".to_string(), Json::Str(self.evidence.clone()));
        Json::Obj(m)
    }
}

/// All findings for one manifest, errors first. [`LintReport::is_clean`]
/// is the gate `dype scenario-sweep` / `dype fleet` consult before
/// building an engine.
#[derive(Debug, Clone)]
pub struct LintReport {
    /// The linted manifest's `name`.
    pub manifest: String,
    /// Findings, sorted errors-first, then by key path, then by code.
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// No error-severity findings (warnings are allowed).
    pub fn is_clean(&self) -> bool {
        self.errors() == 0
    }

    /// True if any finding carries `code`.
    pub fn has_code(&self, code: &str) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        if self.diagnostics.is_empty() {
            out.push_str(&format!("{}: clean\n", self.manifest));
        } else {
            out.push_str(&format!(
                "{}: {} error(s), {} warning(s)\n",
                self.manifest,
                self.errors(),
                self.warnings()
            ));
            for d in &self.diagnostics {
                out.push_str("  ");
                out.push_str(&d.render());
                out.push('\n');
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("manifest".to_string(), Json::Str(self.manifest.clone()));
        m.insert("errors".to_string(), Json::Num(self.errors() as f64));
        m.insert("warnings".to_string(), Json::Num(self.warnings() as f64));
        let ds = self.diagnostics.iter().map(Diagnostic::to_json).collect();
        m.insert("diagnostics".to_string(), Json::Arr(ds));
        Json::Obj(m)
    }
}

fn sort_diagnostics(ds: &mut [Diagnostic]) {
    ds.sort_by(|a, b| {
        b.severity
            .cmp(&a.severity)
            .then_with(|| a.key_path.cmp(&b.key_path))
            .then_with(|| a.code.cmp(b.code))
    });
}

/// Statically analyze one manifest. Structural (value-level) findings
/// that would make the scenario panic at build time block the model
/// checks — a manifest with a negative arrival rate gets its `DY011`
/// and nothing deeper, because the deeper checks would have to build
/// exactly the thing that panics.
pub fn lint_manifest(m: &ScenarioManifest) -> LintReport {
    let mut out = Vec::new();
    let blocked = structural_checks(m, &mut out);
    if !blocked {
        pool_timeline_checks(m, &mut out);
        model_checks(m, &mut out);
    }
    sort_diagnostics(&mut out);
    LintReport { manifest: m.name.clone(), diagnostics: out }
}

/// Config-dependent consistency checks: findings that depend on *which*
/// engine policy a manifest runs under, not on the manifest alone.
pub fn lint_engine_config(m: &ScenarioManifest, cfg: &EngineConfig) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if cfg.repartition.is_none() {
        for (i, s) in m.streams.iter().enumerate() {
            if let Some(MigrationMode::Preempt { .. }) = s.slo.migration {
                out.push(Diagnostic::warning(
                    "DY006",
                    format!("streams[{i}].slo.migration"),
                    "preempt override under frozen leases can never fire",
                    "the engine config has no repartition policy, so no migration ever happens",
                ));
            }
        }
    }
    sort_diagnostics(&mut out);
    out
}

/// Fleet shape checks for running `m` under `cfg`, including the
/// engine-config checks for the per-shard template. Run this *before*
/// constructing a [`crate::fleet::ServingFleet`] — a shard count the
/// pool cannot cover panics in `split_pool`.
pub fn lint_fleet(m: &ScenarioManifest, cfg: &FleetConfig) -> Vec<Diagnostic> {
    let mut out = lint_engine_config(m, &cfg.engine);
    let devices = m.system.n_fpga + m.system.n_gpu;
    if cfg.shards == 0 {
        out.push(Diagnostic::error(
            "DY009",
            "fleet.shards",
            "a fleet needs at least one shard",
            "shards = 0",
        ));
    } else if cfg.shards > devices {
        out.push(Diagnostic::error(
            "DY009",
            "fleet.shards",
            "more shards than devices: the pool split cannot give every shard a device",
            format!("{} shards over {devices} devices", cfg.shards),
        ));
    } else if cfg.shards > m.streams.len() {
        out.push(Diagnostic::warning(
            "DY009",
            "fleet.shards",
            "more shards than streams: some shards idle for the whole run",
            format!("{} shards, {} streams", cfg.shards, m.streams.len()),
        ));
    }
    if cfg.registry_prewarm {
        for (i, s) in m.streams.iter().enumerate() {
            if matches!(s.objective, Objective::Balanced { .. }) {
                out.push(Diagnostic::warning(
                    "DY010",
                    format!("streams[{i}].objective"),
                    "registry prewarm skips balanced-objective lanes",
                    "balanced schedules bypass the cache, so this lane stays cold",
                ));
            }
        }
    }
    sort_diagnostics(&mut out);
    out
}

// ---------------------------------------------------------------------
// Structural pass: value-level mirrors of every build-time panic
// (DY011) and perturbation-script validation (DY007). Returns true when
// a finding blocks the model pass.

fn structural_checks(m: &ScenarioManifest, out: &mut Vec<Diagnostic>) -> bool {
    let mut blocked = false;
    let mut block = |out: &mut Vec<Diagnostic>, d: Diagnostic| {
        out.push(d);
        blocked = true;
    };

    if m.system.n_fpga + m.system.n_gpu == 0 {
        let d = Diagnostic::error("DY011", "system", "the device pool is empty", "n_fpga+n_gpu = 0");
        block(out, d);
    }
    if m.streams.is_empty() {
        let d = Diagnostic::error("DY011", "streams", "the scenario has no streams", "streams = []");
        block(out, d);
    }
    for (i, s) in m.streams.iter().enumerate() {
        let base = format!("streams[{i}]");
        if s.phases.is_empty() {
            block(
                out,
                Diagnostic::error(
                    "DY011",
                    format!("{base}.phases"),
                    "the stream has no phases",
                    "phases = []",
                ),
            );
        } else if s.phases.iter().map(|p| p.count).sum::<usize>() == 0 {
            block(
                out,
                Diagnostic::error(
                    "DY011",
                    format!("{base}.phases"),
                    "every phase count is zero, so the trace is empty",
                    "sum of phase counts = 0",
                ),
            );
        }
        for (field, value) in arrival_value_errors(&s.arrival) {
            block(
                out,
                Diagnostic::error(
                    "DY011",
                    format!("{base}.arrival.{field}"),
                    "arrival parameter out of range",
                    value,
                ),
            );
        }
        for (field, value) in slo_value_errors(s) {
            block(
                out,
                Diagnostic::error(
                    "DY011",
                    format!("{base}.slo.{field}"),
                    "SLO value out of range",
                    value,
                ),
            );
        }
    }
    if let Some(b) = &m.budget {
        if !(b.cap_watts > 0.0 && b.cap_watts.is_finite()) {
            block(
                out,
                Diagnostic::error(
                    "DY011",
                    "budget.cap_watts",
                    "power cap must be positive and finite",
                    format!("cap_watts = {}", b.cap_watts),
                ),
            );
        }
        if !(b.window > 0.0 && b.window.is_finite()) {
            block(
                out,
                Diagnostic::error(
                    "DY011",
                    "budget.window",
                    "budget window must be positive and finite",
                    format!("window = {}", b.window),
                ),
            );
        }
    }

    let mut scale_instants: Vec<f64> = Vec::new();
    for (i, p) in m.perturbations.iter().enumerate() {
        let path = format!("perturbations[{i}]");
        if !(p.at > 0.0 && p.at.is_finite()) {
            block(
                out,
                Diagnostic::error(
                    "DY007",
                    path.clone(),
                    "firing time must be positive and finite",
                    format!("at = {}", p.at),
                ),
            );
            continue;
        }
        match p.kind {
            PerturbationKind::DeviceCut { n_fpga, n_gpu } => {
                if n_fpga + n_gpu == 0 {
                    block(
                        out,
                        Diagnostic::error(
                            "DY007",
                            path,
                            "a device cut must remove at least one device",
                            "n_fpga = 0, n_gpu = 0",
                        ),
                    );
                }
            }
            PerturbationKind::BudgetScale { factor } => {
                if !(factor >= 0.0 && factor.is_finite()) {
                    block(
                        out,
                        Diagnostic::error(
                            "DY007",
                            path,
                            "budget scale factor must be non-negative and finite",
                            format!("factor = {factor}"),
                        ),
                    );
                } else {
                    if m.budget.is_none() {
                        // Non-blocking: the engine runs this as a no-op,
                        // but the script's intent cannot possibly happen.
                        out.push(Diagnostic::error(
                            "DY007",
                            path.clone(),
                            "budget-scale without a budget is a guaranteed no-op",
                            "the manifest defines no energy budget to scale",
                        ));
                    }
                    if scale_instants.iter().any(|t| *t == p.at) {
                        out.push(Diagnostic::warning(
                            "DY007",
                            path,
                            "duplicate budget-scale at the same instant",
                            format!("another budget-scale also fires at t = {}", p.at),
                        ));
                    }
                    scale_instants.push(p.at);
                }
            }
            PerturbationKind::SloTighten { stream, p99_scale, deadline_scale } => {
                if stream >= m.streams.len() {
                    block(
                        out,
                        Diagnostic::error(
                            "DY007",
                            path,
                            "slo-tighten targets a stream that does not exist",
                            format!("stream = {stream}, but the scenario has {}", m.streams.len()),
                        ),
                    );
                } else if !(p99_scale > 0.0 && p99_scale.is_finite())
                    || !(deadline_scale > 0.0 && deadline_scale.is_finite())
                {
                    block(
                        out,
                        Diagnostic::error(
                            "DY007",
                            path,
                            "slo-tighten scales must be positive and finite",
                            format!("p99_scale = {p99_scale}, deadline_scale = {deadline_scale}"),
                        ),
                    );
                } else {
                    let slo = &m.streams[stream].slo;
                    if slo.p99_target.is_none() && slo.deadline.is_none() {
                        out.push(Diagnostic::warning(
                            "DY007",
                            path,
                            "slo-tighten targets a stream with neither p99 target nor deadline",
                            format!("stream {stream} has nothing to tighten"),
                        ));
                    }
                }
            }
        }
    }
    blocked
}

/// Value-level mirror of `Arrival::validate` (which panics): each entry
/// is `(field, evidence)`.
fn arrival_value_errors(a: &Arrival) -> Vec<(&'static str, String)> {
    fn positive(out: &mut Vec<(&'static str, String)>, field: &'static str, x: f64) {
        if !(x > 0.0 && x.is_finite()) {
            out.push((field, format!("{field} = {x}, must be positive and finite")));
        }
    }
    let mut out = Vec::new();
    match a {
        Arrival::Poisson { rate } => positive(&mut out, "rate", *rate),
        Arrival::Diurnal { base_rate, peak_rate, period } => {
            positive(&mut out, "base_rate", *base_rate);
            positive(&mut out, "peak_rate", *peak_rate);
            positive(&mut out, "period", *period);
        }
        Arrival::FlashCrowd { base_rate, peak_rate, start, duration } => {
            positive(&mut out, "base_rate", *base_rate);
            positive(&mut out, "peak_rate", *peak_rate);
            positive(&mut out, "duration", *duration);
            if !(*start >= 0.0 && start.is_finite()) {
                out.push(("start", format!("start = {start}, must be >= 0 and finite")));
            }
        }
        Arrival::Mmpp { rates, dwell } => {
            if rates.is_empty() {
                out.push(("rates", "rates = [], needs at least one state".to_string()));
            }
            for r in rates {
                if !(*r > 0.0 && r.is_finite()) {
                    out.push(("rates", format!("rate {r} must be positive and finite")));
                    break;
                }
            }
            positive(&mut out, "dwell", *dwell);
        }
    }
    out
}

/// Value-level mirror of `StreamSlo::validate` (which panics).
fn slo_value_errors(s: &crate::scenario::StreamCfg) -> Vec<(&'static str, String)> {
    let mut out = Vec::new();
    let slo = &s.slo;
    if !(slo.priority > 0.0 && slo.priority.is_finite()) {
        out.push(("priority", format!("priority = {}, must be positive and finite", slo.priority)));
    }
    if let Some(t) = slo.p99_target {
        if !(t > 0.0 && t.is_finite()) {
            out.push(("p99_target", format!("p99_target = {t}, must be positive and finite")));
        }
    }
    if let Some(d) = slo.deadline {
        if !(d > 0.0 && d.is_finite()) {
            out.push(("deadline", format!("deadline = {d}, must be positive and finite")));
        }
    }
    if let Some(MigrationMode::Preempt { min_remaining }) = slo.migration {
        if !(min_remaining >= 0.0 && min_remaining.is_finite()) {
            out.push((
                "migration",
                format!("min_remaining = {min_remaining}, must be >= 0 and finite"),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------
// Perturbation-timeline pass: walk the scripted device cuts in firing
// order against the pool inventory (DY001 pool exhaustion, DY002
// over-subscription at t = 0 and after each cut).

fn pool_timeline_checks(m: &ScenarioManifest, out: &mut Vec<Diagnostic>) {
    let k = m.streams.len();
    let (mut f, mut g) = (m.system.n_fpga, m.system.n_gpu);
    if k > f + g {
        out.push(Diagnostic::warning(
            "DY002",
            "streams",
            "streams outnumber devices from the start: every lease is time-sliced",
            format!("{k} streams over {} devices", f + g),
        ));
    }
    let mut cuts: Vec<(usize, f64, usize, usize)> = m
        .perturbations
        .iter()
        .enumerate()
        .filter_map(|(i, p)| match p.kind {
            PerturbationKind::DeviceCut { n_fpga, n_gpu } => Some((i, p.at, n_fpga, n_gpu)),
            _ => None,
        })
        .collect();
    cuts.sort_by(|a, b| a.1.total_cmp(&b.1));
    for (i, at, cf, cg) in cuts {
        let before = f + g;
        f = f.saturating_sub(cf);
        g = g.saturating_sub(cg);
        if f + g == 0 {
            out.push(Diagnostic::error(
                "DY001",
                format!("perturbations[{i}]"),
                "this cut empties the device pool",
                format!(
                    "at t = {at} the pool is {before} devices; the engine would clamp to a \
                     phantom single GPU the scenario never declared"
                ),
            ));
            // Continue the timeline the way the engine would.
            g = 1;
        } else if k > f + g && k <= before {
            out.push(Diagnostic::warning(
                "DY002",
                format!("perturbations[{i}]"),
                "after this cut streams outnumber the surviving devices",
                format!("{k} streams over {} devices from t = {at}", f + g),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// Model pass: build the streams, replay the engine's t = 0 lease
// assignment, and derive zero-load batch floors per (stream, phase) by
// running the DP and re-timing its plan — exactly what the engine's
// first admission does. Feeds DY003 (deadline infeasibility), DY004
// (budget starvation), DY005 (p99 below floor), DY006 (preemption
// threshold above any batch), DY008 (events past the trace horizon).

struct StreamModel {
    /// Zero-load batch floor of the cheapest phase (s).
    min_floor: f64,
    /// Zero-load batch floor of the most expensive phase (s).
    max_floor: f64,
    /// Phase index of `max_floor`.
    worst_phase: usize,
    /// Cheapest modeled energy per inference over the phases (J).
    min_energy: f64,
    /// Offered request rate over the trace span (req/s).
    offered_rate: f64,
    /// Last arrival instant (s).
    last_arrival: f64,
}

fn model_checks(m: &ScenarioManifest, out: &mut Vec<Diagnostic>) {
    let mut specs = Vec::new();
    for (i, s) in m.streams.iter().enumerate() {
        match s.build() {
            Ok(spec) => specs.push(spec),
            Err(e) => {
                out.push(Diagnostic::error(
                    "DY011",
                    format!("streams[{i}]"),
                    "the stream does not build",
                    format!("{e:#}"),
                ));
                return;
            }
        }
    }
    let sys = m.system.build();
    let controller = SloController::default();
    let weighted: Vec<f64> = m
        .streams
        .iter()
        .zip(&specs)
        .map(|(cfg, spec)| spec.demand() * controller.weight(&cfg.slo, None))
        .collect();
    let assignment = lease::assign(&sys, &weighted);
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let est = OracleModels { gt: &gt };
    let comm = sys.comm_model();
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());

    // One DP run per distinct (partition shape, workload, objective):
    // partitions share the testbed device configs, so shape is identity.
    let mut memo: Vec<((usize, usize, WorkloadCfg, Objective), (f64, f64, f64))> = Vec::new();

    let mut models: Vec<StreamModel> = Vec::new();
    for (i, s) in m.streams.iter().enumerate() {
        let (part, share) = assignment.lease_of(i);
        let mut model = StreamModel {
            min_floor: f64::INFINITY,
            max_floor: 0.0,
            worst_phase: 0,
            min_energy: f64::INFINITY,
            offered_rate: specs[i].offered_rate(),
            last_arrival: specs[i].trace.last().map_or(0.0, |r| r.arrival),
        };
        if share > 0.0 {
            for (pi, phase) in s.phases.iter().enumerate() {
                if phase.count == 0 {
                    continue;
                }
                let key = (part.n_fpga, part.n_gpu, phase.workload.clone(), s.objective);
                let (period, latency, energy) = match memo.iter().find(|(k, _)| *k == key) {
                    Some((_, v)) => *v,
                    None => {
                        let wl = phase.workload.build();
                        let sched = DpScheduler::new(part, &est).schedule(&wl, s.objective);
                        let timed = evaluate_plan(&wl, &sched.plan(), &est, &comm, &power);
                        let v = (timed.period, timed.latency(), timed.energy_per_inf);
                        memo.push((key, v));
                        v
                    }
                };
                // The engine's measured-regime batch estimate at zero
                // pending load: (period / share) + latency - period.
                let floor = (period / share).max(1e-12) + latency - period;
                if floor < model.min_floor {
                    model.min_floor = floor;
                }
                if floor > model.max_floor {
                    model.max_floor = floor;
                    model.worst_phase = pi;
                }
                if energy < model.min_energy {
                    model.min_energy = energy;
                }
            }
        }
        models.push(model);
    }

    for (i, s) in m.streams.iter().enumerate() {
        let model = &models[i];
        if !model.min_floor.is_finite() {
            continue;
        }
        if let Some(d) = s.slo.deadline {
            if model.min_floor > d {
                out.push(Diagnostic::error(
                    "DY003",
                    format!("streams[{i}].slo.deadline"),
                    "deadline below the zero-load batch floor of every phase: every request sheds",
                    format!("cheapest phase floor {:.6}s > deadline {d}s", model.min_floor),
                ));
            } else if model.max_floor > d {
                out.push(Diagnostic::warning(
                    "DY003",
                    format!("streams[{i}].slo.deadline"),
                    format!(
                        "deadline below the zero-load batch floor of phase {}: its requests shed \
                         even on an idle pool",
                        model.worst_phase
                    ),
                    format!("phase floor {:.6}s > deadline {d}s", model.max_floor),
                ));
            }
        }
        if let Some(t) = s.slo.p99_target {
            if t < model.min_floor {
                out.push(Diagnostic::warning(
                    "DY005",
                    format!("streams[{i}].slo.p99_target"),
                    "p99 target below the zero-load batch floor of every phase: unattainable",
                    format!("cheapest phase floor {:.6}s > target {t}s", model.min_floor),
                ));
            } else if t < model.max_floor {
                out.push(Diagnostic::warning(
                    "DY005",
                    format!("streams[{i}].slo.p99_target"),
                    format!(
                        "p99 target below the zero-load batch floor of phase {}: unattainable \
                         while it serves",
                        model.worst_phase
                    ),
                    format!("phase floor {:.6}s > target {t}s", model.max_floor),
                ));
            }
        }
        if let Some(MigrationMode::Preempt { min_remaining }) = s.slo.migration {
            if model.max_floor > 0.0 && min_remaining >= model.max_floor {
                out.push(Diagnostic::warning(
                    "DY006",
                    format!("streams[{i}].slo.migration"),
                    "preemption threshold exceeds the longest zero-load batch: it can never fire",
                    format!(
                        "min_remaining {min_remaining}s >= worst phase floor {:.6}s",
                        model.max_floor
                    ),
                ));
            }
        }
    }

    if let Some(b) = &m.budget {
        let jpw = b.cap_watts * b.window;
        let demand: Vec<f64> = models
            .iter()
            .map(|md| {
                if md.min_energy.is_finite() {
                    md.min_energy * md.offered_rate * b.window
                } else {
                    0.0
                }
            })
            .collect();
        let total: f64 = demand.iter().sum();
        if total > jpw {
            out.push(Diagnostic::warning(
                "DY004",
                "budget",
                "the cheapest per-window energy demand already exceeds the window budget",
                format!("{total:.3} J demanded per {}s window vs {jpw:.3} J budgeted", b.window),
            ));
        }
        for (i, s) in m.streams.iter().enumerate() {
            let Some(d) = s.slo.deadline else { continue };
            let higher: f64 = m
                .streams
                .iter()
                .zip(&demand)
                .filter(|(o, _)| o.slo.priority > s.slo.priority)
                .map(|(_, dem)| *dem)
                .sum();
            if higher >= jpw && d < b.window {
                out.push(Diagnostic::error(
                    "DY004",
                    format!("streams[{i}].slo.deadline"),
                    "budget starvation: strictly-higher-priority classes drain every window \
                     before this deadline lane runs, and its deadline is shorter than the \
                     window, so deferral is a shed",
                    format!(
                        "higher-priority demand {higher:.3} J >= budget {jpw:.3} J per window; \
                         deadline {d}s < window {}s",
                        b.window
                    ),
                ));
            }
        }
    }

    let horizon = models.iter().map(|md| md.last_arrival).fold(0.0, f64::max);
    for (i, p) in m.perturbations.iter().enumerate() {
        if p.at > horizon {
            out.push(Diagnostic::warning(
                "DY008",
                format!("perturbations[{i}]"),
                "fires after the last arrival: nothing is left to perturb",
                format!("at = {}s, trace horizon = {horizon:.3}s", p.at),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Interconnect;
    use crate::engine::{Perturbation, StreamSlo};
    use crate::scenario::{catalog, BudgetCfg, Phase, StreamCfg, SystemCfg};

    fn codes(ds: &[Diagnostic]) -> Vec<&'static str> {
        ds.iter().map(|d| d.code).collect()
    }

    fn heavy_gcn() -> WorkloadCfg {
        WorkloadCfg::Gcn {
            code: "TF".to_string(),
            graph: "traffic".to_string(),
            vertices: 1_000_000,
            edges: 150_000_000,
            feature_len: 200,
            degree_skew: 0.2,
            layers: 2,
            hidden: 128,
        }
    }

    fn light_gcn() -> WorkloadCfg {
        WorkloadCfg::Gcn {
            code: "TF".to_string(),
            graph: "traffic".to_string(),
            vertices: 1_000_000,
            edges: 2_000_000,
            feature_len: 200,
            degree_skew: 0.2,
            layers: 2,
            hidden: 128,
        }
    }

    fn one_lane(workload: WorkloadCfg, slo: StreamSlo) -> ScenarioManifest {
        ScenarioManifest {
            name: "lint-probe".to_string(),
            description: "synthetic lint probe".to_string(),
            system: SystemCfg { n_fpga: 3, n_gpu: 2, interconnect: Interconnect::Pcie4 },
            streams: vec![StreamCfg {
                name: "lane".to_string(),
                objective: Objective::Performance,
                seed: 7,
                arrival: Arrival::Poisson { rate: 20.0 },
                phases: vec![Phase { workload, count: 8 }],
                slo,
            }],
            budget: None,
            perturbations: Vec::new(),
            telemetry: false,
        }
    }

    #[test]
    fn the_zoo_is_error_clean() {
        for m in catalog::all() {
            let report = lint_manifest(&m);
            assert!(report.is_clean(), "{}", report.render());
        }
    }

    #[test]
    fn emptying_device_cut_is_dy001() {
        let mut m = catalog::device_failure();
        m.perturbations = vec![Perturbation::device_cut(0.6, 99, 99)];
        let report = lint_manifest(&m);
        assert!(report.has_code("DY001"), "{}", report.render());
        assert!(!report.is_clean());
    }

    #[test]
    fn oversubscription_warns_dy002_without_blocking() {
        let report = lint_manifest(&catalog::oversubscribed());
        assert!(report.has_code("DY002"), "{}", report.render());
        assert!(report.is_clean(), "over-subscription is a warning: {}", report.render());
    }

    #[test]
    fn impossible_deadline_is_a_dy003_error() {
        let m = one_lane(heavy_gcn(), StreamSlo::best_effort(3.0).with_deadline(0.005));
        let report = lint_manifest(&m);
        let d = report.diagnostics.iter().find(|d| d.code == "DY003").expect("DY003 fires");
        assert_eq!(d.severity, Severity::Error, "{}", report.render());
        assert_eq!(d.key_path, "streams[0].slo.deadline");
    }

    #[test]
    fn mixed_phase_deadline_still_raises_dy003() {
        // Light phase feasible, heavy phase not: DY003 fires either as
        // the min-floor error or the per-phase warning; both name the
        // deadline. Severity is pinned by the heavy-only fixture above.
        let mut m = one_lane(light_gcn(), StreamSlo::best_effort(3.0).with_deadline(0.250));
        m.streams[0].phases.push(Phase { workload: heavy_gcn(), count: 8 });
        let report = lint_manifest(&m);
        assert!(report.has_code("DY003"), "{}", report.render());
    }

    #[test]
    fn budget_starvation_is_a_dy004_error() {
        let mut m = one_lane(heavy_gcn(), StreamSlo::best_effort(4.0));
        m.streams.push(StreamCfg {
            name: "starved".to_string(),
            objective: Objective::Performance,
            seed: 9,
            arrival: Arrival::Poisson { rate: 20.0 },
            phases: vec![Phase { workload: light_gcn(), count: 8 }],
            slo: StreamSlo::best_effort(1.0).with_deadline(0.2),
        });
        m.budget = Some(BudgetCfg { cap_watts: 0.2, window: 0.5 });
        let report = lint_manifest(&m);
        let d = report.diagnostics.iter().find(|d| d.code == "DY004").expect("DY004 fires");
        assert_eq!(d.severity, Severity::Error, "{}", report.render());
        assert_eq!(d.key_path, "streams[1].slo.deadline");
    }

    #[test]
    fn unattainable_p99_target_warns_dy005() {
        let mut m = catalog::diurnal();
        m.streams[0].slo = StreamSlo::target(1e-6, 2.0);
        let report = lint_manifest(&m);
        assert!(report.has_code("DY005"), "{}", report.render());
        assert!(report.is_clean(), "p99 misses are soft: {}", report.render());
    }

    #[test]
    fn never_firing_preemption_warns_dy006() {
        let slo = StreamSlo::best_effort(2.0)
            .with_migration(MigrationMode::Preempt { min_remaining: 1e6 });
        let report = lint_manifest(&one_lane(light_gcn(), slo));
        assert!(report.has_code("DY006"), "{}", report.render());
        assert!(report.is_clean());
    }

    #[test]
    fn preempt_override_under_frozen_leases_warns_dy006() {
        let slo = StreamSlo::best_effort(2.0)
            .with_migration(MigrationMode::Preempt { min_remaining: 0.005 });
        let m = one_lane(light_gcn(), slo);
        let cfg = EngineConfig::builder().static_leases().build();
        let ds = lint_engine_config(&m, &cfg);
        assert_eq!(codes(&ds), vec!["DY006"], "{ds:?}");
        let adaptive = lint_engine_config(&m, &EngineConfig::default());
        assert!(adaptive.is_empty(), "adaptive engines migrate, the override can fire");
    }

    #[test]
    fn malformed_perturbations_are_dy007_errors_and_never_panic() {
        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.perturbations = vec![Perturbation::device_cut(-1.0, 1, 0)];
        assert!(!lint_manifest(&m).is_clean(), "negative firing time");

        m.perturbations = vec![Perturbation::device_cut(0.5, 0, 0)];
        assert!(lint_manifest(&m).has_code("DY007"), "cut that removes nothing");

        m.perturbations = vec![Perturbation::slo_tighten(0.5, 99, 0.5, 0.5)];
        let report = lint_manifest(&m);
        assert!(report.has_code("DY007"), "out-of-range stream: {}", report.render());
        assert!(!report.is_clean());
    }

    #[test]
    fn budget_scale_without_budget_is_a_dy007_error() {
        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.perturbations = vec![Perturbation::budget_scale(0.5, 0.5)];
        let report = lint_manifest(&m);
        let d = report.diagnostics.iter().find(|d| d.code == "DY007").expect("DY007 fires");
        assert_eq!(d.severity, Severity::Error, "{}", report.render());
    }

    #[test]
    fn duplicate_budget_scales_warn_dy007() {
        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.budget = Some(BudgetCfg { cap_watts: 250.0, window: 0.25 });
        m.perturbations =
            vec![Perturbation::budget_scale(0.5, 0.5), Perturbation::budget_scale(0.5, 0.25)];
        let report = lint_manifest(&m);
        assert!(report.has_code("DY007"), "{}", report.render());
        assert!(report.is_clean(), "duplicates are suspicious, not fatal");
    }

    #[test]
    fn pointless_slo_tighten_warns_dy007() {
        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.perturbations = vec![Perturbation::slo_tighten(0.5, 0, 0.5, 0.5)];
        let report = lint_manifest(&m);
        assert!(report.has_code("DY007"), "{}", report.render());
        assert!(report.is_clean(), "nothing breaks, nothing tightens: {}", report.render());
    }

    #[test]
    fn event_past_the_horizon_warns_dy008() {
        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.perturbations = vec![Perturbation::device_cut(1e9, 1, 0)];
        let report = lint_manifest(&m);
        assert!(report.has_code("DY008"), "{}", report.render());
        assert!(report.is_clean());
    }

    #[test]
    fn fleet_shape_errors_are_dy009() {
        fn dy009_at(ds: &[Diagnostic], severity: Severity) -> bool {
            ds.iter().any(|d| d.code == "DY009" && d.severity == severity)
        }
        let m = catalog::fleet_balanced();
        let devices = m.system.n_fpga + m.system.n_gpu;
        let zero = FleetConfig { shards: 0, ..FleetConfig::default() };
        assert!(dy009_at(&lint_fleet(&m, &zero), Severity::Error));
        let over = FleetConfig { shards: devices + 1, ..FleetConfig::default() };
        assert!(dy009_at(&lint_fleet(&m, &over), Severity::Error));
        let idle = FleetConfig { shards: m.streams.len() + 1, ..FleetConfig::default() };
        let ds = lint_fleet(&m, &idle);
        assert!(dy009_at(&ds, Severity::Warning), "{ds:?} (needs streams < shards <= devices)");
        let ok = FleetConfig { shards: 4, ..FleetConfig::default() };
        assert!(lint_fleet(&m, &ok).is_empty(), "the shipped fleet scenario lints clean");
    }

    #[test]
    fn prewarm_over_balanced_lanes_warns_dy010() {
        let mut m = catalog::fleet_balanced();
        m.streams[0].objective = Objective::balanced();
        let cfg = FleetConfig { shards: 4, registry_prewarm: true, ..FleetConfig::default() };
        let ds = lint_fleet(&m, &cfg);
        assert!(ds.iter().any(|d| d.code == "DY010"), "{ds:?}");
        let cold = FleetConfig { shards: 4, ..FleetConfig::default() };
        assert!(lint_fleet(&m, &cold).is_empty(), "no prewarm, no claim");
    }

    #[test]
    fn degenerate_values_are_dy011_and_never_panic() {
        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.streams[0].arrival = Arrival::Poisson { rate: -3.0 };
        let report = lint_manifest(&m);
        assert!(report.has_code("DY011"), "{}", report.render());
        assert!(!report.is_clean());

        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.streams.clear();
        assert!(lint_manifest(&m).has_code("DY011"), "empty streams");

        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.system.n_fpga = 0;
        m.system.n_gpu = 0;
        assert!(lint_manifest(&m).has_code("DY011"), "empty pool");

        let mut m = one_lane(light_gcn(), StreamSlo::default());
        m.streams[0].slo.priority = f64::NAN;
        assert!(lint_manifest(&m).has_code("DY011"), "NaN priority");
    }

    #[test]
    fn reports_sort_errors_first_and_render_one_line_per_finding() {
        let mut m = one_lane(heavy_gcn(), StreamSlo::best_effort(3.0).with_deadline(0.005));
        m.perturbations = vec![Perturbation::device_cut(1e9, 1, 0)];
        let report = lint_manifest(&m);
        assert!(report.errors() >= 1 && report.warnings() >= 1, "{}", report.render());
        assert_eq!(report.diagnostics[0].severity, Severity::Error, "errors lead");
        let rendered = report.render();
        assert!(rendered.contains("error[DY003] streams[0].slo.deadline:"), "{rendered}");
        let Json::Obj(top) = report.to_json() else { panic!("report serializes to an object") };
        assert!(top.contains_key("diagnostics") && top.contains_key("errors"));
    }
}
