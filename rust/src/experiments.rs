//! Shared evaluation harness for the per-table / per-figure benches.
//!
//! Encapsulates the paper's §VI methodology:
//! * case enumeration (workload × interconnect × system size grids),
//! * ground-truth measurement of any schedule (re-time the plan under the
//!   ground-truth oracle, then stream it through the pipeline simulator),
//! * the baseline battery (static, FleetRec*, GPU-only, FPGA-only,
//!   theoretical-additive) and DYPE's three objective modes.

use crate::config::{Interconnect, Objective, SystemSpec};
use crate::coordinator::{MultiStreamReport, MultiStreamServer, StreamSpec};
use crate::devices::GroundTruth;
use crate::engine::{EnergyBudget, EngineConfig, RepartitionPolicy};
use crate::perfmodel::{calibrate, ModelRegistry, OracleModels, PerfEstimator};
use crate::pipeline::PipelineSim;
use crate::scheduler::{baselines, evaluate_plan, DpScheduler, PowerTable, StagePlan};
use crate::workload::{gnn, transformer, Dataset, Workload};

/// Ground-truth measurement of a schedule *plan*: re-time under the
/// oracle, stream `n` inferences, return (throughput, energy/inf).
pub fn measure_plan(
    sys: &SystemSpec,
    gt: &GroundTruth,
    wl: &Workload,
    plan: &[StagePlan],
    n: usize,
) -> (f64, f64) {
    let oracle = OracleModels { gt };
    let power = PowerTable::new(sys.gpu.clone(), sys.fpga.clone());
    let comm = sys.comm_model();
    let timed = evaluate_plan(wl, plan, &oracle, &comm, &power);
    let report = PipelineSim::new(&power, &comm).run(wl, &timed, n);
    (report.throughput, report.energy_per_inf)
}

/// One evaluation case: a workload on a system, with its ground truth.
pub struct Case {
    pub sys: SystemSpec,
    pub wl: Workload,
    pub gt: GroundTruth,
    /// Label like `GCN-OA @ PCIe4.0`.
    pub label: String,
}

impl Case {
    pub fn new(sys: SystemSpec, wl: Workload, degree_skew: f64) -> Case {
        let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model())
            .with_degree_skew(degree_skew);
        let label = format!("{} @ {}", wl.name, sys.interconnect);
        Case { sys, wl, gt, label }
    }

    pub fn measure(&self, plan: &[StagePlan], n: usize) -> (f64, f64) {
        measure_plan(&self.sys, &self.gt, &self.wl, plan, n)
    }
}

/// The paper's GNN case grid: 2 models × 6 datasets × 3 interconnects.
pub fn gnn_cases() -> Vec<Case> {
    let mut out = Vec::new();
    for ic in Interconnect::ALL {
        let sys = SystemSpec::paper_testbed(ic);
        for ds in Dataset::table1() {
            for wl in gnn::paper_gnn_workloads(&ds) {
                out.push(Case::new(sys.clone(), wl, ds.degree_skew));
            }
        }
    }
    out
}

/// The Table III audit grid (42 cases): the 36 GNN cases plus 6
/// reduced-system (2F+1G) cases on PCIe 4.0 (system-size sensitivity).
pub fn table3_cases() -> Vec<Case> {
    let mut out = gnn_cases();
    let sys = SystemSpec::reduced_testbed(Interconnect::Pcie4);
    for ds in [Dataset::synthetic1(), Dataset::synthetic3(), Dataset::ogbn_arxiv()] {
        for wl in gnn::paper_gnn_workloads(&ds) {
            out.push(Case::new(sys.clone(), wl, ds.degree_skew));
        }
    }
    out
}

/// The paper's transformer case grid: the §IV-B (seq, window) sweep × 3
/// interconnects.
pub fn transformer_cases() -> Vec<Case> {
    let mut out = Vec::new();
    for ic in Interconnect::ALL {
        let sys = SystemSpec::paper_testbed(ic);
        for (seq, win) in transformer::paper_sweep() {
            let wl = transformer::paper_transformer(seq, win);
            out.push(Case::new(sys.clone(), wl, 0.0));
        }
    }
    out
}

/// Cache of calibrated registries (one per interconnect — calibration
/// depends on the comm model only through multi-device terms, but we stay
/// faithful and calibrate per system).
pub struct Registries {
    regs: Vec<(Interconnect, ModelRegistry)>,
}

impl Registries {
    pub fn train() -> Registries {
        let regs = Interconnect::ALL
            .iter()
            .map(|&ic| (ic, calibrate::calibrated_registry(&SystemSpec::paper_testbed(ic))))
            .collect();
        Registries { regs }
    }

    pub fn get(&self, ic: Interconnect) -> &ModelRegistry {
        &self.regs.iter().find(|(i, _)| *i == ic).unwrap().1
    }
}

/// All measured numbers for one case: DYPE's three modes + every baseline,
/// as (throughput, energy-per-inference) pairs.
pub struct CaseResults {
    pub dype_perf: (f64, f64),
    pub dype_balanced: (f64, f64),
    pub dype_energy: (f64, f64),
    pub statik: (f64, f64),
    /// None when the type pinning is infeasible (deep transformers).
    pub fleetrec: Option<(f64, f64)>,
    pub gpu_only: (f64, f64),
    pub fpga_only: (f64, f64),
    /// (summed throughput, averaged efficiency→energy/inf) — §VI-A.
    pub theoretical_additive: (f64, f64),
    pub dype_mnemonics: [String; 3],
}

/// Streamed inferences per measurement.
pub const MEASURE_N: usize = 100;

/// Run the full §VI battery for one case. `reference_wl` is the workload
/// the static plan was tuned on (same model family).
pub fn run_case<E: PerfEstimator>(case: &Case, est: &E, reference_wl: &Workload) -> CaseResults {
    let sys = &case.sys;
    let wl = &case.wl;
    let sched = DpScheduler::new(sys, est);

    let dp = |obj: Objective| sched.schedule(wl, obj);
    let (p, b, e) = (dp(Objective::Performance), dp(Objective::balanced()), dp(Objective::Energy));

    let static_plan = baselines::tune_static_plan(sys, est, reference_wl, Objective::Performance);
    let statik = case.measure(&static_plan, MEASURE_N);

    let fleet = baselines::fleetrec(sys, est, wl, Objective::Performance)
        .map(|s| case.measure(&s.plan(), MEASURE_N));

    let gpu = baselines::gpu_only(sys, est, wl, Objective::Performance);
    let fpga = baselines::fpga_only(sys, est, wl, Objective::Performance);
    // Homogeneous baselines are measured on their reduced systems (the
    // devices of the other type are removed, §VI-A).
    let gpu_sys = SystemSpec { n_fpga: 0, ..sys.clone() };
    let fpga_sys = SystemSpec { n_gpu: 0, ..sys.clone() };
    let gpu_meas = measure_plan(&gpu_sys, &case.gt, wl, &gpu.plan(), MEASURE_N);
    let fpga_meas = measure_plan(&fpga_sys, &case.gt, wl, &fpga.plan(), MEASURE_N);

    // theoretical-additive: sum throughputs, average efficiencies.
    let add_thp = gpu_meas.0 + fpga_meas.0;
    let add_eff = 0.5 * (1.0 / gpu_meas.1 + 1.0 / fpga_meas.1);
    let theoretical_additive = (add_thp, 1.0 / add_eff);

    CaseResults {
        dype_perf: case.measure(&p.plan(), MEASURE_N),
        dype_balanced: case.measure(&b.plan(), MEASURE_N),
        dype_energy: case.measure(&e.plan(), MEASURE_N),
        statik,
        fleetrec: fleet,
        gpu_only: gpu_meas,
        fpga_only: fpga_meas,
        theoretical_additive,
        dype_mnemonics: [p.mnemonic(), b.mnemonic(), e.mnemonic()],
    }
}

/// The canonical multi-stream serving scenario (DESIGN.md §Serving),
/// shared by `examples/multi_stream_serving.rs`,
/// `benches/scheduler_cache.rs`, and the multi-stream integration tests:
///
/// * **gcn-traffic** — a traffic-forecast GCN over a 1M-intersection road
///   network whose interaction-graph edge count follows a day cycle
///   (night → rush hour → evening), repeated `cycles` times so drift
///   *recurs*;
/// * **swin-transformer** — an 8-layer sliding-window transformer service
///   cycling through its sequence-length regimes.
///
/// Each phase contributes `per_phase` requests. Recurrence is what the
/// schedule cache monetizes: the number of distinct quantized regimes is
/// fixed (5 GCN buckets + 3 transformer buckets), so the DP-miss count
/// stays constant while hits grow with `cycles × per_phase`.
pub fn multi_stream_scenario(cycles: usize, per_phase: usize, seed: u64) -> Vec<StreamSpec> {
    build_catalog(crate::scenario::catalog::multi_stream(cycles, per_phase, seed))
}

/// Lower a catalog manifest to its streams. The scenario zoo is the
/// single source of truth for the canonical serving scenarios; these
/// wrappers keep the historical `experiments::*_scenario` entry points
/// (and their exact traces — the manifest round-trip is bit-identical,
/// asserted by the scenario-sweep integration tests).
fn build_catalog(m: crate::scenario::ScenarioManifest) -> Vec<StreamSpec> {
    m.build().expect("catalog manifests are valid").streams
}

/// Serve `streams` on `sys` with the ground-truth oracle as `f_perf`
/// (the example/bench/test entry point for multi-stream serving).
/// Engine defaults apply — since the adaptive-by-default flip that means
/// online re-partitioning with migration-aware cache prewarming; use
/// [`run_multi_stream_static`] for the frozen-lease escape hatch.
pub fn run_multi_stream(sys: &SystemSpec, streams: &[StreamSpec]) -> MultiStreamReport {
    run_multi_stream_with(sys, streams, EngineConfig::default())
}

/// [`run_multi_stream`] with the static-lease escape hatch: the initial
/// demand-proportional leases are frozen for the whole run (the
/// historical PR-1/PR-2 default, kept for A/B runs and for reproducing
/// the static acceptance numbers).
pub fn run_multi_stream_static(sys: &SystemSpec, streams: &[StreamSpec]) -> MultiStreamReport {
    run_multi_stream_with(sys, streams, EngineConfig::builder().static_leases().build())
}

/// [`run_multi_stream`] with an explicit engine configuration — build
/// one with [`EngineConfig::builder`].
pub fn run_multi_stream_with(
    sys: &SystemSpec,
    streams: &[StreamSpec],
    cfg: EngineConfig,
) -> MultiStreamReport {
    let gt = GroundTruth::new(sys.gpu.clone(), sys.fpga.clone(), sys.comm_model());
    let oracle = OracleModels { gt: &gt };
    let mut server = MultiStreamServer::new(sys.clone(), &oracle).with_engine_config(cfg);
    server.serve(streams)
}

/// A demand-skew stress scenario for online re-partitioning: two streams
/// with (near-)equal *total* offered demand but phase-reversed load —
/// `front-loaded` is heavy in its first half and light in its second,
/// `back-loaded` the mirror image. Any static lease sized on the offered
/// totals is therefore wrong in *both* halves; an adaptive engine should
/// migrate devices toward the currently-heavy stream. Used by
/// `benches/engine_repartition.rs` and the engine acceptance tests.
pub fn skewed_pair_scenario(per_phase: usize, seed: u64) -> Vec<StreamSpec> {
    build_catalog(crate::scenario::catalog::skewed_pair(per_phase, seed))
}

/// The canonical **energy/SLO** serving scenario (DESIGN.md §Energy &
/// SLOs): three streams with distinct QoS classes on one pool, built to
/// exercise both halves of multi-objective serving —
///
/// * **latency-critical** — light traffic-forecast batches with a tight
///   p99 target and the highest priority; never deferred, and the SLO
///   controller bids lease weight on its behalf when the target slips;
/// * **bulk-analytics** — heavy batches, mid priority, no latency
///   target: the stream an exhausted joule window defers first among
///   the demand bulk;
/// * **background-embeddings** — medium batches at the lowest priority,
///   deferred before anything else.
///
/// Pair with [`energy_slo_config`] (or any [`EngineConfig`] carrying an
/// [`EnergyBudget`]) to see budget exhaustion defer strictly
/// below-priority work; serve it unbudgeted for the baseline point of
/// the throughput-vs-joules frontier.
pub fn energy_slo_scenario(per_phase: usize, seed: u64) -> Vec<StreamSpec> {
    build_catalog(crate::scenario::catalog::energy_slo(per_phase, seed))
}

/// The engine configuration [`energy_slo_scenario`] is meant to run
/// under: a joule budget of `cap_watts` sustained power in 0.25 s
/// windows, plus a reactive re-partitioning policy so the SLO
/// controller's weights actually reach the lease table. Derive
/// `cap_watts` from the pool's worst case
/// ([`crate::scheduler::PowerTable::pool_power_cap`]) or from a measured
/// baseline run's average draw (`total_energy / makespan`).
pub fn energy_slo_config(cap_watts: f64) -> EngineConfig {
    EngineConfig::builder()
        .repartition(RepartitionPolicy::reactive(2.0))
        .energy_budget(EnergyBudget::from_power_cap(cap_watts, 0.25))
        .build()
}

/// The canonical **deadline** serving scenario (DESIGN.md §Energy &
/// SLOs): mixed deadline and best-effort classes on one pool, built to
/// exercise both halves of deadline-aware admission —
///
/// * **deadline-interactive** — light batches offered well above the
///   stream's service capacity, with a hard 250 ms deadline (and a
///   150 ms p99 target for the feedback controller): once the backlog
///   pushes a request's queueing time past feasibility it is **shed** at
///   admission instead of served stale, so the lane's latency stays
///   bounded while its deadline attainment reports the drop rate. Its
///   [`crate::engine::StreamSlo::migration`] override is `Preempt` — the critical lane
///   takes its new lease immediately at a migration;
/// * **front-loaded / back-loaded** — the phase-reversed best-effort
///   pair from [`skewed_pair_scenario`]: near-equal offered totals,
///   wildly uneven halves, so the demand tracker migrates leases
///   mid-run. No per-stream override — they follow the policy mode;
/// * **bulk-drain** — steady heavy batches at the lowest priority with
///   an explicit `Drain` override: even under a preemptive policy
///   ([`deadline_config`]) this lane always finishes its in-flight slot,
///   demonstrating criticality-tied preemption in the same repartition
///   that preempts its peers.
pub fn deadline_scenario(per_phase: usize, seed: u64) -> Vec<StreamSpec> {
    build_catalog(crate::scenario::catalog::deadline(per_phase, seed))
}

/// The engine configuration [`deadline_scenario`] is meant to run under:
/// the preemptive re-partitioning policy (policy-level mode `Preempt`,
/// so unmarked lanes preempt and the `bulk-drain` override visibly
/// dissents), no energy budget — deadline sheds are a *latency*
/// mechanism and must show up without budget interference. Pair with an
/// [`EnergyBudget`] to see infeasible requests shed instead of
/// budget-deferred.
pub fn deadline_config() -> EngineConfig {
    EngineConfig::builder().preemptive(1.0).build()
}

/// Reference workload for static-plan tuning: same model family on the
/// paper's reference configuration (ogbn-arxiv for GNNs; the mid-grid
/// point for transformers).
pub fn reference_workload(wl: &Workload) -> Workload {
    if wl.name.starts_with("GCN") {
        gnn::gcn_workload(&Dataset::ogbn_arxiv(), 2, 128)
    } else if wl.name.starts_with("GIN") {
        gnn::gin_workload(&Dataset::ogbn_arxiv(), 2, 128, 2)
    } else {
        transformer::paper_transformer(4096, 1024)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::MigrationMode;

    #[test]
    fn case_grids_have_paper_counts() {
        assert_eq!(gnn_cases().len(), 36); // 2 × 6 × 3
        assert_eq!(table3_cases().len(), 42); // + 6 reduced-system
        assert_eq!(transformer_cases().len(), 51); // 17 × 3
    }

    #[test]
    fn multi_stream_scenario_recurring_drift_hits_cache() {
        let streams = multi_stream_scenario(2, 4, 9);
        assert_eq!(streams.len(), 2);
        assert_eq!(streams[0].trace.len(), 2 * 6 * 4);
        assert_eq!(streams[1].trace.len(), 2 * 4 * 4);
        let r = run_multi_stream(&SystemSpec::paper_testbed(Interconnect::Pcie4), &streams);
        assert_eq!(r.total_completed, 48 + 32);
        // 5 + 3 distinct quantized regimes → ≤ 8 DP runs out of 80
        // lookups, *plus* the fallout of plans an adaptive-default
        // migration could not prewarm onto a new partition (usually
        // zero; at most two DP re-runs each across migration chains).
        assert!(
            r.cache.misses <= 8 + 2 * r.engine.prewarm_misses,
            "misses {} vs {} prewarm misses",
            r.cache.misses,
            r.engine.prewarm_misses
        );
        assert!(r.cache.hit_rate() > 0.5, "hit rate {}", r.cache.hit_rate());
    }

    #[test]
    fn skewed_pair_offers_balanced_totals_with_reversed_phases() {
        let streams = skewed_pair_scenario(5, 11);
        assert_eq!(streams.len(), 2);
        let (d0, d1) = (streams[0].demand(), streams[1].demand());
        // Totals are near-equal (deterministic traces; spans differ only
        // by arrival jitter), so the *initial* lease split is even — the
        // skew only shows up online, which is the point of the scenario.
        assert!(d0 / d1 < 2.0 && d1 / d0 < 2.0, "offered totals {d0} vs {d1}");
        // Per-half demand is wildly uneven: heavy phase ≈ 75× light.
        let half = |s: &StreamSpec, first: bool| -> f64 {
            let n = s.trace.len() / 2;
            let slice = if first { &s.trace[..n] } else { &s.trace[n..] };
            slice.iter().map(|r| r.workload.total_flops()).sum()
        };
        assert!(half(&streams[0], true) > 10.0 * half(&streams[0], false));
        assert!(half(&streams[1], false) > 10.0 * half(&streams[1], true));
    }

    #[test]
    fn energy_slo_scenario_orders_qos_classes() {
        let streams = energy_slo_scenario(4, 17);
        assert_eq!(streams.len(), 3);
        assert!(
            streams[0].slo.priority > streams[1].slo.priority
                && streams[1].slo.priority > streams[2].slo.priority,
            "priorities must be strictly ordered for deferral to discriminate"
        );
        assert!(streams[0].slo.p99_target.is_some(), "the critical stream carries a target");
        assert!(streams[1].slo.p99_target.is_none() && streams[2].slo.p99_target.is_none());
        let cfg = energy_slo_config(250.0);
        let budget = cfg.energy_budget.expect("budgeted config");
        assert!((budget.joules_per_window - 250.0 * 0.25).abs() < 1e-9);
        assert!(cfg.repartition.is_some(), "SLO weights need lease re-validation to act");
    }

    #[test]
    fn deadline_scenario_mixes_classes_and_overrides() {
        let streams = deadline_scenario(8, 23);
        assert_eq!(streams.len(), 4);
        let interactive = &streams[0].slo;
        assert_eq!(interactive.deadline, Some(0.250), "the critical lane carries the deadline");
        assert_eq!(interactive.migration, Some(MigrationMode::Preempt { min_remaining: 0.005 }));
        assert!(interactive.p99_target.is_some(), "deadline and p99 target coexist");
        assert!(
            streams[1].slo.migration.is_none() && streams[2].slo.migration.is_none(),
            "the skewed pair follows the policy mode"
        );
        assert_eq!(streams[3].slo.migration, Some(MigrationMode::Drain), "bulk pins drain");
        assert!(streams[3].slo.deadline.is_none(), "best-effort lanes shed nothing");
        assert!(interactive.priority > streams[1].slo.priority);
        // Offered rate far above any single-device service capacity, so
        // the backlog (and with it the shed path) is guaranteed.
        assert!(streams[0].offered_rate() > 25.0, "rate {}", streams[0].offered_rate());
        let cfg = deadline_config();
        let pol = cfg.repartition.expect("deadline serving re-partitions");
        assert!(matches!(pol.migration, MigrationMode::Preempt { .. }), "policy mode preempts");
        assert!(cfg.energy_budget.is_none(), "sheds are a latency mechanism, not a budget one");
    }

    #[test]
    fn run_case_produces_consistent_battery() {
        let cases = gnn_cases();
        let case = &cases[0];
        let regs = Registries::train();
        let est = regs.get(case.sys.interconnect);
        let r = run_case(case, est, &reference_workload(&case.wl));
        // DYPE perf mode ≥ every fixed baseline measured on ground truth
        // is NOT guaranteed (estimator error), but it must be in the same
        // ballpark and all numbers positive.
        for (thp, eng) in [
            r.dype_perf,
            r.dype_balanced,
            r.dype_energy,
            r.statik,
            r.gpu_only,
            r.fpga_only,
        ] {
            assert!(thp > 0.0 && eng > 0.0);
        }
        assert!(r.dype_perf.0 >= r.dype_energy.0 * 0.5, "modes wildly inverted");
        assert!(r.theoretical_additive.0 > r.gpu_only.0);
    }
}
