//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parses `artifacts/manifest.json` (input/output shapes +
//! dtypes per lowered HLO module) with the in-tree JSON parser.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing 'shape'"))?
            .iter()
            .map(|v| v.as_u64().map(|u| u as usize).ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            shape,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor spec missing 'dtype'"))?
                .to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactSpec>,
    constants: Json,
}

impl Manifest {
    pub fn from_json_str(text: &str) -> Result<Manifest> {
        let j = parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut artifacts = HashMap::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for (name, spec) in arts {
            let inputs = spec
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: spec
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing file"))?
                        .to_string(),
                    sha256: spec
                        .get("sha256")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    inputs,
                    output: TensorSpec::from_json(
                        spec.get("output").ok_or_else(|| anyhow!("{name}: missing output"))?,
                    )?,
                },
            );
        }
        let constants = j.get("constants").cloned().unwrap_or(Json::Null);
        Ok(Manifest { artifacts, constants })
    }

    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<(Manifest, PathBuf)> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        Ok((Self::from_json_str(&text)?, dir.to_path_buf()))
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Demo-graph constants written by aot.py (V, F, NRT, ELL, TM, TK).
    pub fn graph_constant(&self, key: &str) -> Result<u64> {
        self.constants
            .get("graph")
            .and_then(|g| g.get(key))
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("constants.graph.{key} missing from manifest"))
    }
}

/// Default artifact directory: `$DYPE_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("DYPE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> &'static str {
        r#"{
            "artifacts": {
                "gemm": {
                    "file": "gemm.hlo.txt",
                    "sha256": "",
                    "inputs": [
                        {"name": "a", "shape": [1024, 128], "dtype": "float32"},
                        {"name": "b", "shape": [128, 128], "dtype": "float32"}
                    ],
                    "output": {"shape": [1024, 128], "dtype": "float32"}
                }
            },
            "constants": {"graph": {"V": 1024}}
        }"#
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json_str(manifest_json()).unwrap();
        let a = m.get("gemm").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].element_count(), 1024 * 128);
        assert_eq!(a.output.dims_i64(), vec![1024, 128]);
        assert_eq!(m.graph_constant("V").unwrap(), 1024);
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::from_json_str(manifest_json()).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn malformed_manifest_errors() {
        assert!(Manifest::from_json_str("{}").is_err());
        assert!(Manifest::from_json_str("{\"artifacts\": {\"x\": {}}}").is_err());
    }
}
