//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute many.
//!
//! Follows /opt/xla-example/load_hlo: HLO *text* is the interchange format
//! (jax ≥0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids). Artifacts are lowered with
//! `return_tuple=True`, so outputs unwrap with `to_tuple1`.
//!
//! `PjRtClient` wraps raw pointers (`!Send`): each pipeline-stage thread
//! owns its own `Runtime`. Compilation is cached per runtime instance —
//! the hot path is pure `execute`.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};

use super::artifacts::Manifest;

/// Host-side tensor (what flows between pipeline stages / enters PJRT).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: &[i64]) -> Self {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        HostTensor::F32(data, dims.to_vec())
    }

    pub fn i32(data: Vec<i32>, dims: &[i64]) -> Self {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        HostTensor::I32(data, dims.to_vec())
    }

    pub fn dims(&self) -> &[i64] {
        match self {
            HostTensor::F32(_, d) | HostTensor::I32(_, d) => d,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            HostTensor::I32(..) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            HostTensor::F32(v, d) => xla::Literal::vec1(v).reshape(d)?,
            HostTensor::I32(v, d) => xla::Literal::vec1(v).reshape(d)?,
        })
    }
}

/// A PJRT CPU runtime holding compiled executables for a set of artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and read the manifest in `dir`.
    pub fn new(dir: &std::path::Path) -> Result<Self> {
        let (manifest, dir) = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, dir, exes: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) the named artifact.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact on host tensors; returns the (single)
    /// output tensor. Validates input arity and element counts against
    /// the manifest before dispatch.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<HostTensor> {
        self.load(name)?;
        let spec = self.manifest.get(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: {} inputs supplied, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            ));
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let n: i64 = t.dims().iter().product();
            if n as usize != s.element_count() {
                return Err(anyhow!(
                    "{name} input {i} ('{}'): {} elements supplied, manifest wants {}",
                    s.name,
                    n,
                    s.element_count()
                ));
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let exe = self.exes.get(name).expect("just loaded");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {name}: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let data = out.to_vec::<f32>().map_err(|e| anyhow!("read {name}: {e:?}"))?;
        Ok(HostTensor::f32(data, &spec.output.dims_i64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_accessors() {
        let t = HostTensor::f32(vec![0.0; 6], &[2, 3]);
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.as_f32().unwrap().len(), 6);
        let i = HostTensor::i32(vec![1, 2], &[2]);
        assert!(i.as_f32().is_err());
    }
}
